"""TPU tile/sublane constraint table for the Pallas kernel path — as data.

The MXU addresses VMEM in (sublane, lane) tiles whose minimum sublane
count depends on the element width: 8 rows for 4-byte types, 16 for
2-byte types, 32 for 1-byte types; the lane (minor) dim is always 128.
PR 2's bf16 ``M % 16 == 8`` padding bug was exactly a violation of this
table, fixed at runtime by ``_check_tiles``; exporting the table as plain
data lets the static analyzer (``repro.analysis.shapes`` / rule RPL009)
evaluate the same constraints at lint time, against the same numbers the
kernels enforce — one source of truth for both.

This module is deliberately **jax-free** so the analyzer can import it
without pulling in a backend.
"""
from __future__ import annotations

#: minor-dim tile quantum (every lane-aligned dim is a multiple of this)
LANE = 128

#: element byte width -> minimum second-to-minor (sublane) tile dim
SUBLANE_BY_ITEMSIZE = {4: 8, 2: 16, 1: 32}

#: dtype name -> element byte width (the dtypes the kernel path accepts)
DTYPE_ITEMSIZE = {
    "float32": 4,
    "int32": 4,
    "uint32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int8": 1,
    "uint8": 1,
    "float8_e4m3fn": 1,
    "float8_e5m2": 1,
}


def sublane(dtype_name: str) -> int:
    """Minimum sublane tile dim for a dtype *name* (jax-free lookup)."""
    try:
        itemsize = DTYPE_ITEMSIZE[dtype_name]
    except KeyError:
        raise ValueError(
            f"unknown kernel dtype {dtype_name!r}; known: "
            f"{sorted(DTYPE_ITEMSIZE)}"
        ) from None
    return SUBLANE_BY_ITEMSIZE[itemsize]
