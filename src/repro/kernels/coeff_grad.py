"""Pallas TPU kernel for the coefficient-gradient projection ``C = Aᵀ B``.

With ``A = x Ũ`` and ``B = (∂L/∂y) Ṽ`` this computes the FeDLRT client's
per-step coefficient gradient ``∇_S̃ L = Aᵀ B`` (the backward hot spot of
the local loop).  Also reused for the basis cotangents ``dU = xᵀ(dy V Sᵀ)``
where the output's leading dim is large — hence the (K, M) grid with the
reduction over M tiles innermost and an f32 VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 512
DEFAULT_BKA = 256


def _atb_kernel(a_ref, b_ref, c_ref, acc_ref, *, nm: int):
    """grid = (ki, mi): C[ki] = Σ_mi A[mi, ki]ᵀ @ B[mi]."""
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        (((0,), (0,)), ((), ())),  # contract over the M (rows) dim
        preferred_element_type=jnp.float32,
    )

    @pl.when(mi == nm - 1)
    def _write():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


def atb(
    A: jax.Array,
    B: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bka: int = DEFAULT_BKA,
    interpret: bool = False,
) -> jax.Array:
    """C = Aᵀ @ B.  A: (M, Ka), B: (M, Kb) → C: (Ka, Kb), f32 accumulate."""
    M, Ka = A.shape
    Kb = B.shape[1]
    bm, bka = min(bm, M), min(bka, Ka)
    assert M % bm == 0 and Ka % bka == 0, (M, Ka, bm, bka)
    # deferred import: lowrank_matmul owns the tile guard (and shares the
    # constraint table in repro.kernels.constraints with the RPL009 linter)
    from repro.kernels.lowrank_matmul import _check_tiles

    _check_tiles(interpret, A.dtype, bm=(bm, "sublane"), bka=(bka, "lane"),
                 Kb=(Kb, "lane"))
    nm = M // bm
    return pl.pallas_call(
        functools.partial(_atb_kernel, nm=nm),
        grid=(Ka // bka, nm),
        in_specs=[
            pl.BlockSpec((bm, bka), lambda ki, mi: (mi, ki)),
            pl.BlockSpec((bm, Kb), lambda ki, mi: (mi, 0)),
        ],
        out_specs=pl.BlockSpec((bka, Kb), lambda ki, mi: (ki, 0)),
        out_shape=jax.ShapeDtypeStruct((Ka, Kb), A.dtype),
        scratch_shapes=[pltpu.VMEM((bka, Kb), jnp.float32)],
        interpret=interpret,
    )(A, B)
