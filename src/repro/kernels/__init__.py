"""Pallas TPU kernels for FeDLRT's compute hot spots.

- lowrank_matmul.py: fused ``(x U) S`` and ``A Vᵀ`` (forward chain)
- coeff_grad.py: ``Aᵀ B`` accumulation (coefficient gradient projection)
- ops.py: jit wrappers + custom VJP; ref.py: pure-jnp oracles

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU in interpret mode against ref.py.
"""
from repro.kernels.ops import (  # noqa: F401
    KERNEL_POLICIES,
    coeff_grad_kernels,
    lowrank_apply,
    lowrank_apply_kernels,
    lowrank_apply_nd,
    use_kernels_for,
)
