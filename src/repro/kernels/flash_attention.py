"""Pallas TPU flash attention (online-softmax, VMEM-tiled).

Addresses the dominant *memory* roofline term of the train/prefill shapes
(EXPERIMENTS.md §Perf, pair 1): the jnp attention materializes
``(B, H, Tq, Tk)`` scores in HBM; this kernel streams KV blocks through
VMEM keeping only ``(bq, bk)`` score tiles and the running max/sum
(Rabe-Staats/FlashAttention recurrence), so HBM traffic drops from
O(T²) to O(T·d).

Grid: ``(B·H, Tq/bq, Tk/bk)`` — the KV dim is innermost so the f32
accumulator, running max ``m`` and sum ``l`` persist in VMEM scratch
across the KV sweep of each query tile.  Causal + sliding-window masking
is evaluated from absolute positions, so the same kernel serves ragged
decode layouts.  MXU alignment: ``bq``,``bk`` multiples of 128 lanes /
8 sublanes; head_dim padded by the ops wrapper if needed.

Supports MHA/GQA via a ``q_head → kv_head`` map folded into the grid.
Validated in interpret mode against :func:`repro.kernels.ref.mha_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(
    qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, nk: int, causal: bool, window: int, scale: float,
):
    """One (batch·head, q-tile, kv-tile) grid step."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]  # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    qp = qpos_ref[0]  # (bq,)
    kp = kpos_ref[0]  # (bk,)
    mask = (kp[None, :] >= 0) & (qp[:, None] >= 0)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m == -inf): exp(-inf - -inf) would be nan
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev) - m_safe)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    causal: bool = True,
    sliding_window: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Tq, H, d); k/v: (B, Tk, Hkv, d) → (B, Tq, H, d).

    GQA: H must be a multiple of Hkv; query head h reads kv head
    ``h // (H // Hkv)``.  Positions are absolute (negative = invalid slot).
    """
    B, Tq, H, d = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = 1.0 / (d ** 0.5)

    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, Tk, bq, bk)
    nq, nk = Tq // bq, Tk // bk

    # layout: (B·H, T, d) with positions broadcast per row-block
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, d)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(B * H, Tk, d)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(B * H, Tk, d)
    qp = jnp.broadcast_to(
        q_positions.astype(jnp.int32)[None], (B * H, Tq)
    )
    kp = jnp.broadcast_to(
        kv_positions.astype(jnp.int32)[None], (B * H, Tk)
    )

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, nk=nk, causal=causal, window=sliding_window,
            scale=scale,
        ),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, bk), lambda bh, qi, ki: (bh, ki)),
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, qr, kr, vr)
    return out.reshape(B, H, Tq, d).transpose(0, 2, 1, 3)
