"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def xus_ref(x: jax.Array, U: jax.Array, S: jax.Array) -> jax.Array:
    """A = (x @ U) @ S.  x: (M, K), U: (K, R), S: (R, R) → (M, R)."""
    return (x @ U) @ S.astype(x.dtype)


def avt_ref(A: jax.Array, V: jax.Array) -> jax.Array:
    """y = A @ Vᵀ.  A: (M, R), V: (N, R) → (M, N)."""
    return A @ V.T


def lowrank_matmul_ref(x, U, S, V):
    """y = ((x U) S) Vᵀ — the paper's client-side bottleneck chain."""
    return avt_ref(xus_ref(x, U, S), V)


def mha_ref(q, k, v, *, q_positions, kv_positions, causal=True, sliding_window=0):
    """Materialized-scores attention oracle (GQA via head repeat)."""
    B, Tq, H, d = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bthd->bhqt", q, kr) / jnp.sqrt(jnp.float32(d))
    m = (kv_positions[None, :] >= 0) & (q_positions[:, None] >= 0)
    if causal:
        m &= kv_positions[None, :] <= q_positions[:, None]
    if sliding_window:
        m &= kv_positions[None, :] > q_positions[:, None] - sliding_window
    s = jnp.where(m[None, None], s.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqt,bthd->bqhd", p, vr)


def atb_ref(A: jax.Array, B: jax.Array) -> jax.Array:
    """C = Aᵀ @ B (f32 accumulation).  A: (M, Ka), B: (M, Kb) → (Ka, Kb).

    With A = x@Ũ and B = dy@Ṽ this is the coefficient gradient
    ∇_S̃ L = Ũᵀ (xᵀ dy) Ṽ — the hot op of the client loop's backward."""
    return (A.astype(jnp.float32).T @ B.astype(jnp.float32)).astype(A.dtype)
