"""Pallas TPU kernels for the low-rank bottleneck chain ``y = x U S Vᵀ``.

Design (TPU-native, not a CUDA port):
- The chain never materializes the ``n_in × n_out`` weight; HBM traffic is
  ``O(M·(n_in + n_out) + (n_in + n_out)·r)`` instead of ``O(n_in·n_out)``.
- :func:`xus` fuses the first two matmuls: grid over (M, K) tiles, f32
  accumulation of ``x·U`` in VMEM scratch, multiply by the small ``S`` in
  the epilogue of the last K step — one HBM pass over ``x``.
- :func:`avt` is a plain (M, N)-tiled matmul against ``Vᵀ`` with the rank
  dim fully resident.
- The rank dim is padded to a multiple of 128 lanes by the ops wrapper;
  padded columns are zero, so results are exact.  MXU alignment: all tile
  dims are multiples of (8, 128) for f32 and (16, 128) for bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.constraints import LANE, SUBLANE_BY_ITEMSIZE

DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 256


def _min_sublane(dtype) -> int:
    """MXU minimum second-to-minor tile dim: 8 (f32) / 16 (bf16) / 32 (i8).

    The numbers live in :mod:`repro.kernels.constraints` (shared with the
    static analyzer's RPL009 shape interpreter); this wrapper only resolves
    the jax dtype object to its byte width.
    """
    return SUBLANE_BY_ITEMSIZE.get(jnp.dtype(dtype).itemsize, 8)


def _check_tiles(interpret: bool, dtype, **tiles):
    """On the compiled TPU path, reject tile dims the MXU cannot address:
    sublane dims must be multiples of the dtype minimum, lane dims of 128.
    Interpret mode (CPU validation) is exempt — it has no tiling hardware.
    """
    if interpret:
        return
    sub = _min_sublane(dtype)
    for name, (size, kind) in tiles.items():
        mult = LANE if kind == "lane" else sub
        if size % mult:
            raise ValueError(
                f"{name}={size} is not a multiple of {mult} "
                f"({kind} dim, dtype {jnp.dtype(dtype).name})"
            )


def _xus_kernel(x_ref, u_ref, s_ref, a_ref, acc_ref, *, nk: int):
    """grid = (mi, kk).  acc (bm, R) persists across the K loop."""
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], u_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kk == nk - 1)
    def _epilogue():
        a_ref[...] = jnp.dot(
            acc_ref[...], s_ref[...], preferred_element_type=jnp.float32
        ).astype(a_ref.dtype)


def xus(x: jax.Array, U: jax.Array, S: jax.Array, *, bm: int = DEFAULT_BM,
        bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """A = (x @ U) @ S.  x: (M, K), U: (K, R), S: (R, R) → A: (M, R)."""
    M, K = x.shape
    R = U.shape[1]
    bm, bk = min(bm, M), min(bk, K)
    assert M % bm == 0 and K % bk == 0, (M, K, bm, bk)
    _check_tiles(interpret, x.dtype, bm=(bm, "sublane"), bk=(bk, "lane"),
                 R=(R, "lane"))
    nk = K // bk
    grid = (M // bm, nk)
    return pl.pallas_call(
        functools.partial(_xus_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, kk: (mi, kk)),
            pl.BlockSpec((bk, R), lambda mi, kk: (kk, 0)),
            pl.BlockSpec((R, R), lambda mi, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, R), lambda mi, kk: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((M, R), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, R), jnp.float32)],
        interpret=interpret,
    )(x, U, S.astype(jnp.float32))


def _avt_kernel(a_ref, v_ref, y_ref):
    """grid = (mi, nj): y tile = A tile @ V tileᵀ."""
    y_ref[...] = jax.lax.dot_general(
        a_ref[...],
        v_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(y_ref.dtype)


def avt(A: jax.Array, V: jax.Array, *, bm: int = DEFAULT_BM,
        bn: int = DEFAULT_BN, interpret: bool = False) -> jax.Array:
    """y = A @ Vᵀ.  A: (M, R), V: (N, R) → y: (M, N)."""
    M, R = A.shape
    N = V.shape[0]
    bm, bn = min(bm, M), min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    _check_tiles(interpret, A.dtype, bm=(bm, "sublane"), bn=(bn, "lane"),
                 R=(R, "lane"))
    return pl.pallas_call(
        _avt_kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, R), lambda mi, nj: (mi, 0)),
            pl.BlockSpec((bn, R), lambda mi, nj: (nj, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, nj: (mi, nj)),
        out_shape=jax.ShapeDtypeStruct((M, N), A.dtype),
        interpret=interpret,
    )(A, V)
