"""jit-ready wrappers around the Pallas kernels with a custom VJP.

``lowrank_apply(x, U, S, V)`` computes the forward chain with the fused
kernels and wires the backward pass through the same primitives:

    A  = (x U) S                     [xus kernel]
    y  = A Vᵀ                        [avt kernel]
    dA = dy V                        [avt-with-swap ≡ matmul vs V]
    dx = (dA Sᵀ) Uᵀ                  [xus(dy·V, Sᵀ)→ then avt vs U]
    dU = xᵀ (dy V Sᵀ)                [atb kernel]
    dS = (x U)ᵀ (dy V)               [atb kernel — the Ũᵀ(·)Ṽ projection]
    dV = dyᵀ (x U S)                 [atb kernel]

On non-TPU backends (this container) the wrappers fall back to the jnp
reference implementation unless ``interpret=True`` is forced — Pallas TPU
kernels only *compile* for TPU; interpret mode executes the kernel body in
Python for correctness validation (used by tests/benchmarks here).

Padding rules (all exact — padded rows/columns are zero):
- the rank dim is padded to a multiple of 128 lanes;
- every other dim is padded up to a multiple of its tile size instead of
  shrinking the tile to a divisor — a prime M costs at most one extra tile
  of zeros, never a degenerate 1-wide grid;
- tile sublanes are dtype-aware: (8, 128) for f32 but (16, 128) for bf16,
  so a bf16 input with ``M % 16 == 8`` pads to the next multiple of 16
  rather than handing the MXU a misaligned tile.

``lowrank_apply_nd`` generalizes to leading activation batch dims
((B, T, d) is flattened to 2D) and stacked factors (leading layer/expert
axes on U/S/V are vmapped — the :class:`LowRankFactor` buffer layout used
by scanned layer stacks and MoE experts).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.coeff_grad import atb
from repro.kernels.constraints import LANE
from repro.kernels.lowrank_matmul import _min_sublane as _sublane
from repro.kernels.lowrank_matmul import avt, xus

#: model-level kernel dispatch policies (ModelConfig.kernels / --kernels)
KERNEL_POLICIES = ("auto", "interpret", "off")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_kernels_for(policy: str):
    """Resolve a kernel policy string to the ``lowrank_apply`` flag.

    - ``"auto"``: Pallas kernels on TPU *without an active GSPMD mesh*,
      jnp reference elsewhere → ``True`` / ``False``.  ``pl.pallas_call``
      has no SPMD partitioning rule, so under a mesh the compiled kernels
      would force all-gathers of the sharded activations/factors; the
      reference chain (which GSPMD partitions fine) is the fast path
      there until the kernels grow a shard_map wrapper.
    - ``"interpret"``: force the kernel path through the Pallas interpreter
      on **any** backend — including TPU, where it overrides the compiled
      path for interpreter-based validation → ``"interpret"``.
    - ``"off"``: plain jnp chain → ``False``.
    """
    if policy not in KERNEL_POLICIES:
        raise ValueError(
            f"kernels policy must be one of {KERNEL_POLICIES}, got {policy!r}"
        )
    if policy == "interpret":
        flag = "interpret"
    elif policy != "auto" or not on_tpu():
        flag = False
    else:
        from repro.utils import meshctx

        flag = meshctx.mesh() is None
    # lazy import: this module must stay importable (and statically
    # interpretable by the RPL009 shape checker) without the hub machinery
    from repro.telemetry import get_hub

    get_hub().counter("kernels.dispatch", policy=policy, resolved=str(flag))
    return flag


def _interpret_mode(use_kernels) -> bool:
    """``use_kernels`` is False / True / "interpret": plain ``True`` means
    compiled-on-TPU, interpreter elsewhere; ``"interpret"`` forces the
    interpreter even on TPU."""
    return use_kernels == "interpret" or not on_tpu()


# ---------------------------------------------------------------------------
# dtype-aware tile padding
# ---------------------------------------------------------------------------


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _block(pref: int, size: int, mult: int) -> int:
    """Tile size for a dim that will be zero-padded to a multiple of
    ``mult``: the preferred block when the (padded) dim exceeds it, else
    the whole padded dim.  Never degrades below ``mult`` — prime dims are
    padded, not shrunk to 1-wide grids."""
    assert pref % mult == 0, (pref, mult)
    padded = _round_up(size, mult)
    return pref if padded >= pref else padded


def _pad2(x, rows: int, cols: int):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    return jnp.pad(x, ((0, pr), (0, pc))) if pr or pc else x


def _pad_rank(U, S, V):
    R = U.shape[1]
    Rp = _round_up(R, LANE)
    if Rp == R:
        return U, S, V
    pu = ((0, 0), (0, Rp - R))
    return (
        jnp.pad(U, pu),
        jnp.pad(S, ((0, Rp - R), (0, Rp - R))),
        jnp.pad(V, pu),
    )


# ---------------------------------------------------------------------------
# shape-safe kernel wrappers (arbitrary M/K/N; rank dims already LANE-padded)
# ---------------------------------------------------------------------------


def _xus(x, U, S, *, interpret: bool):
    """A = (x U) S for arbitrary (M, K); tiles aligned per x's dtype."""
    M, K = x.shape
    bm = _block(256, M, _sublane(x.dtype))
    bk = _block(512, K, LANE)
    x2 = _pad2(x, _round_up(M, bm), _round_up(K, bk))
    U2 = _pad2(U, _round_up(K, bk), U.shape[1])
    return xus(x2, U2, S, bm=bm, bk=bk, interpret=interpret)[:M]


def _avt(A, V, *, interpret: bool):
    """y = A Vᵀ for arbitrary (M, N)."""
    M = A.shape[0]
    N = V.shape[0]
    bm = _block(256, M, _sublane(A.dtype))
    bn = _block(256, N, LANE)
    A2 = _pad2(A, _round_up(M, bm), A.shape[1])
    V2 = _pad2(V, _round_up(N, bn), V.shape[1])
    return avt(A2, V2, bm=bm, bn=bn, interpret=interpret)[:M, :N]


def _atb(A, B, *, interpret: bool):
    """C = Aᵀ B for arbitrary (M, Ka); zero rows are exact under the M
    reduction.  Kb (= the rank dim) must already be LANE-padded."""
    M, Ka = A.shape
    bm = _block(512, M, _sublane(A.dtype))
    bka = _block(256, Ka, LANE)
    A2 = _pad2(A, _round_up(M, bm), _round_up(Ka, bka))
    B2 = _pad2(B, _round_up(M, bm), B.shape[1])
    return atb(A2, B2, bm=bm, bka=bka, interpret=interpret)[:Ka]


def lowrank_apply_kernels(x, U, S, V, *, interpret: bool) -> jax.Array:
    """Forward chain through the Pallas kernels (padded + tiled)."""
    U, S, V = _pad_rank(U, S, V)
    A = _xus(x, U, S, interpret=interpret)
    return _avt(A, V, interpret=interpret)


def coeff_grad_kernels(x, dy, U, V, *, interpret: bool) -> jax.Array:
    """∇_S L = (x U)ᵀ (dy V) via the atb kernel (paper's client backward)."""
    R = U.shape[1]
    U2, _, V2 = _pad_rank(U, jnp.zeros((R, R), U.dtype), V)
    eye = jnp.eye(U2.shape[1], dtype=jnp.float32)
    A = _xus(x, U2, eye, interpret=interpret)
    B = _xus(dy, V2, eye, interpret=interpret)
    return _atb(A, B, interpret=interpret)[:R, :R]


# ---------------------------------------------------------------------------
# custom-VJP entry point
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def lowrank_apply(x, U, S, V, use_kernels=False):
    """y = ((x U) S) Vᵀ with a kernel-backed custom VJP.

    ``use_kernels``: ``True`` → the Pallas path (compiled on TPU, interpret
    elsewhere); ``"interpret"`` → the Pallas path through the interpreter
    on *every* backend (overrides the compiled path on TPU too); ``False``
    → pure-jnp reference (XLA fuses well on its own for small sizes).
    """
    if use_kernels:
        return lowrank_apply_kernels(
            x, U, S, V, interpret=_interpret_mode(use_kernels)
        )
    return ref.lowrank_matmul_ref(x, U, S, V)


def _fwd(x, U, S, V, use_kernels):
    y = lowrank_apply(x, U, S, V, use_kernels)
    return y, (x, U, S, V)


def _bwd(use_kernels, resids, dy):
    x, U, S, V = resids
    interpret = _interpret_mode(use_kernels)

    if use_kernels:
        U_, S_, V_ = _pad_rank(U, S, V)
        eye = jnp.eye(U_.shape[1], dtype=jnp.float32)
        dyV = _xus(dy, V_, eye, interpret=interpret)
        xU = _xus(x, U_, eye, interpret=interpret)
        dA = _xus(dy, V_, jnp.transpose(S_).astype(jnp.float32),
                  interpret=interpret)  # dy V Sᵀ
        dx = _avt(dA, U_, interpret=interpret)
        dU = _atb(x, dA, interpret=interpret)
        dS = _atb(xU, dyV, interpret=interpret)
        xUS = _xus(x, U_, S_.astype(jnp.float32), interpret=interpret)
        dV = _atb(dy, xUS, interpret=interpret)
        R = U.shape[1]
        return (
            dx.astype(x.dtype),
            dU[:, :R].astype(U.dtype),
            dS[:R, :R].astype(S.dtype),
            dV[:, :R].astype(V.dtype),
        )

    dyV = dy @ V
    xU = x @ U
    dx = (dyV @ S.T) @ U.T
    dU = x.T @ (dyV @ S.T)
    dS = xU.T @ dyV
    dV = dy.T @ (xU @ S)
    return (
        dx.astype(x.dtype),
        dU.astype(U.dtype),
        dS.astype(S.dtype),
        dV.astype(V.dtype),
    )


lowrank_apply.defvjp(_fwd, _bwd)


def lowrank_apply_nd(x, U, S, V, use_kernels=False) -> jax.Array:
    """:func:`lowrank_apply` for the shapes model code actually has.

    - ``x`` may carry leading batch dims (``(B, T, d)`` activations): they
      are flattened into the kernel's M dim and restored on the output.
    - ``U/S/V`` may carry leading stack dims (scanned layer stacks, MoE
      experts — the batched :class:`LowRankFactor` buffer layout): the
      apply is vmapped over the stack axis, matching ``x``'s leading axes.
    """
    if U.ndim > 2:
        return jax.vmap(lowrank_apply_nd, in_axes=(0, 0, 0, 0, None))(
            x, U, S, V, use_kernels
        )
    if x.ndim == 2:
        return lowrank_apply(x, U, S, V, use_kernels)
    lead = x.shape[:-1]
    y = lowrank_apply(x.reshape(-1, x.shape[-1]), U, S, V, use_kernels)
    return y.reshape(lead + (V.shape[0],))
