"""jit-ready wrappers around the Pallas kernels with a custom VJP.

``lowrank_apply(x, U, S, V)`` computes the forward chain with the fused
kernels and wires the backward pass through the same primitives:

    A  = (x U) S                     [xus kernel]
    y  = A Vᵀ                        [avt kernel]
    dA = dy V                        [avt-with-swap ≡ matmul vs V]
    dx = (dA Sᵀ) Uᵀ                  [xus(dy·V, Sᵀ)→ then avt vs U]
    dU = xᵀ (dy V Sᵀ)                [atb kernel]
    dS = (x U)ᵀ (dy V)               [atb kernel — the Ũᵀ(·)Ṽ projection]
    dV = dyᵀ (x U S)                 [atb kernel]

On non-TPU backends (this container) the wrappers fall back to the jnp
reference implementation unless ``interpret=True`` is forced — Pallas TPU
kernels only *compile* for TPU; interpret mode executes the kernel body in
Python for correctness validation (used by tests/benchmarks here).

Rank padding: callers may pass any r ≥ 1; inputs are zero-padded to a
multiple of 128 lanes (exact — padded columns are zero).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.coeff_grad import atb
from repro.kernels.lowrank_matmul import avt, xus

LANE = 128


def _pad_rank(U, S, V):
    R = U.shape[1]
    Rp = -(-R // LANE) * LANE
    if Rp == R:
        return U, S, V
    pu = ((0, 0), (0, Rp - R))
    return (
        jnp.pad(U, pu),
        jnp.pad(S, ((0, Rp - R), (0, Rp - R))),
        jnp.pad(V, pu),
    )


def _pad_rows(x, mult):
    M = x.shape[0]
    Mp = -(-M // mult) * mult
    return (jnp.pad(x, ((0, Mp - M), (0, 0))), M) if Mp != M else (x, M)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick(block, size):
    b = min(block, size)
    while size % b:
        b //= 2
    return max(b, 1)


def lowrank_apply_kernels(x, U, S, V, *, interpret: bool) -> jax.Array:
    """Forward chain through the Pallas kernels (padded + tiled)."""
    U, S, V = _pad_rank(U, S, V)
    x2, M = _pad_rows(x, 8)
    bm = _pick(256, x2.shape[0])
    bk = _pick(512, x2.shape[1])
    A = xus(x2, U, S, bm=bm, bk=bk, interpret=interpret)
    bn = _pick(256, V.shape[0])
    y = avt(A, V, bm=bm, bn=bn, interpret=interpret)
    return y[:M]


def coeff_grad_kernels(x, dy, U, V, *, interpret: bool) -> jax.Array:
    """∇_S L = (x U)ᵀ (dy V) via the atb kernel (paper's client backward)."""
    R = U.shape[1]
    U2, _, V2 = _pad_rank(U, jnp.zeros((R, R), U.dtype), V)
    x2, M = _pad_rows(x, 8)
    dy2, _ = _pad_rows(dy, 8)
    eye = jnp.eye(U2.shape[1], dtype=jnp.float32)
    bm = _pick(256, x2.shape[0])
    A = xus(x2, U2, eye, bm=bm, bk=_pick(512, x2.shape[1]), interpret=interpret)
    B = xus(dy2, V2, eye, bm=bm, bk=_pick(512, dy2.shape[1]), interpret=interpret)
    C = atb(A, B, bm=_pick(512, A.shape[0]), bka=_pick(256, A.shape[1]),
            interpret=interpret)
    return C[:R, :R]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def lowrank_apply(x, U, S, V, use_kernels: bool = False):
    """y = ((x U) S) Vᵀ with a kernel-backed custom VJP.

    ``use_kernels``: run the Pallas path (TPU, or interpret on CPU);
    False → pure-jnp reference (XLA fuses well on its own for small sizes).
    """
    if use_kernels:
        interpret = not on_tpu()
        return lowrank_apply_kernels(x, U, S, V, interpret=interpret)
    return ref.lowrank_matmul_ref(x, U, S, V)


def _fwd(x, U, S, V, use_kernels):
    y = lowrank_apply(x, U, S, V, use_kernels)
    return y, (x, U, S, V)


def _bwd(use_kernels, resids, dy):
    x, U, S, V = resids
    interpret = not on_tpu()

    if use_kernels:
        U_, S_, V_ = _pad_rank(U, S, V)
        dy2, M = _pad_rows(dy, 8)
        x2, _ = _pad_rows(x, 8)
        eye = jnp.eye(U_.shape[1], dtype=jnp.float32)
        bm = _pick(256, dy2.shape[0])
        dyV = xus(dy2, V_, eye, bm=bm, bk=_pick(512, dy2.shape[1]), interpret=interpret)
        xU = xus(x2, U_, eye, bm=bm, bk=_pick(512, x2.shape[1]), interpret=interpret)
        dA = xus(dy2, V_, jnp.transpose(S_).astype(jnp.float32), bm=bm,
                 bk=_pick(512, dy2.shape[1]), interpret=interpret)  # dy V Sᵀ
        dx = avt(dA, U_, bm=bm, bn=_pick(256, U_.shape[0]), interpret=interpret)
        dU = atb(x2, dA, bm=_pick(512, x2.shape[0]), bka=_pick(256, x2.shape[1]),
                 interpret=interpret)
        dS = atb(xU, dyV, bm=_pick(512, xU.shape[0]),
                 bka=_pick(256, xU.shape[1]), interpret=interpret)
        xUS = xus(x2, U_, S_.astype(jnp.float32), bm=bm,
                  bk=_pick(512, x2.shape[1]), interpret=interpret)
        dV = atb(dy2, xUS, bm=_pick(512, dy2.shape[0]),
                 bka=_pick(256, dy2.shape[1]), interpret=interpret)
        R = U.shape[1]
        return (dx[: x.shape[0]], dU[:, :R], dS[:R, :R], dV[:, :R])

    dyV = dy @ V
    xU = x @ U
    dx = (dyV @ S.T) @ U.T
    dU = x.T @ (dyV @ S.T)
    dS = xU.T @ dyV
    dV = dy.T @ (xU @ S)
    return (dx, dU, dS, dV)


lowrank_apply.defvjp(_fwd, _bwd)
