"""At-rest factor compression for serving.

Three independent knobs on a checkpointed factorized pytree:

- **int8** (:func:`quantize_params` with ``mode="int8"``): per-*column*
  affine quantization of ``U`` and ``V`` — the at-rest twin of the wire's
  ``int8_affine`` codec (`repro.fed.wire.Int8AffineCodec`), reusing its
  scale formula ``scale = (hi − lo)/255`` with ``q = round((x−lo)/scale) −
  128``, so the absolute dequantization error is bounded by ``scale/2`` per
  element.  Per-column (axis ``-2`` reduction) rather than the wire's
  per-tensor: serving factors are long-lived, so we spend ``8·r_max`` bytes
  of (lo, scale) per factor to keep each basis column's range tight — and,
  crucially, an **inactive column is exactly zero** (the zero-inactive-
  columns invariant), so its ``lo = hi = 0`` and it dequantizes to exactly
  ``0.0``: quantization cannot leak stale directions past the rank mask.
  ``S`` (``r_max × r_max``, tiny) stays f32.
- **bf16** (``mode="bf16"``): plain ``U``/``V`` downcast; ``S`` stays f32.
- **rank slicing** (:func:`rank_slice_params`): host-side load transform
  that drops the exactly-zero columns beyond each factor's active rank,
  shrinking ``r_max`` to the effective rank.  Sound by the same invariant:
  ``U S Vᵀ`` is unchanged because every dropped column contributes zero.

:func:`materialize_params` is the dense debug/baseline path (``U S Vᵀ``
densified per factor); :func:`resident_bytes` prices what a prepared pytree
keeps resident on device.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.factorization import (
    LowRankFactor,
    is_factor,
    mask_coeff,
    materialize,
    rank_mask,
)

Array = jax.Array

QUANT_MODES = ("none", "int8", "bf16")


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["u_q", "u_lo", "u_scale", "v_q", "v_lo", "v_scale", "S", "rank"],
    meta_fields=[],
)
@dataclasses.dataclass
class QuantizedFactor:
    """int8 at-rest form of a :class:`LowRankFactor`.

    ``u_q``/``v_q`` are int8 buffers with per-column affine params
    ``(lo, scale)`` shaped ``(..., 1, r_max)``; ``S`` and ``rank`` ride
    through unchanged.  The int8 buffers stay resident on device — dequant
    happens inside the serving engine's jitted executables, immediately
    before the factor feeds ``lowrank_apply``.
    """

    u_q: Array
    u_lo: Array
    u_scale: Array
    v_q: Array
    v_lo: Array
    v_scale: Array
    S: Array
    rank: Array

    @property
    def r_max(self) -> int:
        return self.u_q.shape[-1]

    @property
    def n_in(self) -> int:
        return self.u_q.shape[-2]

    @property
    def n_out(self) -> int:
        return self.v_q.shape[-2]


def is_quantized(x) -> bool:
    return isinstance(x, QuantizedFactor)


def _factor_like(x) -> bool:
    return is_factor(x) or is_quantized(x)


def _affine_encode(x: Array):
    """Wire-formula int8 affine, per basis column (reduce over axis -2)."""
    x = x.astype(jnp.float32)
    lo = jnp.min(x, axis=-2, keepdims=True)
    hi = jnp.max(x, axis=-2, keepdims=True)
    scale = jnp.maximum((hi - lo) / 255.0, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round((x - lo) / scale) - 128.0, -128, 127)
    return q.astype(jnp.int8), lo, scale


def _affine_decode(q: Array, lo: Array, scale: Array) -> Array:
    return (q.astype(jnp.float32) + 128.0) * scale + lo


def quantize_factor(f: LowRankFactor) -> QuantizedFactor:
    u_q, u_lo, u_scale = _affine_encode(f.U)
    v_q, v_lo, v_scale = _affine_encode(f.V)
    return QuantizedFactor(
        u_q=u_q, u_lo=u_lo, u_scale=u_scale,
        v_q=v_q, v_lo=v_lo, v_scale=v_scale,
        S=f.S, rank=f.rank,
    )


def dequantize_factor(qf: QuantizedFactor) -> LowRankFactor:
    """int8 → f32 factor; inactive columns re-masked to exactly zero.

    A zero column round-trips exactly (``lo = hi = 0``), but the explicit
    mask keeps the zero-inactive-columns invariant *structural* rather than
    numerical — downstream projections never see quantization residue.
    """
    m = rank_mask(qf.rank, qf.r_max)
    u = _affine_decode(qf.u_q, qf.u_lo, qf.u_scale) * m[..., None, :]
    v = _affine_decode(qf.v_q, qf.v_lo, qf.v_scale) * m[..., None, :]
    return LowRankFactor(U=u, S=mask_coeff(qf.S, m), V=v, rank=qf.rank)


def quantization_error_bound(qf: QuantizedFactor) -> float:
    """Max absolute per-element dequant error: ``max(scale)/2`` (wire bound)."""
    worst = jnp.maximum(jnp.max(qf.u_scale), jnp.max(qf.v_scale))
    return float(worst) / 2.0


def quantize_params(params, mode: str):
    """Apply at-rest compression ``mode`` to every factor leaf.

    ``"none"`` is the identity, ``"bf16"`` downcasts ``U``/``V`` in place
    (the leaf stays a :class:`LowRankFactor` — ``lowrank_apply`` consumes
    it unchanged), ``"int8"`` rewrites leaves to :class:`QuantizedFactor`.
    """
    if mode not in QUANT_MODES:
        raise ValueError(f"quantize mode must be one of {QUANT_MODES}, got {mode!r}")
    if mode == "none":
        return params

    def one(leaf):
        if not is_factor(leaf):
            return leaf
        if mode == "bf16":
            return LowRankFactor(
                U=leaf.U.astype(jnp.bfloat16),
                S=leaf.S,
                V=leaf.V.astype(jnp.bfloat16),
                rank=leaf.rank,
            )
        return quantize_factor(leaf)

    return jax.tree.map(one, params, is_leaf=is_factor)


def dequantize_params(params):
    """Restore :class:`LowRankFactor` leaves (identity on everything else).

    Called *inside* the engine's jitted executables so the int8 buffers are
    what stays resident; the f32 views are transient per-call values.
    """
    return jax.tree.map(
        lambda x: dequantize_factor(x) if is_quantized(x) else x,
        params,
        is_leaf=_factor_like,
    )


def _sliced_width(rank, r_max: int) -> int:
    """Concrete post-slice buffer width: effective rank rounded up to a
    multiple of 8 (keeps kernel tiles happy), never above ``r_max``."""
    r = int(np.max(np.asarray(jax.device_get(rank))))
    r = max(r, 1)
    return min(-(-r // 8) * 8, r_max)


def rank_slice_params(params):
    """Drop exactly-zero inactive columns from every factor leaf (host-side).

    For a stacked factor (leading layer/expert dims) the slice width is the
    max active rank across slices — buffers must stay rectangular under
    jit.  ``U S Vᵀ`` is bit-identical by the zero-inactive-columns
    invariant; only ``r_max`` (and hence decode FLOPs/bytes) shrinks.
    """

    def one(leaf):
        if not is_factor(leaf):
            return leaf
        w = _sliced_width(leaf.rank, leaf.r_max)
        if w == leaf.r_max:
            return leaf
        return LowRankFactor(
            U=leaf.U[..., :, :w],
            S=leaf.S[..., :w, :w],
            V=leaf.V[..., :, :w],
            rank=leaf.rank,
        )

    return jax.tree.map(one, params, is_leaf=is_factor)


def materialize_params(params):
    """Densify every factor to ``U S Vᵀ`` — the dense decode baseline.

    The model trunk's ``apply_linear``/``apply_embedding`` dispatch on
    ``is_factor``, so a materialized pytree takes the plain-matmul path
    with identical math (up to f32 associativity) at dense cost.
    """
    return jax.tree.map(
        lambda x: materialize(x) if is_factor(x) else x,
        params,
        is_leaf=is_factor,
    )


def resident_bytes(params) -> int:
    """Device-resident bytes of a prepared serving pytree.

    QuantizedFactor leaves count their int8 buffers + affine params + f32
    ``S`` — the dequantized views are transient inside the jitted step and
    deliberately not charged."""
    return int(sum(x.nbytes for x in jax.tree.leaves(params)))
