"""Low-rank serving: factor-resident decode + continuous batching.

Construction goes through ``repro.api.experiment.serve(spec)`` — the
RPL001 engine-construction rule covers :class:`ServeEngine` and
:class:`ContinuousScheduler` the same way it covers the training engines.
"""
from repro.serve.engine import ServeEngine, decode_matmul_flops  # noqa: F401
from repro.serve.quantize import (  # noqa: F401
    QUANT_MODES,
    QuantizedFactor,
    dequantize_params,
    materialize_params,
    quantization_error_bound,
    quantize_params,
    rank_slice_params,
    resident_bytes,
)
from repro.serve.scheduler import (  # noqa: F401
    SCHED_MODES,
    Completion,
    ContinuousScheduler,
    Request,
)
