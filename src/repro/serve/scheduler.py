"""Continuous-batching scheduler: request queue → decode slots → completions.

Each :meth:`ContinuousScheduler.step` runs ONE decode step of the engine's
fixed ``(max_batch, cache_len)`` executable and, in ``"continuous"`` mode,
first admits queued requests into any freed slots (prefill via the
per-bucket B=1 executable, grafted in by the insert executable).
``"static"`` mode is the legacy baseline the bench compares against: a new
wave is admitted only when *every* slot is free, so the whole batch waits
for its slowest member.

Determinism: admission order is queue order (FIFO), slot choice is lowest
free index, and sampling is keyed on (seed, rid, token index) in the
engine — so for a fixed arrival trace the token streams are reproducible
and independent of batching mode.  All host timing goes through
``repro.telemetry.clock.perf_seconds`` (RPL003).

Telemetry per request: a ``serve.queued`` wall span (submit→admit), a
``serve.prefill`` span, a ``serve.decode`` wall span (admit→finish),
``serve.tokens`` counters and ``serve.queue_depth`` / ``serve.active``
gauges — p50/p99 latency falls out of the standard Perfetto export.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np

from repro.telemetry import get_hub
from repro.telemetry.clock import perf_seconds

SCHED_MODES = ("continuous", "static")


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival_step`` is the decode-step index
    at which :meth:`ContinuousScheduler.run` makes it visible — the seeded
    Poisson trace in the bench is a list of these."""

    rid: int
    tokens: np.ndarray  # 1-D int32 prompt
    max_new_tokens: Optional[int] = None  # None → engine default
    eos_id: Optional[int] = None
    arrival_step: int = 0


@dataclasses.dataclass
class Completion:
    """A finished request with its phase timings (wall seconds)."""

    rid: int
    prompt_len: int
    tokens: np.ndarray  # generated tokens, eos included when hit
    submit_step: int
    admit_step: int
    finish_step: int
    queued_s: float
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        return len(self.tokens) / max(self.decode_s + self.prefill_s, 1e-9)


@dataclasses.dataclass
class _Slot:
    rid: int
    prompt_len: int
    budget: int
    eos_id: Optional[int]
    out: List[int]
    t_submit: float
    t_admit: float
    prefill_s: float
    submit_step: int
    admit_step: int


class ContinuousScheduler:
    """Drive a :class:`repro.serve.engine.ServeEngine` over a request
    stream.  Construct via ``repro.api.experiment.serve(spec)``."""

    def __init__(self, engine, *, max_queue: int = 64,
                 mode: str = "continuous", telemetry=None):
        if mode not in SCHED_MODES:
            raise ValueError(f"mode must be one of {SCHED_MODES}, got {mode!r}")
        self.engine = engine
        self.max_queue = int(max_queue)
        self.mode = mode
        self.hub = telemetry if telemetry is not None else get_hub()
        self.queue: deque = deque()  # (Request, t_submit, submit_step)
        self.slots: List[Optional[_Slot]] = [None] * engine.max_batch
        self.state = engine.new_state()
        self._last = np.zeros(engine.max_batch, np.int32)
        self._rids = np.full(engine.max_batch, -1, np.int32)
        self._tok_idx = np.zeros(engine.max_batch, np.int32)
        self.step_count = 0
        self.decode_steps = 0  # steps that actually ran the executable

    # ---------------------------------------------------------- admission

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def submit(self, req: Request) -> None:
        """Enqueue; raises ``RuntimeError`` when the queue is at capacity
        (backpressure is the caller's problem, not silent drops)."""
        if len(self.queue) >= self.max_queue:
            raise RuntimeError(
                f"serve queue full (max_queue={self.max_queue}); "
                f"apply backpressure upstream"
            )
        self.queue.append((req, perf_seconds(), self.step_count))
        self.hub.gauge("serve.queue_depth", len(self.queue))

    def _budget(self, req: Request) -> int:
        cap = self.engine.max_new_tokens
        want = cap if req.max_new_tokens is None else req.max_new_tokens
        return max(1, min(want, cap))

    def _admit(self, slot_i: int, req: Request, t_submit: float,
               submit_step: int) -> Optional[Completion]:
        t_admit = perf_seconds()
        with self.hub.span("serve.prefill", rid=req.rid):
            logits, cache = self.engine.prefill(req.tokens)
        prefill_s = perf_seconds() - t_admit
        first = int(
            self.engine.sample(logits, np.int32([req.rid]), np.int32([0]))[0]
        )
        slot = _Slot(
            rid=req.rid, prompt_len=int(np.asarray(req.tokens).size),
            budget=self._budget(req), eos_id=req.eos_id, out=[first],
            t_submit=t_submit, t_admit=t_admit, prefill_s=prefill_s,
            submit_step=submit_step, admit_step=self.step_count,
        )
        if len(slot.out) >= slot.budget or first == slot.eos_id:
            return self._complete(slot)  # done at prefill; slot never bound
        self.state = self.engine.insert(
            self.state, cache, slot_i, slot.prompt_len
        )
        self.slots[slot_i] = slot
        self._last[slot_i] = first
        self._rids[slot_i] = req.rid
        self._tok_idx[slot_i] = 1
        return None

    def _complete(self, slot: _Slot) -> Completion:
        t_end = perf_seconds()
        self.hub.span_wall_at(
            "serve.queued", slot.t_submit, slot.t_admit, rid=slot.rid
        )
        self.hub.span_wall_at(
            "serve.decode", slot.t_admit + slot.prefill_s, t_end,
            rid=slot.rid, tokens=len(slot.out),
        )
        self.hub.counter("serve.tokens", len(slot.out))
        self.hub.counter("serve.requests_completed")
        return Completion(
            rid=slot.rid, prompt_len=slot.prompt_len,
            tokens=np.asarray(slot.out, np.int32),
            submit_step=slot.submit_step, admit_step=slot.admit_step,
            finish_step=self.step_count,
            queued_s=slot.t_admit - slot.t_submit,
            prefill_s=slot.prefill_s,
            decode_s=t_end - (slot.t_admit + slot.prefill_s),
        )

    # --------------------------------------------------------------- step

    def step(self) -> List[Completion]:
        """Admit (mode-dependent) + one decode step; returns completions."""
        done: List[Completion] = []
        may_admit = self.mode == "continuous" or self.active == 0
        if may_admit:
            for i, s in enumerate(self.slots):
                if not self.queue:
                    break
                if s is None:
                    req, t_submit, submit_step = self.queue.popleft()
                    c = self._admit(i, req, t_submit, submit_step)
                    if c is not None:
                        done.append(c)
            self.hub.gauge("serve.queue_depth", len(self.queue))

        if self.active:
            logits, self.state = self.engine.step(self.state, self._last)
            nxt = self.engine.sample(logits, self._rids, self._tok_idx)
            self.decode_steps += 1
            for i, s in enumerate(self.slots):
                if s is None:
                    continue
                tok = int(nxt[i])
                s.out.append(tok)
                self._last[i] = tok
                self._tok_idx[i] += 1
                if len(s.out) >= s.budget or tok == s.eos_id:
                    done.append(self._complete(s))
                    self.slots[i] = None
                    self._rids[i] = -1
        self.step_count += 1
        self.hub.gauge("serve.active", self.active)
        return done

    # ---------------------------------------------------------------- run

    def run(self, requests) -> List[Completion]:
        """Drive an arrival trace to completion; returns completions
        ordered by rid.  Requests become visible at their ``arrival_step``
        (in decode-step units — deterministic, unlike wall-clock gating)."""
        pending = deque(
            sorted(requests, key=lambda r: (r.arrival_step, r.rid))
        )
        done: List[Completion] = []
        while pending or self.queue or self.active:
            if (
                pending and not self.queue and not self.active
                and pending[0].arrival_step > self.step_count
            ):
                self.step_count = pending[0].arrival_step  # idle fast-forward
            while pending and pending[0].arrival_step <= self.step_count:
                self.submit(pending.popleft())
            done.extend(self.step())
        return sorted(done, key=lambda c: c.rid)
