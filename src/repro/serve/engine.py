"""Factor-resident decode engine.

The engine owns the jitted executables and the per-slot decode state; the
scheduler (`repro.serve.scheduler`) owns request admission.  Executable
discipline (the jit-invariant the lint's trace auditor pins):

- **one prefill executable per prompt-length bucket** — prompts are
  right-padded to the next multiple of ``prompt_bucket`` and run at
  ``B = 1``; the causal mask keeps pad keys out of every real query and
  ``last_index`` reads the true last-token logits, so bucketing changes
  compilation count, never tokens;
- **one insert executable** — copies a B=1 prefill cache into slot ``i``
  of the per-slot batch state (slot and true length are traced scalars);
- **one decode executable** at the fixed ``(max_batch, cache_len)`` shape —
  every step decodes the full slot array; inactive slots carry garbage
  rows that never escape (the scheduler ignores them).

Params may arrive quantized (`repro.serve.quantize`); dequantization runs
*inside* each executable so only the compressed buffers stay resident.
Every matmul goes through the model trunk's ``apply_linear`` →
``kernels/ops.lowrank_apply`` dispatch: ``U S Vᵀ`` is never materialized
on the factor-resident path.

Sampling is deterministic and batching-invariant: token ``j`` of request
``rid`` draws from ``fold_in(fold_in(key(seed), rid), j)``, so a request's
output is independent of which other requests share the batch (dense
families — exactly the ones ``init_cache(per_slot=True)`` admits).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core.factorization import is_factor
from repro.serve.quantize import dequantize_params, is_quantized
from repro.telemetry import get_hub

Array = jax.Array


def _insert_cache(state, one, slot, length):
    """Graft a B=1 prefill cache into row ``slot`` of the per-slot state.

    Name-directed walk: ``idx`` buffers are (NB, batch) write indices,
    ``pos`` is the (batch,) position vector — both stamped to the true
    prompt ``length`` so the right-pad columns beyond it become stale cache
    entries the attention mask already rejects (kv_pos goes negative).
    Every other leaf carries batch on axis 1 under the (NB, ...) stack.
    """
    out = {}
    for k, dv in state.items():
        sv = one[k]
        if isinstance(dv, dict):
            out[k] = _insert_cache(dv, sv, slot, length)
        elif k == "idx":
            out[k] = dv.at[:, slot].set(length)
        elif k == "pos":
            out[k] = dv.at[slot].set(length)
        else:
            out[k] = jax.lax.dynamic_update_index_in_dim(dv, sv[:, 0], slot, 1)
    return out


def decode_matmul_flops(params, *, factor_resident: bool = True) -> float:
    """Per-token decode FLOPs of the pytree's factor leaves (cost-model
    closed forms).

    Only factor leaves are priced: the dense leaves (norms, biases, any
    never-factorized matrices) are identical between the factor-resident
    and materialized paths and cancel in every comparison this function
    feeds.  Embedding factors are priced with ``gather=True`` — their U row
    is gathered, and a *dense* embedding is a pure gather worth 0 FLOPs.
    """
    total = 0.0
    leaves = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: is_factor(x) or is_quantized(x)
    )[0]
    for path, leaf in leaves:
        if not (is_factor(leaf) or is_quantized(leaf)):
            continue
        u = leaf.U if is_factor(leaf) else leaf.u_q
        stack = math.prod(u.shape[:-2])
        gather = any(getattr(k, "key", None) == "embed" for k in path)
        if factor_resident:
            per = cost_model.lowrank_decode_flops(
                leaf.n_in, leaf.n_out, leaf.r_max, gather=gather
            )
        else:
            per = cost_model.dense_decode_flops(
                leaf.n_in, leaf.n_out, gather=gather
            )
        total += stack * per
    return total


class ServeEngine:
    """Jitted decode executables over one prepared (possibly quantized)
    param pytree.  Construct via ``repro.api.experiment.serve(spec)``."""

    def __init__(
        self,
        model,
        params,
        *,
        max_batch: int = 4,
        max_prompt: int = 64,
        prompt_bucket: int = 16,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        seed: int = 0,
        telemetry=None,
    ):
        cfg = model.cfg
        if cfg.is_encdec:
            raise ValueError(
                "the serving engine decodes per-slot; enc-dec (audio) "
                "models need one shared position and are not servable here"
            )
        if max_prompt % prompt_bucket:
            raise ValueError(
                f"prompt_bucket ({prompt_bucket}) must divide "
                f"max_prompt ({max_prompt})"
            )
        self.model = model
        self.params = params
        self.max_batch = int(max_batch)
        self.max_prompt = int(max_prompt)
        self.prompt_bucket = int(prompt_bucket)
        self.max_new_tokens = int(max_new_tokens)
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.cache_len = self.max_prompt + self.max_new_tokens
        self.hub = telemetry if telemetry is not None else get_hub()

        def step(p, state, tokens):
            return model.serve_step(dequantize_params(p), state, tokens)

        self._step_fn = jax.jit(step)
        self._insert_fn = jax.jit(_insert_cache)
        self._prefill_fns: Dict[int, object] = {}
        self._base_key = jax.random.PRNGKey(self.seed)

        def sample_tokens(logits, rids, steps):
            if self.temperature <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)

            def one(lg, rid, step_i):
                k = jax.random.fold_in(
                    jax.random.fold_in(self._base_key, jnp.maximum(rid, 0)),
                    step_i,
                )
                return jax.random.categorical(k, lg / self.temperature)

            return jax.vmap(one)(logits, rids, steps).astype(jnp.int32)

        self._sample_fn = jax.jit(sample_tokens)

    # ------------------------------------------------------------- state

    def new_state(self):
        """Fresh per-slot decode state at the (max_batch, cache_len) shape."""
        return self.model.init_cache(
            self.params, self.max_batch, self.cache_len, per_slot=True
        )

    # ----------------------------------------------------------- prefill

    def bucket_len(self, length: int) -> int:
        b = self.prompt_bucket
        return -(-length // b) * b

    def prefill(self, prompt):
        """Run one prompt through its length bucket → (logits (1, V), cache).

        Compiles at most ``max_prompt / prompt_bucket`` executables total.
        """
        prompt = np.asarray(prompt, np.int32).ravel()
        length = int(prompt.size)
        if length < 1:
            raise ValueError("empty prompt")
        if length > self.max_prompt:
            raise ValueError(
                f"prompt length {length} exceeds max_prompt={self.max_prompt}"
            )
        lb = self.bucket_len(length)
        fn = self._prefill_fns.get(lb)
        if fn is None:
            cache_len = self.cache_len

            def prefill_fn(p, tokens, last_index):
                return self.model.serve_prefill(
                    dequantize_params(p),
                    {"tokens": tokens},
                    cache_len=cache_len,
                    last_index=last_index,
                )

            fn = jax.jit(prefill_fn)
            self._prefill_fns[lb] = fn
        tokens = np.zeros((1, lb), np.int32)
        tokens[0, :length] = prompt
        return fn(self.params, jnp.asarray(tokens), jnp.int32(length - 1))

    def insert(self, state, cache, slot: int, length: int):
        """Graft a B=1 prefill ``cache`` into ``state`` row ``slot``."""
        return self._insert_fn(state, cache, jnp.int32(slot), jnp.int32(length))

    # ------------------------------------------------------------ decode

    def step(self, state, last_tokens):
        """One decode step over all slots: (B,) tokens → (logits, state)."""
        tokens = jnp.asarray(last_tokens, jnp.int32).reshape(self.max_batch, 1)
        return self._step_fn(self.params, state, tokens)

    def sample(self, logits, rids, steps) -> np.ndarray:
        """Batching-invariant sampling: greedy at temperature 0, else a
        categorical draw keyed on (seed, rid, token index)."""
        out = self._sample_fn(
            jnp.asarray(logits),
            jnp.asarray(rids, jnp.int32),
            jnp.asarray(steps, jnp.int32),
        )
        return np.asarray(out)

    # ----------------------------------------------------------- costing

    def decode_flops_per_token(self) -> Optional[float]:
        """Factor-leaf decode FLOPs per token per sequence (cost model);
        ``None`` for a materialized pytree — once densified, the ex-factor
        leaves are indistinguishable from always-dense ones, so price the
        dense path via ``decode_matmul_flops(factor_params,
        factor_resident=False)`` on the *source* pytree instead."""
        has_factors = any(
            is_factor(x) or is_quantized(x)
            for x in jax.tree.leaves(
                self.params, is_leaf=lambda x: is_factor(x) or is_quantized(x)
            )
        )
        if not has_factors:
            return None
        return decode_matmul_flops(self.params, factor_resident=True)

    def num_executables(self) -> int:
        """Live compiled-executable count (prefill buckets + insert + step)."""
        return len(self._prefill_fns) + 2
