"""Minimal optax-style optimizers (no external deps allowed offline).

FeDLRT clients optimize only the small coefficient matrices, but the
optimizer is generic over pytrees so the same code drives the FedAvg /
FedLin dense baselines and any auxiliary dense parameters (norms, biases).

An :class:`Optimizer` is a pair of pure functions::

    state = opt.init(params)
    updates, state = opt.update(grads, state, step)   # new_p = p + updates

Learning rates are *callables of the step* so cosine schedules stay inside
jit (step is a traced scalar).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_zeros_like

Schedule = Callable[[jax.Array], jax.Array]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, jax.Array], tuple[Any, Any]]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, dtype=jnp.float32)


def sgd(lr, *, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = _as_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return tree_zeros_like(params)

    def update(grads, state, step):
        lam = lr_fn(step)
        if weight_decay:
            # decoupled weight decay is applied by the caller on params; here
            # we fold classic L2 into the gradient for paper-parity with
            # torch SGD(weight_decay=...).
            pass
        if momentum == 0.0:
            upd = jax.tree.map(lambda g: -lam * g, grads)
            return upd, state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        upd = jax.tree.map(lambda m: -lam * m, new_m)
        return upd, new_m

    return Optimizer(init=init, update=update)


def adam(lr, *, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    lr_fn = _as_schedule(lr)

    def init(params):
        return {"m": tree_zeros_like(params), "v": tree_zeros_like(params), }

    def update(grads, state, step):
        lam = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], grads)
        bc1 = 1.0 - jnp.power(b1, t)
        bc2 = 1.0 - jnp.power(b2, t)
        upd = jax.tree.map(
            lambda m_, v_: -lam * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps), m, v
        )
        return upd, {"m": m, "v": v}

    return Optimizer(init=init, update=update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adam":
        kw.pop("momentum", None)  # adam has its own moments
        return adam(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
