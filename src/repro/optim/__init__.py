from repro.optim.optimizers import Optimizer, adam, sgd, make_optimizer  # noqa: F401
from repro.optim.schedules import constant_schedule, cosine_schedule  # noqa: F401
