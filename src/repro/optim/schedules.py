"""Learning-rate schedules (paper Table 2 uses cosine annealing)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def fn(step):
        return jnp.asarray(lr, dtype=jnp.float32)

    return fn


def cosine_schedule(lr_start: float, lr_end: float, total_steps: int):
    """Cosine annealing from ``lr_start`` to ``lr_end`` over ``total_steps``."""

    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr_end + (lr_start - lr_end) * cos

    return fn
