"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — required because the dry-run process
must set XLA_FLAGS before the first jax initialization.

Axis semantics:
  pod   — cross-pod data parallelism (federated clients span pods too)
  data  — within-pod data parallelism = the federated-client axis
  model — tensor/expert parallelism within a client's shard
"""
from __future__ import annotations

import jax


def mesh_kwargs(num_axes: int) -> dict:
    """``axis_types`` only exists on jax ≥ 0.5 (where explicit-sharding
    AxisTypes were introduced); older versions are Auto-only anyway."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * num_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_host_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over forced host devices (unit tests)."""
    return jax.make_mesh((data, model), ("data", "model"), **mesh_kwargs(2))


def data_axis_size(mesh: jax.sharding.Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
