"""Batched serving driver: prefill + greedy/temperature decode loop.

Completes the serving side of the framework: the dry-run proves the
decode shapes lower on the production mesh; this driver actually runs
them (CPU-scale here, same code on a mesh).  Requests are padded into a
fixed batch, prefilled once, then decoded step-by-step with the ring/KV
cache from ``Model.serve_step`` — per-sequence stop handling included.

    PYTHONPATH=src python -m repro.launch.serve --preset llm-tiny --new-tokens 32
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models.config import ModelConfig, reduced


@dataclasses.dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    tokens_generated: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / max(self.decode_s, 1e-9)


class BatchedServer:
    """Static-batch server over a Model: prefill once, decode N tokens."""

    def __init__(self, model, params, *, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, b, cl: model.serve_prefill(p, b, cache_len=cl),
            static_argnums=(2,),
        )
        self._step = jax.jit(model.serve_step)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits / self.temperature).astype(
            jnp.int32
        )

    def generate(
        self,
        prompts: List[np.ndarray],
        *,
        extra_inputs: Optional[dict] = None,
        eos_id: Optional[int] = None,
    ):
        """prompts: list of 1-D int token arrays (right-padded internally)."""
        B = len(prompts)
        L = max(len(p) for p in prompts)
        cfg = self.model.cfg
        pad = np.zeros((B, L), np.int32)
        for i, p in enumerate(prompts):
            pad[i, L - len(p):] = p  # left-pad so last position is real
        batch = {"tokens": jnp.asarray(pad)}
        if extra_inputs:
            batch.update(extra_inputs)

        cache_len = L + cfg.vision_tokens + self.max_new_tokens
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache_len)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        outs = np.zeros((B, self.max_new_tokens), np.int32)
        done = np.zeros(B, bool)
        t0 = time.perf_counter()
        tok = self._sample(logits)
        for t in range(self.max_new_tokens):
            outs[:, t] = np.where(done, eos_id or 0, np.asarray(tok))
            if eos_id is not None:
                done |= np.asarray(tok) == eos_id
                if done.all():
                    outs = outs[:, : t + 1]
                    break
            logits, cache = self._step(self.params, cache, tok[:, None])
            tok = self._sample(logits)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0
        stats = ServeStats(
            prefill_s=t_prefill, decode_s=t_decode,
            tokens_generated=int(outs.size),
        )
        return outs, stats


def main(argv=None):
    from repro.launch.train import PRESETS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--preset", type=str, default="llm-tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg: ModelConfig = (
        get_config(args.arch) if args.arch else PRESETS[args.preset]
    )
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"serving {cfg.name} ({n/1e6:.1f}M params), batch={args.batch}")

    rng = np.random.default_rng(args.seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=rng.integers(4, args.prompt_len + 1))
        .astype(np.int32)
        for _ in range(args.batch)
    ]
    extra = {}
    if cfg.family == "vlm":
        extra["vision_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.vision_tokens, cfg.d_model)),
            dtype=jnp.float32,
        )
    if cfg.family == "audio":
        extra["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder.num_frames, cfg.d_model)),
            dtype=jnp.float32,
        )

    server = BatchedServer(
        model, params, max_new_tokens=args.new_tokens,
        temperature=args.temperature, seed=args.seed,
    )
    outs, stats = server.generate(prompts, extra_inputs=extra)
    print(f"prefill {stats.prefill_s*1e3:.1f} ms; "
          f"decode {stats.decode_s*1e3:.1f} ms for {stats.tokens_generated} "
          f"tokens ({stats.tokens_per_s:.1f} tok/s)")
    print("first sequence:", outs[0][:16].tolist())
    return outs, stats


if __name__ == "__main__":
    main()
