"""Serving CLI — a thin layer over ``repro.api.experiment.serve``.

The legacy flag surface (``--preset``/``--arch``/``--batch``/…) maps
one-to-one onto a :class:`repro.api.spec.ServeSpec`, the way the train
CLI's flags map onto an :class:`ExperimentSpec`: every invocation builds
the spec first, so ``serve(spec)`` stays the single serving construction
site and legacy flags ≡ spec by construction.

    PYTHONPATH=src python -m repro.launch.serve --preset llm-tiny --new-tokens 32
    PYTHONPATH=src python -m repro.launch.serve --preset llm-tiny --quantize int8
    PYTHONPATH=src python -m repro.api serve examples/configs/serve_lowrank.toml

All timing comes back from the scheduler's completions, which stamp
phases with ``repro.telemetry.clock.perf_seconds`` (RPL003) — this module
does no clock reads of its own.
"""
from __future__ import annotations

import argparse

import numpy as np


def synthetic_requests(spec, num_requests: int, *, spread: bool = False):
    """Seeded synthetic prompt set for a spec: lengths in
    ``[4, max_prompt]``, ids in the model vocab.  ``spread=True`` staggers
    arrivals (one request every other decode step) to exercise continuous
    admission; otherwise everything arrives at step 0."""
    from repro.api.tasks import lm_model_config
    from repro.serve import Request

    cfg = lm_model_config(spec.model)
    rng = np.random.default_rng(spec.seed)
    reqs = []
    for i in range(num_requests):
        length = int(rng.integers(4, spec.serve.max_prompt + 1))
        reqs.append(Request(
            rid=i,
            tokens=rng.integers(1, cfg.vocab_size, size=length).astype(np.int32),
            eos_id=spec.serve.eos_id,
            arrival_step=2 * i if spread else 0,
        ))
    return reqs


def summarize(completions) -> str:
    """One-line throughput/latency summary of a completion list."""
    toks = sum(len(c.tokens) for c in completions)
    span = sum(c.prefill_s + c.decode_s for c in completions)
    per_tok = np.concatenate([
        np.full(max(len(c.tokens), 1), c.decode_s / max(len(c.tokens), 1))
        for c in completions
    ])
    p50, p99 = np.percentile(per_tok, [50, 99])
    return (
        f"{len(completions)} requests, {toks} tokens; "
        f"{toks / max(span, 1e-9):.1f} tok/s aggregate; "
        f"per-token latency p50 {p50 * 1e3:.2f} ms / p99 {p99 * 1e3:.2f} ms"
    )


def run_session(spec, num_requests: int = 8) -> int:
    """Build the spec's serving stack, drive synthetic requests, print
    stats.  Shared by ``python -m repro.api serve`` and this module's
    legacy-flag ``main``."""
    from repro.api.experiment import serve

    session = serve(spec)
    print(session.describe())
    comps = session.run(
        synthetic_requests(spec, num_requests,
                           spread=spec.serve.mode == "continuous")
    )
    print(summarize(comps))
    first = comps[0]
    print(f"first sequence: {first.tokens[:16].tolist()}")
    return 0


def main(argv=None):
    from repro.api.spec import ExperimentSpec, ModelSpec, ServeSpec

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--preset", type=str, default="llm-tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--checkpoint", type=str, default=None,
                    help="round_*.npz file or checkpoint dir (latest wins)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quantize", choices=("none", "int8", "bf16"),
                    default="none")
    ap.add_argument("--rank-slice", action="store_true")
    ap.add_argument("--materialize", action="store_true",
                    help="dense U S Vᵀ baseline path")
    ap.add_argument("--mode", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    bucket = max(8, args.prompt_len // 4)
    max_prompt = -(-args.prompt_len // bucket) * bucket
    spec = ExperimentSpec(
        name=f"serve-{args.arch or args.preset}",
        seed=args.seed,
        model=ModelSpec(
            kind="lm",
            preset=None if args.arch else args.preset,
            arch=args.arch,
            smoke=args.smoke,
        ),
        serve=ServeSpec(
            checkpoint=args.checkpoint,
            quantize=args.quantize,
            rank_slice=args.rank_slice,
            materialize=args.materialize,
            mode=args.mode,
            max_batch=args.batch,
            max_prompt=max_prompt,
            prompt_bucket=bucket,
            max_new_tokens=args.new_tokens,
            temperature=args.temperature,
        ),
    )
    return run_session(spec, num_requests=args.requests)


if __name__ == "__main__":
    main()
