"""Federated LM training driver — a thin CLI over :mod:`repro.api`.

The scenario lives in a declarative :class:`repro.api.ExperimentSpec`:
load one from a file, tweak it with dotted overrides, or drive it with the
legacy flags (every historical flag keeps working as an alias onto a spec
field).  Engine construction happens exclusively in
:func:`repro.api.build`.

    PYTHONPATH=src python -m repro.launch.train --preset llm-100m --rounds 300
    PYTHONPATH=src python -m repro.launch.train --preset none --arch qwen2-7b --smoke
    PYTHONPATH=src python -m repro.launch.train --config examples/configs/sync_baseline.toml \
        --set engine.kind=async --set sim.profile=straggler:0.25,10

``--preset`` and ``--arch`` are mutually exclusive (``--preset none``
selects the registry path); precedence is config file < legacy flags <
``--set`` overrides.

On the production mesh this module is launched once per host; the client
axis maps onto ("pod","data") exactly as in the dry-run (launch/dryrun.py
carries the sharding; this driver focuses on the algorithmic loop).
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.api import ExperimentSpec, ParticipationSpec, build, load_spec
from repro.api.serialization import parse_override, set_dotted
from repro.api.tasks import PRESETS  # noqa: F401  (re-export: serve.py, tests)

#: legacy flag → spec field the alias writes (participation/preset/arch are
#: handled specially below)
FLAG_TO_FIELD = {
    "smoke": "model.smoke",
    "kernels": "model.kernels",
    "method": "fed.method",
    "correction": "fed.correction",
    "clients": "fed.clients",
    "local_steps": "fed.local_steps",
    "lr": "fed.lr",
    "tau": "fed.tau",
    "weighted": "fed.weighted",
    "wire_codec": "wire.codec",
    "edge_wire_codec": "wire.edge_codec",
    "engine": "engine.kind",
    "async_buffer": "engine.buffer_size",
    "staleness_power": "engine.staleness_power",
    "edges": "engine.edges",
    "edge_rounds": "engine.edge_rounds",
    "sim_profile": "sim.profile",
    "rounds": "rounds",
    "batch": "data.batch",
    "seq": "data.seq",
    "seed": "seed",
    "checkpoint_dir": "checkpoint.dir",
    "checkpoint_every": "checkpoint.every",
    "log_every": "log_every",
    "telemetry": "telemetry.enabled",
    "telemetry_dir": "telemetry.dir",
    "telemetry_sinks": "telemetry.sinks",
}


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n")[0],
        argument_default=argparse.SUPPRESS,  # only provided flags override
    )
    ap.add_argument("--config", type=str, default=None,
                    help="ExperimentSpec file (.toml or .json) to start from")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    metavar="SECTION.KEY=VALUE",
                    help="dotted spec override, e.g. --set engine.kind=async "
                    "(applied after config and legacy flags; repeatable)")
    ap.add_argument("--arch", type=str,
                    help="architecture registry id (mutually exclusive with "
                    "--preset; implies --preset none)")
    ap.add_argument("--preset", type=str,
                    choices=sorted(PRESETS) + ["none"],
                    help="named LM preset (default llm-tiny); 'none' selects "
                    "the --arch registry path")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", type=str,
                    choices=["fedlrt", "fedavg", "fedlin", "fedlrt_naive"])
    ap.add_argument("--correction", type=str,
                    choices=["none", "simplified", "full"])
    ap.add_argument("--clients", type=int)
    ap.add_argument("--participation", type=str,
                    help="per-round cohort policy: full | uniform:K | "
                    "round_robin:K | dropout:P")
    ap.add_argument("--weighted", action="store_true",
                    help="aggregate with client weights ∝ |X_c| (paper §2 "
                    "extension)")
    ap.add_argument("--kernels", choices=["auto", "interpret", "off"],
                    help="low-rank Pallas kernel dispatch: auto = fused "
                    "kernels on TPU (jnp reference elsewhere), interpret = "
                    "force the Pallas interpreter (CPU validation, slow), "
                    "off = plain jnp chain")
    ap.add_argument("--wire-codec", type=str,
                    help="on-the-wire codec for round payloads: identity | "
                    "downcast[:dtype] | int8_affine | topk_rank (see "
                    "repro.fed.wire); comm totals are measured through it")
    ap.add_argument("--engine", choices=["sync", "async", "hier"],
                    help="aggregation engine: sync (one barrier per round), "
                    "async (FedBuff-style buffered, --async-buffer arrivals "
                    "per aggregate), hier (two-tier edge→cloud; "
                    "--edges/--edge-rounds)")
    ap.add_argument("--sim-profile", type=str,
                    help="client system-profile fleet for virtual-clock "
                    "pricing: uniform | straggler[:FRAC[,SLOWDOWN]] | "
                    "lognormal[:SIGMA] (optionally prefixed dropout:P,). "
                    "Implied 'uniform' for the async/hier engines; omit "
                    "entirely for the plain sync engine")
    ap.add_argument("--async-buffer", type=int,
                    help="async engine: aggregate every K arrivals "
                    "(default: #clients)")
    ap.add_argument("--staleness-power", type=float,
                    help="async engine: staleness discount (1+s)^-p on "
                    "stale updates")
    ap.add_argument("--edges", type=int,
                    help="hier engine: number of edge servers")
    ap.add_argument("--edge-rounds", type=int,
                    help="hier engine: local rounds per cloud round")
    ap.add_argument("--edge-wire-codec", type=str,
                    help="hier engine: codec for the edge→cloud hop "
                    "(default: --wire-codec)")
    ap.add_argument("--rounds", type=int)
    ap.add_argument("--local-steps", type=int)
    ap.add_argument("--batch", type=int)
    ap.add_argument("--seq", type=int)
    ap.add_argument("--lr", type=float)
    ap.add_argument("--tau", type=float)
    ap.add_argument("--seed", type=int)
    ap.add_argument("--checkpoint-dir", type=str)
    ap.add_argument("--checkpoint-every", type=int,
                    help="checkpoint cadence in rounds (needs "
                    "--checkpoint-dir; default 20)")
    ap.add_argument("--log-every", type=int)
    ap.add_argument("--telemetry", action="store_true",
                    help="structured telemetry: round/phase spans, metric "
                    "streams, JSONL event log + Perfetto trace (see "
                    "repro.telemetry; on ≡ off bit-for-bit)")
    ap.add_argument("--telemetry-dir", type=str,
                    help="output directory for the jsonl/perfetto sinks "
                    "(events.jsonl, trace.json)")
    ap.add_argument("--telemetry-sinks", type=str,
                    help="comma list over console,memory,jsonl,perfetto "
                    "(default console)")
    return ap


def spec_from_argv(argv=None) -> ExperimentSpec:
    """Resolve CLI arguments into a validated :class:`ExperimentSpec`.

    Precedence: ``--config`` file < legacy flag aliases < ``--set``.
    """
    ap = _parser()
    args = vars(ap.parse_args(argv))
    sets = args.pop("sets")
    config = args.pop("config")
    spec = load_spec(config) if config else ExperimentSpec()

    # model selection: --preset and --arch are mutually exclusive ("none"
    # is the explicit opt-out; previously --arch silently clobbered the
    # preset default and `choices=list(PRESETS) + [None]` was untypable)
    preset = args.pop("preset", None)
    arch = args.pop("arch", None)
    if preset is not None and preset != "none" and arch is not None:
        ap.error("--preset and --arch are mutually exclusive "
                 "(pass --preset none to use --arch)")
    assignments = {}
    if arch is not None:
        assignments.update({"model.preset": None, "model.arch": arch})
    elif preset == "none":
        assignments["model.preset"] = None
    elif preset is not None:
        assignments.update({"model.preset": preset, "model.arch": None})

    if "participation" in args:
        p = ParticipationSpec.from_string(args.pop("participation"))
        for f in dataclasses.fields(p):
            assignments[f"participation.{f.name}"] = getattr(p, f.name)

    # the variance correction only parameterizes FeDLRT: dense methods get
    # correction='none' implicitly (the legacy CLI's silent coercion), and
    # an *explicit* contradictory --correction is a hard error at spec time
    method = args.get("method")
    if method is not None and not method.startswith("fedlrt"):
        args.setdefault("correction", "none")
    assignments.update({FLAG_TO_FIELD[k]: v for k, v in args.items()})

    # one mutation pass over the plain dict, one validation at the end —
    # flag/override combinations never trip on transient intermediate states
    data = spec.to_dict()
    for path, value in assignments.items():
        set_dotted(ExperimentSpec, data, path, value, parse_str=False)
    for item in sets:
        path, raw = parse_override(item)
        set_dotted(ExperimentSpec, data, path, raw, parse_str=True)
    return ExperimentSpec.from_dict(data)


def main(argv=None):
    spec = spec_from_argv(argv)
    exp = build(spec)
    print(f"{exp.task.description} clients={spec.fed.clients} "
          f"[spec {spec.spec_hash()}]")
    hist = exp.run()
    import numpy as np

    mean_cohort = np.mean([r.cohort_size for r in hist])
    # condition on the *scenario*, not `t_virtual`'s truthiness — a
    # legitimately-zero clock reading (sync engine + profile at round 0)
    # must still print the engine timing
    timing = (
        f"; virtual time {hist[-1].t_virtual:.1f}s [{spec.engine.kind}]"
        if exp.is_simulated
        else ""
    )
    eng = exp.engine
    analytic = (
        f" vs {eng.comm_total_bytes_analytic()/1e6:.1f} MB analytic"
        if hasattr(eng, "comm_total_bytes_analytic") else ""
    )
    print(
        f"done: loss {hist[0].loss_before:.4f} → {hist[-1].loss_before:.4f}; "
        f"total comm {eng.comm_total_bytes()/1e6:.1f} MB measured "
        f"[{spec.wire.codec}]{analytic} (mean cohort {mean_cohort:.1f}/"
        f"{spec.fed.clients}){timing}"
    )
    return hist


if __name__ == "__main__":
    main()
