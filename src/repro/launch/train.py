"""Federated LM training driver (example application entry point).

Builds an arch from the registry (or a named preset), a Markov-chain token
stream partitioned across clients, and runs FeDLRT (or a baseline) rounds
through the FederatedEngine with checkpointing.

    PYTHONPATH=src python -m repro.launch.train --preset llm-100m --rounds 300
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke
    PYTHONPATH=src python -m repro.launch.train --preset llm-tiny \
        --method fedavg --rounds 50

On the production mesh this module is launched once per host; the client
axis maps onto ("pod","data") exactly as in the dry-run (launch/dryrun.py
carries the sharding; this driver focuses on the algorithmic loop).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FedConfig
from repro.data import FederatedBatcher, make_token_stream, partition_iid, partition_sizes
from repro.fed import FederatedEngine, Participation
from repro.models import build_model
from repro.models.config import LowRankPolicy, ModelConfig, reduced

PRESETS = {
    # ~100M-param dense decoder for the end-to-end example (deliverable b)
    "llm-100m": ModelConfig(
        name="llm-100m", family="dense", num_layers=12, d_model=640,
        num_heads=10, num_kv_heads=10, head_dim=64, d_ff=2560,
        vocab_size=8192, compute_dtype="float32", param_dtype="float32",
        lowrank=LowRankPolicy(rank_frac=0.25, r_cap=160, min_dim=256),
        attn_q_chunk=256,
    ),
    # CPU-feasible demo (~2M params)
    "llm-tiny": ModelConfig(
        name="llm-tiny", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512,
        vocab_size=512, compute_dtype="float32", param_dtype="float32",
        lowrank=LowRankPolicy(rank_frac=0.25, r_cap=32, min_dim=32),
        attn_q_chunk=64,
    ),
}


def build_cfg(args) -> ModelConfig:
    if args.preset:
        cfg = PRESETS[args.preset]
    else:
        cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--preset", type=str, default="llm-tiny", choices=list(PRESETS) + [None])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="fedlrt", choices=["fedlrt", "fedavg", "fedlin"])
    ap.add_argument("--correction", default="simplified")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument(
        "--participation", type=str, default="full",
        help="per-round cohort policy: full | uniform:K | round_robin:K | dropout:P",
    )
    ap.add_argument(
        "--weighted", action="store_true",
        help="aggregate with client weights ∝ |X_c| (paper §2 extension)",
    )
    ap.add_argument(
        "--kernels", default="auto", choices=["auto", "interpret", "off"],
        help="low-rank Pallas kernel dispatch: auto = fused kernels on TPU "
        "(jnp reference elsewhere), interpret = force the Pallas "
        "interpreter (CPU validation, slow), off = plain jnp chain",
    )
    ap.add_argument(
        "--wire-codec", default="identity",
        help="on-the-wire codec for round payloads: identity | "
        "downcast[:dtype] | int8_affine | topk_rank (see repro.fed.wire); "
        "comm totals are measured through it",
    )
    ap.add_argument(
        "--engine", default="sync", choices=["sync", "async", "hier"],
        help="aggregation engine: sync (one barrier per round), async "
        "(FedBuff-style buffered, --async-buffer arrivals per aggregate), "
        "hier (two-tier edge→cloud; --edges/--edge-rounds)",
    )
    ap.add_argument(
        "--sim-profile", type=str, default=None,
        help="client system-profile fleet for virtual-clock pricing: "
        "uniform | straggler[:FRAC[,SLOWDOWN]] | lognormal[:SIGMA] "
        "(optionally prefixed dropout:P,).  Implied 'uniform' for the "
        "async/hier engines; omit entirely for the plain sync engine",
    )
    ap.add_argument(
        "--async-buffer", type=int, default=None,
        help="async engine: aggregate every K arrivals (default: #clients)",
    )
    ap.add_argument(
        "--staleness-power", type=float, default=0.5,
        help="async engine: staleness discount (1+s)^-p on stale updates",
    )
    ap.add_argument("--edges", type=int, default=2,
                    help="hier engine: number of edge servers")
    ap.add_argument("--edge-rounds", type=int, default=1,
                    help="hier engine: local rounds per cloud round")
    ap.add_argument(
        "--edge-wire-codec", type=str, default=None,
        help="hier engine: codec for the edge→cloud hop (default: "
        "--wire-codec)",
    )
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--tau", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", type=str, default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)
    if args.arch:
        args.preset = None

    cfg = build_cfg(args)
    if args.kernels != cfg.kernels:
        cfg = dataclasses.replace(cfg, kernels=args.kernels)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model={cfg.name} params={n_params/1e6:.1f}M clients={args.clients}")

    # data: Markov stream with planted low-rank transitions → real loss floor
    tokens = make_token_stream(
        vocab_size=cfg.vocab_size, num_tokens=args.clients * 200_000 // 1,
        rank=16, seed=args.seed,
    )
    T = args.seq
    windows = np.lib.stride_tricks.sliding_window_view(tokens, T + 1)[:: T // 2]
    parts = partition_iid(len(windows), args.clients, seed=args.seed)
    batcher = FederatedBatcher(
        {"tokens": windows}, parts, batch_size=args.batch, seed=args.seed
    )

    fc = FedConfig(
        num_clients=args.clients, s_star=args.local_steps, lr=args.lr,
        correction=args.correction if args.method == "fedlrt" else "none",
        tau=args.tau,
    )
    participation = Participation.from_spec(args.participation, seed=args.seed)
    client_weights = partition_sizes(parts) if args.weighted else None
    if args.engine != "sync" or args.sim_profile is not None:
        from repro.fed.sim import make_sim_engine

        # participation and checkpointing always pass through: engines
        # that can't honor them refuse loudly instead of dropping them
        kw = dict(
            sim_profile=args.sim_profile, seed=args.seed,
            method=args.method, wire_codec=args.wire_codec,
            client_weights=client_weights,
            participation=participation,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=20 if args.checkpoint_dir else 0,
        )
        if args.engine == "async":
            kw.update(
                buffer_size=args.async_buffer,
                staleness_power=args.staleness_power,
            )
        elif args.engine == "hier":
            kw.update(
                num_edges=args.edges, edge_rounds=args.edge_rounds,
                edge_wire_codec=args.edge_wire_codec,
            )
        eng = make_sim_engine(args.engine, model.loss_fn, params, fc, **kw)
    else:
        eng = FederatedEngine(
            model.loss_fn, params, fc, method=args.method,
            participation=participation,
            client_weights=client_weights,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=20 if args.checkpoint_dir else 0,
            wire_codec=args.wire_codec,
        )
    hist = eng.train(batcher, args.rounds, log_every=args.log_every)
    mean_cohort = np.mean([r.cohort_size for r in hist])
    timing = (
        f"; virtual time {hist[-1].t_virtual:.1f}s [{args.engine}]"
        if hist[-1].t_virtual else ""
    )
    analytic = (
        f" vs {eng.comm_total_bytes_analytic()/1e6:.1f} MB analytic"
        if hasattr(eng, "comm_total_bytes_analytic") else ""
    )
    print(
        f"done: loss {hist[0].loss_before:.4f} → {hist[-1].loss_before:.4f}; "
        f"total comm {eng.comm_total_bytes()/1e6:.1f} MB measured "
        f"[{args.wire_codec}]{analytic} (mean cohort {mean_cohort:.1f}/"
        f"{args.clients}){timing}"
    )
    return hist


if __name__ == "__main__":
    main()
