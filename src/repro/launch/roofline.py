"""Roofline-term derivation from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_wire_bytes_per_device / (links · link_bw)

``cost_analysis`` supplies FLOPs and bytes (already per-partition for SPMD
modules).  Collective bytes are NOT in cost_analysis: we parse the
optimized HLO text and apply the standard ring-cost model per collective
kind (sizes are the per-device shard sizes printed in SPMD HLO).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI (per direction, ~3 usable links/chip on a 2-D torus;
we report per-link seconds with links=1 so the term is conservative).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|f8\w*|s\d+|u\d+|c\d+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        key = dt if dt in _DTYPE_BYTES else ("f8" if dt.startswith("f8") else dt)
        total += n * _DTYPE_BYTES.get(key, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring cost model).

    SPMD HLO shapes are per-partition.  Wire cost per device:
      all-reduce       2·S·(n-1)/n ≈ 2·S     (S = result shard size)
      all-gather       S_out·(n-1)/n ≈ S_out (result = gathered shard)
      reduce-scatter   S_in·(n-1)/n ≈ S_in   (operand = pre-scatter shard)
      all-to-all       S·(n-1)/n ≈ S
      collective-permute  S
    We approximate (n-1)/n ≈ 1 (n ≥ 16 on the assigned meshes).
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["total"] = 0.0
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        result_shape, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # count the -start only
        size = _shape_bytes(result_shape)
        if kind == "all-reduce":
            wire = 2.0 * size
        else:
            wire = float(size)
        out[kind] += wire
        out["total"] += wire
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, float]

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "collectives": self.collectives,
        }


def from_compiled(compiled) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax < 0.5 returns [dict] per device
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll["total"],
        collectives={k: v for k, v in coll.items() if k != "total"},
    )


def model_flops(cfg, tokens: int, *, backward: bool) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), N = active params.

    Uses the *factorized* parameter count when low-rank is enabled — the
    useful work of the compressed model."""
    from repro.models import build_model
    import jax

    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k)[0], jax.ShapeDtypeStruct((2,), "uint32"))

    def leaf_params(path, leaf):
        name = jax.tree_util.keystr(path)
        size = 1
        for d in leaf.shape:
            size *= d
        if "moe" in name and ("'up'" in name or "'down'" in name or "'gate'" in name) \
                and "shared" not in name:
            # routed experts: only top_k/E of them are active per token
            size = size * cfg.moe.top_k // cfg.moe.num_experts
        return size

    import jax.tree_util as jtu
    total = sum(
        leaf_params(p, l) for p, l in jtu.tree_leaves_with_path(shapes)
        if hasattr(l, "shape")
    )
    mult = 6.0 if backward else 2.0
    return mult * total * tokens
