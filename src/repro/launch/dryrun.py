import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --------------------------------------------------------------------------
# Multi-pod dry-run (deliverable e): lower + compile every
# (architecture × input shape) on the production meshes, print
# memory_analysis / cost_analysis, and persist the roofline terms.
#
# The XLA_FLAGS line above MUST run before any other import — jax locks the
# device count at first initialization.  Do not move it; do not set this
# flag anywhere global (tests/benches must see the single real CPU device).
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
#   python -m repro.launch.dryrun --arch qwen2-7b --shape decode_32k --multi-pod
#   python -m repro.launch.dryrun --all            # subprocess per combo
# --------------------------------------------------------------------------

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ALIASES, get_config  # noqa: E402
from repro.core import FedConfig  # noqa: E402
from repro.core.fedlrt import fedlrt_round  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import data_axis_size, make_production_mesh  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    SHAPES,
    decode_specs,
    prefill_specs,
    shape_applies,
    train_specs,
)
from repro.models import build_model, sharding  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def param_shapes_and_specs(model):
    """Abstract init: ShapeDtypeStructs for params + the static spec tree."""
    box = {}

    def f(k):
        p, s = model.init(k)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return shapes, box["specs"]


def _named(mesh, spec_tree):
    from jax.sharding import PartitionSpec as P

    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool, s_star: int = 4,
                correction: str = "simplified", overrides=None,
                method: str = "fedlrt"):
    cfg = get_config(arch)
    if method in ("fedlin", "fedavg"):
        # dense baseline: same model, low-rank factorization disabled
        from repro.models.config import LowRankPolicy

        cfg = dataclasses.replace(cfg, lowrank=LowRankPolicy(enable=False))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applies(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    sharding.enable(mesh)
    model = build_model(cfg)
    pshapes, pspecs = param_shapes_and_specs(model)
    from repro.launch.specs import sanitize_specs

    pspecs = sanitize_specs(mesh, pshapes, pspecs)
    pshard = _named(mesh, pspecs)

    t0 = time.time()
    if shape.kind == "train":
        C = data_axis_size(mesh)
        bstructs, bspecs = train_specs(cfg, shape, C, mesh)
        # repro-lint: disable=RPL002 -- offline lowering probe: builds a
        # throwaway FedConfig purely to trace shapes/HLO, never to run a
        # scenario (no data, no engine, nothing to spec-hash)
        fc = FedConfig(
            num_clients=C, s_star=s_star, lr=1e-2, correction=correction,
            tau=0.01, eval_after=False,
        )

        from repro.launch.specs import _batch_axes

        sharding.set_client_mode(True)  # client dim owns the data axes

        if method == "fedlrt":
            def step(params, batch):
                return fedlrt_round(
                    model.loss_fn, params, batch, fc, spec_tree=pspecs,
                    client_axes=_batch_axes(mesh),
                )
        else:
            from repro.core.baselines import fedavg_round, fedlin_round

            base_fn = fedlin_round if method == "fedlin" else fedavg_round

            def step(params, batch):
                return base_fn(model.loss_fn, params, batch, fc)

        lowered = jax.jit(
            step,
            in_shardings=(pshard, _named(mesh, bspecs)),
            out_shardings=(pshard, None),
        ).lower(pshapes, bstructs)
    elif shape.kind == "prefill":
        bstructs, bspecs = prefill_specs(cfg, shape, mesh)

        def step(params, batch):
            return model.serve_prefill(params, batch, cache_len=shape.seq_len)

        lowered = jax.jit(
            step, in_shardings=(pshard, _named(mesh, bspecs))
        ).lower(pshapes, bstructs)
    else:  # decode
        (cstructs, tokens), (cspecs, tok_spec) = decode_specs(cfg, model, shape, mesh)
        lowered = jax.jit(
            model.serve_step,
            in_shardings=(pshard, _named(mesh, cspecs),
                          jax.sharding.NamedSharding(mesh, tok_spec)),
        ).lower(pshapes, cstructs, tokens)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    roof = rl.from_compiled(compiled)
    tokens_total = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1
    )
    mflops = rl.model_flops(cfg, tokens_total, backward=(shape.kind == "train"))
    if shape.kind == "train":
        # the FeDLRT round does (1 basis-grad + s_star coeff) fwd+bwd passes
        mflops = mflops * (1 + s_star)
    n_dev = mesh.devices.size
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(n_dev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "roofline": roof.to_dict(),
        "model_flops_total": mflops,
        "model_flops_per_device": mflops / n_dev,
        "useful_flops_ratio": (
            (mflops / n_dev) / roof.flops_per_device
            if roof.flops_per_device else None
        ),
    }


def run_one(args):
    res = lower_combo(
        args.arch, args.shape, multi_pod=args.multi_pod, s_star=args.s_star,
        correction=args.correction, method=args.method,
    )
    res["method"] = args.method
    outdir = os.path.abspath(args.out or RESULTS_DIR)
    os.makedirs(outdir, exist_ok=True)
    suffix = "" if args.method == "fedlrt" else f"__{args.method}"
    tag = f"{res.get('mesh', 'skip')}__{args.arch}__{args.shape}{suffix}.json"
    with open(os.path.join(outdir, tag), "w") as f:
        json.dump(res, f, indent=2)
    if "skipped" in res:
        print(f"SKIP  {args.arch} × {args.shape}: {res['skipped']}")
        return
    r = res["roofline"]
    print(
        f"OK    {args.arch} × {args.shape} [{res['mesh']}] "
        f"compile={res['compile_s']}s "
        f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
        f"collective={r['collective_s']*1e3:.2f}ms dominant={r['dominant']} "
        f"temp={res['memory']['temp_bytes']/2**30:.2f}GiB/dev"
    )


def run_all(args):
    combos = []
    for arch in ALIASES:
        for shape in SHAPES:
            combos.append((arch, shape, False))
            if args.multi_pod_all:
                combos.append((arch, shape, True))
    failures = []
    for arch, shape, mp in combos:
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape,
        ] + (["--multi-pod"] if mp else []) + (
            ["--out", args.out] if args.out else []
        )
        t0 = time.time()
        p = subprocess.run(cmd, capture_output=True, text=True,
                           env=dict(os.environ, PYTHONPATH="src"))
        sys.stdout.write(p.stdout)
        if p.returncode != 0:
            failures.append((arch, shape, mp))
            print(f"FAIL  {arch} × {shape} mp={mp} ({time.time()-t0:.0f}s)")
            sys.stderr.write(p.stderr[-2000:])
    print(f"\n{len(combos) - len(failures)}/{len(combos)} combos OK")
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser(description="FeDLRT multi-pod dry-run")
    ap.add_argument("--arch", type=str, default="qwen2-7b")
    ap.add_argument("--shape", type=str, default="train_4k", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--multi-pod-all", action="store_true")
    ap.add_argument("--s-star", type=int, default=4)
    ap.add_argument("--correction", type=str, default="simplified")
    ap.add_argument(
        "--method", type=str, default="fedlrt",
        choices=["fedlrt", "fedlin", "fedavg"],
        help="fedlin/fedavg lower the dense full-rank baseline round",
    )
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all(args))
    run_one(args)


if __name__ == "__main__":
    main()
