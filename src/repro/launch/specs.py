"""Input ShapeDtypeStructs + shardings for every (arch × input shape).

``input_specs`` returns weak-type-correct, shardable stand-ins — no device
allocation — for each of the four assigned shapes:

  train_4k     seq 4,096   global_batch 256   → FeDLRT train round
  prefill_32k  seq 32,768  global_batch 32    → serve_prefill
  decode_32k   seq 32,768  global_batch 128   → serve_step (1 new token,
                                                 cache of 32k)
  long_500k    seq 524,288 global_batch 1     → serve_step (sub-quadratic
                                                 archs only; see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def sanitize_specs(mesh, shapes, specs):
    """Drop sharding on dims the mesh doesn't divide (GSPMD in_shardings
    require exact divisibility — e.g. whisper's vocab 51866 on model=16)."""

    def fix(spec: P, s) -> P:
        dims = s.shape
        out = []
        for i, ax in enumerate(spec):
            if ax is None or i >= len(dims):
                out.append(None if i >= len(dims) else ax)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            out.append(ax if dims[i] % n == 0 else None)
        return P(*out)

    return jax.tree.map(
        fix, specs, shapes, is_leaf=lambda x: isinstance(x, P)
    )


def shape_applies(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(applies, reason-if-not).  The documented skips of DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k decode requires sub-quadratic mixer"
    if cfg.is_encdec and shape.name == "long_500k":
        return False, "enc-dec decoder is full-attention (448-token design)"
    return True, ""


def _extra_inputs(cfg: ModelConfig, B: int, batch_axes) -> Dict[str, Any]:
    """Stub-frontend embeddings (the one sanctioned stub)."""
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        out["vision_embeds"] = (
            SDS((B, cfg.vision_tokens, cfg.d_model), jnp.float32),
            P(batch_axes, None, None),
        )
    if cfg.family == "audio":
        out["frames"] = (
            SDS((B, cfg.encoder.num_frames, cfg.d_model), jnp.float32),
            P(batch_axes, None, None),
        )
    return out


def _batch_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def train_specs(cfg: ModelConfig, shape: InputShape, num_clients: int, mesh=None):
    """Client-batched LM batch: tokens (C, B, T+1)."""
    assert shape.global_batch % num_clients == 0
    B = shape.global_batch // num_clients
    T = shape.seq_len
    clients = _batch_axes(mesh) if mesh is not None else ("data",)
    batch = {
        "tokens": (SDS((num_clients, B, T + 1), jnp.int32), P(clients, None, None))
    }
    for k, (s, spec) in _extra_inputs(cfg, B, None).items():
        batch[k] = (
            SDS((num_clients,) + s.shape, s.dtype),
            P(clients, *spec[1:] if len(spec) > 1 else ()),
        )
    # text tokens shrink so vision/audio prefix keeps total seq at T
    if cfg.family == "vlm":
        batch["tokens"] = (
            SDS((num_clients, B, T - cfg.vision_tokens + 1), jnp.int32),
            P(clients, None, None),
        )
    structs = {k: v[0] for k, v in batch.items()}
    specs = {k: v[1] for k, v in batch.items()}
    return structs, specs


def prefill_specs(cfg: ModelConfig, shape: InputShape, mesh=None):
    B, T = shape.global_batch, shape.seq_len
    batch_ax = _batch_axes(mesh) if mesh is not None else ("data",)
    items = {"tokens": (SDS((B, T), jnp.int32), P(batch_ax, None))}
    if cfg.family == "vlm":
        items["tokens"] = (
            SDS((B, T - cfg.vision_tokens), jnp.int32), P(batch_ax, None)
        )
    items.update(_extra_inputs(cfg, B, batch_ax))
    structs = {k: v[0] for k, v in items.items()}
    specs = {k: v[1] for k, v in items.items()}
    return structs, specs


def cache_specs(cfg: ModelConfig, model, B: int, cache_len: int, mesh) -> Tuple[Any, Any]:
    """ShapeDtypeStructs + shardings for the decode cache."""
    from repro.launch.mesh import data_axis_size

    structs = jax.eval_shape(lambda: model.init_cache(None, B, cache_len))
    dsize = data_axis_size(mesh)
    batch_ax = _batch_axes(mesh)
    shard_seq = B < dsize  # long_500k: B=1 → shard the cache sequence dim

    msize = mesh.shape["model"]

    def fit(dim: int, axis):
        """Only shard divisible dims (GSPMD in_shardings require it)."""
        if axis is None:
            return None
        n = dsize if axis == batch_ax else msize
        return axis if dim % n == 0 else None

    def spec_for(path, s) -> P:
        name = jax.tree_util.keystr(path)
        nd = len(s.shape)
        bax = None if shard_seq else batch_ax
        if "'k'" in name or "'v'" in name:
            # (NB, B, S, Hkv, hd): prefer kv-head sharding; small-GQA archs
            # (kv < model size) shard head_dim instead; long_500k shards S.
            kv_ax = fit(s.shape[3], "model")
            hd_ax = fit(s.shape[4], "model") if kv_ax is None else None
            seq_ax = batch_ax if shard_seq else None
            return P(None, fit(s.shape[1], bax), seq_ax, kv_ax, hd_ax)
        if "'S'" in name:  # rwkv state (NB, B, H, hd, hd)
            return P(None, fit(s.shape[1], bax), fit(s.shape[2], "model"), None, None)
        if "'h'" in name and nd == 4:  # mamba (NB, B, d_inner, N)
            return P(None, fit(s.shape[1], bax), fit(s.shape[2], "model"), None)
        if "'conv'" in name:  # (NB, B, K-1, d_inner)
            return P(None, fit(s.shape[1], bax), None, fit(s.shape[3], "model"))
        if "'shift'" in name:  # (NB, B, 1, d)
            return P(None, fit(s.shape[1], bax), None, None)
        if "enc_h" in name:  # (B, F, d)
            return P(fit(s.shape[0], bax), None, None)
        return P()  # idx / pos scalars

    specs = jax.tree_util.tree_map_with_path(spec_for, structs)
    return structs, specs


def decode_specs(cfg: ModelConfig, model, shape: InputShape, mesh):
    from repro.launch.mesh import data_axis_size

    B = shape.global_batch
    dsize = data_axis_size(mesh)
    batch_ax = _batch_axes(mesh)
    tok_spec = P(batch_ax, None) if B >= dsize else P(None, None)
    cache_len = shape.seq_len if not cfg.sliding_window else min(
        shape.seq_len, cfg.sliding_window
    )
    cstructs, cspecs = cache_specs(cfg, model, B, cache_len, mesh)
    tokens = SDS((B, 1), jnp.int32)
    return (cstructs, tokens), (cspecs, tok_spec)
