from repro.fed.engine import (  # noqa: F401
    ROUND_METHODS,
    FederatedEngine,
    RoundResult,
    register_round_method,
    round_program_for,
)
from repro.fed.participation import Participation  # noqa: F401
from repro.fed.wire import (  # noqa: F401
    CODEC_SPECS,
    DowncastCodec,
    IdentityCodec,
    Int8AffineCodec,
    Payload,
    TopKRankCodec,
    Wire,
    WireCodec,
    WireMsg,
    make_codec,
)
