from repro.fed.engine import FederatedEngine, RoundResult  # noqa: F401
from repro.fed.participation import Participation  # noqa: F401
from repro.fed.wire import (  # noqa: F401
    CODEC_SPECS,
    DowncastCodec,
    IdentityCodec,
    Int8AffineCodec,
    Payload,
    TopKRankCodec,
    Wire,
    WireCodec,
    WireMsg,
    make_codec,
)
