from repro.fed.engine import FederatedEngine, RoundResult  # noqa: F401
from repro.fed.participation import Participation  # noqa: F401
