from repro.fed.engine import FederatedEngine, RoundResult  # noqa: F401
