"""The wire layer: typed round payloads, pluggable codecs, measured bytes.

FeDLRT's headline claim is an order-of-magnitude cut in *communication*,
yet a simulated round passes raw pytrees between phases — nothing in the
code represents what actually crosses the server↔client wire.  This module
makes the exchange explicit:

- :class:`Payload` — a typed unit of transmission: a pytree of named
  tensors plus static metadata (payload name, whether it carries a leading
  client axis).
- :class:`WireCodec` — the protocol every wire format implements:
  ``encode(Payload) -> WireMsg``, ``decode(WireMsg) -> Payload``,
  ``nbytes(WireMsg) -> bytes on the wire``.
- :class:`Wire` — the engine-owned object that round runners thread
  payloads through (:func:`repro.core.round.run_round` round-trips every
  phase-boundary payload and reports measured bytes in the round metrics).

Codecs (see :func:`make_codec` for the spec strings):

==============  =========  =================================================
codec           lossy?     on-wire representation
==============  =========  =================================================
``identity``    no         tensors as-is (bytes = size × itemsize)
``downcast``    ~eps       floats as bf16/f16 on the wire, f32 at rest
``int8_affine`` bounded    per-tensor affine int8 + f32 dequant (lo, scale)
``topk_rank``   no         factor leaves priced at their *effective* rank —
                           only the leading-σ slice is transmitted; the
                           zero-inactive-columns invariant makes the
                           zero-padded reconstruction exact
==============  =========  =================================================

Everything here runs inside the jitted round: encode/decode are traced jax
ops and ``nbytes`` is a python int when shapes determine it (identity /
downcast / int8) or a traced scalar when it depends on the dynamic rank
(topk_rank) — either way it flows out through the round metrics.

Compression applies only to leaves that can absorb it: floating-point
tensors with at least :data:`MIN_COMPRESS_ELEMS` elements per client slice.
Small vectors, scalars (losses, drift, the factor ``rank`` counter) and
integer tensors always travel verbatim, so codec error never corrupts
bookkeeping state.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Protocol, Union, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.factorization import (
    AugmentedFactor,
    LowRankFactor,
    augmented_mask,
    is_factor,
    mask_coeff,
    rank_mask,
)

Array = jax.Array
Bytes = Union[int, float, Array]  # static count, or traced (rank-dependent)

#: leaves below this many elements (per client slice) always pass verbatim
MIN_COMPRESS_ELEMS = 64


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["tensors"],
    meta_fields=["name", "batched"],
)
@dataclasses.dataclass(frozen=True)
class Payload:
    """One direction's worth of round traffic.

    ``tensors`` is an arbitrary pytree of arrays (factor leaves allowed);
    ``name`` identifies the protocol message (``broadcast`` /
    ``per_client`` / ``client_out``); ``batched`` marks a leading client
    axis ``C`` — per-client codecs then keep statistics per slice, and
    per-client byte counts divide the total by ``C``.
    """

    tensors: Any
    name: str = "payload"
    batched: bool = False


@dataclasses.dataclass(frozen=True)
class WireMsg:
    """An encoded :class:`Payload`: what would actually be transmitted.

    ``buffers`` mirrors the payload structure with on-wire tensors (possibly
    downcast / quantized), ``aux`` carries decode-side metadata (original
    dtypes, dequant scales), and ``nbytes`` is the measured wire size —
    already accounting for the aux data a real serialization would ship.
    """

    buffers: Any
    aux: Any
    name: str
    batched: bool
    nbytes: Bytes


@runtime_checkable
class WireCodec(Protocol):
    """Wire format: how a payload is serialized and how big it is."""

    name: str

    def encode(self, payload: Payload) -> WireMsg:
        ...

    def decode(self, msg: WireMsg) -> Payload:
        ...

    def nbytes(self, msg: WireMsg) -> Bytes:
        ...


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _slice_elems(x, batched: bool) -> int:
    """Element count per client slice (drop the leading C axis if batched)."""
    shape = x.shape[1:] if batched and x.ndim >= 1 else x.shape
    return int(math.prod(shape))


def _compressible(x, batched: bool) -> bool:
    return (
        jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
        and _slice_elems(x, batched) >= MIN_COMPRESS_ELEMS
    )


def payload_nbytes(tree) -> int:
    """Verbatim (identity-codec) wire size of a payload pytree in bytes."""
    return int(
        sum(x.size * jnp.asarray(x).dtype.itemsize for x in jax.tree.leaves(tree))
    )


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


class IdentityCodec:
    """Tensors travel verbatim; the reference point every codec is measured
    against (and the engine default — *measured* accounting, zero loss)."""

    name = "identity"

    def encode(self, payload: Payload) -> WireMsg:
        return WireMsg(
            buffers=payload.tensors,
            aux=None,
            name=payload.name,
            batched=payload.batched,
            nbytes=payload_nbytes(payload.tensors),
        )

    def decode(self, msg: WireMsg) -> Payload:
        return Payload(tensors=msg.buffers, name=msg.name, batched=msg.batched)

    def nbytes(self, msg: WireMsg) -> Bytes:
        return msg.nbytes


class DowncastCodec:
    """Floats cross the wire at a narrower dtype, are restored to the rest
    dtype on arrival (Konečný et al.'s simplest structured update)."""

    def __init__(self, wire_dtype=jnp.bfloat16):
        self.wire_dtype = jnp.dtype(wire_dtype)
        self.name = f"downcast:{self.wire_dtype.name}"

    def encode(self, payload: Payload) -> WireMsg:
        wire_dt, batched = self.wire_dtype, payload.batched

        def enc(x):
            if _compressible(x, batched) and jnp.asarray(x).dtype.itemsize > wire_dt.itemsize:
                return x.astype(wire_dt)
            return x

        dtypes = jax.tree.map(lambda x: jnp.asarray(x).dtype, payload.tensors)
        buffers = jax.tree.map(enc, payload.tensors)
        return WireMsg(
            buffers=buffers,
            aux=dtypes,
            name=payload.name,
            batched=batched,
            nbytes=payload_nbytes(buffers),
        )

    def decode(self, msg: WireMsg) -> Payload:
        tensors = jax.tree.map(lambda x, dt: x.astype(dt), msg.buffers, msg.aux)
        return Payload(tensors=tensors, name=msg.name, batched=msg.batched)

    def nbytes(self, msg: WireMsg) -> Bytes:
        return msg.nbytes


class Int8AffineCodec:
    """Per-tensor affine int8 quantization with f32 dequant scales.

    ``q = round((x − lo)/scale) − 128`` with ``scale = (hi − lo)/255`` so
    the absolute dequantization error is bounded by ``scale/2`` per element.
    Batched payloads keep (lo, scale) per client slice — each client
    quantizes its own upload, the server its own broadcast.  The 8 bytes of
    (lo, scale) per transmitted tensor are charged to ``nbytes``.
    """

    name = "int8_affine"

    def encode(self, payload: Payload) -> WireMsg:
        # flat-leaf processing: payload trees may contain tuples/None nodes
        # of their own, so aux rides as a leaf-aligned list, not a pytree
        leaves, treedef = jax.tree.flatten(payload.tensors)
        batched = payload.batched
        nbytes = 0
        out, aux = [], []
        for x in leaves:
            if not _compressible(x, batched):
                nbytes += x.size * jnp.asarray(x).dtype.itemsize
                out.append(x)
                aux.append(None)
                continue
            axes = tuple(range(1 if batched else 0, x.ndim))
            lo = jnp.min(x, axis=axes, keepdims=True)
            hi = jnp.max(x, axis=axes, keepdims=True)
            scale = jnp.maximum((hi - lo) / 255.0, jnp.finfo(jnp.float32).tiny)
            q = jnp.clip(jnp.round((x - lo) / scale) - 128.0, -128, 127)
            out.append(q.astype(jnp.int8))
            aux.append((lo.astype(jnp.float32), scale.astype(jnp.float32), x.dtype))
            nbytes += x.size  # int8 payload …
            nbytes += 2 * 4 * lo.size  # … + f32 (lo, scale) per tensor/slice
        return WireMsg(
            buffers=treedef.unflatten(out), aux=aux,
            name=payload.name, batched=batched, nbytes=nbytes,
        )

    def decode(self, msg: WireMsg) -> Payload:
        leaves, treedef = jax.tree.flatten(msg.buffers)
        out = []
        for q, a in zip(leaves, msg.aux):
            if a is None:
                out.append(q)
            else:
                lo, scale, dtype = a
                out.append(((q.astype(jnp.float32) + 128.0) * scale + lo).astype(dtype))
        return Payload(tensors=treedef.unflatten(out), name=msg.name, batched=msg.batched)

    def nbytes(self, msg: WireMsg) -> Bytes:
        return msg.nbytes


class TopKRankCodec:
    """Transmit only the leading-σ slice of factor leaves.

    The factor invariant (coefficients zero outside the active block, basis
    columns beyond ``rank`` exactly zero) means a sender that ships only
    the first ``rank`` columns of U/V (for an :class:`AugmentedFactor`, the
    ``2·rank`` active columns) and the active coefficient block loses
    nothing: the receiver zero-pads back to the static buffer and recovers
    the tensors bit-for-bit.  The simulation therefore keeps full buffers
    (re-masked for safety) and *meters* the effective bytes, which track
    the adaptive rank downward — ``nbytes`` is a traced scalar.

    Non-factor leaves travel verbatim, so the savings concentrate on the
    dominant O(n·r) basis broadcast.
    """

    name = "topk_rank"

    def encode(self, payload: Payload) -> WireMsg:
        nbytes: Bytes = 0

        def enc(x):
            nonlocal nbytes
            if isinstance(x, AugmentedFactor):
                m = augmented_mask(x.rank, x.r_max, dtype=x.U.dtype)
                masked = dataclasses.replace(
                    x, U=x.U * m[..., None, :], V=x.V * m[..., None, :],
                    S=mask_coeff(x.S, m),
                )
                cols = 2.0 * x.rank.astype(jnp.float32)  # active directions
            elif isinstance(x, LowRankFactor):
                m = rank_mask(x.rank, x.r_max, dtype=x.U.dtype)
                masked = dataclasses.replace(
                    x, U=x.U * m[..., None, :], V=x.V * m[..., None, :],
                    S=mask_coeff(x.S, m),
                )
                cols = x.rank.astype(jnp.float32)
            else:
                nbytes = nbytes + payload_nbytes(x)
                return x
            itemsize = jnp.asarray(x.U).dtype.itemsize
            per_slice = (x.U.shape[-2] + x.V.shape[-2]) * cols + cols * cols
            nbytes = nbytes + itemsize * jnp.sum(per_slice)
            nbytes = nbytes + 4 * x.rank.size  # the rank counter itself
            return masked

        buffers = jax.tree.map(enc, payload.tensors, is_leaf=is_factor)
        return WireMsg(
            buffers=buffers,
            aux=None,
            name=payload.name,
            batched=payload.batched,
            nbytes=nbytes,
        )

    def decode(self, msg: WireMsg) -> Payload:
        return Payload(tensors=msg.buffers, name=msg.name, batched=msg.batched)

    def nbytes(self, msg: WireMsg) -> Bytes:
        return msg.nbytes


_CODECS = {
    "identity": IdentityCodec,
    "downcast": DowncastCodec,
    "int8_affine": Int8AffineCodec,
    "topk_rank": TopKRankCodec,
}

CODEC_SPECS = ("identity", "downcast", "downcast:float16", "int8_affine", "topk_rank")


def make_codec(spec: Union[str, WireCodec]) -> WireCodec:
    """Build a codec from a spec string: ``identity`` | ``downcast[:dtype]``
    | ``int8_affine`` | ``topk_rank`` (an already-built codec passes
    through)."""
    if not isinstance(spec, str):
        return spec
    kind, _, arg = spec.partition(":")
    if kind not in _CODECS:
        raise ValueError(
            f"unknown wire codec {spec!r}; expected one of {sorted(_CODECS)}"
        )
    if kind == "downcast":
        return DowncastCodec(jnp.dtype(arg)) if arg else DowncastCodec()
    if arg:
        raise ValueError(f"codec {kind!r} takes no argument, got {spec!r}")
    return _CODECS[kind]()


# ---------------------------------------------------------------------------
# the wire itself
# ---------------------------------------------------------------------------


class Wire:
    """A codec bound to the server↔client boundary.

    :func:`repro.core.round.run_round` threads every phase-boundary payload
    through :meth:`roundtrip`; the engine owns one Wire per run and reads
    the measured per-direction bytes back out of the round metrics.  The
    Wire is stateless across rounds, so one instance serves every cached
    executable.
    """

    def __init__(self, codec: Union[str, WireCodec] = "identity", telemetry=None):
        self.codec = make_codec(codec)
        # optional TelemetryHub: host-side roundtrips (the hier engine's
        # edge↔cloud hop) emit encode/decode spans tagged with measured
        # nbytes.  Traced roundtrips (inside a jitted round) skip
        # instrumentation — a span there would fire at trace time only and
        # its nbytes may be a tracer; the engines publish those bytes from
        # the round metrics instead.
        self.telemetry = telemetry

    @property
    def name(self) -> str:
        return self.codec.name

    def roundtrip(self, tree, *, name: str = "payload", batched: bool = False):
        """Encode→decode ``tree`` through the codec.

        Returns ``(decoded_tree, nbytes)`` — what the receiver sees, and
        what the transmission measured.  ``None`` payloads (a program with
        no per-client downlink) cost nothing and stay ``None``.
        """
        if tree is None:
            return None, 0
        hub = self.telemetry
        if hub is not None and hub.enabled and not any(
            isinstance(x, jax.core.Tracer) for x in jax.tree.leaves(tree)
        ):
            with hub.span(f"wire.{self.codec.name}.encode", payload=name):
                msg = self.codec.encode(
                    Payload(tensors=tree, name=name, batched=batched)
                )
            with hub.span(f"wire.{self.codec.name}.decode", payload=name):
                decoded = self.codec.decode(msg).tensors
            nbytes = self.codec.nbytes(msg)
            hub.counter(
                f"wire.{self.codec.name}.bytes",
                float(jnp.asarray(nbytes)), payload=name,
            )
            return decoded, nbytes
        msg = self.codec.encode(Payload(tensors=tree, name=name, batched=batched))
        return self.codec.decode(msg).tensors, self.codec.nbytes(msg)

    def __repr__(self):
        return f"Wire(codec={self.codec.name!r})"
