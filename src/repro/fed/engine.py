"""Federated training engine: multi-round driver over any round function.

Wires together a model loss, a data pipeline (:class:`FederatedBatcher`),
a round method (FeDLRT / FedAvg / FedLin / naive low-rank), a per-round
:class:`repro.fed.participation.Participation` policy and optional
checkpointing into a restartable driver.  The round function itself stays
pure/jitted; the engine owns the host-side loop, cohort selection, metric
history, and eval.

Partial participation: the engine asks the participation policy for the
active cohort each round, pulls a cohort-shaped batch from the batcher,
and dispatches to a jitted step *cached per cohort size* (batch shapes —
and therefore executables — depend only on ``k``, so a C=64 run with
uniform-8 sampling compiles exactly one extra executable).  ``dropout``
mode — the one policy with a fluctuating cohort size — is *cohort-padded*:
every round's batch is padded up to the population size with zero-weight
repeats of active clients, so the whole run shares a single executable
(see :meth:`FederatedEngine.run_round`).  Weighted aggregation
(``client_weights`` ∝ |X_c|) is threaded per cohort as a traced argument,
so re-weighting never recompiles.

The wire: the engine owns a :class:`repro.fed.wire.Wire` (``wire_codec``,
default ``"identity"``) and threads it through every round's phase
boundaries, so the server↔client payloads are explicit, optionally
compressed on the wire, and *measured* — :meth:`FederatedEngine.
comm_total_bytes` sums what the codec actually shipped, while the analytic
cost-model estimate stays available as :meth:`comm_total_bytes_analytic`.

Restartability: checkpoints carry ``round_idx`` and a sidecar snapshot of
the batcher stream state; :meth:`FederatedEngine.restore` resumes a run
that replays the remaining rounds bit-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, fedlrt_round
from repro.core.baselines import (
    FedAvgProgram,
    FedLinProgram,
    FedLRTNaiveProgram,
    fedavg_round,
    fedlin_round,
    fedlrt_naive_round,
)
from repro.core.fedlrt import FedLRTProgram
from repro.fed.participation import Participation
from repro.fed.wire import Wire
from repro.telemetry import default_hub
from repro.telemetry.clock import perf_seconds

#: round-method registry: name → round function.  Extend via
#: :func:`register_round_method`, never by editing this module — the sim
#: engines (and future scenario programs) plug in through the registry.
ROUND_METHODS: Dict[str, Callable] = {}

#: name → zero-arg factory of the method's :class:`RoundProgram` (for
#: engines that need phase-level access, e.g. the async simulator's
#: staleness-grouped execution).  ``None`` for methods registered without
#: a program (legacy monolithic round functions).
ROUND_PROGRAMS: Dict[str, Optional[Callable]] = {}


def register_round_method(name: str, fn: Callable, *, program=None, overwrite=False):
    """Register a federated round method under ``name``.

    ``fn`` is the round entry point with the standard signature
    ``(loss_fn, params, client_batches, cfg, *, round_idx, client_weights,
    wire) → (new_params, metrics)``.  ``program`` (optional) is a zero-arg
    factory returning the method's :class:`repro.core.round.RoundProgram`
    — required by engines that decompose rounds into phases (the async
    simulator).  Re-registration needs ``overwrite=True``.
    """
    if not overwrite and name in ROUND_METHODS:
        raise ValueError(
            f"round method {name!r} is already registered "
            f"(pass overwrite=True to replace it)"
        )
    ROUND_METHODS[name] = fn
    ROUND_PROGRAMS[name] = program


def round_program_for(method: str):
    """Instantiate the registered :class:`RoundProgram` for ``method``
    (raises for methods registered without one)."""
    factory = ROUND_PROGRAMS.get(method)
    if factory is None:
        raise ValueError(
            f"round method {method!r} has no registered RoundProgram; "
            f"register_round_method(..., program=...) to enable phase-level "
            f"engines"
        )
    return factory()


register_round_method("fedlrt", fedlrt_round, program=FedLRTProgram)
register_round_method("fedavg", fedavg_round, program=FedAvgProgram)
register_round_method("fedlin", fedlin_round, program=FedLinProgram)
register_round_method("fedlrt_naive", fedlrt_naive_round, program=FedLRTNaiveProgram)


@dataclasses.dataclass
class RoundResult:
    round_idx: int
    loss_before: float
    loss_after: Optional[float]
    comm_bytes_per_client: float
    ranks: Dict[str, np.ndarray]
    seconds: float
    cohort_size: int = 0
    cohort: Optional[np.ndarray] = None
    # effective-rank on-wire bytes (shrinks as truncation adapts ranks);
    # 0.0 for methods that don't report it (dense baselines)
    comm_bytes_per_client_effective: float = 0.0
    # *measured* wire-layer bytes (per client, per direction) — what the
    # round's codec actually put on the wire; see repro.fed.wire
    wire_bytes_down_per_client: float = 0.0
    wire_bytes_up_per_client: float = 0.0
    wire_codec: str = ""
    # virtual-clock timing (repro.fed.sim): how long the round took in
    # simulated seconds and the clock reading at its end; 0.0 when the run
    # is not priced through a system simulator.
    virtual_seconds: float = 0.0
    t_virtual: float = 0.0
    # mean staleness (server versions) of the aggregated contributions —
    # always 0.0 for synchronous rounds
    staleness_mean: float = 0.0


#: version tag of the checkpoint state sidecar.  v1: ``history`` is a list
#: of JSON-safe dicts (ints/floats/strs/lists/None only) instead of pickled
#: :class:`RoundResult` objects — pickles of the dataclass break whenever a
#: field is added/renamed (e.g. the sim timing fields), plain dicts don't.
STATE_VERSION = 1


def history_to_state(history: List[RoundResult]) -> List[dict]:
    """``history`` as JSON-safe dicts (the v1 sidecar representation)."""
    out = []
    for r in history:
        d = dataclasses.asdict(r)
        d["ranks"] = {k: np.asarray(v).tolist() for k, v in r.ranks.items()}
        d["cohort"] = None if r.cohort is None else np.asarray(r.cohort).tolist()
        out.append(d)
    return out


def history_from_state(rounds: List[dict]) -> List[RoundResult]:
    """Inverse of :func:`history_to_state`, tolerant of field drift: dict
    keys the current dataclass lacks are dropped, missing fields take the
    dataclass defaults — so a checkpoint written before a field was added
    (or after one is removed) still restores."""
    fields = {f.name for f in dataclasses.fields(RoundResult)}
    out = []
    for d in rounds:
        d = {k: v for k, v in d.items() if k in fields}
        if d.get("ranks") is not None:
            d["ranks"] = {k: np.asarray(v) for k, v in d["ranks"].items()}
        if d.get("cohort") is not None:
            d["cohort"] = np.asarray(d["cohort"])
        out.append(RoundResult(**d))
    return out


class FederatedEngine:
    def __init__(
        self,
        loss_fn: Callable,
        params,
        cfg: FedConfig,
        *,
        method: str = "fedlrt",
        participation: Optional[Participation] = None,
        eval_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        donate: bool = True,
        client_weights=None,
        wire_codec="identity",
        checkpoint_meta: Optional[dict] = None,
        telemetry=None,
    ):
        if method not in ROUND_METHODS:
            raise ValueError(f"method must be one of {list(ROUND_METHODS)}")
        self.cfg = cfg
        self.method = method
        self.params = params
        self.participation = (
            participation if participation is not None else Participation()
        )
        self.eval_fn = eval_fn
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        # extra metadata stamped into every checkpoint (e.g. the experiment
        # API's spec hash, so resume() can refuse a mismatched spec)
        self.checkpoint_meta = dict(checkpoint_meta) if checkpoint_meta else {}
        self.history: List[RoundResult] = []
        self.round_idx = 0
        self.client_weights = (
            None if client_weights is None else np.asarray(client_weights, np.float32)
        )
        # telemetry hub (repro.telemetry): the engine only ever *reads*
        # state into it, so instrumentation can never perturb a run.  The
        # default hub renders progress events to stdout and drops the rest.
        self.telemetry = telemetry if telemetry is not None else default_hub()
        self._loss_fn = loss_fn
        self._round_fn = ROUND_METHODS[method]
        self._donate = donate
        self._step_cache: Dict[tuple, Callable] = {}
        self._batcher = None  # set by train(); snapshotted into checkpoints
        # the wire: every round's data plane passes through it, so comm
        # accounting is *measured* (identity codec = verbatim bytes), not
        # estimated.  wire_codec=None opts out (raw pytrees, no metering).
        if wire_codec is None:
            self.wire: Optional[Wire] = None
        elif isinstance(wire_codec, Wire):
            self.wire = wire_codec
        else:
            self.wire = Wire(wire_codec)

    def _step_for(self, cohort_size: int, *, weighted: bool) -> Callable:
        """Jitted round step for an active cohort of ``cohort_size`` clients.

        One executable per (cohort size, weighted?) pair — batch shapes are
        k-dependent; ``round_idx`` and ``client_weights`` are traced
        arguments so they never trigger recompiles.
        """
        key = (cohort_size, weighted)
        step = self._step_cache.get(key)
        if step is None:
            cfg_k = dataclasses.replace(self.cfg, num_clients=cohort_size)
            round_fn, loss_fn, wire = self._round_fn, self._loss_fn, self.wire
            if weighted:
                def raw(p, b, r, w):
                    return round_fn(
                        loss_fn, p, b, cfg_k, round_idx=r, client_weights=w,
                        wire=wire,
                    )
            else:
                def raw(p, b, r):
                    return round_fn(loss_fn, p, b, cfg_k, round_idx=r, wire=wire)
            step = jax.jit(raw, donate_argnums=(0,) if self._donate else ())
            self._step_cache[key] = step
        return step

    def run_round(self, client_batches, *, cohort=None) -> RoundResult:
        """One aggregation round on ``client_batches`` (leading axis = the
        active cohort).  ``cohort`` (optional index array) attributes the
        batch rows to population clients — used to slice ``client_weights``
        and recorded in the history.

        Cohort padding: when the participation policy reports a fixed
        ``padded_size`` (dropout mode), the batch is padded up to it with
        repeats of active clients carrying **zero aggregation weight** —
        mathematically inert (every aggregate is weight-normalized) but
        shape-stable, so the whole run reuses a single jit executable
        instead of one per distinct cohort size.  Comm accounting and the
        recorded ``cohort_size`` stay at the *true* active-cohort size.
        """
        t0 = perf_seconds()
        k = jax.tree.leaves(client_batches)[0].shape[0]
        cohort = np.arange(k) if cohort is None else np.asarray(cohort)
        pad_to = self.participation.padded_size(self.cfg.num_clients)
        # one span over the jitted round step: broadcast → client_step →
        # aggregate → finalize all execute inside this dispatch (phase-level
        # spans for staleness groups live in the async engine, which runs
        # the phases separately)
        with self.telemetry.span(
            "round.step", round=int(self.round_idx), cohort=int(k)
        ):
            if pad_to is not None:
                w_active = (
                    np.asarray(self.client_weights[cohort], np.float32)
                    if self.client_weights is not None
                    else np.ones(k, np.float32)
                )
                if k < pad_to:
                    fill = np.arange(pad_to - k) % k  # repeat active clients
                    idx = np.concatenate([np.arange(k), fill])
                    client_batches = jax.tree.map(
                        lambda a: jnp.asarray(a)[idx], client_batches
                    )
                w = jnp.asarray(
                    np.concatenate([w_active, np.zeros(pad_to - k, np.float32)])
                )
                step = self._step_for(pad_to, weighted=True)
                self.params, metrics = step(
                    self.params, client_batches, jnp.int32(self.round_idx), w
                )
            elif self.client_weights is None:
                step = self._step_for(k, weighted=False)
                self.params, metrics = step(
                    self.params, client_batches, jnp.int32(self.round_idx)
                )
            else:
                step = self._step_for(k, weighted=True)
                w = jnp.asarray(self.client_weights[cohort])
                self.params, metrics = step(
                    self.params, client_batches, jnp.int32(self.round_idx), w
                )
            metrics = jax.device_get(metrics)
        ranks = metrics.get("rank", {})
        if not isinstance(ranks, dict):  # single-factor methods (naive)
            ranks = {"": ranks}
        res = RoundResult(
            round_idx=self.round_idx,
            loss_before=float(metrics["loss_before"]),
            loss_after=(
                float(metrics["loss_after"]) if "loss_after" in metrics else None
            ),
            comm_bytes_per_client=float(metrics.get("comm_bytes_per_client", 0.0)),
            ranks={k_: np.asarray(v) for k_, v in ranks.items()},
            seconds=perf_seconds() - t0,
            cohort_size=k,
            cohort=cohort,
            comm_bytes_per_client_effective=float(
                metrics.get("comm_bytes_per_client_effective", 0.0)
            ),
            wire_bytes_down_per_client=float(
                metrics.get("wire_bytes_down_per_client", 0.0)
            ),
            wire_bytes_up_per_client=float(
                metrics.get("wire_bytes_up_per_client", 0.0)
            ),
            wire_codec=self.wire.name if self.wire is not None else "",
        )
        self.history.append(res)
        self._publish_round(res, metrics)
        self.round_idx += 1
        if (
            self.checkpoint_dir
            and self.checkpoint_every
            and self.round_idx % self.checkpoint_every == 0
        ):
            self._save_checkpoint()
        return res

    def _publish_round(self, res: RoundResult, metrics: dict) -> None:
        """Per-round telemetry: effective-rank and variance-correction
        gauges plus measured wire bytes per direction.  Read-only — the
        hub observes the finished round, it never feeds back into one."""
        hub = self.telemetry
        if not hub.enabled:
            return
        r = int(res.round_idx)
        if res.ranks:
            hub.gauge(
                "rank.effective_mean",
                float(np.mean([np.mean(v) for v in res.ranks.values()])),
                round=r,
            )
        if "max_coeff_drift" in metrics:
            hub.gauge(
                "correction.coeff_drift_max",
                float(metrics["max_coeff_drift"]),
                round=r,
            )
        if res.wire_codec:
            hub.counter(
                "wire.bytes_down",
                res.wire_bytes_down_per_client * res.cohort_size,
                round=r, codec=res.wire_codec,
            )
            hub.counter(
                "wire.bytes_up",
                res.wire_bytes_up_per_client * res.cohort_size,
                round=r, codec=res.wire_codec,
            )

    # -- checkpoint / restore ----------------------------------------------

    def _ckpt_path(self, round_idx: int) -> str:
        return f"{self.checkpoint_dir}/round_{round_idx:06d}.npz"

    def _save_checkpoint(self):
        from repro.checkpoint import save_checkpoint

        path = self._ckpt_path(self.round_idx)
        save_checkpoint(
            path,
            self.params,
            meta={
                "round": self.round_idx,
                "method": self.method,
                **self.checkpoint_meta,
            },
        )
        # sidecar: data-stream state (so a restored run replays the
        # remaining rounds bit-identically — same per-client shuffle
        # cursors and RNG states) plus the round history (so cumulative
        # accounting like comm_total_bytes() spans the whole run).  The
        # history is stored as versioned JSON-safe dicts, never pickled
        # dataclasses — see :data:`STATE_VERSION`.
        state = {
            "version": STATE_VERSION,
            "history": history_to_state(self.history),
        }
        if self._batcher is not None and hasattr(self._batcher, "state"):
            state["batcher"] = self._batcher.state()
        np.save(
            path + ".state.npy",
            np.asarray(state, dtype=object),
            allow_pickle=True,
        )

    def restore(self, path: str, *, batcher=None) -> dict:
        """Resume from a checkpoint written by this engine.

        Restores ``params``, ``round_idx`` (so participation policies —
        seeded by ``(seed, round_idx)`` — replay the same cohorts) and the
        round ``history`` (so ``comm_total_bytes()`` keeps counting the
        pre-restart rounds); if ``batcher`` is given and the
        ``<path>.state.npy`` sidecar exists, also the batcher's stream
        state.  A restored run then reproduces the uninterrupted run
        bit-for-bit.  Returns the checkpoint metadata.
        """
        import os

        from repro.checkpoint import load_checkpoint

        params, meta = load_checkpoint(path)
        self.params = params
        self.round_idx = int(meta.get("round", 0))
        state_path = path + ".state.npy"
        if os.path.exists(state_path):
            # repro-lint: disable=RPL007 -- THE versioned checkpoint
            # sidecar this rule points everyone else at: a STATE_VERSION-
            # stamped dict of JSON-safe scalars written by our own save
            # path (np.save requires allow_pickle for object arrays)
            state = np.load(state_path, allow_pickle=True).item()
            if state.get("version", 0) >= 1:
                self.history = history_from_state(state.get("history", []))
            else:
                # legacy (pre-versioned) sidecar: the history rode along as
                # pickled RoundResult objects — loadable as long as the
                # pickle resolves, kept for old checkpoints on disk
                self.history = list(state.get("history", []))
            if batcher is not None and "batcher" in state:
                batcher.set_state(state["batcher"])
        return meta

    def train(self, batcher, num_rounds: int, *, log_every: int = 10, to_device=None):
        num_clients = self.cfg.num_clients
        self._batcher = batcher
        for _ in range(num_rounds):
            cohort = self.participation.cohort(self.round_idx, num_clients)
            # full participation keeps the legacy no-arg batcher contract so
            # duck-typed batchers work; partial needs cohort-aware batching
            if self.participation.mode == "full":
                batch = batcher.next_round()
            else:
                batch = batcher.next_round(cohort)
            batch = jax.tree.map(jnp.asarray, batch)
            res = self.run_round(batch, cohort=cohort)
            if log_every and res.round_idx % log_every == 0:
                extra = ""
                if res.ranks:
                    mean_rank = np.mean([np.mean(v) for v in res.ranks.values()])
                    extra = f" mean_rank={mean_rank:.1f}"
                if res.cohort_size != num_clients:
                    extra += f" cohort={res.cohort_size}/{num_clients}"
                wire_mb = (
                    res.wire_bytes_down_per_client + res.wire_bytes_up_per_client
                ) / 1e6
                comm = (
                    f" wire {wire_mb:.2f} MB/client [{res.wire_codec}]"
                    if res.wire_codec
                    else f" comm {res.comm_bytes_per_client/1e6:.2f} MB/client"
                )
                self.telemetry.progress(
                    f"[{self.method}] round {res.round_idx:4d} "
                    f"loss {res.loss_before:.4f}"
                    + (f" → {res.loss_after:.4f}" if res.loss_after is not None else "")
                    + comm
                    + extra,
                    round=int(res.round_idx),
                )
        return self.history

    def evaluate(self, batch) -> float:
        assert self.eval_fn is not None
        return float(self.eval_fn(self.params, batch))

    def comm_total_bytes(self) -> float:
        """Total server-side on-wire bytes so far — **measured** uniformly.

        Sums the wire layer's measured per-direction bytes (down + up, per
        client) over every recorded round, scaled by that round's *active*
        cohort.  Every method reports the same measurement (the old
        behaviour silently fell back to analytic static-``r_max`` numbers
        for methods without effective-rank counters); the analytic figure
        remains available as :meth:`comm_total_bytes_analytic`.

        Best-effort caveat: rounds that carry no measurement (run with
        ``wire_codec=None``, or restored from a pre-wire checkpoint)
        contribute the analytic estimate instead.  Measured and analytic
        price different protocols (phase-boundary payloads vs the paper's
        multi-message exchange), so a mixed history is an approximation —
        for strictly comparable figures use :meth:`comm_total_bytes_analytic`,
        which is uniform across all rounds.
        """
        total = 0.0
        for r in self.history:
            # getattr: histories restored from pre-wire checkpoints lack
            # the measured fields and fall back to the analytic figure
            per_client = getattr(r, "wire_bytes_down_per_client", 0.0) + getattr(
                r, "wire_bytes_up_per_client", 0.0
            )
            if per_client == 0.0 and not getattr(r, "wire_codec", ""):
                per_client = r.comm_bytes_per_client  # unmetered round
            total += per_client * r.cohort_size
        return float(total)

    def comm_total_bytes_analytic(self) -> float:
        """Total bytes under the analytic cost model (static ``r_max``
        protocol volumes, :mod:`repro.core.cost_model`) — the paper-style
        estimate the measured figure is cross-checked against."""
        return float(
            sum(r.comm_bytes_per_client * r.cohort_size for r in self.history)
        )
