"""Federated training engine: multi-round driver over any round function.

Wires together a model loss, a data pipeline (:class:`FederatedBatcher`),
a round method (FeDLRT / FedAvg / FedLin) and optional checkpointing into a
restartable driver.  The round function itself stays pure/jitted; the engine
owns the host-side loop, metric history, and eval.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, fedlrt_round
from repro.core.baselines import fedavg_round, fedlin_round

ROUND_METHODS = {
    "fedlrt": fedlrt_round,
    "fedavg": fedavg_round,
    "fedlin": fedlin_round,
}


@dataclasses.dataclass
class RoundResult:
    round_idx: int
    loss_before: float
    loss_after: Optional[float]
    comm_bytes_per_client: float
    ranks: Dict[str, np.ndarray]
    seconds: float


class FederatedEngine:
    def __init__(
        self,
        loss_fn: Callable,
        params,
        cfg: FedConfig,
        *,
        method: str = "fedlrt",
        eval_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        donate: bool = True,
        client_weights=None,
    ):
        if method not in ROUND_METHODS:
            raise ValueError(f"method must be one of {list(ROUND_METHODS)}")
        self.cfg = cfg
        self.method = method
        self.params = params
        self.eval_fn = eval_fn
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.history: List[RoundResult] = []
        self.round_idx = 0
        round_fn = ROUND_METHODS[method]

        if method == "fedlrt":
            def step(p, b, r):
                return round_fn(
                    loss_fn, p, b, cfg, round_idx=r,
                    client_weights=client_weights,
                )
        else:
            def step(p, b, r):
                return round_fn(loss_fn, p, b, cfg)

        self._step = jax.jit(step, donate_argnums=(0,) if donate else ())

    def run_round(self, client_batches) -> RoundResult:
        t0 = time.time()
        self.params, metrics = self._step(
            self.params, client_batches, jnp.int32(self.round_idx)
        )
        metrics = jax.device_get(metrics)
        res = RoundResult(
            round_idx=self.round_idx,
            loss_before=float(metrics["loss_before"]),
            loss_after=(
                float(metrics["loss_after"]) if "loss_after" in metrics else None
            ),
            comm_bytes_per_client=float(metrics.get("comm_bytes_per_client", 0.0)),
            ranks={
                k: np.asarray(v) for k, v in metrics.get("rank", {}).items()
            },
            seconds=time.time() - t0,
        )
        self.history.append(res)
        self.round_idx += 1
        if (
            self.checkpoint_dir
            and self.checkpoint_every
            and self.round_idx % self.checkpoint_every == 0
        ):
            from repro.checkpoint import save_checkpoint

            save_checkpoint(
                f"{self.checkpoint_dir}/round_{self.round_idx:06d}.npz",
                self.params,
                meta={"round": self.round_idx, "method": self.method},
            )
        return res

    def train(self, batcher, num_rounds: int, *, log_every: int = 10, to_device=None):
        for _ in range(num_rounds):
            batch = batcher.next_round()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            res = self.run_round(batch)
            if log_every and res.round_idx % log_every == 0:
                extra = ""
                if res.ranks:
                    mean_rank = np.mean([np.mean(v) for v in res.ranks.values()])
                    extra = f" mean_rank={mean_rank:.1f}"
                print(
                    f"[{self.method}] round {res.round_idx:4d} "
                    f"loss {res.loss_before:.4f}"
                    + (f" → {res.loss_after:.4f}" if res.loss_after is not None else "")
                    + f" comm {res.comm_bytes_per_client/1e6:.2f} MB/client"
                    + extra
                )
        return self.history

    def evaluate(self, batch) -> float:
        assert self.eval_fn is not None
        return float(self.eval_fn(self.params, batch))

    def comm_total_bytes(self) -> float:
        return float(
            sum(r.comm_bytes_per_client for r in self.history)
            * self.cfg.num_clients
        )
