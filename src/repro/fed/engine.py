"""Federated training engine: multi-round driver over any round function.

Wires together a model loss, a data pipeline (:class:`FederatedBatcher`),
a round method (FeDLRT / FedAvg / FedLin / naive low-rank), a per-round
:class:`repro.fed.participation.Participation` policy and optional
checkpointing into a restartable driver.  The round function itself stays
pure/jitted; the engine owns the host-side loop, cohort selection, metric
history, and eval.

Partial participation: the engine asks the participation policy for the
active cohort each round, pulls a cohort-shaped batch from the batcher,
and dispatches to a jitted step *cached per cohort size* (batch shapes —
and therefore executables — depend only on ``k``, so a C=64 run with
uniform-8 sampling compiles exactly one extra executable; ``dropout``
mode has a fluctuating cohort size and compiles one executable per
distinct size it encounters — prefer uniform/round_robin for large
models until cohort padding lands).  Weighted
aggregation (``client_weights`` ∝ |X_c|) is threaded per cohort as a
traced argument, so re-weighting never recompiles.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, fedlrt_round
from repro.core.baselines import fedavg_round, fedlin_round, fedlrt_naive_round
from repro.fed.participation import Participation

ROUND_METHODS = {
    "fedlrt": fedlrt_round,
    "fedavg": fedavg_round,
    "fedlin": fedlin_round,
    "fedlrt_naive": fedlrt_naive_round,
}


@dataclasses.dataclass
class RoundResult:
    round_idx: int
    loss_before: float
    loss_after: Optional[float]
    comm_bytes_per_client: float
    ranks: Dict[str, np.ndarray]
    seconds: float
    cohort_size: int = 0
    cohort: Optional[np.ndarray] = None


class FederatedEngine:
    def __init__(
        self,
        loss_fn: Callable,
        params,
        cfg: FedConfig,
        *,
        method: str = "fedlrt",
        participation: Optional[Participation] = None,
        eval_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        donate: bool = True,
        client_weights=None,
    ):
        if method not in ROUND_METHODS:
            raise ValueError(f"method must be one of {list(ROUND_METHODS)}")
        self.cfg = cfg
        self.method = method
        self.params = params
        self.participation = (
            participation if participation is not None else Participation()
        )
        self.eval_fn = eval_fn
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.history: List[RoundResult] = []
        self.round_idx = 0
        self.client_weights = (
            None if client_weights is None else np.asarray(client_weights, np.float32)
        )
        self._loss_fn = loss_fn
        self._round_fn = ROUND_METHODS[method]
        self._donate = donate
        self._step_cache: Dict[int, Callable] = {}

    def _step_for(self, cohort_size: int) -> Callable:
        """Jitted round step for an active cohort of ``cohort_size`` clients.

        One executable per cohort size (batch shapes are k-dependent);
        ``round_idx`` and ``client_weights`` are traced arguments so they
        never trigger recompiles.
        """
        step = self._step_cache.get(cohort_size)
        if step is None:
            cfg_k = dataclasses.replace(self.cfg, num_clients=cohort_size)
            round_fn, loss_fn = self._round_fn, self._loss_fn
            if self.client_weights is None:
                def raw(p, b, r):
                    return round_fn(loss_fn, p, b, cfg_k, round_idx=r)
            else:
                def raw(p, b, r, w):
                    return round_fn(
                        loss_fn, p, b, cfg_k, round_idx=r, client_weights=w
                    )
            step = jax.jit(raw, donate_argnums=(0,) if self._donate else ())
            self._step_cache[cohort_size] = step
        return step

    def run_round(self, client_batches, *, cohort=None) -> RoundResult:
        """One aggregation round on ``client_batches`` (leading axis = the
        active cohort).  ``cohort`` (optional index array) attributes the
        batch rows to population clients — used to slice ``client_weights``
        and recorded in the history."""
        t0 = time.time()
        k = jax.tree.leaves(client_batches)[0].shape[0]
        cohort = np.arange(k) if cohort is None else np.asarray(cohort)
        step = self._step_for(k)
        if self.client_weights is None:
            self.params, metrics = step(
                self.params, client_batches, jnp.int32(self.round_idx)
            )
        else:
            w = jnp.asarray(self.client_weights[cohort])
            self.params, metrics = step(
                self.params, client_batches, jnp.int32(self.round_idx), w
            )
        metrics = jax.device_get(metrics)
        ranks = metrics.get("rank", {})
        if not isinstance(ranks, dict):  # single-factor methods (naive)
            ranks = {"": ranks}
        res = RoundResult(
            round_idx=self.round_idx,
            loss_before=float(metrics["loss_before"]),
            loss_after=(
                float(metrics["loss_after"]) if "loss_after" in metrics else None
            ),
            comm_bytes_per_client=float(metrics.get("comm_bytes_per_client", 0.0)),
            ranks={k_: np.asarray(v) for k_, v in ranks.items()},
            seconds=time.time() - t0,
            cohort_size=k,
            cohort=cohort,
        )
        self.history.append(res)
        self.round_idx += 1
        if (
            self.checkpoint_dir
            and self.checkpoint_every
            and self.round_idx % self.checkpoint_every == 0
        ):
            from repro.checkpoint import save_checkpoint

            save_checkpoint(
                f"{self.checkpoint_dir}/round_{self.round_idx:06d}.npz",
                self.params,
                meta={"round": self.round_idx, "method": self.method},
            )
        return res

    def train(self, batcher, num_rounds: int, *, log_every: int = 10, to_device=None):
        num_clients = self.cfg.num_clients
        for _ in range(num_rounds):
            cohort = self.participation.cohort(self.round_idx, num_clients)
            # full participation keeps the legacy no-arg batcher contract so
            # duck-typed batchers work; partial needs cohort-aware batching
            if self.participation.mode == "full":
                batch = batcher.next_round()
            else:
                batch = batcher.next_round(cohort)
            batch = jax.tree.map(jnp.asarray, batch)
            res = self.run_round(batch, cohort=cohort)
            if log_every and res.round_idx % log_every == 0:
                extra = ""
                if res.ranks:
                    mean_rank = np.mean([np.mean(v) for v in res.ranks.values()])
                    extra = f" mean_rank={mean_rank:.1f}"
                if res.cohort_size != num_clients:
                    extra += f" cohort={res.cohort_size}/{num_clients}"
                print(
                    f"[{self.method}] round {res.round_idx:4d} "
                    f"loss {res.loss_before:.4f}"
                    + (f" → {res.loss_after:.4f}" if res.loss_after is not None else "")
                    + f" comm {res.comm_bytes_per_client/1e6:.2f} MB/client"
                    + extra
                )
        return self.history

    def evaluate(self, batch) -> float:
        assert self.eval_fn is not None
        return float(self.eval_fn(self.params, batch))

    def comm_total_bytes(self) -> float:
        """Total server-side on-wire bytes so far.

        Scales with the *active cohort* of every round, not the client
        population — under uniform-k sampling this is k/C of the full-
        participation figure.
        """
        return float(
            sum(r.comm_bytes_per_client * r.cohort_size for r in self.history)
        )
