"""Per-round client participation policies (partial-participation FL).

The standard FL regime (Konečný et al.; McMahan et al.) samples a cohort
of clients each round instead of waiting on the full population.  A
:class:`Participation` config describes how the engine picks the active
cohort; the round programs themselves are cohort-oblivious — they simply
receive ``k``-client batches and a ``FedConfig.num_clients == k``.

Modes
-----
- ``full``        every client, every round (the paper's setting).
- ``uniform``     uniform-k sampling without replacement per round.
- ``round_robin`` deterministic rotation of size-k cohorts: each round
                  takes the next k clients in cyclic order, so
                  participation counts equalize every lcm(C,k)/k rounds
                  (exactly once per C/k rounds when k divides C).
- ``dropout``     every client intends to participate, but each round a
                  client straggles/drops with probability ``dropout_prob``
                  and is excluded from the cohort (straggler exclusion);
                  at least ``min_cohort`` clients are always retained.

Cohorts are returned **sorted** so that sampling all ``C`` clients is
bit-for-bit identical to full participation (same batch stacking order,
same jit cache entry).

All randomness is derived from ``(seed, round_idx)`` so cohorts are
deterministic, restartable from a round index, and independent of call
order — the engine can replay any round.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

MODES = ("full", "uniform", "round_robin", "dropout")


@dataclasses.dataclass(frozen=True)
class Participation:
    """Which clients are active each round."""

    mode: str = "full"
    cohort_size: Optional[int] = None  # k for uniform / round_robin
    dropout_prob: float = 0.0  # per-client straggle probability (dropout mode)
    min_cohort: int = 1  # dropout mode never shrinks the cohort below this
    seed: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.mode in ("uniform", "round_robin") and not self.cohort_size:
            raise ValueError(f"{self.mode} participation requires cohort_size")
        if not 0.0 <= self.dropout_prob <= 1.0:
            raise ValueError("dropout_prob must be in [0, 1]")
        if self.min_cohort < 1:
            raise ValueError("min_cohort must be >= 1")

    @classmethod
    def from_spec(cls, spec: str, *, seed: int = 0) -> "Participation":
        """Parse a CLI spec: ``full`` | ``uniform:K`` | ``round_robin:K`` |
        ``dropout:P``."""
        mode, _, arg = spec.partition(":")
        if mode == "full":
            return cls(seed=seed)
        if mode in ("uniform", "round_robin"):
            return cls(mode=mode, cohort_size=int(arg), seed=seed)
        if mode == "dropout":
            return cls(mode="dropout", dropout_prob=float(arg), seed=seed)
        raise ValueError(f"bad participation spec {spec!r}")

    def _rng(self, round_idx: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, int(round_idx)))

    def cohort(self, round_idx: int, num_clients: int) -> np.ndarray:
        """Sorted indices of the clients active in ``round_idx``."""
        if self.mode == "full":
            return np.arange(num_clients, dtype=np.int64)
        if self.mode == "uniform":
            k = min(self.cohort_size, num_clients)
            return np.sort(
                self._rng(round_idx).choice(num_clients, size=k, replace=False)
            ).astype(np.int64)
        if self.mode == "round_robin":
            k = min(self.cohort_size, num_clients)
            start = (int(round_idx) * k) % num_clients
            return np.sort((start + np.arange(k)) % num_clients).astype(np.int64)
        # dropout: independent straggle coin per client, exclusion of the
        # stragglers, deterministic backfill if too few survive.
        rng = self._rng(round_idx)
        coins = rng.random(num_clients)
        active = np.where(coins >= self.dropout_prob)[0]
        if len(active) < self.min_cohort:
            # retain the least-unlucky stragglers so the round can proceed
            order = np.argsort(coins)[::-1]
            active_set = set(active.tolist())
            extra = [c for c in order if c not in active_set]
            need = self.min_cohort - len(active)
            active = np.concatenate([active, np.asarray(extra[:need], np.int64)])
        return np.sort(active).astype(np.int64)

    def padded_size(self, num_clients: int) -> Optional[int]:
        """Fixed size the engine pads cohort batches to, or None.

        ``dropout`` is the only mode with a *fluctuating* cohort size; left
        unpadded it compiles one jit executable per distinct size it
        encounters.  Padding every round up to the population size with
        zero-weight filler clients keeps the engine at exactly one
        executable per run.  The static-cohort modes (full / uniform /
        round_robin) need no padding.
        """
        return int(num_clients) if self.mode == "dropout" else None

    def expected_cohort_size(self, num_clients: int) -> float:
        """Mean active-cohort size — used for analytic comm budgeting."""
        if self.mode == "full":
            return float(num_clients)
        if self.mode in ("uniform", "round_robin"):
            return float(min(self.cohort_size, num_clients))
        return max(
            float(self.min_cohort), num_clients * (1.0 - self.dropout_prob)
        )
