"""Per-client system profiles: pricing federated rounds in *seconds*.

The cost model (:mod:`repro.core.cost_model`) and the wire layer
(:mod:`repro.fed.wire`) report what a round costs in FLOPs and bytes.  A
deployment is judged in wall-clock under heterogeneous fleets, so this
module supplies the missing conversion: a :class:`SystemProfile` per client
(compute throughput, up/down bandwidth, per-message latency, availability)
turns those counts into per-client round latencies, and a :class:`Fleet`
bundles one profile per population client plus the seeded randomness for
dropout traces.

Everything is deterministic: fleets drawn from distributions are seeded,
and per-dispatch dropout coins are derived from ``(fleet seed, client,
dispatch index)`` so a simulated run replays bit-identically (the async
determinism pin in ``tests/test_sim.py``).

Pricing convention: a client's round is ``download → compute → upload``
executed serially, each message paying the fixed per-direction latency on
top of size/bandwidth (the binding-constraint framing of Konečný et al. —
uplink time on slow clients dominates).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.core import cost_model


@dataclasses.dataclass(frozen=True)
class SystemProfile:
    """One client's (or link's) system characteristics.

    Defaults sketch a mid-range edge device: ~50 GFLOP/s of usable
    compute, 100 Mbit/s up, 400 Mbit/s down, 50 ms per-message latency.
    """

    flops_per_sec: float = 50e9
    up_bytes_per_sec: float = 12.5e6
    down_bytes_per_sec: float = 50e6
    latency_sec: float = 0.05  # fixed per-message overhead, each direction
    drop_prob: float = 0.0  # probability a dispatched round is lost mid-flight
    rejoin_delay_sec: float = 0.0  # offline time after a drop before re-dispatch
    name: str = ""

    def __post_init__(self):
        for f in ("flops_per_sec", "up_bytes_per_sec", "down_bytes_per_sec"):
            if getattr(self, f) <= 0:
                raise ValueError(f"{f} must be positive, got {getattr(self, f)}")
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError(f"drop_prob must be in [0, 1), got {self.drop_prob}")

    def compute_seconds(self, flops: float) -> float:
        return float(flops) / self.flops_per_sec

    def up_seconds(self, nbytes: float) -> float:
        return self.latency_sec + float(nbytes) / self.up_bytes_per_sec

    def down_seconds(self, nbytes: float) -> float:
        return self.latency_sec + float(nbytes) / self.down_bytes_per_sec

    def round_seconds(self, flops: float, down_bytes: float, up_bytes: float) -> float:
        """Latency of one full client round: receive, compute, send."""
        return (
            self.down_seconds(down_bytes)
            + self.compute_seconds(flops)
            + self.up_seconds(up_bytes)
        )

    def slowed(self, factor: float) -> "SystemProfile":
        """This profile with compute and both links ``factor``× slower."""
        return dataclasses.replace(
            self,
            flops_per_sec=self.flops_per_sec / factor,
            up_bytes_per_sec=self.up_bytes_per_sec / factor,
            down_bytes_per_sec=self.down_bytes_per_sec / factor,
            latency_sec=self.latency_sec * factor,
            name=(self.name + f"/slow{factor:g}x").lstrip("/"),
        )


class Fleet:
    """One :class:`SystemProfile` per population client + seeded dropout.

    Build via :meth:`from_spec` (the CLI surface), :meth:`uniform`,
    :meth:`straggler`, or :meth:`lognormal`, or pass an explicit profile
    sequence for a fixed fleet.
    """

    def __init__(self, profiles: Sequence[SystemProfile], *, seed: int = 0):
        if not profiles:
            raise ValueError("a fleet needs at least one profile")
        self.profiles = tuple(profiles)
        self.seed = seed

    def __len__(self) -> int:
        return len(self.profiles)

    def __getitem__(self, client: int) -> SystemProfile:
        return self.profiles[client]

    def __repr__(self):
        return f"Fleet({len(self.profiles)} clients, seed={self.seed})"

    # -- constructors ------------------------------------------------------

    @classmethod
    def uniform(
        cls, num_clients: int, profile: Optional[SystemProfile] = None, *, seed: int = 0
    ) -> "Fleet":
        """Identical profiles — the degenerate fleet the sync engine assumes."""
        p = profile if profile is not None else SystemProfile(name="uniform")
        return cls([p] * num_clients, seed=seed)

    @classmethod
    def straggler(
        cls,
        num_clients: int,
        *,
        slow_frac: float = 0.25,
        slowdown: float = 10.0,
        base: Optional[SystemProfile] = None,
        seed: int = 0,
    ) -> "Fleet":
        """A fixed fraction of clients is ``slowdown``× slower end-to-end.

        The slow clients are the *last* ``ceil(slow_frac·C)`` ids —
        deterministic, so engine comparisons straggle the same clients.
        """
        if not 0.0 <= slow_frac <= 1.0:
            raise ValueError(f"slow_frac must be in [0, 1], got {slow_frac}")
        if slowdown < 1.0:
            raise ValueError(f"slowdown must be >= 1, got {slowdown}")
        p = base if base is not None else SystemProfile(name="base")
        n_slow = int(math.ceil(slow_frac * num_clients)) if slow_frac > 0 else 0
        slow = p.slowed(slowdown)
        return cls(
            [p] * (num_clients - n_slow) + [slow] * n_slow, seed=seed
        )

    @classmethod
    def lognormal(
        cls,
        num_clients: int,
        *,
        sigma: float = 0.5,
        base: Optional[SystemProfile] = None,
        seed: int = 0,
    ) -> "Fleet":
        """Per-client slowdowns drawn i.i.d. log-normal(0, sigma), seeded."""
        p = base if base is not None else SystemProfile(name="base")
        rng = np.random.default_rng((seed, 0xF1EE7))
        factors = np.exp(rng.normal(0.0, sigma, size=num_clients))
        factors = np.maximum(factors, 1.0)  # slowdowns, never speedups
        return cls([p.slowed(float(f)) for f in factors], seed=seed)

    @classmethod
    def from_spec(cls, spec: str, num_clients: int, *, seed: int = 0) -> "Fleet":
        """Parse a CLI fleet spec.

        ``uniform``                      identical default profiles
        ``straggler[:FRAC[,SLOWDOWN]]``  FRAC of clients SLOWDOWN× slower
                                         (defaults 0.25, 10)
        ``lognormal[:SIGMA]``            log-normal slowdown draw (default 0.5)
        ``dropout:P[,...]``              any of the above with per-dispatch
                                         drop probability P (prefix modifier)
        """
        spec = spec.strip()
        drop = 0.0
        if spec.startswith("dropout:"):
            rest = spec[len("dropout:"):]
            head, _, tail = rest.partition(",")
            drop, spec = float(head), (tail or "uniform")
        kind, _, arg = spec.partition(":")
        base = SystemProfile(drop_prob=drop, name=kind)
        if kind == "uniform":
            if arg:
                raise ValueError(f"uniform fleet takes no argument, got {spec!r}")
            return cls.uniform(num_clients, base, seed=seed)
        if kind == "straggler":
            frac, slowdown = 0.25, 10.0
            if arg:
                parts = arg.split(",")
                frac = float(parts[0])
                if len(parts) > 1:
                    slowdown = float(parts[1])
            return cls.straggler(
                num_clients, slow_frac=frac, slowdown=slowdown, base=base, seed=seed
            )
        if kind == "lognormal":
            return cls.lognormal(
                num_clients, sigma=float(arg) if arg else 0.5, base=base, seed=seed
            )
        raise ValueError(
            f"unknown fleet spec {spec!r}; expected uniform | "
            f"straggler[:FRAC[,SLOWDOWN]] | lognormal[:SIGMA] "
            f"(optionally prefixed dropout:P,)"
        )

    # -- seeded randomness -------------------------------------------------

    def drop_draw(self, client: int, dispatch_idx: int) -> "tuple[bool, float]":
        """Seeded dropout coin for one dispatch of ``client``.

        Returns ``(dropped, fraction)``: whether this dispatch is lost, and
        (if so) the fraction of its round latency completed before the drop
        — deterministic in ``(fleet seed, client, dispatch index)``.
        """
        p = self.profiles[client].drop_prob
        if p <= 0.0:
            return False, 1.0
        rng = np.random.default_rng((self.seed, int(client), int(dispatch_idx)))
        u, frac = rng.random(2)
        return bool(u < p), float(frac)

    def is_uniform(self) -> bool:
        return all(p == self.profiles[0] for p in self.profiles)


# ---------------------------------------------------------------------------
# FLOP pricing of one client round
# ---------------------------------------------------------------------------


def batch_tokens(client_batch, per_step_batches: bool = False) -> int:
    """Tokens one local step consumes, inferred from a *single client's*
    batch pytree (no leading client axis).

    The per-step batch leaf is ``(b, ...)`` (``(s*, b, ...)`` under the
    per-step layout — the leading s* axis is stripped first).  Integer
    leaves with a trailing axis are token-id sequences (LM batches): tokens
    = b × T.  Float leaves are row-vector features: tokens = b.
    """
    leaf = jax.tree.leaves(client_batch)[0]
    shape = leaf.shape[1:] if per_step_batches else leaf.shape
    b = int(shape[0]) if shape else 1
    if np.issubdtype(np.asarray(leaf).dtype, np.integer) and len(shape) >= 2:
        return b * int(shape[1])
    return b


def client_round_flops(params, cfg, client_batch) -> float:
    """FLOPs of one client's round: s* local fwd+bwd steps on ``params``.

    Factor leaves price the low-rank chain, dense 2-D leaves a full matmul
    (:func:`repro.core.cost_model.client_step_flops`); vectors/scalars are
    free.  ``client_batch`` is one client's batch pytree (no client axis).
    """
    tokens = batch_tokens(client_batch, cfg.per_step_batches)
    return float(cfg.s_star) * cost_model.client_step_flops(params, tokens)


FlopsFn = Callable[..., float]  # (params, cfg, client_batch) -> flops
