from repro.fed.sim.clock import Timeline, VirtualClock  # noqa: F401
from repro.fed.sim.engines import (  # noqa: F401
    AsyncFederatedEngine,
    HierarchicalEngine,
    SyncSimEngine,
    make_sim_engine,
)
from repro.fed.sim.events import (  # noqa: F401
    ClientAvailable,
    ClientDropped,
    ClientFinished,
    EventQueue,
    ServerAggregate,
)
from repro.fed.sim.profiles import (  # noqa: F401
    Fleet,
    SystemProfile,
    client_round_flops,
)
