"""Virtual clock and per-run timeline for the event-driven simulator.

The simulator never sleeps: time is a number that only moves forward, to
the timestamp of the next event (:class:`VirtualClock`), and everything
that happens is appended to a :class:`Timeline` — the per-run record the
benchmarks and the determinism tests read back.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple


class VirtualClock:
    """Monotonic virtual time in seconds."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance_to(self, t: float) -> float:
        if t < self.now:
            raise ValueError(f"clock cannot run backwards: {t} < {self.now}")
        self.now = float(t)
        return self.now

    def __repr__(self):
        return f"VirtualClock(now={self.now:.6f})"


@dataclasses.dataclass(frozen=True)
class TimelineEntry:
    """One recorded occurrence: ``(t, kind, client, round_idx, detail)``.

    ``client`` is -1 for server-side entries (aggregations); ``detail`` is
    a short free-form annotation (staleness, buffer fill, drop fraction).
    """

    t: float
    kind: str
    client: int = -1
    round_idx: int = -1
    detail: str = ""

    def key(self) -> Tuple[float, str, int, int, str]:
        """Canonical tuple — what the determinism pin compares."""
        return (self.t, self.kind, self.client, self.round_idx, self.detail)


class Timeline:
    """Append-only record of everything the simulator did, in time order."""

    def __init__(self):
        self.entries: List[TimelineEntry] = []

    def record(
        self,
        t: float,
        kind: str,
        *,
        client: int = -1,
        round_idx: int = -1,
        detail: str = "",
    ) -> TimelineEntry:
        e = TimelineEntry(
            t=float(t), kind=kind, client=int(client),
            round_idx=int(round_idx), detail=detail,
        )
        self.entries.append(e)
        return e

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self) -> Iterator[TimelineEntry]:
        return iter(self.entries)

    def of_kind(self, kind: str) -> List[TimelineEntry]:
        return [e for e in self.entries if e.kind == kind]

    def span(self) -> float:
        """Virtual seconds covered by the run (0 for an empty timeline)."""
        return self.entries[-1].t if self.entries else 0.0

    def keys(self) -> List[Tuple]:
        """Canonical per-entry tuples (the determinism-pin comparison)."""
        return [e.key() for e in self.entries]

    def time_to(self, predicate) -> Optional[float]:
        """Timestamp of the first entry satisfying ``predicate``, or None."""
        for e in self.entries:
            if predicate(e):
                return e.t
        return None
