"""Event types and the deterministic event queue of the simulator.

The queue is a binary heap ordered by ``(time, client_id, seq)`` — the
tie-break the determinism pin in ``tests/test_sim.py`` relies on: two
events at the same virtual timestamp always pop in client-id order (and
for the same client, in push order), never in hash/dict order.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: something that happens at virtual time ``time``."""

    time: float
    client_id: int


@dataclasses.dataclass(frozen=True)
class ClientFinished(Event):
    """A dispatched client's update arrives at the server.

    ``version`` is the server aggregation count at dispatch (its staleness
    at arrival is ``server_version − version``); ``dispatch_idx`` is the
    client's own dispatch counter (keys the pending-work table).
    """

    version: int = 0
    dispatch_idx: int = 0


@dataclasses.dataclass(frozen=True)
class ClientDropped(Event):
    """A dispatched client fails mid-round; its update never arrives."""

    version: int = 0
    dispatch_idx: int = 0


@dataclasses.dataclass(frozen=True)
class ClientAvailable(Event):
    """A previously unavailable client becomes dispatchable again."""


@dataclasses.dataclass(frozen=True)
class ServerAggregate(Event):
    """The server folds a buffer of arrivals into a new model version.

    Aggregations happen synchronously at the triggering arrival's
    timestamp, so this event is never *queued* — the async engine
    constructs one per flush and records its fields on the timeline.
    ``client_id`` is -1: the server is not a client.
    """

    version: int = 0
    buffer_fill: int = 0


class EventQueue:
    """Deterministic priority queue over :class:`Event`s.

    Orders by ``(time, client_id, seq)``; ``seq`` is a monotonically
    increasing push counter, so ordering never consults the event objects
    themselves (no dataclass comparison, no dict order anywhere).
    """

    def __init__(self):
        self._heap: List[tuple] = []
        self._seq = 0

    def push(self, event: Event) -> None:
        heapq.heappush(
            self._heap, (float(event.time), int(event.client_id), self._seq, event)
        )
        self._seq += 1

    def pop(self) -> Event:
        if not self._heap:
            raise IndexError("pop from empty EventQueue")
        return heapq.heappop(self._heap)[3]

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop_until(self, t: float) -> List[Event]:
        """Pop every event with ``time <= t`` (in deterministic order)."""
        out = []
        while self._heap and self._heap[0][0] <= t:
            out.append(self.pop())
        return out

    def clear(self) -> None:
        self._heap.clear()

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
