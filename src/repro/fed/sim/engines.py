"""Simulation engines: federated rounds priced on a virtual clock.

Three engines share :class:`repro.fed.engine.FederatedEngine`'s interface
(``train(batcher, rounds)``, ``params``, ``history``,
``comm_total_bytes()``) but differ in *when the server aggregates*:

- :class:`SyncSimEngine` — the synchronous engine with a clock attached:
  each round's virtual duration is the **max** over the active cohort of
  ``download + compute + upload`` (the straggler barrier), priced from the
  cohort's :class:`repro.fed.sim.profiles.SystemProfile`s, the cost-model
  FLOP counts and the wire layer's measured bytes.
- :class:`AsyncFederatedEngine` — FedBuff-style buffered asynchrony: the
  server aggregates every ``buffer_size`` *arrivals*.  Contributions carry
  the server version they departed from; staleness discounts their
  aggregation weight (``(1+s)^-staleness_power``) through the existing
  weighted ``ctx.aggregate``.  Stale FeDLRT coefficient updates are
  transported between augmented bases by Galerkin projection
  (``Ū_aᵀ Ū_v · ΔS̃ · V̄_vᵀ V̄_a``) and re-masked to the anchor's active
  directions, so the zero-inactive-columns invariant survives stale
  augmented factors.  With identical profiles and ``buffer_size == C`` the
  engine reduces to the synchronous round sequence **bit-for-bit** (every
  buffer is one zero-staleness full cohort, executed through the same
  jitted round step the sync engine caches).
- :class:`HierarchicalEngine` — two-tier edge→cloud federation: each edge
  server runs ``edge_rounds`` ordinary synchronous rounds over its own
  clients (``run_round`` unchanged), then the edge→cloud hop crosses a
  second :class:`repro.fed.wire.Wire` with its own codec and byte tally;
  the cloud folds edge models back together by weight-space averaging plus
  per-factor SVD re-factorization (the Alg.-6 refactorization cost, paid
  only once per cloud round at the top tier).

The round programs, kernels and codecs are untouched — the engines only
*compose* them, which is what the RoundProgram/Wire layering exists for.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core.dlrt import coeff_grad_mask
from repro.core.factorization import (
    LowRankFactor,
    is_factor,
    materialize,
    mask_coeff,
    rank_mask,
)
from repro.core.fedlrt import trainable_of
from repro.core.round import (
    _per_client_bytes,
    make_context,
    run_client_phases,
    split_server,
)
from repro.fed.engine import (
    FederatedEngine,
    RoundResult,
    round_program_for,
)
from repro.fed.participation import Participation
from repro.fed.sim.clock import Timeline, VirtualClock
from repro.fed.sim.events import (
    ClientAvailable,
    ClientDropped,
    ClientFinished,
    EventQueue,
    ServerAggregate,
)
from repro.fed.sim.profiles import Fleet, SystemProfile, client_round_flops
from repro.fed.wire import Wire
from repro.telemetry import default_hub
from repro.telemetry.clock import perf_seconds


def _analytic_direction_bytes(params, method: str, correction: str):
    """Analytic (down, up) per-client bytes — the cold-start latency
    estimate before any measured round exists (and the only estimate under
    ``wire_codec=None``)."""
    try:
        d = cost_model.wire_round_bytes(params, method, correction=correction)
        return float(d["down"]), float(d["up"])
    except (ValueError, TypeError):
        # unknown/custom method: price the full parameter pytree each way
        size = float(
            sum(np.asarray(x).size * np.asarray(x).dtype.itemsize
                for x in jax.tree.leaves(params))
        )
        return size, size


def _round_direction_bytes(res: RoundResult, params, method: str, correction: str):
    """(down, up) per-client bytes of a completed round: measured if the
    round was metered, else the analytic data-plane volumes."""
    if res.wire_codec and (res.wire_bytes_down_per_client or res.wire_bytes_up_per_client):
        return res.wire_bytes_down_per_client, res.wire_bytes_up_per_client
    return _analytic_direction_bytes(params, method, correction)


def _analytic_round_bytes(params, method: str, correction: str) -> float:
    """Per-client bytes of one round under the paper's multi-message
    protocol — the ``comm_bytes_per_client`` convention of the round
    metrics (0.0 for methods the cost model doesn't know)."""
    with contextlib.suppress(ValueError, TypeError, KeyError):
        if method.startswith("fedlrt") and not method.startswith("fedlrt_naive"):
            return float(cost_model.fedlrt_round_comm_bytes(params, correction))
        if method in ("fedavg", "fedlin"):
            return float(cost_model.dense_round_comm_bytes(params, method))
    return 0.0


def _tree_concat(trees):
    return jax.tree.map(lambda *xs: np.concatenate([np.asarray(x) for x in xs]), *trees)


def _tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _resave_checkpoint_if_due(engine: FederatedEngine):
    """Checkpoints fire *inside* the base engine's round bookkeeping,
    before a sim engine assigns the round's timing fields — re-save so the
    sidecar's history carries ``virtual_seconds``/``t_virtual``/
    ``staleness_mean`` (idempotent: same path, now-complete history)."""
    if (
        engine.checkpoint_dir
        and engine.checkpoint_every
        and engine.round_idx % engine.checkpoint_every == 0
    ):
        engine._save_checkpoint()


def _collect_ranks(params) -> dict:
    ranks = {}

    def visit(path, x):
        if is_factor(x):
            ranks[jax.tree_util.keystr(path)] = np.asarray(x.rank)
        return x

    jax.tree_util.tree_map_with_path(visit, params, is_leaf=is_factor)
    return ranks


# ---------------------------------------------------------------------------
# synchronous engine + virtual clock
# ---------------------------------------------------------------------------


class SyncSimEngine(FederatedEngine):
    """:class:`FederatedEngine` with rounds priced on a virtual clock.

    Numerically identical to the plain engine (it *is* the plain engine);
    each round additionally advances a :class:`VirtualClock` by the
    straggler barrier — the slowest active client's
    ``download + compute + upload`` — and records
    ``virtual_seconds``/``t_virtual`` on the :class:`RoundResult`.
    """

    def __init__(self, loss_fn, params, cfg, *, fleet: Optional[Fleet] = None,
                 flops_fn: Optional[Callable] = None, **kw):
        super().__init__(loss_fn, params, cfg, **kw)
        self.fleet = fleet if fleet is not None else Fleet.uniform(cfg.num_clients)
        if len(self.fleet) != cfg.num_clients:
            raise ValueError(
                f"fleet has {len(self.fleet)} profiles for "
                f"{cfg.num_clients} clients"
            )
        self.flops_fn = flops_fn if flops_fn is not None else client_round_flops
        self.clock = VirtualClock()
        self.timeline = Timeline()
        self.telemetry.attach_clock(self.clock)

    def run_round(self, client_batches, *, cohort=None) -> RoundResult:
        one_client = jax.tree.map(lambda a: np.asarray(a)[0], client_batches)
        res = super().run_round(client_batches, cohort=cohort)
        # FLOP pricing only reads static shapes, so post-round params price
        # the same round the pre-round params would
        flops = self.flops_fn(self.params, self.cfg, one_client)
        down, up = _round_direction_bytes(
            res, self.params, self.method, self.cfg.correction
        )
        dt = max(
            self.fleet[int(c)].round_seconds(flops, down, up) for c in res.cohort
        )
        t_prev = self.clock.now
        self.clock.advance_to(self.clock.now + dt)
        res.virtual_seconds = dt
        res.t_virtual = self.clock.now
        # the straggler barrier on the virtual clock: one span per round
        # on the server track (every client's virtual round is inside it)
        self.telemetry.span_at(
            "round", t_prev, self.clock.now,
            round=int(res.round_idx), cohort=int(res.cohort_size),
        )
        _resave_checkpoint_if_due(self)
        self.timeline.record(
            self.clock.now, "aggregate", round_idx=res.round_idx,
            detail=f"K={res.cohort_size}",
        )
        return res


# ---------------------------------------------------------------------------
# async (buffered) engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Pending:
    """One in-flight dispatch: which server version it departed from and
    the client's drawn batch (leaves have leading axis 1)."""

    client: int
    version: int
    batch: dict
    t_dispatch: float


class AsyncFederatedEngine(FederatedEngine):
    """FedBuff-style buffered-asynchronous federated engine.

    Event-driven: every idle client is immediately (re)dispatched from the
    *current* server params; its arrival lands at
    ``dispatch + download + compute + upload`` virtual seconds, priced by
    its :class:`SystemProfile`.  The server folds the buffer into a new
    model version at every ``buffer_size``-th arrival.

    Aggregation semantics (see the flush methods for the math):

    - arrivals that departed from the current version follow the ordinary
      synchronous phase path — when the whole buffer is one such group it
      is executed through the *identical* jitted round step the sync engine
      uses, so uniform fleets with ``buffer_size == num_clients``
      reproduce :class:`FederatedEngine` bit-for-bit;
    - stale arrivals are re-anchored: their local coefficient deltas are
      transported between augmented bases by Galerkin projection, masked
      back to the anchor's active directions (the zero-inactive-columns
      invariant), and aggregated with staleness-discounted weights
      ``w_c ∝ base_c · (1 + staleness_c)^-staleness_power`` through the
      same weighted ``ctx.aggregate`` every synchronous round uses.

    Determinism: the event queue tie-breaks by ``(time, client_id, push
    order)`` and all dropout randomness is seeded per ``(fleet seed,
    client, dispatch index)`` — two runs with the same seed produce
    identical event timelines and bit-identical parameters.
    """

    def __init__(
        self,
        loss_fn,
        params,
        cfg,
        *,
        fleet: Optional[Fleet] = None,
        buffer_size: Optional[int] = None,
        staleness_power: float = 0.5,
        flops_fn: Optional[Callable] = None,
        method: str = "fedlrt",
        participation: Optional[Participation] = None,
        **kw,
    ):
        if participation is not None and participation.mode != "full":
            raise ValueError(
                "AsyncFederatedEngine derives participation from client "
                "availability (profiles/dropout), not a Participation policy"
            )
        # donation would invalidate the per-version params snapshots that
        # in-flight (stale) clients still reference
        kw.pop("donate", None)
        super().__init__(loss_fn, params, cfg, method=method, donate=False, **kw)
        self.fleet = fleet if fleet is not None else Fleet.uniform(cfg.num_clients)
        if len(self.fleet) != cfg.num_clients:
            raise ValueError(
                f"fleet has {len(self.fleet)} profiles for "
                f"{cfg.num_clients} clients"
            )
        self.buffer_size = int(buffer_size) if buffer_size else cfg.num_clients
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {buffer_size}")
        self.staleness_power = float(staleness_power)
        self.flops_fn = flops_fn if flops_fn is not None else client_round_flops
        self.clock = VirtualClock()
        self.timeline = Timeline()
        self.telemetry.attach_clock(self.clock)
        self._program = round_program_for(method)
        self._queue = EventQueue()
        self._buffer: List[_Pending] = []  # arrivals awaiting aggregation
        self._pending: dict = {}  # (client, dispatch_idx) -> _Pending
        self._snapshots: dict = {}  # version -> [params, refcount]
        self._dispatch_count = [0] * cfg.num_clients
        self._phase_cache: dict = {}
        self._t_last_flush = 0.0

    # -- event loop --------------------------------------------------------

    def train(self, batcher, num_rounds: int, *, log_every: int = 10, to_device=None):
        """Run until ``num_rounds`` more server aggregations completed.

        Each ``train`` call is one simulated run: any in-flight work left
        over from a previous call is discarded (the virtual clock keeps
        counting up, histories concatenate).
        """
        self._batcher = batcher
        self._queue.clear()
        self._buffer.clear()
        self._pending.clear()
        self._snapshots.clear()
        target = self.round_idx + num_rounds
        idle = list(range(self.cfg.num_clients))
        dispatch_budget = 10_000 * max(num_rounds, 1)
        while self.round_idx < target:
            for c in sorted(idle):
                self._dispatch(c)
                dispatch_budget -= 1
            idle.clear()
            if dispatch_budget < 0:
                raise RuntimeError(
                    "async simulation dispatched >10k rounds per aggregation "
                    "— check the fleet's drop_prob / buffer_size"
                )
            if not self._queue:
                break  # nothing in flight and nothing to dispatch
            t = self._queue.peek_time()
            self.clock.advance_to(t)
            popped = self._queue.pop_until(t)
            self.telemetry.counter("sim.events_popped", len(popped))
            for ev in popped:
                if isinstance(ev, ClientFinished):
                    p = self._pending.pop((ev.client_id, ev.dispatch_idx))
                    self._buffer.append(p)
                    self.timeline.record(
                        t, "arrive", client=ev.client_id, round_idx=p.version,
                        detail=f"stale={self.round_idx - p.version}",
                    )
                    # the client's whole virtual round (download + compute
                    # + upload) on its own trace track
                    self.telemetry.span_at(
                        "client_round", p.t_dispatch, t,
                        client=int(ev.client_id), version=int(p.version),
                        staleness=int(self.round_idx - p.version),
                    )
                    idle.append(ev.client_id)
                    if (
                        len(self._buffer) >= self.buffer_size
                        and self.round_idx < target
                    ):
                        res = self._flush()
                        if log_every and res.round_idx % log_every == 0:
                            self.telemetry.progress(
                                f"[async/{self.method}] round {res.round_idx:4d} "
                                f"loss {res.loss_before:.4f} "
                                f"t={res.t_virtual:.1f}s "
                                f"stale={res.staleness_mean:.2f}",
                                round=int(res.round_idx),
                            )
                elif isinstance(ev, ClientDropped):
                    p = self._pending.pop((ev.client_id, ev.dispatch_idx))
                    self._release(p.version)
                    self.timeline.record(
                        t, "drop", client=ev.client_id, round_idx=p.version
                    )
                    self.telemetry.span_at(
                        "client_dropped", p.t_dispatch, t,
                        client=int(ev.client_id), version=int(p.version),
                    )
                    delay = self.fleet[ev.client_id].rejoin_delay_sec
                    if delay > 0:
                        self._queue.push(
                            ClientAvailable(time=t + delay, client_id=ev.client_id)
                        )
                    else:
                        idle.append(ev.client_id)
                elif isinstance(ev, ClientAvailable):
                    idle.append(ev.client_id)
        return self.history

    def _dispatch(self, client: int):
        t = self.clock.now
        didx = self._dispatch_count[client]
        self._dispatch_count[client] += 1
        version = self.round_idx
        batch = self._batcher.next_round([client])
        one_client = jax.tree.map(lambda a: np.asarray(a)[0], batch)
        flops = self.flops_fn(self.params, self.cfg, one_client)
        down, up = self._bytes_estimate()
        dt = self.fleet[client].round_seconds(flops, down, up)
        dropped, frac = self.fleet.drop_draw(client, didx)
        self._hold(version)
        self._pending[(client, didx)] = _Pending(
            client=client, version=version, batch=batch, t_dispatch=t
        )
        cls = ClientDropped if dropped else ClientFinished
        self._queue.push(
            cls(
                time=t + (frac * dt if dropped else dt),
                client_id=client, version=version, dispatch_idx=didx,
            )
        )
        self.timeline.record(t, "dispatch", client=client, round_idx=version)

    def _bytes_estimate(self):
        """Per-direction bytes for latency pricing: the last round's
        *measured* wire bytes once one exists (measurement-calibrated
        scheduling), the analytic data-plane volumes before that."""
        if self.history:
            return _round_direction_bytes(
                self.history[-1], self.params, self.method, self.cfg.correction
            )
        return _analytic_direction_bytes(
            self.params, self.method, self.cfg.correction
        )

    def _hold(self, version: int):
        slot = self._snapshots.get(version)
        if slot is None:
            self._snapshots[version] = [self.params, 1]
        else:
            slot[1] += 1

    def _release(self, version: int):
        slot = self._snapshots[version]
        slot[1] -= 1
        if slot[1] == 0:
            del self._snapshots[version]

    # -- aggregation -------------------------------------------------------

    def _flush(self) -> RoundResult:
        t = self.clock.now
        arrivals = list(self._buffer)
        self._buffer.clear()
        staleness = [self.round_idx - a.version for a in arrivals]
        if all(s == 0 for s in staleness):
            # the whole buffer departed from the current params: the round
            # is exactly a synchronous round over the arrival cohort, run
            # through the same jitted step the sync engine caches — with
            # identical profiles and buffer_size == C this path reproduces
            # FederatedEngine bit-for-bit
            batch = _tree_concat([a.batch for a in arrivals])
            res = super().run_round(
                batch, cohort=np.asarray([a.client for a in arrivals])
            )
        else:
            res = self._flush_stale(arrivals)
        for a in arrivals:
            self._release(a.version)
        res.virtual_seconds = t - self._t_last_flush
        res.t_virtual = t
        res.staleness_mean = float(np.mean(staleness))
        # inter-flush interval on the server's virtual track
        self.telemetry.span_at(
            "aggregate", self._t_last_flush, t,
            round=int(res.round_idx), buffer_fill=len(arrivals),
        )
        self.telemetry.gauge(
            "staleness_mean", res.staleness_mean, round=int(res.round_idx)
        )
        self._t_last_flush = t
        _resave_checkpoint_if_due(self)
        ev = ServerAggregate(
            time=t, client_id=-1, version=res.round_idx,
            buffer_fill=len(arrivals),
        )
        self.timeline.record(
            ev.time, "aggregate", client=ev.client_id, round_idx=ev.version,
            detail=f"K={ev.buffer_fill};stale={res.staleness_mean:g}",
        )
        return res

    def _phase_step(self, k: int, weighted: bool):
        """Jitted ``broadcast → client_step`` executable for a staleness
        group of ``k`` clients (cache mirrors the engine's round-step
        cache; no donation — version snapshots stay live)."""
        key = (k, weighted)
        step = self._phase_cache.get(key)
        if step is None:
            cfg_k = dataclasses.replace(self.cfg, num_clients=k)
            program, loss_fn, wire = self._program, self._loss_fn, self.wire

            if weighted:
                def raw(p, b, r, w):
                    ctx = make_context(cfg_k, round_idx=r, client_weights=w)
                    return run_client_phases(program, loss_fn, p, b, ctx, wire=wire)
            else:
                def raw(p, b, r):
                    ctx = make_context(cfg_k, round_idx=r, client_weights=None)
                    return run_client_phases(program, loss_fn, p, b, ctx, wire=wire)

            step = jax.jit(raw)
            self._phase_cache[key] = step
        return step

    def _run_group(self, version: int, group: Sequence[_Pending]):
        """Client phases for one staleness group, anchored at the params
        the group departed from.  The broadcast (basis augmentation,
        variance-correction terms) is computed over the *group* cohort at
        the departure point — corrections stay anchored to each client's
        departure basis and sum to zero within the group."""
        params_v = self._snapshots[version][0]
        batch = jax.tree.map(jnp.asarray, _tree_concat([p.batch for p in group]))
        if self.client_weights is not None:
            w = jnp.asarray(
                self.client_weights[[p.client for p in group]], jnp.float32
            )
            shared, outs, nbytes = self._phase_step(len(group), True)(
                params_v, batch, jnp.int32(version), w
            )
        else:
            shared, outs, nbytes = self._phase_step(len(group), False)(
                params_v, batch, jnp.int32(version)
            )
        bs, bpc, bup = (float(jax.device_get(b)) for b in nbytes)
        per_down = float(_per_client_bytes(bs, bpc, len(group)))
        return shared, outs, per_down * len(group), bup

    def _transport_out(self, out, shared_v, shared_a):
        """Re-anchor one stale client output into the anchor broadcast's
        coefficient space, as a pseudo client output.

        FeDLRT: ``S̃_pseudo = S̃⁰_a + mask_a(Ū_aᵀ Ū_v (S̃_c − S̃⁰_v) V̄_vᵀ V̄_a)``
        — the weight-space delta ``Ū_v ΔS̃ V̄_vᵀ`` Galerkin-projected onto
        the anchor's augmented basis and re-masked to its active block, so
        the zero-inactive-columns invariant is preserved exactly.  Dense
        programs re-anchor the plain parameter delta; programs whose
        client outputs are absolute (the naive baseline's per-client
        factors, aggregated in weight space) pass through unchanged.
        """
        if isinstance(shared_a, dict) and "aug_params" in shared_a:
            tr, drift = out
            aug_a, aug_v = shared_a["aug_params"], shared_v["aug_params"]
            delta = jax.tree.map(
                lambda x, y: x - y, tr, trainable_of(aug_v)
            )

            def one(fa, fv, ra, d):
                if is_factor(fa):
                    pu = jnp.einsum("...nr,...nk->...rk", fa.U, fv.U)
                    pv = jnp.einsum("...nk,...nr->...kr", fv.V, fa.V)
                    d2 = jnp.einsum("...rk,...kl,...lm->...rm", pu, d, pv)
                    return ra + mask_coeff(d2, coeff_grad_mask(fa))
                return ra + d

            pseudo = jax.tree.map(
                one, aug_a, aug_v, trainable_of(aug_a), delta, is_leaf=is_factor
            )
            return pseudo, drift
        if isinstance(shared_a, dict) and "params0" in shared_a:
            delta = jax.tree.map(lambda x, y: x - y, out, shared_v["params0"])
            return jax.tree.map(lambda x, y: x + y, shared_a["params0"], delta)
        return out  # absolute outputs (weight-space aggregation)

    def _server_delta(self, out, shared_v):
        """One stale output as a delta in the *current server params'*
        coefficient space (factor leaves: Galerkin projection onto the
        truncated basis, masked to its active rank)."""
        if isinstance(shared_v, dict) and "aug_params" in shared_v:
            tr, _drift = out
            aug_v = shared_v["aug_params"]
            delta = jax.tree.map(lambda x, y: x - y, tr, trainable_of(aug_v))

            def one(ps, fv, d):
                if is_factor(ps):
                    pu = jnp.einsum("...nr,...nk->...rk", ps.U, fv.U)
                    pv = jnp.einsum("...nk,...nr->...kr", fv.V, ps.V)
                    d2 = jnp.einsum("...rk,...kl,...lm->...rm", pu, d, pv)
                    return mask_coeff(
                        d2, rank_mask(ps.rank, ps.r_max, dtype=d2.dtype)
                    )
                return d

            return jax.tree.map(one, self.params, aug_v, delta, is_leaf=is_factor)
        if isinstance(shared_v, dict) and "params0" in shared_v:
            return jax.tree.map(lambda x, y: x - y, out, shared_v["params0"])
        raise NotImplementedError(
            f"method {self.method!r} has no delta form for fully-stale "
            f"buffered aggregation"
        )

    def _discounted_weights(self, arrivals: Sequence[_Pending]) -> np.ndarray:
        base = (
            self.client_weights[[a.client for a in arrivals]]
            if self.client_weights is not None
            else np.ones(len(arrivals), np.float32)
        )
        stale = np.asarray(
            [self.round_idx - a.version for a in arrivals], np.float32
        )
        return np.asarray(
            base * (1.0 + stale) ** (-self.staleness_power), np.float32
        )

    def _flush_stale(self, arrivals: Sequence[_Pending]) -> RoundResult:
        """Aggregate a mixed-staleness buffer.

        Groups arrivals by departure version and runs each group's client
        phases at its own departure params.  If some arrivals departed
        from the *current* version, that group's broadcast is the anchor:
        stale outputs become transported pseudo-outputs in the anchor's
        coefficient space and the whole buffer flows through the ordinary
        ``aggregate → finalize`` (truncation included) with
        staleness-discounted weights.  If every arrival is stale (the
        anchor basis would predate the current params), the buffer is
        applied FedBuff-style instead: discounted deltas projected onto
        the current params, no rank adaptation this round.
        """
        t0 = perf_seconds()
        program, cfg = self._program, self.cfg
        K = len(arrivals)
        groups: dict = {}
        for i, a in enumerate(arrivals):
            groups.setdefault(a.version, []).append(i)
        shared_by_v, outs_by_i = {}, [None] * K
        bytes_down = bytes_up = 0.0
        for v in sorted(groups):
            idxs = groups[v]
            with self.telemetry.span(
                "phase.client_step", version=int(v), group=len(idxs),
                round=int(self.round_idx),
            ):
                shared, outs, bdown, bup = self._run_group(
                    v, [arrivals[i] for i in idxs]
                )
            shared_by_v[v] = shared
            for j, i in enumerate(idxs):
                outs_by_i[i] = jax.tree.map(lambda x, j=j: x[j], outs)
            bytes_down += bdown
            bytes_up += bup
        w = self._discounted_weights(arrivals)
        anchor_v = max(groups)
        if anchor_v == self.round_idx:
            shared_a = shared_by_v[anchor_v]
            pseudo = [
                outs_by_i[i]
                if arrivals[i].version == anchor_v
                else self._transport_out(
                    outs_by_i[i], shared_by_v[arrivals[i].version], shared_a
                )
                for i in range(K)
            ]
            ctx = make_context(
                dataclasses.replace(cfg, num_clients=K),
                round_idx=self.round_idx,
                client_weights=jnp.asarray(w),
            )
            with self.telemetry.span(
                "phase.aggregate", round=int(self.round_idx), cohort=K
            ):
                agg = program.aggregate(shared_a, _tree_stack(pseudo), ctx)
            batches = jax.tree.map(
                jnp.asarray, _tree_concat([a.batch for a in arrivals])
            )
            with self.telemetry.span(
                "phase.finalize", round=int(self.round_idx), cohort=K
            ):
                new_params, metrics = program.finalize(
                    self._loss_fn, self.params, shared_a, agg, batches, ctx
                )
                metrics = jax.device_get(metrics)
            pub_metrics = metrics
            loss_after = (
                float(metrics["loss_after"]) if "loss_after" in metrics else None
            )
            loss_before = float(metrics["loss_before"])
            comm = float(metrics.get("comm_bytes_per_client", 0.0))
            comm_eff = float(metrics.get("comm_bytes_per_client_effective", 0.0))
            ranks = metrics.get("rank", {})
            if not isinstance(ranks, dict):
                ranks = {"": ranks}
            ranks = {k: np.asarray(v) for k, v in ranks.items()}
        else:
            # no current-version group: fold discounted deltas into the
            # current params (pure FedBuff application, basis unchanged)
            wn = w / w.sum()
            deltas = [
                self._server_delta(outs_by_i[i], shared_by_v[arrivals[i].version])
                for i in range(K)
            ]
            dsum = jax.tree.map(
                lambda *xs: sum(wi * x for wi, x in zip(wn, xs)), *deltas
            )

            def apply(ps, d):
                if is_factor(ps):
                    return dataclasses.replace(ps, S=ps.S + d)
                return ps + d

            new_params = jax.tree.map(apply, self.params, dsum, is_leaf=is_factor)
            _, server_state = split_server(shared_by_v[anchor_v])
            loss_before = (
                float(jax.device_get(server_state["loss_before"]))
                if server_state and "loss_before" in server_state
                else float("nan")
            )
            loss_after = None
            # no finalize ran, so no metrics: price the analytic figure
            # directly — comm_total_bytes_analytic() must keep counting
            # these rounds
            comm = _analytic_round_bytes(
                self.params, self.method, cfg.correction
            )
            comm_eff = 0.0
            ranks = _collect_ranks(new_params)
            pub_metrics = {}
        self.params = new_params
        res = RoundResult(
            round_idx=self.round_idx,
            loss_before=loss_before,
            loss_after=loss_after,
            comm_bytes_per_client=comm,
            ranks=ranks,
            seconds=perf_seconds() - t0,
            cohort_size=K,
            cohort=np.asarray([a.client for a in arrivals]),
            comm_bytes_per_client_effective=comm_eff,
            wire_bytes_down_per_client=bytes_down / K if self.wire else 0.0,
            wire_bytes_up_per_client=bytes_up / K if self.wire else 0.0,
            wire_codec=self.wire.name if self.wire is not None else "",
        )
        self.history.append(res)
        self._publish_round(res, pub_metrics)
        self.round_idx += 1
        if (
            self.checkpoint_dir
            and self.checkpoint_every
            and self.round_idx % self.checkpoint_every == 0
        ):
            self._save_checkpoint()
        return res


# ---------------------------------------------------------------------------
# hierarchical (edge → cloud) engine
# ---------------------------------------------------------------------------


class HierarchicalEngine:
    """Two-tier federation: edge servers aggregate their own clients with
    ordinary synchronous rounds; the cloud periodically folds the edge
    models together.

    Clients are split contiguously across ``num_edges`` edges.  One cloud
    round = every edge receiving the cloud model (through the edge↔cloud
    :class:`Wire`, its *own* codec and byte tally), running
    ``edge_rounds`` local :func:`run_round`s over its clients — the round
    programs and the client-tier wire are reused unchanged — then
    uploading its model for the cloud aggregate: weight-space weighted
    mean per factor leaf followed by an SVD re-factorization at the edge
    ranks' elementwise max (the paper's Alg.-6 refactorization cost, paid
    once per cloud round at the top tier only).

    Virtual time: edges run in parallel; a cloud round costs
    ``max_e(downlink_e + Σ local rounds' straggler barriers + uplink_e)``
    on the clock.
    """

    def __init__(
        self,
        loss_fn,
        params,
        cfg,
        *,
        method: str = "fedlrt",
        num_edges: int = 2,
        edge_rounds: int = 1,
        fleet: Optional[Fleet] = None,
        edge_profiles=None,
        wire_codec="identity",
        edge_wire_codec=None,
        client_weights=None,
        flops_fn: Optional[Callable] = None,
        eval_fn=None,
        telemetry=None,
    ):
        C = cfg.num_clients
        if not 1 <= num_edges <= C:
            raise ValueError(f"num_edges must be in [1, {C}], got {num_edges}")
        self.cfg = cfg
        self.method = method
        self.params = params
        self.num_edges = int(num_edges)
        self.edge_rounds = int(edge_rounds)
        self.fleet = fleet if fleet is not None else Fleet.uniform(C)
        self.flops_fn = flops_fn if flops_fn is not None else client_round_flops
        self.eval_fn = eval_fn
        self.history: List[RoundResult] = []
        self.round_idx = 0
        self.clock = VirtualClock()
        self.timeline = Timeline()
        self.telemetry = telemetry if telemetry is not None else default_hub()
        self.telemetry.attach_clock(self.clock)
        self.edge_cohorts = [
            np.asarray(c) for c in np.array_split(np.arange(C), num_edges)
        ]
        # the edge↔cloud backhaul: typically far fatter than client links
        if edge_profiles is None:
            backhaul = SystemProfile(
                flops_per_sec=1e12, up_bytes_per_sec=1.25e8,
                down_bytes_per_sec=1.25e8, latency_sec=0.02, name="backhaul",
            )
            edge_profiles = [backhaul] * num_edges
        self.edge_profiles = list(edge_profiles)
        self.edge_wire = Wire(
            edge_wire_codec if edge_wire_codec is not None else wire_codec,
            telemetry=self.telemetry,
        )
        self._cloud_bytes = 0.0
        self._loss_fn = loss_fn
        self.client_weights = (
            None if client_weights is None
            else np.asarray(client_weights, np.float32)
        )
        self.edge_engines = []
        for cohort in self.edge_cohorts:
            cw = (
                self.client_weights[cohort]
                if self.client_weights is not None else None
            )
            self.edge_engines.append(
                FederatedEngine(
                    loss_fn, params,
                    dataclasses.replace(cfg, num_clients=len(cohort)),
                    method=method, wire_codec=wire_codec,
                    client_weights=cw, donate=False,
                    telemetry=self.telemetry,
                )
            )
        # cloud-side aggregation weight of each edge ∝ its population mass
        self.edge_weights = np.asarray(
            [
                self.client_weights[c].sum()
                if self.client_weights is not None
                else float(len(c))
                for c in self.edge_cohorts
            ],
            np.float64,
        )

    def _edge_hop(self, tree, name):
        decoded, nbytes = self.edge_wire.roundtrip(tree, name=name)
        return decoded, float(jax.device_get(jnp.asarray(nbytes)))

    def _cloud_aggregate(self, edge_params: List):
        """Weight-space weighted mean + per-factor SVD re-factorization."""
        w = self.edge_weights / self.edge_weights.sum()

        def one(*leaves):
            f0 = leaves[0]
            if is_factor(f0):
                W = sum(wi * materialize(f) for wi, f in zip(w, leaves))
                P, s, Qt = jnp.linalg.svd(W, full_matrices=False)
                r_max = f0.r_max
                rank = leaves[0].rank
                for f in leaves[1:]:
                    rank = jnp.maximum(rank, f.rank)
                keep = rank_mask(rank, r_max, dtype=s.dtype)
                U = P[..., :, :r_max] * keep[..., None, :]
                V = jnp.swapaxes(Qt, -1, -2)[..., :, :r_max] * keep[..., None, :]
                S = (s[..., :r_max] * keep)[..., :, None] * jnp.eye(
                    r_max, dtype=s.dtype
                )
                return LowRankFactor(
                    U=U.astype(f0.U.dtype), S=S.astype(f0.S.dtype),
                    V=V.astype(f0.V.dtype), rank=rank,
                )
            return sum(wi * x for wi, x in zip(w, leaves))

        return jax.tree.map(one, *edge_params, is_leaf=is_factor)

    def train(self, batcher, num_rounds: int, *, log_every: int = 10, to_device=None):
        """``num_rounds`` *cloud* rounds (each = ``edge_rounds`` local
        rounds on every edge plus the edge↔cloud exchange)."""
        for _ in range(num_rounds):
            t0 = self.clock.now
            # cloud → edge broadcast (one payload, received by every edge)
            down_dec, down_bytes = self._edge_hop(self.params, "edge_down")
            self._cloud_bytes += down_bytes * self.num_edges
            edge_times, edge_losses, up_list, up_bytes_list = [], [], [], []
            for e, eng in enumerate(self.edge_engines):
                eng.params = down_dec
                t_e = self.edge_profiles[e].down_seconds(down_bytes)
                for _j in range(self.edge_rounds):
                    batch = batcher.next_round(self.edge_cohorts[e])
                    batch = jax.tree.map(jnp.asarray, batch)
                    one_client = jax.tree.map(
                        lambda a: np.asarray(a)[0], batch
                    )
                    res = eng.run_round(batch)
                    flops = self.flops_fn(eng.params, eng.cfg, one_client)
                    down, up = _round_direction_bytes(
                        res, eng.params, self.method, self.cfg.correction
                    )
                    t_e += max(
                        self.fleet[int(c)].round_seconds(flops, down, up)
                        for c in self.edge_cohorts[e]
                    )
                edge_losses.append(eng.history[-self.edge_rounds].loss_before)
                up_dec, up_bytes = self._edge_hop(eng.params, "edge_up")
                self._cloud_bytes += up_bytes
                up_list.append(up_dec)
                up_bytes_list.append(up_bytes)
                t_e += self.edge_profiles[e].up_seconds(up_bytes)
                edge_times.append(t_e)
                self.timeline.record(
                    t0 + t_e, "edge_up", client=e, round_idx=self.round_idx
                )
                # one edge's full down → local rounds → up window, on the
                # edge's own track (client = edge index)
                self.telemetry.span_at(
                    "edge_round", t0, t0 + t_e,
                    client=int(e), round=int(self.round_idx),
                )
            self.params = self._cloud_aggregate(up_list)
            dt = max(edge_times)
            self.clock.advance_to(t0 + dt)
            ew = self.edge_weights / self.edge_weights.sum()
            res = RoundResult(
                round_idx=self.round_idx,
                loss_before=float(np.dot(ew, np.asarray(edge_losses))),
                loss_after=None,
                comm_bytes_per_client=0.0,
                ranks=_collect_ranks(self.params),
                seconds=0.0,
                cohort_size=self.num_edges,
                cohort=np.arange(self.num_edges),
                wire_bytes_down_per_client=down_bytes,
                wire_bytes_up_per_client=float(np.mean(up_bytes_list)),
                wire_codec=self.edge_wire.name,
                virtual_seconds=dt,
                t_virtual=self.clock.now,
            )
            self.history.append(res)
            self.round_idx += 1
            self.timeline.record(
                self.clock.now, "aggregate", round_idx=res.round_idx,
                detail=f"edges={self.num_edges}",
            )
            self.telemetry.span_at(
                "cloud_round", t0, self.clock.now,
                round=int(res.round_idx), edges=int(self.num_edges),
            )
            if log_every and res.round_idx % log_every == 0:
                self.telemetry.progress(
                    f"[hier/{self.method}] cloud round {res.round_idx:4d} "
                    f"loss {res.loss_before:.4f} t={res.t_virtual:.1f}s",
                    round=int(res.round_idx),
                )
        return self.history

    def comm_total_bytes(self) -> float:
        """Client-tier measured bytes (summed over the edge engines) plus
        the edge↔cloud tier's own tally."""
        return float(
            sum(e.comm_total_bytes() for e in self.edge_engines)
            + self._cloud_bytes
        )

    def evaluate(self, batch) -> float:
        assert self.eval_fn is not None
        return float(self.eval_fn(self.params, batch))


# ---------------------------------------------------------------------------
# factory (the CLI surface)
# ---------------------------------------------------------------------------


def make_sim_engine(
    engine: str,
    loss_fn,
    params,
    cfg,
    *,
    sim_profile: Optional[str] = None,
    fleet: Optional[Fleet] = None,
    seed: int = 0,
    buffer_size: Optional[int] = None,
    staleness_power: float = 0.5,
    num_edges: int = 2,
    edge_rounds: int = 1,
    edge_wire_codec=None,
    **kw,
):
    """Build a simulation engine from CLI-style specs.

    ``engine``: ``sync`` | ``async`` | ``hier``.  ``sim_profile`` is a
    :meth:`Fleet.from_spec` string (default ``uniform``); an explicit
    ``fleet`` overrides it.
    """
    if fleet is None:
        fleet = Fleet.from_spec(sim_profile or "uniform", cfg.num_clients, seed=seed)
    if engine == "sync":
        return SyncSimEngine(loss_fn, params, cfg, fleet=fleet, **kw)
    if engine == "async":
        return AsyncFederatedEngine(
            loss_fn, params, cfg, fleet=fleet, buffer_size=buffer_size,
            staleness_power=staleness_power, **kw,
        )
    if engine == "hier":
        # loud, not lossy: the hierarchical engine supports neither
        # checkpointing nor Participation policies — refusing beats
        # silently dropping the user's request
        participation = kw.pop("participation", None)
        if participation is not None and participation.mode != "full":
            raise ValueError(
                "the hier engine runs full participation within each edge; "
                f"got participation mode {participation.mode!r}"
            )
        if kw.pop("checkpoint_dir", None) or kw.pop("checkpoint_every", 0):
            raise ValueError(
                "the hier engine does not support checkpointing yet"
            )
        kw.pop("checkpoint_meta", None)  # nothing to stamp without checkpoints
        return HierarchicalEngine(
            loss_fn, params, cfg, fleet=fleet, num_edges=num_edges,
            edge_rounds=edge_rounds, edge_wire_codec=edge_wire_codec, **kw,
        )
    raise ValueError(
        f"unknown engine {engine!r}; expected sync | async | hier"
    )
