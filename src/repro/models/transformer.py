"""Block assembly: attention/Mamba/RWKV mixers + MLP/MoE, scanned stacks.

Layer stacks are organized as *superblocks*: the repeating
``cfg.block_pattern`` is unrolled inside a ``lax.scan`` body whose xs are
the per-position parameter stacks (leading dim = number of superblocks).
This preserves the true layer interleaving (e.g. Jamba's 7:1 mamba:attn)
while keeping the lowered HLO one-superblock-sized — essential for CPU
compile times of the 512-device dry-run.

Serving: every mixer exposes a cache slice; the same scan threads cache
slices through as scan ys, so decode is a single fused HLO too.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.config import ModelConfig
from repro.models.layers import (
    Builder,
    apply_linear,
    apply_rope,
    attention,
    rms_norm,
)
from repro.models.moe import build_moe, moe_block
from repro.models.ssm import (
    build_mamba,
    build_rwkv,
    mamba_init_state,
    mamba_mix,
    rwkv_init_state,
    rwkv_mix,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------


def build_attn(b: Builder, prefix: str, cfg: ModelConfig, n_blocks: int, *, cross: bool = False):
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    bs, ba = (n_blocks,), ("layers",)
    b.linear(f"{prefix}/q", d, H * hd, li="embed", lo="heads",
             batch_shape=bs, batch_axes=ba, bias=cfg.qkv_bias)
    b.linear(f"{prefix}/k", d, Hkv * hd, li="embed", lo="kv_heads",
             batch_shape=bs, batch_axes=ba, bias=cfg.qkv_bias)
    b.linear(f"{prefix}/v", d, Hkv * hd, li="embed", lo="kv_heads",
             batch_shape=bs, batch_axes=ba, bias=cfg.qkv_bias)
    b.linear(f"{prefix}/o", H * hd, d, li="heads", lo="embed",
             batch_shape=bs, batch_axes=ba)
    if cfg.qk_norm:
        b.vector(f"{prefix}/q_norm", bs + (hd,), axes=ba + (None,))
        b.vector(f"{prefix}/k_norm", bs + (hd,), axes=ba + (None,))
    if cross:
        b.linear(f"{prefix}/xq", d, H * hd, li="embed", lo="heads",
                 batch_shape=bs, batch_axes=ba)
        b.linear(f"{prefix}/xk", d, Hkv * hd, li="embed", lo="kv_heads",
                 batch_shape=bs, batch_axes=ba)
        b.linear(f"{prefix}/xv", d, Hkv * hd, li="embed", lo="kv_heads",
                 batch_shape=bs, batch_axes=ba)
        b.linear(f"{prefix}/xo", H * hd, d, li="heads", lo="embed",
                 batch_shape=bs, batch_axes=ba)
        b.vector(f"{prefix}/ln_x", bs + (d,), axes=ba + (None,))


def build_mlp(b: Builder, prefix: str, cfg: ModelConfig, n_blocks: int):
    d, dff = cfg.d_model, cfg.d_ff
    bs, ba = (n_blocks,), ("layers",)
    if cfg.gated_mlp:
        b.linear(f"{prefix}/gate", d, dff, li="embed", lo="ffn",
                 batch_shape=bs, batch_axes=ba)
    b.linear(f"{prefix}/up", d, dff, li="embed", lo="ffn",
             batch_shape=bs, batch_axes=ba)
    b.linear(f"{prefix}/down", dff, d, li="ffn", lo="embed",
             batch_shape=bs, batch_axes=ba)


def build_block(b: Builder, prefix: str, kind: str, cfg: ModelConfig,
                n_blocks: int, *, moe_here: bool, cross: bool = False):
    bs, ba = (n_blocks,), ("layers",)
    b.vector(f"{prefix}/ln1", bs + (cfg.d_model,), axes=ba + (None,))
    b.vector(f"{prefix}/ln2", bs + (cfg.d_model,), axes=ba + (None,))
    if kind == "attn":
        build_attn(b, f"{prefix}/attn", cfg, n_blocks, cross=cross)
    elif kind == "mamba":
        build_mamba(b, f"{prefix}/mamba", cfg, n_blocks)
    elif kind == "rwkv":
        build_rwkv(b, f"{prefix}/rwkv", cfg, n_blocks)
    else:
        raise ValueError(kind)
    if moe_here:
        build_moe(b, f"{prefix}/moe", cfg, n_blocks)
    else:
        build_mlp(b, f"{prefix}/mlp", cfg, n_blocks)


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def attn_mix(
    p: dict,
    x: Array,
    cfg: ModelConfig,
    *,
    positions: Array,
    cache: Optional[dict],
    causal: bool = True,
    cross_kv: Optional[Tuple[Array, Array]] = None,
    use_rope: bool = True,
):
    """Self-attention (+ optional cached decode, + optional cross-attn block).

    cache: {"k": (B,S,Hkv,hd), "v": ..., "idx": scalar int32} or None.
    A *per-slot* cache carries ``idx`` of shape (B,) instead — one write
    position per sequence (continuous batching admits requests into freed
    slots, so rows decode at different depths); ``positions`` is then
    (B, T).  Returns (y, new_cache).
    """
    B, T, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = apply_linear(p["q"], x, bias=p.get("q_b"), kernels=cfg.kernels).reshape(B, T, H, hd)
    k = apply_linear(p["k"], x, bias=p.get("k_b"), kernels=cfg.kernels).reshape(B, T, Hkv, hd)
    v = apply_linear(p["v"], x, bias=p.get("v_b"), kernels=cfg.kernels).reshape(B, T, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # context parallelism: queries stay sequence-sharded; keys/values are
    # gathered (small for GQA) so every shard attends over the full context
    q = sharding.shard(q, "batch", "seq", None, None)

    new_cache = None
    if cache is not None:
        S = cache["k"].shape[1]
        if cfg.sliding_window and S < cfg.sliding_window and S < 4096:
            raise ValueError("cache smaller than the attention window")
        # ring/linear write at idx (mod cache length).  NOTE: a multi-token
        # write (prefill) must not wrap: callers size the prefill cache at
        # ≥ prompt length; decode writes are single-token and wrap freely.
        idx = cache["idx"]
        if idx.ndim:  # per-slot (B,): each row writes at its own position
            slot = (idx % S).astype(jnp.int32)
            write = lambda cb, xb, sb: jax.lax.dynamic_update_slice(
                cb, xb, (sb, 0, 0)
            )
            ck = jax.vmap(write)(cache["k"], k.astype(cache["k"].dtype), slot)
            cv = jax.vmap(write)(cache["v"], v.astype(cache["v"].dtype), slot)
            s_idx = jnp.arange(S, dtype=jnp.int32)[None, :]
            newest = idx.astype(jnp.int32)[:, None] + T - 1  # (B, 1)
            kv_pos = newest - ((newest - s_idx) % S)  # (B, S)
        else:
            slot = (idx % S).astype(jnp.int32)
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            # absolute position held by each slot: the newest p ≤ newest-
            # written position with p % S == s; never written → negative.
            s_idx = jnp.arange(S, dtype=jnp.int32)
            newest = idx.astype(jnp.int32) + T - 1
            kv_pos = newest - ((newest - s_idx) % S)
        kv_pos = jnp.where(kv_pos < 0, jnp.int32(-(10**9)), kv_pos)
        y = attention(
            q, ck, cv,
            q_positions=positions, kv_positions=kv_pos,
            causal=causal, sliding_window=cfg.sliding_window,
            q_chunk=cfg.attn_q_chunk,
        )
        new_cache = {"k": ck, "v": cv, "idx": cache["idx"] + T}
    else:
        kv_pos = positions
        y = attention(
            q, k, v,
            q_positions=positions, kv_positions=kv_pos,
            causal=causal, sliding_window=cfg.sliding_window,
            q_chunk=cfg.attn_q_chunk,
        )

    y = sharding.shard(y, "batch", "seq", None, None)
    out = apply_linear(p["o"], y.reshape(B, T, H * hd), kernels=cfg.kernels)

    if cross_kv is not None:
        # cross_kv: encoder hidden states (B, Tenc, d)
        xh = rms_norm(x + out, p["ln_x"], cfg.norm_eps)
        qx = apply_linear(p["xq"], xh, kernels=cfg.kernels).reshape(B, T, H, hd)
        Tenc = cross_kv.shape[1]
        ek = apply_linear(p["xk"], cross_kv, kernels=cfg.kernels).reshape(B, Tenc, Hkv, hd)
        ev = apply_linear(p["xv"], cross_kv, kernels=cfg.kernels).reshape(B, Tenc, Hkv, hd)
        yx = attention(
            qx, ek, ev,
            q_positions=positions,
            kv_positions=jnp.arange(Tenc),
            causal=False, sliding_window=0, q_chunk=cfg.attn_q_chunk,
        )
        out = out + apply_linear(p["xo"], yx.reshape(B, T, H * hd), kernels=cfg.kernels)
    return out, new_cache


def mlp_apply(p: dict, x: Array, cfg: ModelConfig) -> Array:
    if cfg.gated_mlp:
        h = jax.nn.silu(
            apply_linear(p["gate"], x, kernels=cfg.kernels)
        ) * apply_linear(p["up"], x, kernels=cfg.kernels)
    else:
        h = jax.nn.gelu(apply_linear(p["up"], x, kernels=cfg.kernels))
    h = sharding.shard(h, "batch", "seq", None)
    return apply_linear(p["down"], h, kernels=cfg.kernels)


def block_apply(
    p: dict,
    kind: str,
    x: Array,
    cfg: ModelConfig,
    *,
    positions: Array,
    cache: Optional[dict],
    causal: bool = True,
    cross_kv=None,
    use_rope: bool = True,
):
    """One (mixer + FFN/MoE) block with pre-norm residuals.

    Returns (x, new_cache, aux_loss)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        mix_out, new_cache = attn_mix(
            p["attn"], h, cfg, positions=positions, cache=cache,
            causal=causal, cross_kv=cross_kv, use_rope=use_rope,
        )
    elif kind == "mamba":
        mix_out, new_cache = mamba_mix(p["mamba"], h, cfg, state=cache)
    elif kind == "rwkv":
        mix_out, new_cache = rwkv_mix(p["rwkv"], h, cfg, state=cache)
    else:
        raise ValueError(kind)
    x = x + mix_out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        ffn_out, aux = moe_block(p["moe"], h2, cfg)
    else:
        ffn_out, aux = mlp_apply(p["mlp"], h2, cfg), jnp.zeros((), jnp.float32)
    return x + ffn_out, new_cache, aux


# ---------------------------------------------------------------------------
# scanned stack over superblocks
# ---------------------------------------------------------------------------


def init_cache_stack(
    cfg: ModelConfig, batch: int, cache_len: int, dtype, *,
    per_slot: bool = False,
) -> dict:
    """Per-position cache stacks (leading dim = superblocks).

    ``per_slot=True`` makes the attention write index a vector over the
    batch — (NB, batch) instead of (NB,) — so a serving engine can hold
    sequences at different positions in one decode batch."""
    NB = cfg.superblocks
    Hkv, hd = cfg.num_kv_heads, cfg.hd
    idx_shape = (NB, batch) if per_slot else (NB,)
    caches = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            c = {
                "k": jnp.zeros((NB, batch, cache_len, Hkv, hd), dtype),
                "v": jnp.zeros((NB, batch, cache_len, Hkv, hd), dtype),
                "idx": jnp.zeros(idx_shape, jnp.int32),
            }
        elif kind == "mamba":
            c = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (NB,) + x.shape).copy(),
                mamba_init_state(cfg, batch, dtype),
            )
        elif kind == "rwkv":
            c = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (NB,) + x.shape).copy(),
                rwkv_init_state(cfg, batch, dtype),
            )
        else:
            raise ValueError(kind)
        caches[f"pos{i}"] = c
    return caches


def stack_apply(
    blocks: dict,
    x: Array,
    cfg: ModelConfig,
    *,
    positions: Array,
    caches: Optional[dict] = None,
    causal: bool = True,
    cross_kv=None,
    use_rope: bool = True,
    pattern: Optional[Tuple[str, ...]] = None,
    moe_positions: Optional[Tuple[bool, ...]] = None,
):
    """Scan the superblock stack.  blocks/caches: dicts of stacked params.

    Returns (x, new_caches, total_aux)."""
    pattern = pattern or cfg.block_pattern

    # remat per *layer*, not per superblock: a superblock backward would
    # otherwise hold every member layer's recomputed internals live at once
    # (ruinous for Jamba's 7 Mamba layers per superblock).
    def one_block(kind):
        def f(p_i, h, c_i):
            # pin the layout of the residual stream at every layer: the
            # checkpoint below saves this tensor, and an unpinned save point
            # is replicated (72 × full-T·d f32 on jamba — dozens of GiB)
            h = sharding.shard(h, "batch", "seq", None)
            return block_apply(
                p_i, kind, h, cfg,
                positions=positions, cache=c_i, causal=causal,
                cross_kv=cross_kv, use_rope=use_rope,
            )

        if cfg.remat:
            return jax.checkpoint(f, prevent_cse=False)
        return f

    block_fns = [one_block(kind) for kind in pattern]

    def superblock(carry, xs):
        h, aux = carry
        h = sharding.shard(h, "batch", "seq", None)
        p_sb, c_sb = xs
        new_c = {}
        for i, _kind in enumerate(pattern):
            c_i = c_sb.get(f"pos{i}") if c_sb is not None else None
            h, nc, a = block_fns[i](p_sb[f"pos{i}"], h, c_i)
            if nc is not None:
                new_c[f"pos{i}"] = nc
            aux = aux + a
        return (h, aux), (new_c if new_c else None)

    body = superblock

    if caches is None:
        (h, aux), _ = jax.lax.scan(
            lambda c, p_sb: (body(c, (p_sb, None))[0], ()),
            (x, jnp.zeros((), jnp.float32)),
            blocks,
        )
        return h, None, aux
    (h, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (blocks, caches)
    )
    return h, new_caches, aux
