"""Model configuration dataclasses covering all assigned architectures.

One :class:`ModelConfig` describes any of the six architecture families
(dense / moe / audio-enc-dec / vlm / hybrid / ssm) via optional sub-configs.
``block_pattern`` is the repeating *superblock* of sequence-mixer types —
``("attn",)`` for pure transformers, ``("mamba",)*7 + ("attn",)``-style for
Jamba, ``("rwkv",)`` for RWKV6 — scanned over ``num_layers //
len(block_pattern)`` repetitions so the lowered HLO stays compact at
512 host devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    num_shared_experts: int = 0
    d_shared: int = 0  # hidden size of the always-on shared expert block
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    every_k_layers: int = 1  # MoE on layer i iff i % every_k == offset
    offset: int = 0


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model/16)
    # time-chunk for the selective scan: the (B, chunk, d_inner, N) workspace
    # is the layer's peak memory; the recurrence carries h across chunks.
    scan_chunk: int = 512


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64  # rank of the data-dependent decay LoRA (Finch)
    chunk_len: int = 64  # chunked linear-attention block length


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Audio (whisper-style) encoder: consumes stub frame embeddings."""

    num_layers: int
    num_frames: int = 1500  # 30 s of audio after the conv frontend (stubbed)


@dataclasses.dataclass(frozen=True)
class LowRankPolicy:
    """Which weight matrices FeDLRT factorizes, and at what rank budget.

    ``r_max = min(rank_frac · min(n_in, n_out), r_cap)`` rounded up to a
    multiple of 8 (TPU sublane); matrices with ``min(n_in,n_out) < min_dim``
    stay dense (norm scales, tiny routers, biases are always dense).
    """

    enable: bool = True
    rank_frac: float = 0.125
    r_cap: int = 256
    min_dim: int = 256
    factorize_embed: bool = True
    factorize_head: bool = True
    init_rank_frac: float = 1.0  # initial rank as a fraction of r_max

    def r_max_for(self, n_in: int, n_out: int) -> int:
        r = int(self.rank_frac * min(n_in, n_out))
        r = min(r, self.r_cap, min(n_in, n_out) // 2)
        return max(8 * ((r + 7) // 8), 1)

    def applies(self, n_in: int, n_out: int) -> bool:
        return self.enable and min(n_in, n_out) >= self.min_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | audio | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    gated_mlp: bool = True  # SwiGLU (all assigned LLMs); False → GELU MLP
    sliding_window: int = 0  # 0 → full causal attention
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    block_pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision_tokens: int = 0  # >0 → VLM: stub patch embeddings prepended
    tie_embeddings: bool = False
    lowrank: LowRankPolicy = dataclasses.field(default_factory=LowRankPolicy)
    compute_dtype: str = "bfloat16"
    # factor/param storage dtype; server-side QR/SVD always upcasts to f32.
    # bf16 halves the (replicated) factor footprint on the production mesh;
    # reduced smoke configs use f32 end-to-end.
    param_dtype: str = "bfloat16"
    remat: bool = True
    attn_q_chunk: int = 1024  # blockwise-attention query chunk (memory bound)
    loss_seq_chunk: int = 0  # 0 → unchunked cross-entropy
    # Pallas low-rank kernel dispatch for every factorized matmul:
    #   "auto"      fused xus/avt/atb kernels on TPU without an active GSPMD
    #               mesh (pallas_call has no SPMD partitioning rule), jnp
    #               reference elsewhere
    #   "interpret" force the kernel path through the Pallas interpreter on
    #               any backend (validation of the TPU path — slow, tests)
    #   "off"       plain jnp chain (no custom VJP)
    kernels: str = "auto"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def superblocks(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            self.name,
            self.num_layers,
            self.block_pattern,
        )
        return self.num_layers // len(self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode (500k cache) is supported.

        SSM/linear-RNN and hybrid (Mamba-dominant) architectures qualify,
        as do sliding-window attention archs (per-token cost bounded by the
        window).  Pure full-attention archs are skipped (DESIGN.md §4)."""
        mixers = set(self.block_pattern)
        if mixers & {"mamba", "rwkv"}:
            return True
        return self.sliding_window > 0

    def moe_on_layer(self, i: int) -> bool:
        return (
            self.moe is not None
            and i % self.moe.every_k_layers == self.moe.offset
        )


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: ≤2 superblocks, d_model ≤ 512, ≤4 experts."""
    pat = cfg.block_pattern
    d_model = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    changes = dict(
        num_layers=len(pat) * min(2, cfg.superblocks),
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        lowrank=dataclasses.replace(cfg.lowrank, min_dim=32, rank_frac=0.25),
        compute_dtype="float32",
        param_dtype="float32",
        attn_q_chunk=64,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=min(cfg.moe.d_expert, 128),
            d_shared=min(cfg.moe.d_shared, 128) if cfg.moe.d_shared else 0,
        )
    if cfg.mamba is not None:
        changes["mamba"] = dataclasses.replace(cfg.mamba, d_state=8)
    if cfg.rwkv is not None:
        changes["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_dim=32, decay_lora=16, chunk_len=16
        )
    if cfg.encoder is not None:
        changes["encoder"] = dataclasses.replace(
            cfg.encoder, num_layers=2, num_frames=32
        )
    if cfg.vision_tokens:
        changes["vision_tokens"] = 16
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
