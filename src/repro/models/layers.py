"""Layer primitives: parameter builder, maybe-factorized linears, norms,
rotary embeddings, and (blockwise) attention.

Every weight matrix goes through :meth:`Builder.linear`, which decides —
from the :class:`LowRankPolicy` — whether the layer is a FeDLRT-managed
:class:`LowRankFactor` or a plain dense array, and registers the matching
PartitionSpec.  Model code is agnostic: :func:`apply_linear` dispatches on
the leaf type.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.factorization import (
    LowRankFactor,
    init_factor,
    is_factor,
    lr_matmul,
)
from repro.kernels.ops import lowrank_apply_nd, use_kernels_for
from repro.models import sharding
from repro.models.config import LowRankPolicy

Array = jax.Array


# ---------------------------------------------------------------------------
# parameter builder
# ---------------------------------------------------------------------------


class Builder:
    """Collects (params, specs) as parallel nested dicts keyed by '/'-paths."""

    def __init__(self, key: Array, policy: LowRankPolicy, dtype=jnp.float32):
        self.policy = policy
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}
        self._key = key

    def next_key(self) -> Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _put(self, path: str, value, spec_leaf):
        parts = path.split("/")
        p, s = self.params, self.specs
        for part in parts[:-1]:
            p = p.setdefault(part, {})
            s = s.setdefault(part, {})
        assert parts[-1] not in p, f"duplicate param {path}"
        p[parts[-1]] = value
        s[parts[-1]] = spec_leaf

    def linear(
        self,
        path: str,
        n_in: int,
        n_out: int,
        *,
        li: Optional[str] = None,
        lo: Optional[str] = None,
        batch_shape: Tuple[int, ...] = (),
        batch_axes: Tuple[Optional[str], ...] = (),
        bias: bool = False,
        force_dense: bool = False,
        init_scale: Optional[float] = None,
    ):
        """A (possibly factorized) ``n_in → n_out`` weight at ``path``.

        ``batch_shape``/``batch_axes`` add leading stacking dims (layer
        stack, experts).  Returns nothing; parameters are collected.
        """
        assert len(batch_shape) == len(batch_axes)
        if self.policy.applies(n_in, n_out) and not force_dense:
            r_max = self.policy.r_max_for(n_in, n_out)
            init_rank = max(int(self.policy.init_rank_frac * r_max), 1)
            f = init_factor(
                self.next_key(),
                n_in,
                n_out,
                r_max,
                init_rank=init_rank,
                dtype=self.dtype,
                batch_shape=batch_shape,
            )
            self._put(path, f, sharding.factor_spec(batch_axes, li, lo))
        else:
            scale = init_scale if init_scale is not None else (2.0 / n_in) ** 0.5
            w = scale * jax.random.normal(
                self.next_key(), batch_shape + (n_in, n_out), dtype=self.dtype
            )
            # dense weights can use each mesh axis once: if both logical dims
            # resolve to the same axis (e.g. embed & ffn → model), keep the
            # output dim sharded (megatron convention)
            if sharding._resolve(li) is not None and sharding._resolve(
                li
            ) == sharding._resolve(lo):
                li = None
            self._put(path, w, sharding.spec(*batch_axes, li, lo))
        if bias:
            self._put(
                path + "_b",
                jnp.zeros(batch_shape + (n_out,), self.dtype),
                sharding.spec(*batch_axes, lo),
            )

    def vector(self, path: str, shape, *, axes=(), init: float = 1.0):
        v = jnp.full(shape, init, self.dtype)
        self._put(path, v, sharding.spec(*axes))

    def normal(self, path: str, shape, *, axes=(), scale: float = 0.02):
        v = scale * jax.random.normal(self.next_key(), shape, dtype=self.dtype)
        self._put(path, v, sharding.spec(*axes))

    def build(self):
        return self.params, self.specs


# ---------------------------------------------------------------------------
# apply helpers
# ---------------------------------------------------------------------------


def apply_linear(
    w,
    x: Array,
    *,
    bias: Optional[Array] = None,
    dtype=None,
    kernels: str = "off",
) -> Array:
    """``y = x @ W (+ b)`` dispatching on dense vs LowRankFactor leaves.

    ``kernels`` (a :data:`repro.kernels.KERNEL_POLICIES` policy, usually
    ``ModelConfig.kernels``) routes factor leaves — LowRankFactor *and* the
    client loop's 2r-wide AugmentedFactor — through the fused Pallas
    ``xus``/``avt`` chain with the ``atb``-backed custom VJP.  The
    augmented factors' active-direction masking survives the kernel path
    unchanged: inactive basis columns and coefficient blocks are exactly
    zero (factorization.py invariant), so the fused chain equals the
    masked reference chain.
    """
    dtype = dtype or x.dtype
    if is_factor(w):
        if kernels != "off":
            y = lowrank_apply_nd(
                x,
                w.U.astype(dtype),
                w.S.astype(dtype),
                w.V.astype(dtype),
                use_kernels_for(kernels),
            )
        else:
            # rank-bottleneck chain; never materializes the n_in×n_out matrix
            y = (
                jnp.matmul(jnp.matmul(x, w.U.astype(dtype)), w.S.astype(dtype))
                @ w.V.T.astype(dtype)
            )
    else:
        y = jnp.matmul(x, w.astype(dtype))
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def apply_embedding(w, tokens: Array, *, dtype=jnp.float32, kernels: str = "off") -> Array:
    """Token embedding lookup (gather).

    The embedding factor's U is kept *replicated* (it is small once
    factorized: vocab × r), so the gather is local on every shard — a
    one-hot matmul against a vocab-sharded table would materialize a
    (B, T, vocab) temp, which dominated dry-run memory.

    Kernel path: the gathered rows ``u = U[tokens]`` play the activation
    role of the fused chain with the coefficient as the projection —
    ``((u S) I) Vᵀ`` — so ``y = u S Vᵀ`` reuses :func:`lowrank_apply_nd`'s
    custom VJP (dS arrives through the kernel's dU slot).
    """
    if is_factor(w):
        u = jnp.take(w.U, tokens, axis=0).astype(dtype)  # (..., r)
        if kernels != "off":
            eye = jnp.eye(w.S.shape[-1], dtype=dtype)
            return lowrank_apply_nd(
                u, w.S.astype(dtype), eye, w.V.astype(dtype),
                use_kernels_for(kernels),
            )
        return jnp.matmul(u, w.S.astype(dtype)) @ w.V.T.astype(dtype)
    return jnp.take(w, tokens, axis=0).astype(dtype)


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(T: int, d: int, dtype=jnp.float32) -> Array:
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d)
    )
    pe = jnp.zeros((T, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, blockwise over query chunks)
# ---------------------------------------------------------------------------


def _gqa_scores(q: Array, k: Array) -> Array:
    """q: (B,Tq,H,hd), k: (B,Tk,Hkv,hd) → scores (B,H,Tq,Tk) with GQA."""
    B, Tq, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Tq, Hkv, g, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    return s.reshape(B, Hkv * g, Tq, k.shape[1])


def _gqa_combine(p: Array, v: Array) -> Array:
    """p: (B,H,Tq,Tk), v: (B,Tk,Hkv,hd) → (B,Tq,H,hd)."""
    B, H, Tq, Tk = p.shape
    Hkv = v.shape[2]
    g = H // Hkv
    pg = p.reshape(B, Hkv, g, Tq, Tk)
    o = jnp.einsum("bkgqt,btkd->bqkgd", pg, v)
    return o.reshape(B, Tq, H, v.shape[-1])


def attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    q_positions: Array,
    kv_positions: Array,
    causal: bool = True,
    sliding_window: int = 0,
    q_chunk: int = 0,
) -> Array:
    """Masked dot-product attention, blockwise over query chunks.

    Blockwise evaluation bounds the live score tensor at
    ``(B, H, q_chunk, Tk)`` — O(T·chunk) memory for O(T²) compute — which
    is what lets prefill_32k lower within HBM on the target mesh.  The
    mask combines causality and an optional sliding window; ``positions``
    are absolute so the same code serves ragged decode (cache) layouts.

    Positions may carry a leading batch dim — ``q_positions`` ``(B, Tq)``
    and/or ``kv_positions`` ``(B, Tk)`` — for *per-slot* ragged decode
    (continuous batching: each cache row at its own sequence position).
    Batched positions take the single-block path; per-slot decode is
    ``Tq == 1``, so chunking never applies there anyway.
    """
    Tq = q.shape[1]
    q_chunk = q_chunk or Tq
    q_chunk = min(q_chunk, Tq)
    if q_positions.ndim > 1 or kv_positions.ndim > 1:
        q_chunk = Tq
    # Under sequence parallelism each shard already holds only Tq/msize
    # query rows; chunking below that size fights the sharding (the chunk
    # reshape forces per-iteration q gathers).  Skip chunking when the
    # per-shard score block is small enough.
    from repro.utils import meshctx

    if meshctx.mesh() is not None and "model" in meshctx.axis_names():
        local_rows = Tq // meshctx.axis_size("model")
        if 0 < local_rows <= q_chunk:
            q_chunk = Tq

    def mask_for(qpos, kpos):
        # negative kv positions mark never-written cache slots; the
        # broadcasting form yields (Tq, Tk) for shared positions and
        # (B, Tq, Tk) when either side is per-slot
        m = (kpos[..., None, :] >= 0) & (qpos[..., :, None] >= 0)
        if causal:
            m &= kpos[..., None, :] <= qpos[..., :, None]
        if sliding_window:
            m &= kpos[..., None, :] > qpos[..., :, None] - sliding_window
        return m

    def block(qc, qpos):
        s = _gqa_scores(qc, k).astype(jnp.float32)  # (B,H,qc,Tk)
        m = mask_for(qpos, kv_positions)
        m = m[:, None] if m.ndim == 3 else m[None, None]
        s = jnp.where(m, s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return _gqa_combine(p, v)

    if Tq == q_chunk:
        return block(q, q_positions)

    n_chunks = -(-Tq // q_chunk)
    pad = n_chunks * q_chunk - Tq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pp = jnp.pad(q_positions, (0, pad), constant_values=-1)
    qs = qp.reshape(q.shape[0], n_chunks, q_chunk, *q.shape[2:])
    ps = pp.reshape(n_chunks, q_chunk)

    def scan_body(_, inp):
        qc, qpos = inp
        return (), block(qc, qpos)

    _, outs = jax.lax.scan(
        scan_body, (), (qs.swapaxes(0, 1), ps)
    )  # outs: (n_chunks, B, q_chunk, H, hd)
    out = outs.swapaxes(0, 1).reshape(q.shape[0], n_chunks * q_chunk, *q.shape[2:])
    return out[:, :Tq]
