"""Top-level model: init / train-loss / serve — uniform over all families.

``build_model(cfg)`` returns a :class:`Model` with
- ``init(key) → (params, specs)``: params pytree mixing LowRankFactor and
  dense leaves + matching PartitionSpec pytree,
- ``loss_fn(params, batch) → scalar``: next-token cross-entropy (+ MoE aux),
  the function handed to ``fedlrt_round`` / baselines,
- ``serve_prefill(params, batch) → (logits, cache)`` and
  ``serve_step(params, cache, tokens) → (logits, cache)``: KV-cached decode.

Batch layouts by family (leaves may carry extra leading client axes):
  dense/moe/ssm/hybrid: {"tokens": (B, T+1) i32}
  vlm:   + {"vision_embeds": (B, n_vis, d) f32}  (stub frontend output)
  audio: {"frames": (B, n_frames, d) f32, "tokens": (B, T+1) i32}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.config import ModelConfig
from repro.models.layers import (
    Builder,
    apply_embedding,
    apply_linear,
    rms_norm,
    sinusoidal_positions,
)
from repro.models.transformer import (
    build_block,
    init_cache_stack,
    stack_apply,
)

Array = jax.Array


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def build_params(cfg: ModelConfig, key: Array):
    pol = cfg.lowrank
    pdt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    b = Builder(key, pol, dtype=pdt)
    NB = cfg.superblocks

    # embeddings / head.  embed stays replicated (gather must be local);
    # lm_head V is vocab-sharded (logits computed shard-local, CE reduces).
    b.linear(
        "embed", cfg.vocab_size, cfg.d_model, li=None, lo="embed",
        force_dense=not pol.factorize_embed,
    )
    b.linear(
        "lm_head", cfg.d_model, cfg.vocab_size, li="embed", lo="vocab",
        force_dense=not pol.factorize_head,
    )
    b.vector("final_norm", (cfg.d_model,))

    for i, kind in enumerate(cfg.block_pattern):
        moe_here = cfg.moe is not None and (
            i % cfg.moe.every_k_layers == cfg.moe.offset
        )
        build_block(
            b, f"blocks/pos{i}", kind, cfg, NB,
            moe_here=moe_here, cross=cfg.is_encdec,
        )

    if cfg.is_encdec:
        enc = cfg.encoder
        for i in range(1):  # encoder superblock pattern is ("attn",)
            build_block(
                b, f"enc_blocks/pos{i}", "attn", cfg, enc.num_layers,
                moe_here=False, cross=False,
            )
        b.vector("enc_norm", (cfg.d_model,))

    return b.build()


def _encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """Whisper-style encoder over stub frame embeddings (bidirectional)."""
    dt = _dtype(cfg)
    h = frames.astype(dt)
    h = h + sinusoidal_positions(h.shape[1], cfg.d_model, dt)[None]
    pos = jnp.arange(h.shape[1])
    h, _, _ = stack_apply(
        params["enc_blocks"], h, cfg, positions=pos, caches=None,
        causal=False, use_rope=False, pattern=("attn",),
    )
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _logits(params, h: Array, kernels: str = "off") -> Array:
    logits = apply_linear(params["lm_head"], h, kernels=kernels)
    # sequence-sharded logits: CE is elementwise over (B, T), so the whole
    # loss pipeline stays seq-parallel; vocab stays local to the shard.
    return sharding.shard(logits, "batch", "seq", None)


def _xent(logits: Array, labels: Array, mask: Optional[Array] = None) -> Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / (jnp.sum(mask) + 1e-6)
    return jnp.mean(nll)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[Array], Tuple[Any, Any]]
    loss_fn: Callable[[Any, Any], Array]
    serve_prefill: Callable[[Any, Any], Tuple[Array, Any]]
    serve_step: Callable[[Any, Any, Array], Tuple[Array, Any]]
    init_cache: Callable[[Any, int, int], Any]


def build_model(cfg: ModelConfig) -> Model:
    dt = _dtype(cfg)

    # ----------------------------------------------------------- training
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        # NOTE: the embedding output is deliberately NOT seq-sharded — the
        # backward of a gather with updates sharded over both the data and
        # the model axis trips an XLA SPMD-partitioner CHECK (scatter group
        # mismatch).  The first superblock constraint reshards to seq.
        # Lookup directly in compute dtype: the f32 intermediate was
        # all-gathered (1.75 GiB/device on qwen2 train) before the cast.
        emb = apply_embedding(params["embed"], inputs, dtype=dt, kernels=cfg.kernels)
        emb = sharding.shard(emb, "batch", None, None)

        cross_kv = None
        n_prefix = 0
        if cfg.family == "vlm" and "vision_embeds" in batch:
            vis = batch["vision_embeds"].astype(dt)
            emb = jnp.concatenate([vis, emb], axis=1)
            n_prefix = vis.shape[1]
        if cfg.is_encdec:
            cross_kv = _encode(params, batch["frames"], cfg)
            emb = emb + sinusoidal_positions(emb.shape[1], cfg.d_model, dt)[None]

        positions = jnp.arange(emb.shape[1])
        h, _, aux = _trunk_simple(params, emb, positions, cross_kv)
        h = h[:, n_prefix:]
        logits = _logits(params, h, cfg.kernels)
        return _xent(logits, labels) + aux.astype(jnp.float32)

    def _trunk_simple(params, h, positions, cross_kv):
        use_rope = not cfg.is_encdec
        h, caches, aux = stack_apply(
            params["blocks"], h, cfg, positions=positions, caches=None,
            causal=True, cross_kv=cross_kv, use_rope=use_rope,
        )
        return rms_norm(h, params["final_norm"], cfg.norm_eps), caches, aux

    # ------------------------------------------------------------ serving
    def init_cache(params, batch: int, cache_len: int, *, per_slot: bool = False):
        """``per_slot=True``: positions tracked per batch row — ``pos`` is
        (batch,) and the attention write indices are (NB, batch) — so a
        continuous-batching engine can admit a new request into a freed
        slot while the others keep decoding (decoder-only families)."""
        if per_slot and cfg.is_encdec:
            raise ValueError(
                "per-slot decode needs per-row positions; the enc-dec "
                "sinusoidal lookup indexes one shared position"
            )
        cache = {
            "stack": init_cache_stack(cfg, batch, cache_len, dt, per_slot=per_slot),
            "pos": jnp.zeros((batch,) if per_slot else (), jnp.int32),
        }
        if cfg.is_encdec:
            cache["enc_h"] = jnp.zeros(
                (batch, cfg.encoder.num_frames, cfg.d_model), dt
            )
        return cache

    def serve_prefill(params, batch, cache_len: int = 0, last_index=None):
        """Process the full prompt; returns (last-token logits, cache).

        ``last_index`` (traced i32, optional) reads the logits at that
        sequence position instead of the final one and stamps ``pos`` to
        ``last_index + 1`` — right-padded prompts stay exact: the causal
        mask keeps pad keys out of every real query, and the serving
        engine's slot insert truncates the cache index to the true length
        so stale pad entries are masked (kv_pos > newest ⇒ negative).
        """
        tokens = batch["tokens"]  # (B, S)
        B, S = tokens.shape
        emb = apply_embedding(
            params["embed"], tokens, dtype=jnp.float32, kernels=cfg.kernels
        ).astype(dt)
        if cfg.family == "vlm" and "vision_embeds" in batch:
            vis = batch["vision_embeds"].astype(dt)
            emb = jnp.concatenate([vis, emb], axis=1)
        cross_kv = None
        cache = init_cache(params, B, cache_len or emb.shape[1])
        if cfg.is_encdec:
            cross_kv = _encode(params, batch["frames"], cfg)
            cache["enc_h"] = cross_kv
            emb = emb + sinusoidal_positions(emb.shape[1], cfg.d_model, dt)[None]
        positions = jnp.arange(emb.shape[1])
        h, new_stack, _ = stack_apply(
            params["blocks"], emb, cfg, positions=positions,
            caches=cache["stack"], causal=True, cross_kv=cross_kv,
            use_rope=not cfg.is_encdec,
        )
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        cache["stack"] = new_stack
        if last_index is None:
            cache["pos"] = jnp.int32(emb.shape[1])
            h_last = h[:, -1:]
        else:
            last = jnp.asarray(last_index, jnp.int32)
            cache["pos"] = last + 1
            h_last = jax.lax.dynamic_slice_in_dim(h, last, 1, axis=1)
        logits = _logits(params, h_last, cfg.kernels)[:, 0]
        return logits, cache

    def serve_step(params, cache, tokens):
        """One decode step.  tokens: (B, 1) → (logits (B, vocab), cache).

        With a per-slot cache (``pos`` shaped (B,)), positions broadcast
        to (B, T) and every row attends at its own depth."""
        B = tokens.shape[0]
        emb = apply_embedding(
            params["embed"], tokens, dtype=jnp.float32, kernels=cfg.kernels
        ).astype(dt)
        pos = cache["pos"]
        positions = pos[..., None] + jnp.arange(tokens.shape[1])
        cross_kv = cache.get("enc_h") if cfg.is_encdec else None
        if cfg.is_encdec:
            pe = sinusoidal_positions(8192, cfg.d_model, dt)
            emb = emb + jax.lax.dynamic_slice_in_dim(pe, pos, 1, 0)[None]
        h, new_stack, _ = stack_apply(
            params["blocks"], emb, cfg, positions=positions,
            caches=cache["stack"], causal=True, cross_kv=cross_kv,
            use_rope=not cfg.is_encdec,
        )
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        new_cache = dict(cache, stack=new_stack, pos=pos + tokens.shape[1])
        logits = _logits(params, h[:, -1:], cfg.kernels)[:, 0]
        return logits, new_cache

    return Model(
        cfg=cfg,
        init=lambda key: build_params(cfg, key),
        loss_fn=loss_fn,
        serve_prefill=serve_prefill,
        serve_step=serve_step,
        init_cache=init_cache,
    )
