"""Mixture-of-Experts block with capacity-based routing and low-rank experts.

Routing is the standard TPU-friendly sort-based dispatch (no giant one-hot
dispatch tensors): per expert, tokens that selected it are ranked by
position and the first ``capacity`` are gathered into an ``(E, cap, d)``
batch.  Expert weights are *stacked factorized* matrices ``U:(E,d,r)``
sharded over the ``experts``→``model`` mesh axis (expert parallelism); the
scatter-combine reduces across the expert axis, which GSPMD lowers to the
expert-parallel all-reduce/all-to-all family of collectives.

FeDLRT applies per expert: every expert's ``(U_e, S_e, V_e)`` follows the
shared-basis augmentation/truncation like any other factor leaf (the stacked
leading axis is just a batch dim to the batched QR/SVD of core.dlrt) — i.e.
each expert learns its own adaptive rank, and only ``O(E·d·r)`` is ever
communicated instead of ``O(E·d·d_ff)``: the paper's saving is largest
exactly here, as MoE weights dominate the parameter count.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.factorization import is_factor, lr_matmul
from repro.models import sharding
from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import Builder

Array = jax.Array


def build_moe(b: Builder, prefix: str, cfg: ModelConfig, n_blocks: int):
    """Register MoE params for a scanned stack of ``n_blocks`` layers."""
    m = cfg.moe
    d = cfg.d_model
    bs, ba = (n_blocks, m.num_experts), ("layers", "experts")
    b.linear(f"{prefix}/router", d, m.num_experts, batch_shape=(n_blocks,),
             batch_axes=("layers",), force_dense=True, init_scale=0.02)
    # expert-parallel only: the expert dim carries the "model" axis, so the
    # per-expert feature dims must stay unsharded (a mesh axis can appear
    # once per spec)
    b.linear(f"{prefix}/up", d, m.d_expert, li=None, lo=None,
             batch_shape=bs, batch_axes=ba)
    b.linear(f"{prefix}/gate", d, m.d_expert, li=None, lo=None,
             batch_shape=bs, batch_axes=ba)
    b.linear(f"{prefix}/down", m.d_expert, d, li=None, lo=None,
             batch_shape=bs, batch_axes=ba)
    if m.num_shared_experts:
        ds = m.d_shared or m.d_expert * m.num_shared_experts
        b.linear(f"{prefix}/shared_up", d, ds, li="embed", lo="ffn",
                 batch_shape=(n_blocks,), batch_axes=("layers",))
        b.linear(f"{prefix}/shared_gate", d, ds, li="embed", lo="ffn",
                 batch_shape=(n_blocks,), batch_axes=("layers",))
        b.linear(f"{prefix}/shared_down", ds, d, li="ffn", lo="embed",
                 batch_shape=(n_blocks,), batch_axes=("layers",))


def _stacked_linear(w, x: Array, kernels: str = "off") -> Array:
    """x: (E, cap, n_in) through stacked (E, n_in, n_out) dense or factor.

    Factor leaves under a kernel policy go through
    :func:`repro.kernels.lowrank_apply_nd`, which vmaps the fused chain
    over the stacked expert axis (expert-wise grids on TPU).
    """
    if is_factor(w):
        if kernels != "off":
            return lr_matmul(x, w, kernels=kernels)
        h = jnp.einsum("ecd,edr->ecr", x, w.U.astype(x.dtype))
        h = jnp.einsum("ecr,ers->ecs", h, w.S.astype(x.dtype))
        return jnp.einsum("ecs,efs->ecf", h, w.V.astype(x.dtype))
    return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))


def _dense_linear(w, x: Array, kernels: str = "off") -> Array:
    if is_factor(w):
        if kernels != "off":
            return lr_matmul(x, w, kernels=kernels)
        h = (x @ w.U.astype(x.dtype)) @ w.S.astype(x.dtype)
        return h @ w.V.T.astype(x.dtype)
    return x @ w.astype(x.dtype)


def moe_block(p: dict, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Apply one MoE FFN. x: (B, T, d) → (y, aux_loss).

    **Grouped (per-row) routing**: each batch row routes its own T tokens
    with capacity ``1.25·k·T/E``.  Dispatch gathers and the combine
    scatter then act along the row-local T axis — no collective crosses
    the data (batch/client) axis.  Global-competition routing (one
    capacity pool over B·T tokens) lowered its dispatch gather to a
    (E, cap, d) select+all-reduce across the data axis — 5 GiB/device on
    the 1M-token prefill (perf iteration M1, EXPERIMENTS.md §Perf).
    Expert weights stay model-sharded (expert parallelism): the dispatched
    (B, E, cap, d) batch is sharded over batch×experts, so expert compute
    is two-axis parallel with no resharding.
    """
    m = cfg.moe
    B, T, d = x.shape
    N = B * T
    xf = x.reshape(N, d)
    E, k = m.num_experts, m.top_k
    cap = max(int(m.capacity_factor * k * N / E), 1)
    cap = min(cap, N)

    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)  # (N, k)
    gates = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)

    # (N, E) gate matrix: g[n,e] = combined gate if expert e chosen by n
    chose = jnp.zeros((N, E), jnp.float32)
    chose = chose.at[jnp.arange(N)[:, None], topi].set(gates)

    # sort-based dispatch: per expert take the first `cap` choosing tokens.
    # NOTE (perf iterations M1–M3, EXPERIMENTS.md §Perf): per-row "grouped"
    # routing was tried to kill the dispatch gather's select+all-reduce
    # lowering; it regressed 5× (the row-local argsort/gather still cross
    # the seq-sharded axis and multiply under the client vmap).  Global
    # competition + expert-parallel compute measured strictly better under
    # GSPMD; a Pallas dispatch kernel is the real fix on hardware.
    prio = jnp.where(chose > 0, jnp.arange(N, dtype=jnp.int32)[:, None], N)
    order = jnp.argsort(prio, axis=0)  # (N, E)
    take = order[:cap]  # (cap, E) token ids
    w_taken = jnp.take_along_axis(chose, take, axis=0)  # (cap, E); 0 ⇒ filler

    xe = xf[take.T]  # (E, cap, d) gather
    # every stage of the expert pipeline is pinned to the expert-parallel
    # layout — propagation alone loses it through the dot_general reshapes
    # and replicates multi-GiB expert activations on every device
    xe = sharding.shard(xe, "experts", None, None)
    gate_h = sharding.shard(
        _stacked_linear(p["gate"], xe, cfg.kernels), "experts", None, None
    )
    up_h = sharding.shard(
        _stacked_linear(p["up"], xe, cfg.kernels), "experts", None, None
    )
    h = jax.nn.silu(gate_h) * up_h
    ye = _stacked_linear(p["down"], h, cfg.kernels)  # (E, cap, d)
    ye = sharding.shard(ye, "experts", None, None)
    ye = ye * w_taken.T[..., None].astype(ye.dtype)

    out = jnp.zeros((N, d), ye.dtype)
    out = out.at[take.T.reshape(-1)].add(ye.reshape(E * cap, d))

    # shared ("always-on") experts — DeepSeekMoE fine-grained design
    if "shared_up" in p:
        hs = jax.nn.silu(
            _dense_linear(p["shared_gate"], xf, cfg.kernels)
        ) * _dense_linear(p["shared_up"], xf, cfg.kernels)
        out = out + _dense_linear(p["shared_down"], hs, cfg.kernels)

    # switch-style load-balance auxiliary loss
    frac_routed = jnp.mean((chose > 0).astype(jnp.float32), axis=0)  # (E,)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.aux_loss_weight * E * jnp.sum(frac_routed * mean_prob)
    return out.reshape(B, T, d), aux
