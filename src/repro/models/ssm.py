"""State-space & linear-RNN sequence mixers: Mamba (Jamba) and RWKV6 (Finch).

Both are attention-free, O(T) mixers, which is what qualifies the
``rwkv6-7b`` and ``jamba-1.5-large`` configs for the 500k-token decode
shape.  Their *projection* matrices (in/out, r/k/v/g) are FeDLRT-factorized
like any other layer; the recurrence parameters (A, conv taps, decay LoRA,
bonus u) are small structured tensors kept dense (FedLin-style aggregation).

TPU adaptation notes (DESIGN.md §3): the CUDA selective-scan of Mamba and
the fused wkv kernel of RWKV are re-expressed as
- Mamba: `associative_scan` over the diagonal SSM recurrence — maps to the
  TPU's parallel-prefix lowering, channels sharded over the `model` axis
  (the recurrence is elementwise in channels ⇒ no collectives inside).
- RWKV6: chunked linear attention (flash-linear-attention style): per-chunk
  quadratic mixing via MXU matmuls + a lax.scan over chunk states.  This is
  MXU-friendly where a literal per-token scan would be VPU-bound.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.config import ModelConfig
from repro.models.layers import Builder, apply_linear, rms_norm

Array = jax.Array


# ===========================================================================
# Mamba
# ===========================================================================


@jax.custom_vjp
def linear_recurrence(a: Array, b: Array, h0: Array) -> Array:
    """``h_t = a_t ⊙ h_{t-1} + b_t`` along axis 1, returning all ``h_t``.

    Forward uses ``associative_scan`` (parallel-prefix on TPU).  The custom
    VJP matters: differentiating ``associative_scan`` directly retains
    O(log T) full-size intermediates per layer (≈50 GiB/device for Jamba's
    train_4k), while the adjoint is itself a *reverse* linear recurrence —
        λ_t = ḡ_t + a_{t+1} ⊙ λ_{t+1};  ā_t = λ_t ⊙ h_{t-1};  b̄_t = λ_t
    — needing only ``a`` and the forward outputs as residuals.
    """
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    b0 = b.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, b0), axis=1)
    return h


def _linrec_fwd(a, b, h0):
    h = linear_recurrence(a, b, h0)
    return h, (a, h, h0)


def _linrec_bwd(res, dh):
    a, h, h0 = res

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    # λ_t = dh_t + a_{t+1} λ_{t+1}: reverse scan with decay a shifted left
    a_rev = jnp.flip(a, axis=1)
    a_shift = jnp.concatenate(
        [jnp.ones_like(a_rev[:, :1]), a_rev[:, :-1]], axis=1
    )
    _, lam_rev = jax.lax.associative_scan(
        combine, (a_shift, jnp.flip(dh, axis=1)), axis=1
    )
    lam = jnp.flip(lam_rev, axis=1)
    h_prev = jnp.concatenate([h0[:, None], h[:, :-1]], axis=1)
    da = lam * h_prev
    db = lam
    dh0 = a[:, 0] * lam[:, 0]
    return da, db, dh0


linear_recurrence.defvjp(_linrec_fwd, _linrec_bwd)


def mamba_dims(cfg: ModelConfig):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, m.d_state, m.d_conv


def build_mamba(b: Builder, prefix: str, cfg: ModelConfig, n_blocks: int):
    d = cfg.d_model
    d_inner, dt_rank, d_state, d_conv = mamba_dims(cfg)
    bs, ba = (n_blocks,), ("layers",)
    b.linear(f"{prefix}/in_x", d, d_inner, li="embed", lo="mamba_inner",
             batch_shape=bs, batch_axes=ba)
    b.linear(f"{prefix}/in_z", d, d_inner, li="embed", lo="mamba_inner",
             batch_shape=bs, batch_axes=ba)
    b.linear(f"{prefix}/x_proj", d_inner, dt_rank + 2 * d_state,
             li="mamba_inner", lo=None, batch_shape=bs, batch_axes=ba)
    b.linear(f"{prefix}/dt_proj", dt_rank, d_inner, li=None, lo="mamba_inner",
             batch_shape=bs, batch_axes=ba, bias=True)
    b.linear(f"{prefix}/out", d_inner, d, li="mamba_inner", lo="embed",
             batch_shape=bs, batch_axes=ba)
    # conv taps + SSM parameters (structured, dense)
    b.normal(f"{prefix}/conv_w", bs + (d_conv, d_inner),
             axes=ba + (None, "mamba_inner"), scale=0.5 / d_conv)
    a_log = jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32))
    b._put(f"{prefix}/A_log",
           jnp.broadcast_to(a_log, bs + (d_inner, d_state)).copy(),
           sharding.spec(*ba, "mamba_inner", None))
    b.vector(f"{prefix}/D", bs + (d_inner,), axes=ba + ("mamba_inner",), init=1.0)
    b.vector(f"{prefix}/dt_bias", bs + (d_inner,), axes=ba + ("mamba_inner",),
             init=-4.6)  # softplus⁻¹(0.01)


def _causal_conv(x: Array, w: Array, tail: Optional[Array]) -> Tuple[Array, Array]:
    """Depthwise causal conv along T.  x: (B,T,C), w: (K,C).

    ``tail`` is the last K-1 inputs from the previous call (decode cache);
    returns (y, new_tail).
    """
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, T+K-1, C)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return y, xp[:, -(K - 1) :]


def mamba_mix(
    p: dict,
    x: Array,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
) -> Tuple[Array, Optional[dict]]:
    """Selective-SSM mixer.  x: (B,T,d).  ``state`` for decode:
    {"h": (B, d_inner, N), "conv": (B, K-1, d_inner)}."""
    d_inner, dt_rank, d_state, d_conv = mamba_dims(cfg)
    dt = x.dtype

    xz = apply_linear(p["in_x"], x, kernels=cfg.kernels)
    z = apply_linear(p["in_z"], x, kernels=cfg.kernels)
    xz = sharding.shard(xz, "batch", None, "mamba_inner")

    tail = state["conv"] if state is not None else None
    xc, new_tail = _causal_conv(xz, p["conv_w"].astype(dt), tail)
    xc = jax.nn.silu(xc)

    proj = apply_linear(p["x_proj"], xc, kernels=cfg.kernels).astype(jnp.float32)
    dt_low, Bp, Cp = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(
        apply_linear(
            p["dt_proj"], dt_low.astype(dt), bias=p["dt_bias"], kernels=cfg.kernels
        ).astype(
            jnp.float32
        )
    )  # (B,T,d_inner) — keep channel-sharded (unpinned it replicates, f32)
    delta = sharding.shard(delta, "batch", None, "mamba_inner")
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (d_inner, N)

    xc32 = xc.astype(jnp.float32)
    scan_dt = dt  # bf16 workspace on production configs, f32 on smoke
    B, T = xc.shape[0], xc.shape[1]
    d_in = xc.shape[2]

    if state is not None:
        # decode: T small (usually 1) — step sequentially
        a = jnp.exp(delta[..., None] * A).astype(scan_dt)
        b_in = ((delta * xc32)[..., None] * Bp[..., None, :]).astype(scan_dt)
        h0 = state["h"].astype(scan_dt)

        def step(h, ab):
            a_t, b_t = ab
            h = a_t * h + b_t
            return h, h

        hT, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b_in.swapaxes(0, 1)))
        h_seq = hs.swapaxes(0, 1)
        new_state = {"h": hT.astype(jnp.float32), "conv": new_tail}
        y = jnp.sum(h_seq.astype(jnp.float32) * Cp[..., None, :], axis=-1)
    else:
        # training: time-chunked recurrence.  The (B, Lc, d_inner, N)
        # decay/input products exist one chunk at a time — this bounds the
        # layer's peak memory (a monolithic T-long workspace is ~T/Lc times
        # larger and dominated Jamba's train HBM).
        Lc = min(cfg.mamba.scan_chunk, T)
        nc = -(-T // Lc)
        pad = nc * Lc - T
        padT = lambda z: jnp.pad(z, ((0, 0), (0, pad)) + ((0, 0),) * (z.ndim - 2))
        dl = padT(delta).reshape(B, nc, Lc, d_in)
        xcl = padT(delta * xc32).reshape(B, nc, Lc, d_in)
        Bpl = padT(Bp).reshape(B, nc, Lc, -1)
        Cpl = padT(Cp).reshape(B, nc, Lc, -1)

        def chunk(h0, xs):
            d_c, dx_c, B_c, C_c = xs  # (B, Lc, …)
            a_c = jnp.exp(d_c[..., None] * A).astype(scan_dt)
            b_c = (dx_c[..., None] * B_c[..., None, :]).astype(scan_dt)
            h_c = linear_recurrence(a_c, b_c, h0)
            y_c = jnp.sum(h_c.astype(jnp.float32) * C_c[..., None, :], axis=-1)
            return h_c[:, -1], y_c

        body = jax.checkpoint(chunk, prevent_cse=False) if T > Lc else chunk
        xs = tuple(z.swapaxes(0, 1) for z in (dl, xcl, Bpl, Cpl))
        _, ys = jax.lax.scan(
            body, jnp.zeros((B, d_in, d_state), scan_dt), xs
        )
        y = ys.swapaxes(0, 1).reshape(B, nc * Lc, d_in)[:, :T]
        new_state = None

    y = y + p["D"].astype(jnp.float32) * xc32
    y = (y.astype(dt)) * jax.nn.silu(z)
    out = apply_linear(p["out"], y, kernels=cfg.kernels)
    return out, new_state


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_inner, _, d_state, d_conv = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
    }


# ===========================================================================
# RWKV6 (Finch) — data-dependent decay linear attention
# ===========================================================================


def rwkv_dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    H = cfg.d_model // hd
    return H, hd


def build_rwkv(b: Builder, prefix: str, cfg: ModelConfig, n_blocks: int):
    d = cfg.d_model
    H, hd = rwkv_dims(cfg)
    lora = cfg.rwkv.decay_lora
    bs, ba = (n_blocks,), ("layers",)
    for name in ("r", "k", "v", "g"):
        b.linear(f"{prefix}/{name}", d, d, li="embed", lo="rwkv_heads",
                 batch_shape=bs, batch_axes=ba)
    b.linear(f"{prefix}/out", d, d, li="rwkv_heads", lo="embed",
             batch_shape=bs, batch_axes=ba)
    # data-dependent decay LoRA (the Finch mechanism) — small, dense
    b.normal(f"{prefix}/w_lora_a", bs + (d, lora), axes=ba + (None, None), scale=0.02)
    b.normal(f"{prefix}/w_lora_b", bs + (lora, d), axes=ba + (None, "rwkv_heads"), scale=0.02)
    b.vector(f"{prefix}/w0", bs + (d,), axes=ba + ("rwkv_heads",), init=-1.0)
    b.vector(f"{prefix}/u", bs + (H, hd), axes=ba + ("rwkv_heads", None), init=0.5)
    # static token-shift mixing coefficients (simplified from ddlerp)
    for name in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
        b.vector(f"{prefix}/{name}", bs + (d,), axes=ba + (None,), init=0.5)
    b.vector(f"{prefix}/ln_x", bs + (d,), axes=ba + ("rwkv_heads",), init=1.0)


def _token_shift(x: Array, prev: Optional[Array]) -> Tuple[Array, Array]:
    """Shift right by one along T; ``prev`` is the last token of the
    previous segment (decode cache)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    xx = jnp.concatenate([prev, x[:, :-1]], axis=1)
    return xx, x[:, -1:]


def _rwkv_chunked(
    r: Array, k: Array, v: Array, logw: Array, u: Array, S0: Array, chunk: int
) -> Tuple[Array, Array]:
    """Chunked wkv.  r,k,v: (B,T,H,hd); logw ≤ 0: (B,T,H,hd); u: (H,hd).

    Recurrence (per head, hd_k = hd_v = hd):
        S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
        o_t = r_t S_{t-1} + (r_t ⊙ u)·k_t · v_t
    Returns (o: (B,T,H,hd), S_T: (B,H,hd,hd)).
    """
    B, T, H, hd = r.shape
    L = min(chunk, T)
    n = -(-T // L)
    pad = n * L - T
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    shp = (B, n, L, H, hd)
    rc, kc, vc = r.reshape(shp), k.reshape(shp), v.reshape(shp),
    lwc = logw.reshape(shp)

    # within-chunk inclusive log-decay prefix  P_t = Σ_{m≤t} logw_m
    lp = jnp.cumsum(lwc, axis=2)  # (B,n,L,H,hd)
    lp_prev = lp - lwc  # exclusive prefix Σ_{m<t}
    CLAMP = 30.0
    r_t = rc * jnp.exp(jnp.maximum(lp_prev, -CLAMP))  # r̃_t = r_t ⊙ W_{<t}
    k_t = kc * jnp.exp(jnp.minimum(-lp, CLAMP))  # k̃_i = k_i / W_{≤i}

    # intra-chunk strict-lower attention  (B,n,H,L,L)
    att = jnp.einsum("bnlhd,bnmhd->bnhlm", r_t, k_t)
    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)
    att = att * tri[None, None, None]
    # diagonal bonus term
    bonus = jnp.einsum("bnlhd,hd,bnlhd->bnlh", rc, u, kc)
    intra = jnp.einsum("bnhlm,bnmhd->bnlhd", att, vc)
    intra = intra + bonus[..., None] * vc

    # cross-chunk: scan over chunk states
    k_for_state = kc * jnp.exp(jnp.minimum(lp[:, :, -1:] - lp, CLAMP))  # k_i ⊙ W_{i+1..L}
    dS = jnp.einsum("bnlhd,bnlhe->bnhde", k_for_state, vc)  # (B,n,H,hd,hd)
    wtot = jnp.exp(jnp.maximum(lp[:, :, -1], -CLAMP))  # (B,n,H,hd)

    def chunk_step(S, inp):
        dS_c, wtot_c, r_c = inp  # (B,H,hd,hd), (B,H,hd), (B,L,H,hd)
        inter = jnp.einsum("blhd,bhde->blhe", r_c, S)
        S_new = S * wtot_c[..., None] + dS_c
        return S_new, inter

    xs = (dS.swapaxes(0, 1), wtot.swapaxes(0, 1), r_t.swapaxes(0, 1))
    S_T, inters = jax.lax.scan(chunk_step, S0, xs)
    inter = inters.swapaxes(0, 1)  # (B,n,L,H,hd)

    o = (intra + inter).reshape(B, n * L, H, hd)
    return o[:, :T], S_T


def rwkv_mix(
    p: dict,
    x: Array,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
) -> Tuple[Array, Optional[dict]]:
    """RWKV6 time-mixing.  state = {"S": (B,H,hd,hd), "shift": (B,1,d)}."""
    B, T, d = x.shape
    H, hd = rwkv_dims(cfg)
    dt = x.dtype

    prev = state["shift"] if state is not None else None
    xx, last = _token_shift(x, prev)

    def mix(mu):
        return x + (xx - x) * mu.astype(dt)

    r = apply_linear(p["r"], mix(p["mu_r"]), kernels=cfg.kernels).reshape(B, T, H, hd)
    k = apply_linear(p["k"], mix(p["mu_k"]), kernels=cfg.kernels).reshape(B, T, H, hd)
    v = apply_linear(p["v"], mix(p["mu_v"]), kernels=cfg.kernels).reshape(B, T, H, hd)
    g = apply_linear(p["g"], mix(p["mu_g"]), kernels=cfg.kernels)

    # data-dependent decay (Finch): w = exp(-exp(w0 + tanh(x A) B))
    xw = mix(p["mu_w"]).astype(jnp.float32)
    dd = jnp.tanh(xw @ p["w_lora_a"].astype(jnp.float32)) @ p["w_lora_b"].astype(
        jnp.float32
    )
    logw = -jnp.exp(
        jnp.clip(p["w0"].astype(jnp.float32) + dd, -8.0, 4.0)
    )  # ≤ 0, (B,T,d)
    logw = logw.reshape(B, T, H, hd)

    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    u = p["u"].astype(jnp.float32)
    S0 = (
        state["S"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, H, hd, hd), jnp.float32)
    )
    o, S_T = _rwkv_chunked(r32, k32, v32, logw, u, S0, cfg.rwkv.chunk_len)

    o = o.reshape(B, T, d)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps).astype(dt)
    o = o * jax.nn.silu(g)
    out = apply_linear(p["out"], o, kernels=cfg.kernels)
    new_state = {"S": S_T, "shift": last} if state is not None else None
    return out, new_state


def rwkv_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    H, hd = rwkv_dims(cfg)
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
    }
