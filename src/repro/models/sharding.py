"""Logical-axis sharding rules (GSPMD annotations).

Model code tags every parameter and key activation with *logical* axis
names; this module maps them to mesh axes:

    batch   → ("pod", "data")   — the federated-client axis
    heads / ffn / experts / vocab / mamba_inner → "model"  (tensor/expert
                                                             parallelism)
    everything else → replicated

The mapping is applied only when :data:`ENABLED` is on (the launcher turns
it on inside a mesh context; CPU unit tests run with it off so no mesh is
required).  ``with_sharding_constraint`` is likewise gated.

For a factorized weight ``W = U S Vᵀ`` the *bases* carry the tensor-parallel
sharding of the corresponding dense dimension (U on n_in's axis, V on
n_out's axis) while the small ``S`` and the rank scalar stay replicated —
so tensor-parallel partial sums are reduced at width ``r`` instead of the
dense width: the low-rank bottleneck shrinks TP collectives as well as the
federated aggregation (quantified in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.core.factorization import LowRankFactor, is_factor
from repro.utils import meshctx

ENABLED = False

# logical axis name → mesh axis (None = replicated)
RULES = {
    "batch": ("pod", "data"),
    "clients": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "vocab": "model",
    # FSDP-style factor sharding: low-rank bases are cheap to all-gather
    # (O(n·r) not O(n²)), so the d_model-sized dim of U/V shards too —
    # without this, jamba-scale replicated factors dominate device HBM.
    "embed": "model",
    "mamba_inner": "model",
    "rwkv_heads": "model",
    # sequence parallelism: the residual stream's T dim lives on the model
    # axis between blocks (works for any head count; GSPMD inserts the
    # gather/scatter around attention). Decode (T=1) degrades to replicated
    # automatically via the divisibility check in shard().
    "seq": "model",
    "layers": None,
    "rank": None,
}

_ACTIVE_MESH_AXES: Tuple[str, ...] = ()


def enable(mesh: Optional[jax.sharding.Mesh]):
    """Turn on sharding annotations for the given mesh (launcher only)."""
    global ENABLED, _ACTIVE_MESH_AXES
    meshctx.enable(mesh)
    if mesh is None:
        ENABLED = False
        _ACTIVE_MESH_AXES = ()
    else:
        ENABLED = True
        _ACTIVE_MESH_AXES = tuple(mesh.axis_names)


_CLIENT_MODE = False


def set_client_mode(on: bool):
    """Under the FeDLRT client vmap (spmd_axis_name carries the data axes),
    in-model "batch" constraints must not name those axes — the per-client
    batch is purely local.  The launcher flips this for train lowering."""
    global _CLIENT_MODE
    _CLIENT_MODE = on


def _resolve(logical: Optional[str]):
    if logical is None:
        return None
    if _CLIENT_MODE and logical in ("batch", "clients"):
        return None
    mesh_axis = RULES.get(logical)
    if mesh_axis is None:
        return None
    if isinstance(mesh_axis, tuple):
        avail = tuple(a for a in mesh_axis if a in _ACTIVE_MESH_AXES)
        return avail if avail else None
    return mesh_axis if mesh_axis in _ACTIVE_MESH_AXES else None


def spec(*logical_axes) -> P:
    """PartitionSpec for a tensor whose dims carry these logical names."""
    return P(*[_resolve(a) for a in logical_axes])


def shard(x, *logical_axes):
    """Activation sharding constraint (no-op unless ENABLED).

    Dims the mesh does not evenly divide are left unconstrained (GSPMD
    requires exact divisibility; e.g. 28 heads on a model=16 axis).
    """
    if not ENABLED:
        return x
    return meshctx.constrain(x, P(*[_resolve(a) for a in logical_axes]))


def factor_spec(batch_axes: Tuple[Optional[str], ...], li: Optional[str], lo: Optional[str]):
    """Sharding pytree for a LowRankFactor with logical dims (li → lo).

    A pytree *template* of PartitionSpecs in factor shape, not tensor
    data — the taint analysis sees ``spec()`` returns non-arrays, so no
    RPL005 suppression is needed (PR 7's lexical rule required one).
    """
    return LowRankFactor(
        U=spec(*batch_axes, li, "rank"),
        S=spec(*batch_axes, "rank", "rank"),
        V=spec(*batch_axes, lo, "rank"),
        rank=spec(*batch_axes),
    )


def tree_shardings(mesh: jax.sharding.Mesh, spec_tree):
    """Map a pytree of PartitionSpecs to NamedShardings on ``mesh``."""
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
