from repro.models.config import (  # noqa: F401
    EncoderConfig,
    LowRankPolicy,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
)
from repro.models.model import Model, build_model  # noqa: F401
