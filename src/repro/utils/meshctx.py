"""Process-global mesh context for sharding annotations.

Lives in utils (not models/) so that core/ can constrain intermediate
tensors — e.g. the augmented bases inside a FeDLRT round — without a
core → models import cycle.  Disabled (no-op) unless a launcher calls
:func:`enable`; unit tests run mesh-free.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_MESH: Optional[jax.sharding.Mesh] = None


def enable(mesh: Optional[jax.sharding.Mesh]):
    global _MESH
    _MESH = mesh


def mesh() -> Optional[jax.sharding.Mesh]:
    return _MESH


def axis_names():
    return tuple(_MESH.axis_names) if _MESH is not None else ()


def axis_size(name) -> int:
    if _MESH is None:
        return 1
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= _MESH.shape[a]
        return n
    return _MESH.shape[name]


def constrain(x, spec: P):
    """with_sharding_constraint gated on the active mesh; drops sharding on
    dims the mesh doesn't evenly divide."""
    if _MESH is None:
        return x
    fixed = []
    for i, ax in enumerate(spec):
        if ax is None or i >= x.ndim:
            fixed.append(None)
            continue
        if x.shape[i] % axis_size(ax) != 0:
            fixed.append(None)
        else:
            fixed.append(ax)
    fixed += [None] * (x.ndim - len(fixed))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_MESH, P(*fixed))
    )
