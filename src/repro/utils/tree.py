"""Small pytree helpers used across the federated runtime.

These are deliberately free of any model/optimizer knowledge so they can be
used on raw param pytrees, gradient pytrees, and optimizer-state pytrees
alike.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_mean_leading_axis(tree):
    """Mean over a leading (client) axis on every leaf.

    Under GSPMD, when the leading axis is sharded over the ("pod", "data")
    mesh axes, this lowers to the server `aggregate` all-reduce of the paper.
    """
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_global_norm(tree):
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_size_bytes(tree) -> int:
    """Static byte count of a pytree (python int; usable outside jit)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
