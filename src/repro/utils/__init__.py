from repro.utils.tree import (  # noqa: F401
    tree_add,
    tree_axpy,
    tree_global_norm,
    tree_mean_leading_axis,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)
