"""Jamba-1.5-Large (398B) [arXiv:2403.19887]: hybrid Mamba+attention at 1:7
interleave (attention at position 4 of each 8-layer block), MoE (16 experts
top-2) on every other layer."""
from repro.models.config import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    rope_theta=1e4,
    block_pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_expert=24576,
        capacity_factor=1.25,
        every_k_layers=2,
        offset=1,
    ),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)
