"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained experts, 2 shared + 64
routed top-6, expert hidden 1408.  (Deviation noted in DESIGN.md: the
published model keeps layer 0 as a dense FFN; we use MoE on every layer so
the scanned superblock stays homogeneous.)"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    rope_theta=1e4,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_expert=1408,
        num_shared_experts=2,
        d_shared=2816,
        capacity_factor=1.25,
    ),
)
