"""Whisper-large-v3 [arXiv:2212.04356]: enc-dec; conv/mel frontend is a STUB
(input_specs provides 1280-d frame embeddings), per the assignment carve-out."""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,          # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    gated_mlp=False,        # whisper uses GELU MLP
    encoder=EncoderConfig(num_layers=32, num_frames=1500),
)
