"""OLMoE-1B-7B [arXiv:2409.02060]: 64 experts top-8, expert hidden 1024."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,
    rope_theta=1e4,
    moe=MoEConfig(
        num_experts=64,
        top_k=8,
        d_expert=1024,
        capacity_factor=1.25,
    ),
)
