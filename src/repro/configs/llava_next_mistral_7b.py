"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf]:
ViT tower + anyres projector are a STUB (input_specs provides 4096-d patch
embeddings, 2880 tokens ≈ anyres max).  Mistral sliding window 4096 makes
long_500k decode admissible (O(window) attention per token)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=1e4,
    vision_tokens=2880,
)
