"""RWKV6-World-7B "Finch" [arXiv:2404.05892]: attention-free linear RNN with
data-dependent decay (LoRA-parameterized).  (Deviation noted in DESIGN.md:
token-shift mixing coefficients are static rather than ddlerp; channel-mix
uses the shared MLP primitive.)"""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,           # 4096 / 64 head_dim
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    gated_mlp=False,
    block_pattern=("rwkv",),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk_len=64),
)
