"""Architecture registry: the 10 assigned configs (+ paper test problems).

Each ``<arch>.py`` module exposes ``CONFIG: ModelConfig`` with the exact
published dimensions (source cited in the module docstring).  Reduced smoke
variants come from :func:`repro.models.config.reduced`.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced  # noqa: F401

ARCH_IDS = (
    "qwen2_7b",
    "deepseek_moe_16b",
    "whisper_large_v3",
    "codeqwen15_7b",
    "qwen3_32b",
    "llava_next_mistral_7b",
    "jamba_15_large",
    "qwen15_32b",
    "olmoe_1b_7b",
    "rwkv6_7b",
)

# CLI ids (dashes) → module names
ALIASES = {
    "qwen2-7b": "qwen2_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-large-v3": "whisper_large_v3",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "qwen3-32b": "qwen3_32b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "jamba-1.5-large-398b": "jamba_15_large",
    "qwen1.5-32b": "qwen15_32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "rwkv6-7b": "rwkv6_7b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
