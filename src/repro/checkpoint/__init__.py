from repro.checkpoint.io import (  # noqa: F401
    load_checkpoint,
    load_checkpoint_meta,
    save_checkpoint,
)
