"""Self-describing npz checkpoints for factorized parameter pytrees.

Factor leaves are stored field-wise (``<path>@U/S/V/rank``), so a restored
checkpoint reproduces the exact LowRankFactor objects — including each
layer's adaptive rank — without needing a template pytree.  Metadata
(round index, method, anything json-serializable) rides along under
``__meta__``.
"""
from __future__ import annotations

import io
import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.factorization import LowRankFactor, is_factor

_SEP = "|"  # path separator safe for npz keys


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if is_factor(tree):
        out[prefix + "@U"] = tree.U
        out[prefix + "@S"] = tree.S
        out[prefix + "@V"] = tree.V
        out[prefix + "@rank"] = tree.rank
        return out
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, prefix + _SEP + str(k) if prefix else str(k)))
        return out
    out[prefix] = tree
    return out


def save_checkpoint(path: str, params, *, meta: Optional[dict] = None):
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(params).items()}
    flat["__meta__"] = np.frombuffer(
        json.dumps(meta or {}).encode(), dtype=np.uint8
    ).copy()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def load_checkpoint_meta(path: str) -> dict:
    """The checkpoint's ``__meta__`` dict alone — npz members are lazy, so
    this never materializes the parameter arrays (cheap pre-restore guard
    checks, e.g. the experiment API's spec-hash match)."""
    with np.load(path) as z:
        if "__meta__" not in z.files:
            return {}
        return json.loads(bytes(z["__meta__"]).decode())


def load_checkpoint(path: str):
    """Returns (params, meta)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    meta = json.loads(bytes(flat.pop("__meta__")).decode()) if "__meta__" in flat else {}

    # group factor fields
    factors: Dict[str, dict] = {}
    plain: Dict[str, np.ndarray] = {}
    for k, v in flat.items():
        if "@" in k:
            base, field = k.rsplit("@", 1)
            factors.setdefault(base, {})[field] = v
        else:
            plain[k] = v

    tree: dict = {}

    def insert(path: str, value):
        parts = path.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value

    for k, v in plain.items():
        insert(k, jnp.asarray(v))
    for k, fields in factors.items():
        insert(
            k,
            # restores verbatim buffers saved under the invariant; masking
            # here would silently repair (and so hide) a corrupted
            # checkpoint — the taint analysis proves this verbatim move
            # clean, so no RPL005 suppression is needed
            LowRankFactor(
                U=jnp.asarray(fields["U"]),
                S=jnp.asarray(fields["S"]),
                V=jnp.asarray(fields["V"]),
                rank=jnp.asarray(fields["rank"]),
            ),
        )
    if set(tree) == {""}:  # bare root-level leaf (e.g. a single factor)
        return tree[""], meta
    return tree, meta
