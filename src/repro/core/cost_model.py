"""Analytic compute / memory / communication cost model (paper Table 1).

Two layers of fidelity:

- :func:`table1_*` — the closed forms of Table 1 for a square ``n×n`` layer,
  used by the Fig.-3 benchmark (scaling curves and the amortization point).
- exact per-pytree byte counters used by the federated engine's metrics and
  cross-checked against the collective bytes parsed from the dry-run HLO
  (see launch/roofline.py): the all-reduce operand sizes of a mesh-lowered
  FeDLRT round must match :func:`fedlrt_round_comm_bytes` to within the
  dense-leaf contribution.

Conventions: counts are *per client per round* in **elements** unless a
function says bytes; ``b`` = local batch size, ``s*`` = local iterations.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.factorization import LowRankFactor, is_factor

BYTES = 4  # f32 on-wire, matching the paper's float accounting


# ---------------------------------------------------------------------------
# Table 1 closed forms (square n×n layer, rank r)
# ---------------------------------------------------------------------------


def table1(method: str, *, n: int, r: int, s_star: int = 1, b: int = 1) -> dict:
    """Return the Table-1 row for ``method`` as a dict of element counts."""
    rows = {
        "fedavg": dict(
            client_compute=s_star * b * n**2,
            client_memory=2 * n**2,
            server_compute=n**2,
            server_memory=2 * n**2,
            comm=2 * n**2,
            rounds=1,
        ),
        "fedlin": dict(
            client_compute=s_star * b * n**2,
            client_memory=2 * n**2,
            server_compute=n**2,
            server_memory=2 * n**2,
            comm=4 * n**2,
            rounds=2,
        ),
        "fedlrt": dict(
            client_compute=s_star * b * (4 * n * r + 4 * r**2),
            client_memory=4 * (n * r + 2 * r**2),
            server_compute=2 * n * r + (8 + 4 * n) * r**2 + 8 * r**3,
            server_memory=2 * n * r + 4 * r**2,
            comm=6 * n * r + 6 * r**2,
            rounds=2,
        ),
        "fedlrt_simplified": dict(
            client_compute=s_star * b * (4 * n * r + 4 * r**2) + r**2,
            client_memory=4 * (n * r + 2 * r**2),
            server_compute=2 * n * r + (8 + 4 * n) * r**2 + 8 * r**3,
            server_memory=2 * n * r + 4 * r**2,
            comm=6 * n * r + 8 * r**2,
            rounds=2,
        ),
        "fedlrt_full": dict(
            client_compute=s_star * b * (4 * n * r + 4 * r**2) + 4 * r**2,
            client_memory=4 * (n * r + 2 * r**2),
            server_compute=2 * n * r + (8 + 4 * n) * r**2 + 8 * r**3,
            server_memory=2 * n * r + 4 * r**2,
            comm=6 * n * r + 10 * r**2,
            rounds=3,
        ),
        "fedlr": dict(  # post-hoc SVD compression baseline [31]
            client_compute=s_star * b * n**2 + n**3,
            client_memory=2 * n**2,
            server_compute=n**2 + n**3,
            server_memory=4 * n * r,
            comm=4 * n * r,
            rounds=1,
        ),
    }
    if method not in rows:
        raise ValueError(f"unknown method {method!r}")
    return rows[method]


def amortization_rank(n: int) -> float:
    """Rank below which FeDLRT communicates less than FedLin: 6nr+8r² < 4n²."""
    # solve 8r² + 6nr − 4n² = 0 for r > 0
    import math

    return (-6 * n + math.sqrt(36 * n**2 + 128 * n**2)) / 16.0


# ---------------------------------------------------------------------------
# exact per-pytree counters
# ---------------------------------------------------------------------------


def _factor_leaves(params):
    return [
        x for x in jax.tree.leaves(params, is_leaf=is_factor) if is_factor(x)
    ]


def _dense_leaves(params):
    return [
        x for x in jax.tree.leaves(params, is_leaf=is_factor) if not is_factor(x)
    ]


def fedlrt_round_comm_bytes(params, correction: str = "simplified") -> int:
    """Per-client on-wire bytes of one FeDLRT round for this param pytree.

    Counted (up = client→server, down = server→client):
      down: U, V, S at round start                (2nr + r²)
      up:   G_U, G_V                              (2nr)      [+ G_S simplified]
      down: Ū, V̄                                 (2nr)      [+ G_S simplified]
      full correction only: up G_S̃ / down G_S̃   (2·4r²)
      up:   S̃_c^{s*}                              (4r²)
    Dense leaves follow FedLin: down W, up G, down Ḡ, up W_c  (4·size).
    """
    total = 0
    for f in _factor_leaves(params):
        n_in, n_out, r = f.n_in, f.n_out, f.r_max
        # stacked-layer / expert factors (leading buffer dims) put every
        # slice on the wire
        stack = 1
        for d in f.U.shape[:-2]:
            stack *= int(d)
        nr = (n_in + n_out) * r
        per = nr + r * r  # initial broadcast
        per += nr  # basis-gradient upload
        per += nr  # augmented-basis broadcast
        if correction == "simplified":
            per += 2 * r * r  # G_S up + down
        elif correction == "full":
            per += 2 * (2 * r) ** 2  # G_S̃ up + down
        per += (2 * r) ** 2  # coefficient upload
        total += stack * per
    for x in _dense_leaves(params):
        total += 4 * x.size
    return total * BYTES


def fedlrt_round_comm_bytes_effective(params, correction: str = "simplified"):
    """Per-client on-wire bytes priced at each factor's *current* rank.

    Same accounting as :func:`fedlrt_round_comm_bytes` but with ``r`` the
    factor's dynamic ``rank`` instead of the static ``r_max`` buffer width
    — this is what a deployment that ships only active columns would put on
    the wire, and (unlike the static bound) it shrinks as truncation adapts
    ranks downward.  jnp-based so it can be traced inside a jitted round;
    returns an f32 scalar.  Batched (stacked-layer / expert) factors sum
    their per-slice ranks.  Always ≤ the static bound.
    """
    total = jnp.zeros((), jnp.float32)
    for f in _factor_leaves(params):
        r = f.rank.astype(jnp.float32)  # scalar or (stack...,) per-slice ranks
        nr = (f.n_in + f.n_out) * r
        r2 = r * r
        per = nr + r2  # initial broadcast (U, V, S at rank r)
        per = per + nr  # basis-gradient upload
        per = per + nr  # augmented-basis broadcast
        if correction == "simplified":
            per = per + 2.0 * r2  # G_S up + down
        elif correction == "full":
            per = per + 2.0 * (2.0 * r) ** 2  # G_S̃ up + down
        per = per + (2.0 * r) ** 2  # coefficient upload
        total = total + jnp.sum(per)
    for x in _dense_leaves(params):
        total = total + 4.0 * x.size
    return total * BYTES


def wire_round_bytes(
    params, method: str = "fedlrt", *, correction: str = "simplified"
) -> dict:
    """Analytic per-client bytes of the round's *wire-layer data plane*.

    This prices exactly what :func:`repro.core.round.run_round` transmits
    under the identity codec (f32 accounting, like the rest of this
    module), per direction:

    - ``down``: the shared broadcast (received once by every client) plus
      that client's per-client slice — for FeDLRT the augmented factors
      ``Ū, S̃, V̄`` (+ the rank counters) and, under correction, the
      ``2r̂ × 2r̂`` correction block per factor; for the dense baselines the
      global weights (+ FedLin's correction slice).
    - ``up``: the client upload — FeDLRT's coefficient blocks (+ dense
      leaves and the drift diagnostic scalar), a dense baseline's full
      weights.

    The wire layer's *measured* ``wire_bytes_{down,up}_per_client`` metrics
    must match these numbers exactly for the identity codec — pinned by
    ``tests/test_wire.py``.  Note the difference from
    :func:`fedlrt_round_comm_bytes`: that counter follows the paper's
    multi-message protocol (basis-gradient upload, augmented-basis
    re-broadcast, …), while this one prices the phase-boundary payloads the
    simulation actually ships.
    """
    fbytes = [
        (
            math.prod(f.U.shape[:-2]),  # stacked-layer slices
            f.n_in,
            f.n_out,
            f.r_max,
            int(jnp.asarray(f.rank).size),
        )
        for f in _factor_leaves(params)
    ]
    dense = sum(x.size for x in _dense_leaves(params))
    if method.startswith("fedlrt_naive") or method == "naive":
        (stack, n_in, n_out, r, rank_sz), = fbytes  # single-factor setting
        down = (n_in + n_out) * r + r * r + rank_sz
        up = (n_in + n_out) * 2 * r + 4 * r * r
        return {"down": down * BYTES, "up": up * BYTES}
    if method.startswith("fedlrt"):
        aug = sum(
            stack * ((n_in + n_out) * 2 * r + 4 * r * r) + rank_sz
            for stack, n_in, n_out, r, rank_sz in fbytes
        )
        coeff = sum(stack * 4 * r * r for stack, _, _, r, _ in fbytes)
        down = aug + dense
        if correction in ("simplified", "full"):
            down += coeff + dense  # per-client correction slice
        up = coeff + dense + 1  # + the drift diagnostic scalar
        return {"down": down * BYTES, "up": up * BYTES}
    if method in ("fedavg", "fedlin"):
        size = sum(x.size for x in jax.tree.leaves(params))
        down = size * (2 if method == "fedlin" else 1)
        return {"down": down * BYTES, "up": size * BYTES}
    raise ValueError(f"unknown method {method!r}")


def dense_round_comm_bytes(params, method: str = "fedlin") -> int:
    """FedAvg (2×) / FedLin (4×) full-weight bytes for a dense pytree."""
    mult = {"fedavg": 2, "fedlin": 4}[method]
    return mult * sum(x.size for x in jax.tree.leaves(params)) * BYTES


def round_total_comm_bytes(
    params, method: str = "fedlrt", *, correction: str = "simplified",
    cohort_size: int,
) -> int:
    """Total server-side on-wire bytes of one round.

    Per-client volumes are participation-independent, but the server's
    aggregate traffic scales with the *active cohort* — under uniform-k
    sampling a round costs ``k/C`` of the full-participation round.
    """
    if method.startswith("fedlrt"):
        per_client = fedlrt_round_comm_bytes(params, correction)
    else:
        per_client = dense_round_comm_bytes(params, method)
    return per_client * cohort_size


def client_flops_per_local_step(params, batch_tokens: int) -> float:
    """Forward+backward matmul FLOPs of the factor leaves per local step.

    fwd: 2·b(n_in·r + r² + r·n_out); bwd ≈ 2× fwd.
    """
    total = 0.0
    for f in _factor_leaves(params):
        r = f.r_max
        total += 6.0 * batch_tokens * (f.n_in * r + r * r + r * f.n_out)
    return total


def client_step_flops(params, batch_tokens: int) -> float:
    """Fwd+bwd matmul FLOPs of one local step over the *whole* pytree.

    Extends :func:`client_flops_per_local_step` (factor leaves only) with
    the dense 2-D leaves, priced as full matmuls (fwd ``2·b·n·m``, bwd
    ≈ 2× fwd) — so dense baselines (FedAvg/FedLin) get comparable compute
    pricing in the system simulator.  Vectors and scalars are free.
    """
    total = client_flops_per_local_step(params, batch_tokens)
    for x in _dense_leaves(params):
        if getattr(x, "ndim", 0) >= 2:
            total += 6.0 * batch_tokens * math.prod(x.shape[-2:])
    return total


def lowrank_decode_flops(n_in: int, n_out: int, r: int, *, gather: bool = False) -> float:
    """Per-token matmul FLOPs of one factor-resident linear in the decode
    path: ``x(1×n_in)·U + (xU)·S + (xUS)·Vᵀ = 2(n_in·r + r² + r·n_out)``.

    ``gather=True`` prices an embedding factor: the U row is gathered, not
    multiplied, so only the ``S`` / ``Vᵀ`` terms remain.
    """
    flops = 2.0 * (r * r + r * n_out)
    if not gather:
        flops += 2.0 * n_in * r
    return flops


def dense_decode_flops(n_in: int, n_out: int, *, gather: bool = False) -> float:
    """Per-token FLOPs of the same linear once ``U S Vᵀ`` is materialized:
    ``2·n_in·n_out`` — or zero for an embedding (a dense embed is a pure
    gather with no matmul at all)."""
    return 0.0 if gather else 2.0 * n_in * n_out


def factor_storage_bytes(params) -> int:
    return sum(
        (f.U.size + f.S.size + f.V.size) * f.U.dtype.itemsize
        for f in _factor_leaves(params)
    )
