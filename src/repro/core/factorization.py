"""Low-rank factor algebra for FeDLRT.

A layer weight is represented as ``W = U S Vᵀ`` with orthonormal bases
``U ∈ R^{n_in × r_max}``, ``V ∈ R^{n_out × r_max}`` and a coefficient matrix
``S ∈ R^{r_max × r_max}``.

**Masked adaptive rank.** The paper's rank ``r`` changes every aggregation
round (augment to 2r, truncate to r₁).  jit requires static shapes, so we
keep *fixed* buffers of width ``r_max`` (and ``2·r_max`` for the augmented
state) plus a dynamic scalar ``rank``.  The invariant that makes every
operation exact under padding is:

    S is zero outside its leading ``rank × rank`` block; the first ``rank``
    columns of U/V are orthonormal and all columns beyond ``rank`` are
    ZERO.

Then ``W = U S Vᵀ`` ignores inactive columns automatically and every
quantity below (products, gradients, projections) equals its
dynamically-shaped counterpart.  Zero (rather than junk-orthonormal)
inactive columns make projections like ``G − U UᵀG`` exact with the full
buffer — no contamination from stale directions is possible.

``rank`` is stored as float32 so the factor pytree stays differentiable
(`jax.grad` rejects integer leaves); it only ever enters comparisons, which
have zero cotangent.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@partial(jax.tree_util.register_dataclass, data_fields=["U", "S", "V", "rank"], meta_fields=[])
@dataclasses.dataclass
class LowRankFactor:
    """``W = U S Vᵀ`` with masked adaptive rank (see module docstring)."""

    U: Array  # (n_in, r_max)
    S: Array  # (r_max, r_max); zero outside [:rank, :rank]
    V: Array  # (n_out, r_max)
    rank: Array  # f32 scalar, active rank

    @property
    def r_max(self) -> int:
        return self.U.shape[-1]

    @property
    def n_in(self) -> int:
        return self.U.shape[-2]

    @property
    def n_out(self) -> int:
        return self.V.shape[-2]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["U", "S", "V", "rank"],
    meta_fields=[],
)
@dataclasses.dataclass
class AugmentedFactor:
    """Augmented state between basis augmentation and truncation.

    ``U, V ∈ R^{n × 2·r_max}``, ``S ∈ R^{2·r_max × 2·r_max}``.  The *active*
    augmented directions are indices ``[0, r) ∪ [r_max, r_max + r)`` where
    ``r`` is the pre-augmentation rank: original basis columns followed by
    the orthonormalized basis-gradient columns (rank r → 2r, paper Eq. (6)).
    """

    U: Array
    S: Array
    V: Array
    rank: Array  # pre-augmentation rank

    @property
    def r_max(self) -> int:
        return self.U.shape[-1] // 2


def rank_mask(rank: Array, width: int, dtype=jnp.float32) -> Array:
    """``m[..., i] = 1.0 if i < rank else 0.0``; batched over ``rank``'s shape.

    ``rank`` may be a scalar (single factor) or shaped ``(...,)`` for
    stacked-layer factors (per-layer adaptive ranks inside a lax.scan stack).
    """
    rank = jnp.asarray(rank)
    return (jnp.arange(width) < rank[..., None]).astype(dtype)


def augmented_mask(rank: Array, r_max: int, dtype=jnp.float32) -> Array:
    """Active-direction mask of the augmented basis, last dim ``2·r_max``.

    Active = first ``rank`` original columns plus the first ``rank``
    gradient columns (which QR places at offset ``r_max``).  Batched over
    ``rank``'s shape like :func:`rank_mask`.
    """
    rank = jnp.asarray(rank)
    i = jnp.arange(2 * r_max)
    r = rank[..., None]
    active = (i < r) | ((i >= r_max) & (i < r_max + r))
    return active.astype(dtype)


def mask_coeff(S: Array, mask: Array) -> Array:
    """Zero S outside the active block: ``m ⊙ S ⊙ mᵀ`` (batched over ...)."""
    return S * mask[..., :, None] * mask[..., None, :]


def materialize(f: LowRankFactor | AugmentedFactor) -> Array:
    """Reconstruct the full ``n_in × n_out`` matrix (tests / tiny layers only)."""
    return jnp.einsum("...ir,...rs,...js->...ij", f.U, f.S, f.V)


def lr_matmul(
    x: Array,
    f: LowRankFactor | AugmentedFactor,
    *,
    precision=None,
    kernels: str = "off",
) -> Array:
    """``y = x @ (U S Vᵀ)`` evaluated through the rank bottleneck.

    Cost ``O(b·n·r)`` instead of ``O(b·n²)``; the full matrix is never
    formed.  This is the client-side compute saving of the paper
    (Table 1) and the contraction our Pallas kernel fuses on TPU:
    ``kernels`` ("auto" | "interpret" | "off") dispatches to the fused
    ``xus``/``avt`` chain with its ``atb``-backed custom VJP.  Works for
    both factor classes — the AugmentedFactor's zeroed inactive columns
    keep the fused chain exactly equal to the masked reference chain.
    """
    if kernels != "off":
        from repro.kernels.ops import lowrank_apply_nd, use_kernels_for

        return lowrank_apply_nd(
            x,
            f.U.astype(x.dtype),
            f.S.astype(x.dtype),
            f.V.astype(x.dtype),
            use_kernels_for(kernels),
        )
    h = jnp.matmul(x, f.U, precision=precision)
    h = jnp.matmul(h, f.S.astype(h.dtype), precision=precision)
    return jnp.matmul(h, f.V.T.astype(h.dtype), precision=precision)


def lr_rowlookup(idx: Array, f: LowRankFactor, *, out_dtype=None) -> Array:
    """Row lookup ``W[idx, :]`` for factorized embedding tables.

    ``gather`` of the ``r``-wide row of U followed by two small matmuls;
    never materializes the ``vocab × d`` table.
    """
    u = jnp.take(f.U, idx, axis=0)  # (..., r_max)
    out = (u @ f.S) @ f.V.T
    return out.astype(out_dtype) if out_dtype is not None else out


def is_factor(x) -> bool:
    return isinstance(x, (LowRankFactor, AugmentedFactor))


def orthonormal_init(
    key: Array, n: int, r: int, dtype=jnp.float32, batch_shape: tuple = ()
) -> Array:
    """Random orthonormal ``n × r`` basis (batched) via QR of a Gaussian."""
    a = jax.random.normal(key, batch_shape + (n, r), dtype=jnp.float32)
    q, _ = jnp.linalg.qr(a)
    return q.astype(dtype)


def init_factor(
    key: Array,
    n_in: int,
    n_out: int,
    r_max: int,
    *,
    init_rank: Optional[int] = None,
    spectrum_scale: Optional[float] = None,
    dtype=jnp.float32,
    batch_shape: tuple = (),
) -> LowRankFactor:
    """Initialize ``U¹, V¹`` orthonormal and ``S¹`` full-rank diagonal.

    The singular spectrum is set so that ``W = U S Vᵀ`` has He-like scale:
    ``E‖W x‖² ≈ (2/n_in)·‖x‖²`` concentrated on ``init_rank`` directions,
    matching dense init magnitude for stable training at round 0.
    """
    # The augmented basis [U | G] must fit min(n_in, n_out) orthonormal
    # columns, so the rank buffer is capped at half the smaller dimension.
    r_cap = max(min(n_in, n_out) // 2, 1)
    r_max = min(r_max, r_cap)
    if init_rank is None:
        init_rank = r_max
    init_rank = min(init_rank, r_max)
    ku, kv = jax.random.split(key)
    U = orthonormal_init(ku, n_in, r_max, dtype, batch_shape)
    V = orthonormal_init(kv, n_out, r_max, dtype, batch_shape)
    if spectrum_scale is None:
        # Match Frobenius norm of He-init dense matrix: ||W||_F² = 2·n_out.
        spectrum_scale = (2.0 * n_out / max(init_rank, 1)) ** 0.5  # python math: eval_shape-safe
    sigma = spectrum_scale * jnp.exp(
        -jnp.arange(r_max, dtype=jnp.float32) / max(init_rank, 1)
    )
    m = rank_mask(jnp.float32(init_rank), r_max)
    sigma = sigma * m
    S = jnp.broadcast_to(jnp.diag(sigma), batch_shape + (r_max, r_max)).astype(dtype)
    rank = jnp.broadcast_to(jnp.float32(init_rank), batch_shape)
    # zero-columns invariant: inactive basis columns are exactly zero
    return LowRankFactor(U=U * m, S=S, V=V * m, rank=rank)


def factor_param_count(f: LowRankFactor) -> int:
    """Static parameter count of the communicated/stored factors."""
    return f.U.size + f.S.size + f.V.size


def effective_rank(f: LowRankFactor) -> Array:
    return f.rank


def check_invariants(f: LowRankFactor, *, atol: float = 1e-4) -> dict:
    """Diagnostics (tests): active-block orthonormality, zero inactive
    columns, S-mask violation.  Batched factors report the max over batch.
    """
    mT = lambda a: jnp.swapaxes(a, -1, -2)
    m = rank_mask(f.rank, f.r_max)

    def defect(B):
        B = B.astype(jnp.float32)
        gram = mT(B) @ B
        # active block must be the identity; inactive columns must be zero
        want = jnp.eye(f.r_max) * m[..., None, :] * m[..., :, None]
        active_err = jnp.linalg.norm(
            (gram - want) * m[..., None, :] * m[..., :, None], axis=(-2, -1)
        )
        inactive_err = jnp.linalg.norm(B * (1 - m)[..., None, :], axis=(-2, -1))
        return jnp.max(active_err + inactive_err)

    s_violation = jnp.linalg.norm(f.S - mask_coeff(f.S, m), axis=(-2, -1))
    return {
        "u_ortho_defect": defect(f.U),
        "v_ortho_defect": defect(f.V),
        "s_mask_violation": jnp.max(s_violation),
    }
