"""Baselines the paper compares against.

- :func:`fedavg_round`  — Algorithm 3 (McMahan et al.).
- :func:`fedlin_round`  — Algorithm 4 (Mitra et al.): FedAvg + variance
  correction, an extra communication round for the global gradient.
- :func:`fedlrt_naive_round` — Algorithm 6: per-client low-rank training
  with *client-local* bases.  Aggregation must reconstruct the full weight
  matrix and re-factorize it with an ``n×n`` SVD — the expensive scheme
  FeDLRT's shared basis eliminates.  Implemented for completeness and used
  by tests/benchmarks on small layers.

All round functions share the (params, client_batches) → (params, metrics)
contract of :func:`repro.core.fedlrt.fedlrt_round` so the engine and the
benchmarks can swap methods freely.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core.dlrt import qr_pos
from repro.core.factorization import (
    AugmentedFactor,
    LowRankFactor,
    is_factor,
    mask_coeff,
    rank_mask,
)
from repro.core.fedlrt import FedConfig
from repro.optim import make_optimizer
from repro.utils.tree import tree_mean_leading_axis

Array = jax.Array
LossFn = Callable[[Any, Any], Array]


def _local_sgd(loss_fn, params0, corr_c, batches, cfg: FedConfig):
    """s* local steps of (optionally corrected) SGD — shared by both baselines."""
    opt = make_optimizer(cfg.optimizer, cfg.lr, momentum=cfg.momentum)

    def client(corr, batch):
        state0 = opt.init(params0)

        def step(carry, s):
            p, ost = carry
            b = batch
            if cfg.per_step_batches:
                b = jax.tree.map(
                    lambda x: jax.lax.dynamic_index_in_dim(x, s, 0, keepdims=False),
                    batch,
                )
            g = jax.grad(loss_fn)(p, b)
            g = jax.tree.map(jnp.add, g, corr)
            upd, ost = opt.update(g, ost, s)
            new_p = jax.tree.map(lambda t, u: t + u.astype(t.dtype), p, upd)
            return (new_p, ost), ()

        (p, _), _ = jax.lax.scan(step, (params0, state0), jnp.arange(cfg.s_star))
        return p

    return jax.vmap(client, in_axes=(0, 0))(corr_c, batches)


def fedavg_round(loss_fn: LossFn, params, client_batches, cfg: FedConfig):
    """Algorithm 3: local SGD, aggregate by averaging."""
    first = client_batches
    if cfg.per_step_batches:
        first = jax.tree.map(lambda x: x[:, 0], client_batches)
    losses = jax.vmap(loss_fn, in_axes=(None, 0))(params, first)
    zeros = jax.tree.map(
        lambda t: jnp.zeros((cfg.num_clients,) + t.shape, t.dtype), params
    )
    params_c = _local_sgd(loss_fn, params, zeros, client_batches, cfg)
    new_params = tree_mean_leading_axis(params_c)
    metrics = {
        "loss_before": jnp.mean(losses),
        "comm_bytes_per_client": jnp.float32(
            cost_model.dense_round_comm_bytes(params, "fedavg")
        ),
    }
    if cfg.eval_after:
        metrics["loss_after"] = jnp.mean(
            jax.vmap(loss_fn, in_axes=(None, 0))(new_params, first)
        )
    return new_params, metrics


def fedlin_round(loss_fn: LossFn, params, client_batches, cfg: FedConfig):
    """Algorithm 4: FedAvg + variance correction (Eq. (4)).

    Effective client gradient: ∇L_c(w) − ∇L_c(wᵗ) + ∇L(wᵗ).
    """
    first = client_batches
    if cfg.per_step_batches:
        first = jax.tree.map(lambda x: x[:, 0], client_batches)
    losses, g_c = jax.vmap(jax.value_and_grad(loss_fn), in_axes=(None, 0))(
        params, first
    )
    g = tree_mean_leading_axis(g_c)
    corr_c = jax.tree.map(
        lambda gbar, gc: jnp.broadcast_to(gbar, gc.shape) - gc, g, g_c
    )
    params_c = _local_sgd(loss_fn, params, corr_c, client_batches, cfg)
    new_params = tree_mean_leading_axis(params_c)
    metrics = {
        "loss_before": jnp.mean(losses),
        "comm_bytes_per_client": jnp.float32(
            cost_model.dense_round_comm_bytes(params, "fedlin")
        ),
    }
    if cfg.eval_after:
        metrics["loss_after"] = jnp.mean(
            jax.vmap(loss_fn, in_axes=(None, 0))(new_params, first)
        )
    return new_params, metrics


# ---------------------------------------------------------------------------
# Algorithm 6: naive per-client low-rank (client-local bases)
# ---------------------------------------------------------------------------


def _naive_client_round(loss_fn, f: LowRankFactor, batch, cfg: FedConfig):
    """One client's local basis-augment + single coefficient step (Alg. 6)."""

    def as_loss(U, S, V):
        return loss_fn(LowRankFactor(U=U, S=S, V=V, rank=f.rank), batch)

    gU, gV = jax.grad(as_loss, argnums=(0, 2))(f.U, f.S, f.V)
    r_max = f.r_max
    m = rank_mask(f.rank, r_max, dtype=f.U.dtype)
    U_t = qr_pos(jnp.concatenate([f.U, gU * m[None, :]], axis=1))
    V_t = qr_pos(jnp.concatenate([f.V, gV * m[None, :]], axis=1))
    S_t = jnp.zeros((2 * r_max, 2 * r_max), f.S.dtype).at[:r_max, :r_max].set(f.S)

    def aug_loss(S):
        return loss_fn(
            AugmentedFactor(U=U_t, S=S, V=V_t, rank=f.rank), batch
        )

    amask = (jnp.arange(2 * r_max) < f.rank) | (
        (jnp.arange(2 * r_max) >= r_max) & (jnp.arange(2 * r_max) < r_max + f.rank)
    )
    amask = amask.astype(S_t.dtype)
    S_c = S_t
    for _ in range(1):  # Alg. 6 does one coefficient step per round
        gS = mask_coeff(jax.grad(aug_loss)(S_c), amask)
        S_c = S_c - cfg.lr * gS
    return U_t, S_c, V_t


def fedlrt_naive_round(
    loss_fn: Callable[[LowRankFactor, Any], Array],
    f: LowRankFactor,
    client_batches,
    cfg: FedConfig,
):
    """Algorithm 6 on a single factorized layer (the paper's setting).

    Per-client bases diverge, so the server must reconstruct
    ``W* = mean_c Ũ_c S̃_c Ṽ_cᵀ`` and run a full ``n×n`` SVD — the cost this
    paper's shared basis removes (Table 1 rows FeDLR / Riemannian FL).
    """
    U_c, S_c, V_c = jax.vmap(
        lambda b: _naive_client_round(loss_fn, f, b, cfg)
    )(client_batches)
    W_star = jnp.mean(jnp.einsum("cik,ckl,cjl->cij", U_c, S_c, V_c), axis=0)
    P, sigma, Qt = jnp.linalg.svd(W_star, full_matrices=False)
    r_max = f.r_max
    tail = jnp.cumsum(jnp.square(sigma[::-1]))[::-1]
    theta = cfg.tau * jnp.linalg.norm(sigma)
    ok = tail < jnp.square(theta)
    r1 = jnp.clip(jnp.where(jnp.any(ok), jnp.argmax(ok), sigma.shape[0]), 1, r_max)
    keep = rank_mask(r1.astype(jnp.float32), r_max)
    new_f = LowRankFactor(
        U=P[:, :r_max],
        S=jnp.diag(sigma[:r_max] * keep),
        V=Qt[:r_max, :].T,
        rank=r1.astype(jnp.float32),
    )
    losses = jax.vmap(lambda b: loss_fn(f, b))(client_batches)
    metrics = {
        "loss_before": jnp.mean(losses),
        "rank": new_f.rank,
        # Alg. 6 communicates both augmented bases and coefficients per client
        "comm_bytes_per_client": jnp.float32(
            4
            * (
                (f.n_in + f.n_out) * 2 * f.r_max
                + (2 * f.r_max) ** 2
                + (f.n_in + f.n_out) * f.r_max
                + f.r_max**2
            )
        ),
    }
    if cfg.eval_after:
        metrics["loss_after"] = jnp.mean(
            jax.vmap(lambda b: loss_fn(new_f, b))(client_batches)
        )
    return new_f, metrics


ROUND_FNS = {
    "fedavg": fedavg_round,
    "fedlin": fedlin_round,
}
