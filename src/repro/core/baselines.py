"""Baselines the paper compares against, as round programs.

- :func:`fedavg_round`  — Algorithm 3 (McMahan et al.).
- :func:`fedlin_round`  — Algorithm 4 (Mitra et al.): FedAvg + variance
  correction, an extra communication round for the global gradient.
- :func:`fedlrt_naive_round` — Algorithm 6: per-client low-rank training
  with *client-local* bases.  Aggregation must reconstruct the full weight
  matrix and re-factorize it with an ``n×n`` SVD — the expensive scheme
  FeDLRT's shared basis eliminates.  Implemented for completeness and used
  by tests/benchmarks on small layers.

Each algorithm is a :class:`repro.core.round.RoundProgram`; the module-level
round functions are thin :func:`repro.core.round.run_round` wrappers keeping
the ``(params, client_batches) → (params, metrics)`` contract of
:func:`repro.core.fedlrt.fedlrt_round` so the engine and the benchmarks can
swap methods freely.  All of them accept ``client_weights`` (weighted
aggregation) and cohort-sized batches under partial participation.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core.dlrt import qr_pos
from repro.core.factorization import (
    AugmentedFactor,
    LowRankFactor,
    mask_coeff,
    rank_mask,
)
from repro.core.round import (
    SERVER,
    FedConfig,
    RoundContext,
    first_step_batch,
    local_sgd_scan,
    run_round,
    variance_correction,
)

Array = jax.Array
LossFn = Callable[[Any, Any], Array]


# ---------------------------------------------------------------------------
# Algorithms 3 and 4: dense FedAvg / FedLin
# ---------------------------------------------------------------------------


class _DenseProgram:
    """Shared skeleton of the dense baselines; subclasses pick the
    correction (none for FedAvg, control-variate for FedLin)."""

    method: str = "fedavg"
    corrected: bool = False

    def broadcast(self, loss_fn: LossFn, params, client_batches, ctx: RoundContext):
        first = first_step_batch(client_batches, ctx.cfg)
        if self.corrected:
            losses, g_c = ctx.vmap_c(jax.value_and_grad(loss_fn), in_axes=(None, 0))(
                params, first
            )
            corr_c = variance_correction(ctx.aggregate(g_c), g_c)
        else:
            losses = ctx.vmap_c(loss_fn, in_axes=(None, 0))(params, first)
            corr_c = None  # FedAvg sends no per-client correction
        # downlink: the global weights; loss metric stays server-side
        shared = {
            "params0": params,
            # ctx.aggregate, not jnp.mean: consistent with the weighted
            # parameter aggregation (and spmd_axis_name under sharding)
            SERVER: {"loss_before": ctx.aggregate(losses)},
        }
        return shared, corr_c

    def client_step(self, loss_fn, shared, corr, batches, ctx: RoundContext):
        p, _ = local_sgd_scan(loss_fn, shared["params0"], corr, batches, ctx.cfg)
        return p

    def aggregate(self, shared, client_out, ctx: RoundContext):
        return ctx.aggregate(client_out)

    def finalize(self, loss_fn, params, shared, agg, client_batches, ctx: RoundContext):
        new_params = agg
        metrics = {
            "loss_before": shared[SERVER]["loss_before"],
            "comm_bytes_per_client": jnp.float32(
                cost_model.dense_round_comm_bytes(params, self.method)
            ),
        }
        if ctx.cfg.eval_after:
            first = first_step_batch(client_batches, ctx.cfg)
            metrics["loss_after"] = ctx.aggregate(
                ctx.vmap_c(loss_fn, in_axes=(None, 0))(new_params, first)
            )
        return new_params, metrics


class FedAvgProgram(_DenseProgram):
    """Algorithm 3: local SGD, aggregate by (weighted) averaging."""

    method = "fedavg"
    corrected = False


class FedLinProgram(_DenseProgram):
    """Algorithm 4: FedAvg + variance correction (Eq. (4)).

    Effective client gradient: ∇L_c(w) − ∇L_c(wᵗ) + ∇L(wᵗ).
    """

    method = "fedlin"
    corrected = True


def fedavg_round(
    loss_fn: LossFn,
    params,
    client_batches,
    cfg: FedConfig,
    *,
    round_idx: Array | int = 0,
    client_weights: Optional[Array] = None,
    wire=None,
):
    """Algorithm 3: local SGD, aggregate by averaging."""
    return run_round(
        FedAvgProgram(), loss_fn, params, client_batches, cfg,
        round_idx=round_idx, client_weights=client_weights, wire=wire,
    )


def fedlin_round(
    loss_fn: LossFn,
    params,
    client_batches,
    cfg: FedConfig,
    *,
    round_idx: Array | int = 0,
    client_weights: Optional[Array] = None,
    wire=None,
):
    """Algorithm 4: FedAvg + variance correction (extra comm round)."""
    return run_round(
        FedLinProgram(), loss_fn, params, client_batches, cfg,
        round_idx=round_idx, client_weights=client_weights, wire=wire,
    )


# ---------------------------------------------------------------------------
# Algorithm 6: naive per-client low-rank (client-local bases)
# ---------------------------------------------------------------------------


def _naive_client_round(loss_fn, f: LowRankFactor, batch, cfg: FedConfig):
    """One client's local basis-augment + single coefficient step (Alg. 6)."""

    def as_loss(U, S, V):
        return loss_fn(LowRankFactor(U=U, S=S, V=V, rank=f.rank), batch)

    gU, gV = jax.grad(as_loss, argnums=(0, 2))(f.U, f.S, f.V)
    r_max = f.r_max
    m = rank_mask(f.rank, r_max, dtype=f.U.dtype)
    U_t = qr_pos(jnp.concatenate([f.U, gU * m[None, :]], axis=1))
    V_t = qr_pos(jnp.concatenate([f.V, gV * m[None, :]], axis=1))
    S_t = jnp.zeros((2 * r_max, 2 * r_max), f.S.dtype).at[:r_max, :r_max].set(f.S)

    def aug_loss(S):
        return loss_fn(
            AugmentedFactor(U=U_t, S=S, V=V_t, rank=f.rank), batch
        )

    amask = (jnp.arange(2 * r_max) < f.rank) | (
        (jnp.arange(2 * r_max) >= r_max) & (jnp.arange(2 * r_max) < r_max + f.rank)
    )
    amask = amask.astype(S_t.dtype)
    S_c = S_t
    for _ in range(1):  # Alg. 6 does one coefficient step per round
        gS = mask_coeff(jax.grad(aug_loss)(S_c), amask)
        S_c = S_c - cfg.lr * gS
    return U_t, S_c, V_t


class FedLRTNaiveProgram:
    """Algorithm 6 on a single factorized layer (the paper's setting).

    Per-client bases diverge, so the server must reconstruct
    ``W* = mean_c Ũ_c S̃_c Ṽ_cᵀ`` and run a full ``n×n`` SVD — the cost this
    paper's shared basis removes (Table 1 rows FeDLR / Riemannian FL).
    """

    def broadcast(self, loss_fn, f: LowRankFactor, client_batches, ctx: RoundContext):
        losses = ctx.vmap_c(lambda b: loss_fn(f, b))(client_batches)
        return {"f": f, SERVER: {"loss_before": ctx.aggregate(losses)}}, None

    def client_step(self, loss_fn, shared, _pc, batch, ctx: RoundContext):
        return _naive_client_round(loss_fn, shared["f"], batch, ctx.cfg)

    def aggregate(self, shared, client_out, ctx: RoundContext):
        U_c, S_c, V_c = client_out
        return ctx.aggregate(jnp.einsum("cik,ckl,cjl->cij", U_c, S_c, V_c))

    def finalize(self, loss_fn, f, shared, W_star, client_batches, ctx: RoundContext):
        cfg = ctx.cfg
        P, sigma, Qt = jnp.linalg.svd(W_star, full_matrices=False)
        r_max = f.r_max
        tail = jnp.cumsum(jnp.square(sigma[::-1]))[::-1]
        theta = cfg.tau * jnp.linalg.norm(sigma)
        ok = tail < jnp.square(theta)
        r1 = jnp.clip(
            jnp.where(jnp.any(ok), jnp.argmax(ok), sigma.shape[0]), 1, r_max
        )
        keep = rank_mask(r1.astype(jnp.float32), r_max)
        # masking U/V is value-neutral (S's zero rows already annihilate the
        # truncated-SVD junk columns) but keeps the zero-inactive-columns
        # layout invariant literally true on the reconstructed factor
        new_f = LowRankFactor(
            U=P[:, :r_max] * keep[None, :],
            S=jnp.diag(sigma[:r_max] * keep),
            V=Qt[:r_max, :].T * keep[None, :],
            rank=r1.astype(jnp.float32),
        )
        metrics = {
            "loss_before": shared[SERVER]["loss_before"],
            "rank": new_f.rank,
            # Alg. 6 communicates both augmented bases and coefficients per client
            "comm_bytes_per_client": jnp.float32(
                4
                * (
                    (f.n_in + f.n_out) * 2 * f.r_max
                    + (2 * f.r_max) ** 2
                    + (f.n_in + f.n_out) * f.r_max
                    + f.r_max**2
                )
            ),
        }
        if cfg.eval_after:
            metrics["loss_after"] = ctx.aggregate(
                ctx.vmap_c(lambda b: loss_fn(new_f, b))(client_batches)
            )
        return new_f, metrics


def fedlrt_naive_round(
    loss_fn: Callable[[LowRankFactor, Any], Array],
    f: LowRankFactor,
    client_batches,
    cfg: FedConfig,
    *,
    round_idx: Array | int = 0,
    client_weights: Optional[Array] = None,
    wire=None,
):
    """Algorithm 6 round — thin :func:`run_round` wrapper."""
    return run_round(
        FedLRTNaiveProgram(), loss_fn, f, client_batches, cfg,
        round_idx=round_idx, client_weights=client_weights, wire=wire,
    )
