"""FeDLRT: one federated aggregation round (paper Algorithms 1 and 5).

The round is expressed as a :class:`repro.core.round.RoundProgram` — the
four-phase skeleton (broadcast / client_step / aggregate / finalize) shared
with the baselines — and is *generic over a parameter pytree* whose leaves
are either :class:`LowRankFactor` (FeDLRT-managed weight matrices) or plain
arrays (norm scales, biases, anything not factorized — these receive
FedLin-style full aggregation, which is cheap since they are O(n) objects).

Federation model
----------------
Clients are an explicit leading axis ``C`` on the batch pytree.  All
client-parallel work is expressed with ``jax.vmap`` over that axis and all
server aggregation with a (weighted) mean over it.  This gives one
implementation that

- runs as a plain single-device simulation on CPU (tests, examples), and
- under ``jit`` with the client axis sharded over the mesh's
  ``("pod", "data")`` axes, lowers the client loop to per-device compute and
  the server aggregation to ``all-reduce`` collectives whose operand sizes
  are exactly the paper's communication volumes (O(n·r) for basis
  gradients, O(r²) for coefficients) — this is how the communication claim
  is made visible to the roofline analysis.

``C`` is the *active cohort* of the round: under partial participation
(:mod:`repro.fed.participation`) the engine hands the round only the
sampled clients' batches and a matching ``FedConfig.num_clients``.

Round structure (Alg. 1 / Alg. 5) mapped onto the phases:
  broadcast:
    1. broadcast {U,V,S}           → implicit (replicated params)
    2. client basis gradients      → ``vmap(grad(loss))`` at shared params
       server aggregate            → mean over C            [comm: 2nr (+r²)]
    3. server basis augmentation   → QR (dlrt.augment_basis)
       broadcast {Ū,V̄}            → implicit               [comm: 2nr]
    4. (full v/c only) aggregate augmented coefficient gradients  [comm: 4r²×2]
  client_step:
    5. client coefficient loop     → ``lax.scan`` of s* masked-SGD steps on S̃
  aggregate:
    6. aggregate S̃* = mean_c S̃_c  → Eq. (10)               [comm: 4r²]
  finalize:
    7. truncation (2r×2r SVD)      → automatic compression
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core.dlrt import augment_basis, coeff_grad_mask, truncate
from repro.core.factorization import (
    AugmentedFactor,
    LowRankFactor,
    is_factor,
    mask_coeff,
)
from repro.core.round import (
    SERVER,
    FedConfig,
    LossFn,
    RoundContext,
    first_step_batch,
    last_step_batch,
    local_sgd_scan,
    run_round,
    variance_correction,
)
from repro.utils import meshctx

__all__ = ["FedConfig", "FedLRTProgram", "fedlrt_round", "make_fedlrt_step"]

Array = jax.Array


# ---------------------------------------------------------------------------
# pytree plumbing: factor leaves vs dense leaves
# ---------------------------------------------------------------------------


def _map_params(fn, params, *rest):
    """tree.map over params treating LowRankFactor/AugmentedFactor as leaves."""
    return jax.tree.map(fn, params, *rest, is_leaf=is_factor)


def trainable_of(aug_params):
    """Per-client trainable view: S̃ for factor leaves, the array itself else."""
    return _map_params(lambda x: x.S if is_factor(x) else x, aug_params)


def merge_trainable(aug_params, trainable):
    """Inverse of :func:`trainable_of`."""
    return _map_params(
        lambda x, t: dataclasses.replace(x, S=t) if is_factor(x) else t,
        aug_params,
        trainable,
    )


def _mask_coeff_grads(aug_params, grads):
    """Restrict coefficient gradients to the paper's 2r active directions."""

    def one(x, g):
        if is_factor(x):
            return mask_coeff(g, coeff_grad_mask(x))
        return g

    return _map_params(one, aug_params, grads)


def _mask_trainable(aug_params, trainable):
    def one(x, t):
        if is_factor(x):
            return mask_coeff(t, coeff_grad_mask(x))
        return t

    return _map_params(one, aug_params, trainable)


def _coeff_drift(aug_params, trainable, trainable0):
    """‖S̃ − S̃⁰‖ over factor-coefficient leaves only."""
    sq = jnp.zeros(())
    pairs = jax.tree.leaves(
        _map_params(
            lambda x, a, b: (is_factor(x), a, b), aug_params, trainable, trainable0
        ),
        is_leaf=lambda t: isinstance(t, tuple),
    )
    for isf, a, b in pairs:
        if isf:
            sq = sq + jnp.sum(jnp.square((a - b).astype(jnp.float32)))
    return jnp.sqrt(sq)


def _coeff_grad_norm(params, g_global):
    """‖∇_S L‖ over all factor leaves (enters Thm. 1/2 diagnostics)."""
    sq = jnp.zeros(())
    leaves = jax.tree.leaves(
        _map_params(lambda p, g: (p, g), params, g_global),
        is_leaf=lambda x: isinstance(x, tuple),
    )
    for p, g in leaves:
        if isinstance(p, LowRankFactor):
            sq = sq + jnp.sum(jnp.square(g.S.astype(jnp.float32)))
    return jnp.sqrt(sq)


def _constrain_factor(x, spec):
    """Re-pin U/V tensor-parallel sharding on augmented/truncated factors.

    Spec leaves come from the model's param-spec tree; the rank dim widens
    r → 2r through augmentation but the PartitionSpec (which shards only
    the feature dim) still applies.  Without this, GSPMD materializes the
    replicated f32 QR/SVD intermediates of every layer (several GiB/device
    on 7B-scale configs).
    """
    if spec is None or not is_factor(x):
        return x
    return dataclasses.replace(
        x,
        U=meshctx.constrain(x.U, spec.U),
        V=meshctx.constrain(x.V, spec.V),
    )


def _constrain_clientwise(tree, ctx: RoundContext):
    """Pin (C, …) per-client pytrees to P(client_axes, *param_spec)."""
    if ctx.spec_tree is None or ctx.client_axes is None:
        return tree
    import jax.sharding as jsh

    def one(g, s):
        def leafc(gl, sl):
            return meshctx.constrain(gl, jsh.PartitionSpec(ctx.client_axes, *sl))

        if is_factor(g):
            return jax.tree.map(leafc, g, s)
        return leafc(g, s)

    return _map_params(one, tree, ctx.spec_tree)


# ---------------------------------------------------------------------------
# the round program
# ---------------------------------------------------------------------------


class FedLRTProgram:
    """Algorithms 1 (full correction) / 5 (simplified) as a round program."""

    def broadcast(self, loss_fn: LossFn, params, client_batches, ctx: RoundContext):
        cfg = ctx.cfg
        first_batch = first_step_batch(client_batches, cfg)

        # -- 1/2: client basis (and coefficient) gradients at the shared point
        losses, per_client_g = ctx.vmap_c(
            jax.value_and_grad(loss_fn), in_axes=(None, 0)
        )(params, first_batch)
        per_client_g = _constrain_clientwise(per_client_g, ctx)
        # weighted mean, consistent with every other aggregate of the round
        # (a bare jnp.mean under client_weights reports the unweighted loss
        # of a weighted run, and drops spmd_axis_name on a sharded C axis)
        loss_before = ctx.aggregate(losses)
        g_global = ctx.aggregate(per_client_g)  # server aggregate

        # -- 3: server-side basis augmentation (QR), Lemma-1 S̃ assembly -----
        def _augment(p, g, spec=None):
            if isinstance(p, LowRankFactor):
                u_spec = spec.U if spec is not None and is_factor(spec) else None
                v_spec = spec.V if spec is not None and is_factor(spec) else None
                return augment_basis(p, g.U, g.V, u_spec=u_spec, v_spec=v_spec)
            return p  # dense leaf: untouched here

        if ctx.spec_tree is not None:
            aug_params = _map_params(_augment, params, g_global, ctx.spec_tree)
        else:
            aug_params = _map_params(_augment, params, g_global)
        if ctx.spec_tree is not None:
            if cfg.replicate_augmented:
                import jax.sharding as jsh

                repl = jax.tree.map(
                    lambda s: jsh.PartitionSpec(), ctx.spec_tree,
                    is_leaf=lambda x: isinstance(x, jsh.PartitionSpec),
                )
                aug_params = _map_params(_constrain_factor, aug_params, repl)
            else:
                aug_params = _map_params(_constrain_factor, aug_params, ctx.spec_tree)

        trainable0 = trainable_of(aug_params)
        local_loss = self._local_loss(loss_fn, aug_params)

        # -- 4: variance correction term per client -------------------------
        # corr_c enters the update as: S̃ ← S̃ − λ(∇L_c(S̃_c) + corr_c),
        # corr_c = G_S̃ − G_S̃,c  (global minus own; paper Eq. (8)).
        if cfg.correction == "full":
            # extra communication round: aggregate ∇_S̃ L_c at the augmented point
            g0_c = ctx.vmap_c(jax.grad(local_loss), in_axes=(None, 0))(
                trainable0, first_batch
            )
            corr_c = variance_correction(ctx.aggregate(g0_c), g0_c)
        elif cfg.correction == "simplified":
            # reuse the round-1 gradients: pad ∇_S L into the top-left block
            # (Eq. (9)); dense leaves get the FedLin correction from the same
            # round-1 gradients — no extra communication.
            def simpl(p, gbar, gc):
                if isinstance(p, LowRankFactor):
                    r_max = p.r_max
                    # gc.S: (C, ..., r_max, r_max) — batched (stacked-layer) safe
                    block = jnp.zeros(
                        gc.S.shape[:-2] + (2 * r_max, 2 * r_max), gc.S.dtype
                    )
                    block = block.at[..., :r_max, :r_max].set(gbar.S[None] - gc.S)
                    return block
                return jnp.broadcast_to(gbar, gc.shape) - gc

            corr_c = jax.tree.map(
                simpl, params, g_global, per_client_g, is_leaf=is_factor
            )
        else:  # "none"
            corr_c = None  # uncorrected: nothing to send down per client

        # downlink: the augmented factors (Ū, V̄, S̃ — what the paper
        # broadcasts after augmentation); everything else is server-local
        # and never crosses the wire.
        shared = {
            "aug_params": aug_params,
            SERVER: {"g_global": g_global, "loss_before": loss_before},
        }
        return shared, corr_c

    @staticmethod
    def _local_loss(loss_fn, aug_params):
        def local_loss(trainable, batch):
            return loss_fn(merge_trainable(aug_params, trainable), batch)

        return local_loss

    def client_step(self, loss_fn, shared, corr, batches, ctx: RoundContext):
        # -- 5: client coefficient optimization (s* local steps) ------------
        cfg = ctx.cfg
        # the client derives its trainable view from the *received* factors
        # (S̃ is a projection of the broadcast, not a separate transmission)
        aug_params = shared["aug_params"]
        trainable0 = trainable_of(aug_params)
        drift_fn = (
            (lambda tr: _coeff_drift(aug_params, tr, trainable0))
            if cfg.track_drift
            else None
        )
        return local_sgd_scan(
            self._local_loss(loss_fn, aug_params),
            trainable0,
            corr,
            batches,
            cfg,
            transform_grads=lambda g: _mask_coeff_grads(aug_params, g),
            # keep the zero-padding invariant exact under momentum etc.
            project=lambda tr: _mask_trainable(aug_params, tr),
            drift_fn=drift_fn,
        )

    def aggregate(self, shared, client_out, ctx: RoundContext):
        # -- 6: aggregation  S̃* = mean_c S̃_c^{s*}  (Eq. (10)) ---------------
        trainable_c, drift_c = client_out
        return ctx.aggregate(trainable_c), drift_c

    def finalize(self, loss_fn, params, shared, agg, client_batches, ctx: RoundContext):
        # -- 7: truncation (automatic compression) --------------------------
        cfg = ctx.cfg
        trainable_star, drift_c = agg
        merged = merge_trainable(shared["aug_params"], trainable_star)

        infos = {}

        def _truncate(path, x):
            if isinstance(x, AugmentedFactor):
                new_f, info = truncate(x, tau=cfg.tau)
                infos[jax.tree_util.keystr(path)] = info
                return new_f
            return x

        new_params = jax.tree_util.tree_map_with_path(
            _truncate, merged, is_leaf=is_factor
        )
        if ctx.spec_tree is not None:
            new_params = _map_params(_constrain_factor, new_params, ctx.spec_tree)

        metrics = {
            "loss_before": shared[SERVER]["loss_before"],
            "rank": {k: v["rank"] for k, v in infos.items()},
            "trunc_err": {k: v["trunc_err"] for k, v in infos.items()},
            "grad_norm_S": _coeff_grad_norm(params, shared[SERVER]["g_global"]),
            # static r_max bound (python int, jit-constant) …
            "comm_bytes_per_client": jnp.float32(
                cost_model.fedlrt_round_comm_bytes(params, cfg.correction)
            ),
            # … and the effective-rank bytes of the *post-truncation* state:
            # this is the figure that shrinks as truncation adapts ranks.
            "comm_bytes_per_client_effective": (
                cost_model.fedlrt_round_comm_bytes_effective(
                    new_params, cfg.correction
                )
            ),
        }
        if cfg.track_drift:
            metrics["max_coeff_drift"] = jnp.max(drift_c)
        if cfg.eval_after:
            last_batch = last_step_batch(client_batches, cfg)
            losses_after = ctx.vmap_c(loss_fn, in_axes=(None, 0))(
                new_params, last_batch
            )
            metrics["loss_after"] = ctx.aggregate(losses_after)
        return new_params, metrics


def fedlrt_round(
    loss_fn: LossFn,
    params,
    client_batches,
    cfg: FedConfig,
    *,
    round_idx: Array | int = 0,
    spec_tree=None,
    client_axes=None,
    client_weights: Optional[Array] = None,
    wire=None,
):
    """One full FeDLRT aggregation round.  Returns ``(new_params, metrics)``.

    Thin wrapper over :func:`repro.core.round.run_round` with
    :class:`FedLRTProgram` — kept as the stable
    ``(params, client_batches) → (params, metrics)`` entry point.

    ``client_batches`` leaves carry a leading client axis ``C``
    (``(C, s*, ...)`` if ``cfg.per_step_batches``).  ``spec_tree`` (optional,
    mirrors ``params`` with PartitionSpec leaves) keeps the augmented and
    truncated factors on their tensor-parallel layout under GSPMD;
    ``client_axes`` names the mesh axes carrying the client dim so that
    per-client gradient pytrees stay sharded (client over data axes ×
    feature dims over model) instead of replicating.

    ``client_weights`` (optional, shape (C,)): non-uniform aggregation
    weights ∝ |X_c| — the paper's §2 weighted-average extension.  Applied
    to every ``aggregate`` (basis gradients, correction gradients,
    coefficients); normalized internally.

    ``wire`` (optional :class:`repro.fed.wire.Wire`): on-the-wire codec for
    the round's data plane — the augmented-factor broadcast, the per-client
    correction slices and the coefficient uploads pass through it, and the
    metrics gain measured ``wire_bytes_{down,up}_per_client``.
    """
    return run_round(
        FedLRTProgram(),
        loss_fn,
        params,
        client_batches,
        cfg,
        round_idx=round_idx,
        client_weights=client_weights,
        spec_tree=spec_tree,
        client_axes=client_axes,
        wire=wire,
    )


def make_fedlrt_step(loss_fn: LossFn, cfg: FedConfig):
    """jit-ready ``(params, client_batches, round_idx) → (params, metrics)``."""

    @jax.jit
    def step(params, client_batches, round_idx):
        return fedlrt_round(loss_fn, params, client_batches, cfg, round_idx=round_idx)

    return step
