"""The paper's primary contribution: FeDLRT — federated dynamical low-rank
training with variance correction, plus its baselines and cost model."""
from repro.core.factorization import (  # noqa: F401
    AugmentedFactor,
    LowRankFactor,
    init_factor,
    is_factor,
    lr_matmul,
    lr_rowlookup,
    materialize,
)
from repro.core.round import (  # noqa: F401
    SERVER,
    FedConfig,
    RoundContext,
    RoundProgram,
    local_sgd_scan,
    make_aggregator,
    run_round,
    split_server,
    variance_correction,
)
from repro.core.fedlrt import FedLRTProgram, fedlrt_round, make_fedlrt_step  # noqa: F401
from repro.core.baselines import (  # noqa: F401
    FedAvgProgram,
    FedLinProgram,
    FedLRTNaiveProgram,
    fedavg_round,
    fedlin_round,
    fedlrt_naive_round,
)
