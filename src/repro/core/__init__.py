"""The paper's primary contribution: FeDLRT — federated dynamical low-rank
training with variance correction, plus its baselines and cost model."""
from repro.core.factorization import (  # noqa: F401
    AugmentedFactor,
    LowRankFactor,
    init_factor,
    is_factor,
    lr_matmul,
    lr_rowlookup,
    materialize,
)
from repro.core.fedlrt import FedConfig, fedlrt_round, make_fedlrt_step  # noqa: F401
from repro.core.baselines import fedavg_round, fedlin_round  # noqa: F401
