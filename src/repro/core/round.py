"""Round programs: the shared skeleton of every federated aggregation round.

The paper's Algorithms 1–6 (FeDLRT full/simplified, FedAvg, FedLin, naive
per-client low-rank) all instantiate the same four-phase round::

    broadcast   server-side prep at the shared point: global gradients,
                basis augmentation, per-client correction terms
    client_step one client's local work (vmapped over the cohort axis by
                the runner — jit/GSPMD friendly, no host loop)
    aggregate   server reduction over the cohort (weighted mean → under a
                sharded client axis this lowers to the paper's all-reduce)
    finalize    truncation / metric assembly on the aggregated state

:func:`run_round` executes any :class:`RoundProgram` through that skeleton.
The phases communicate through plain pytrees; everything cohort-shaped
carries a leading client axis ``C`` (the *active cohort*, which under
partial participation is smaller than the population — see
:mod:`repro.fed.participation`).

The phase boundaries are also the round's *data plane*: what ``broadcast``
hands the clients crosses the wire down, what ``client_step`` returns
crosses up.  :func:`run_round` optionally threads those payloads through a
:class:`repro.fed.wire.Wire` (owned by the engine) — encode/decode plus
measured byte accounting — while server-local state stays out of the
transmission via the ``shared[SERVER]`` convention (see :data:`SERVER`).

Shared building blocks that used to be duplicated per algorithm live here:
:func:`local_sgd_scan` (the s*-step client loop as one ``lax.scan``) and
:func:`variance_correction` (the FedLin/FeDLRT control-variate term).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.optim import make_optimizer
from repro.utils.tree import tree_mean_leading_axis

Array = jax.Array
LossFn = Callable[[Any, Any], Array]  # (params, batch) -> scalar


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Hyperparameters of one federated optimization run.

    ``num_clients`` is the size of the *active cohort* a round function
    sees — with partial participation the engine rebuilds the config per
    cohort size (jit caches one executable per size).
    """

    num_clients: int
    s_star: int  # local iterations per round
    lr: float = 1e-3
    correction: str = "simplified"  # "none" | "simplified" | "full"
    tau: float = 0.01  # relative singular-value truncation threshold
    optimizer: str = "sgd"
    momentum: float = 0.0
    per_step_batches: bool = False  # batch leaves have a (C, s*, ...) layout
    eval_after: bool = True  # compute global loss after the round (extra fwd)
    track_drift: bool = False  # record max_s ‖S̃_c^s − S̃‖ (Theorem-1 diagnostics)
    # replicate the augmented bases for the client loop (hypothesis Q3 in
    # EXPERIMENTS.md §Perf: gather-once beats per-step gathers).  REFUTED on
    # qwen2 train_4k — XLA already hoists the per-step gathers out of the
    # scan, so forced replication only added resharding traffic (+75% on
    # the collective term) and +4.5 GiB temp.  Kept as a switch.
    replicate_augmented: bool = False

    def __post_init__(self):
        if self.correction not in ("none", "simplified", "full"):
            raise ValueError(
                f"correction must be 'none', 'simplified' or 'full', "
                f"got {self.correction!r}"
            )
        if self.num_clients <= 0:
            raise ValueError(
                f"num_clients must be a positive cohort size, got {self.num_clients}"
            )
        if self.s_star <= 0:
            raise ValueError(
                f"s_star (local iterations per round) must be positive, "
                f"got {self.s_star}"
            )
        if self.lr <= 0:
            raise ValueError(f"lr must be positive, got {self.lr}")
        if not 0.0 <= self.tau < 1.0:
            raise ValueError(
                f"tau is a *relative* singular-value threshold and must lie "
                f"in [0, 1), got {self.tau}"
            )


@dataclasses.dataclass(frozen=True)
class RoundContext:
    """Everything a phase needs beyond its own pytrees.

    ``aggregate`` reduces a leading-client-axis pytree to the server value
    (plain or ``client_weights``-weighted mean); ``vmap_c`` is the client
    vmap, carrying ``spmd_axis_name`` when the client axis lives on mesh
    axes.  Both are closures so programs stay oblivious to weighting and
    sharding concerns.
    """

    cfg: FedConfig
    round_idx: Array
    aggregate: Callable[[Any], Any]
    vmap_c: Callable
    client_weights: Optional[Array] = None
    spec_tree: Any = None
    client_axes: Any = None


#: key under which ``broadcast`` stashes server-local state.  Everything
#: else in the shared dict is *downlink payload* — it crosses the wire to
#: every client (and is what a :class:`repro.fed.wire.Wire` encodes).
#: ``client_step`` never sees the server entry; ``aggregate``/``finalize``
#: get the full original shared dict (the server keeps its own copies).
SERVER = "__server__"

#: canonical phase names of a federated round, in execution order.  The
#: telemetry span names the engines emit (``phase.client_step``,
#: ``phase.aggregate``, …) are ``"phase." + <one of these>`` — keep them
#: in sync so traces stay greppable against the RoundProgram protocol.
PHASES = ("broadcast", "client_step", "aggregate", "finalize")


def split_server(shared):
    """Split a broadcast ``shared`` dict into ``(downlink, server_state)``.

    Programs that predate the wire layer (plain dicts without a
    :data:`SERVER` entry) broadcast everything.
    """
    if isinstance(shared, dict) and SERVER in shared:
        return {k: v for k, v in shared.items() if k != SERVER}, shared[SERVER]
    return shared, None


@runtime_checkable
class RoundProgram(Protocol):
    """One federated algorithm, decomposed into the four round phases."""

    def broadcast(self, loss_fn: LossFn, params, client_batches, ctx: RoundContext):
        """Server-side prep.  Returns ``(shared, per_client)`` where
        ``shared`` is broadcast state closed over by every client and
        ``per_client`` carries a leading client axis (or is None).

        Wire contract: ``shared`` entries are *transmitted* to every
        client; values only the server needs (metrics, cached gradients)
        belong under ``shared[SERVER]`` so they are neither measured nor
        degraded by a lossy wire codec.  ``per_client`` is sliced along its
        leading axis — client ``c`` receives (and is billed for) row ``c``.
        """
        ...

    def client_step(self, loss_fn: LossFn, shared, per_client, batches, ctx: RoundContext):
        """One client's local work (the runner vmaps this over the cohort).

        ``shared``/``per_client`` here are the *received* payloads: the
        :data:`SERVER` entry is stripped, and under a lossy wire codec the
        tensors carry that codec's on-wire representation error.
        """
        ...

    def aggregate(self, shared, client_out, ctx: RoundContext):
        """Server reduction over the stacked client outputs.  ``shared`` is
        the original broadcast dict (server-side copies); ``client_out`` is
        what arrived back over the wire."""
        ...

    def finalize(self, loss_fn: LossFn, params, shared, agg, client_batches, ctx: RoundContext):
        """Post-aggregation server work.  Returns ``(new_params, metrics)``."""
        ...


def make_aggregator(client_weights: Optional[Array]) -> Callable[[Any], Any]:
    """Leading-axis reduction: plain mean, or normalized ``w``-weighted mean
    (the paper's §2 non-uniform |X_c| extension)."""
    if client_weights is None:
        return tree_mean_leading_axis
    w = jnp.asarray(client_weights, jnp.float32)
    w = w / jnp.sum(w)

    def aggregate(tree):
        return jax.tree.map(
            lambda x: jnp.tensordot(
                w.astype(jnp.float32), x.astype(jnp.float32), axes=1
            ).astype(x.dtype),
            tree,
        )

    return aggregate


def make_context(
    cfg: FedConfig,
    *,
    round_idx: Array | int = 0,
    client_weights: Optional[Array] = None,
    spec_tree=None,
    client_axes=None,
) -> RoundContext:
    vmap_c = (
        functools.partial(jax.vmap, spmd_axis_name=client_axes)
        if client_axes
        else jax.vmap
    )
    return RoundContext(
        cfg=cfg,
        round_idx=jnp.asarray(round_idx),
        aggregate=make_aggregator(client_weights),
        vmap_c=vmap_c,
        client_weights=client_weights,
        spec_tree=spec_tree,
        client_axes=client_axes,
    )


def run_client_phases(
    program: RoundProgram,
    loss_fn: LossFn,
    params,
    client_batches,
    ctx: RoundContext,
    *,
    wire=None,
):
    """The data-plane half of a round: ``broadcast`` then the vmapped
    ``client_step``, with every boundary payload threaded through ``wire``.

    Returns ``(shared, client_out, (bytes_shared, bytes_per_client,
    bytes_up))`` — the server-side broadcast dict (with its ``SERVER``
    entry intact), the stacked client outputs *as received over the wire*,
    and the measured byte totals per payload.  :func:`run_round` is this
    followed by ``aggregate``/``finalize``; the async simulation engine
    (:mod:`repro.fed.sim`) calls it directly to run departure-anchored
    client work for one staleness group at a time.
    """
    shared, per_client = program.broadcast(loss_fn, params, client_batches, ctx)
    # clients only ever see the downlink part; the server keeps `shared`
    client_shared, _ = split_server(shared)
    bytes_shared = bytes_pc = bytes_up = 0
    if wire is not None:
        client_shared, bytes_shared = wire.roundtrip(client_shared, name="broadcast")
        per_client, bytes_pc = wire.roundtrip(
            per_client, name="per_client", batched=True
        )
    client_out = ctx.vmap_c(
        lambda pc, b: program.client_step(loss_fn, client_shared, pc, b, ctx),
        in_axes=(0, 0),
    )(per_client, client_batches)
    if wire is not None:
        client_out, bytes_up = wire.roundtrip(
            client_out, name="client_out", batched=True
        )
    return shared, client_out, (bytes_shared, bytes_pc, bytes_up)


def run_round(
    program: RoundProgram,
    loss_fn: LossFn,
    params,
    client_batches,
    cfg: FedConfig,
    *,
    round_idx: Array | int = 0,
    client_weights: Optional[Array] = None,
    spec_tree=None,
    client_axes=None,
    wire=None,
):
    """Execute one round of ``program``.  Returns ``(new_params, metrics)``.

    ``wire`` (optional :class:`repro.fed.wire.Wire`) decorates the phase
    boundaries — the data plane of the round: the broadcast downlink and
    per-client slices are encoded/decoded before ``client_step`` sees them,
    the client outputs before ``aggregate`` sees them.  Measured bytes land
    in the metrics as ``wire_bytes_down_per_client`` /
    ``wire_bytes_up_per_client`` (down counts the shared broadcast once per
    client plus that client's slice).  Programs need no changes: with the
    identity codec the round is bit-identical to ``wire=None``.
    """
    ctx = make_context(
        cfg,
        round_idx=round_idx,
        client_weights=client_weights,
        spec_tree=spec_tree,
        client_axes=client_axes,
    )
    shared, client_out, (bytes_shared, bytes_pc, bytes_up) = run_client_phases(
        program, loss_fn, params, client_batches, ctx, wire=wire
    )
    agg = program.aggregate(shared, client_out, ctx)
    new_params, metrics = program.finalize(
        loss_fn, params, shared, agg, client_batches, ctx
    )
    if wire is not None:
        metrics = dict(metrics)
        metrics["wire_bytes_down_per_client"] = _per_client_bytes(
            bytes_shared, bytes_pc, cfg.num_clients
        )
        metrics["wire_bytes_up_per_client"] = _per_client_bytes(
            0, bytes_up, cfg.num_clients
        )
    return new_params, metrics


def _per_client_bytes(shared_bytes, batched_bytes, num_clients: int):
    """``shared + batched/C`` per-client bytes, exactly when possible.

    Static codec counts are python ints whose batched totals divide evenly
    over the ``C`` equal-size client slices — integer arithmetic keeps the
    measured == analytic contract exact up to int32 range (~2 GiB/client/
    direction) instead of f32's 2^24 bytes.  Traced counts (topk_rank's
    rank-dependent meter) take the f32 path.
    """
    if (
        isinstance(shared_bytes, int)
        and isinstance(batched_bytes, int)
        and batched_bytes % num_clients == 0
    ):
        return shared_bytes + batched_bytes // num_clients
    return jnp.float32(shared_bytes) + jnp.float32(batched_bytes) / num_clients


# ---------------------------------------------------------------------------
# shared building blocks
# ---------------------------------------------------------------------------


def first_step_batch(client_batches, cfg: FedConfig):
    """The cohort's step-0 batch: ``x[:, 0]`` under per-step layout."""
    if cfg.per_step_batches:
        return jax.tree.map(lambda x: x[:, 0], client_batches)
    return client_batches


def last_step_batch(client_batches, cfg: FedConfig):
    if cfg.per_step_batches:
        return jax.tree.map(lambda x: x[:, -1], client_batches)
    return client_batches


def select_step_batch(batches, s: Array, cfg: FedConfig):
    """One client's batch for local step ``s`` (inside the vmapped scan)."""
    if cfg.per_step_batches:
        return jax.tree.map(
            lambda x: jax.lax.dynamic_index_in_dim(x, s, 0, keepdims=False), batches
        )
    return batches


def variance_correction(g_global, g_clients):
    """Control-variate term ``corr_c = ḡ − g_c`` (paper Eq. (4) / Eq. (8)).

    Enters each local step as ``∇L_c(w) + corr_c`` so the expected client
    update follows the *global* gradient; with a plain-mean aggregate the
    corrections sum to zero over the cohort.
    """
    return jax.tree.map(
        lambda gbar, gc: jnp.broadcast_to(gbar, gc.shape) - gc, g_global, g_clients
    )


def local_sgd_scan(
    loss_fn: LossFn,
    params0,
    corr,
    batches,
    cfg: FedConfig,
    *,
    transform_grads: Optional[Callable[[Any], Any]] = None,
    project: Optional[Callable[[Any], Any]] = None,
    drift_fn: Optional[Callable[[Any], Array]] = None,
):
    """One client's s* local (optionally corrected) SGD steps as a scan.

    The single implementation behind every round program's client loop:
    FeDLRT passes ``transform_grads``/``project`` to keep coefficient
    updates in the 2r active directions, the dense baselines use it bare.
    ``corr=None`` means uncorrected (no control variate is added — and, under
    the wire layer, none is transmitted).  ``drift_fn`` (optional)
    accumulates ``max_s drift_fn(params_s)`` — the Theorem-1 diagnostic.
    Returns ``(params_s*, max_drift)``.
    """
    opt = make_optimizer(cfg.optimizer, cfg.lr, momentum=cfg.momentum)
    state0 = opt.init(params0)

    def step(carry, s):
        p, ost, drift = carry
        b = select_step_batch(batches, s, cfg)
        g = jax.grad(loss_fn)(p, b)
        if corr is not None:
            g = jax.tree.map(jnp.add, g, corr)
        if transform_grads is not None:
            g = transform_grads(g)
        upd, ost = opt.update(g, ost, s)
        # cast: f32 lr × bf16 grad promotes; carry dtype must be stable
        p = jax.tree.map(lambda t, u: t + u.astype(t.dtype), p, upd)
        if project is not None:
            p = project(p)
        if drift_fn is not None:
            drift = jnp.maximum(drift, drift_fn(p))
        return (p, ost, drift), ()

    (p, _, drift), _ = jax.lax.scan(
        step, (params0, state0, jnp.zeros(())), jnp.arange(cfg.s_star)
    )
    return p, drift
