"""Dynamical low-rank (BUG splitting) primitives: augment & truncate.

These are the *server-side* operations of FeDLRT (paper §3.1):

- :func:`augment_basis` — Eq. (6): orthonormalize ``[Uᵗ | G_U]`` /
  ``[Vᵗ | G_V]`` and assemble the augmented coefficient
  ``S̃ = [[Sᵗ, 0], [0, 0]]`` (Lemma 1 — no projection matmul needed).
- :func:`truncate` — automatic compression: ``2r×2r`` SVD of the aggregated
  coefficient, rank chosen by the singular-value tail threshold
  ``‖[σ_{r₁}, …, σ_{2r}]‖₂ < ϑ``, bases rotated by the singular vectors.

Everything is shape-static (``r_max`` buffers, see factorization.py), so the
whole FeDLRT round jits and lowers to a single HLO for the dry-run.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.factorization import (
    AugmentedFactor,
    LowRankFactor,
    augmented_mask,
    mask_coeff,
    rank_mask,
)

Array = jax.Array


def qr_pos(a: Array) -> Array:
    """QR with the sign convention ``diag(R) ≥ 0`` (batched over leading dims).

    Needed so that when the leading columns of ``a`` are already orthonormal
    (as in ``[Uᵗ | G_U]``), ``Q``'s leading columns equal them *exactly*
    (up to roundoff) instead of up to a sign — this is what makes Lemma 1
    (``S̃`` assembly without projection) valid.
    """
    q, r = jnp.linalg.qr(a)
    d = jnp.sign(jnp.diagonal(r, axis1=-2, axis2=-1))
    d = jnp.where(d == 0, 1.0, d).astype(q.dtype)
    return q * d[..., None, :]


def _ortho_complement_cholqr2(U: Array, G: Array, eps: float = 1e-7, spec=None) -> Array:
    """Orthonormalize ``G`` against the orthonormal ``U`` — CholeskyQR2.

    TPU adaptation (DESIGN.md §5): the Householder QR of ``[U | G]`` used
    verbatim from the paper allocates O(n·2r) LAPACK workspace per layer
    (GiBs on 7B configs, replicated on every device, and sequential —
    MXU-hostile).  Because the left block is *already orthonormal*, the
    same span is obtained by projecting ``G`` off ``U`` and running
    CholeskyQR twice: pure batched matmuls + an ``r×r`` Cholesky.
    Rank-deficient columns surface as junk-but-masked directions (the
    coefficient mask keeps them inert, and the truncation SVD's rotation
    is supported on the active block only — see factorization.py docstring).

    Severely rank-deficient blocks (e.g. MoE expert factors whose expert
    saw almost no routed tokens, so ``G`` spans far fewer than r
    directions) can drive the Cholesky to a non-PD matrix and emit
    non-finite columns; those are zeroed — a zero basis column is exactly
    inert (contributes nothing to ``Ũ S̃ Ṽᵀ``), whereas a NaN one poisons
    the whole factor through the client loss.
    """
    def pin(Q):
        # keep the row (feature) dim sharded: every matmul here contracts
        # over rows (→ small r×r psums) or is row-local, so no step needs
        # the gathered basis — without the pin, GSPMD all-gathers the f32
        # QR workspace of every layer (≈4.4 GiB/device on qwen2 train)
        from repro.utils import meshctx

        return meshctx.constrain(Q, spec) if spec is not None else Q

    def once(Q):
        Q = pin(Q - U @ (jnp.swapaxes(U, -1, -2) @ Q))
        C = jnp.swapaxes(Q, -1, -2) @ Q
        C = C + eps * jnp.eye(C.shape[-1], dtype=C.dtype)
        L = jnp.linalg.cholesky(C)
        # Q L^{-T} via an explicit r×r inverse + matmul: XLA's SPMD
        # partitioner all-gathers triangular_solve operands (n×r, f32 —
        # GiBs/device), whereas the solve against the identity is r×r
        # (replicated, negligible) and the matmul stays row-sharded.
        eye = jnp.eye(C.shape[-1], dtype=C.dtype)
        L_inv = jax.lax.linalg.triangular_solve(
            L, jnp.broadcast_to(eye, C.shape), left_side=True, lower=True
        )
        return pin(Q @ jnp.swapaxes(L_inv, -1, -2))

    def finite(Q):
        return jnp.where(jnp.isfinite(Q), Q, 0.0)

    return finite(once(finite(once(G))))


def augment_basis(
    f: LowRankFactor, G_U: Array, G_V: Array, *, method: str = "cholqr2",
    u_spec=None, v_spec=None,
) -> AugmentedFactor:
    """Paper Eq. (6) + Lemma 1.

    ``Ũ = qr([Uᵗ | G_U])`` (and likewise for V).  The gradient block is
    masked to the active rank first: columns of ``∇_U L`` beyond ``rank``
    are zero anyway (S is masked), but masking defensively keeps the
    invariant exact in reduced precision.

    ``method``: "cholqr2" (default, matmul-only — see
    :func:`_ortho_complement_cholqr2`) or "householder" (paper-literal QR).

    Returns the augmented factor with ``S̃ = [[Sᵗ,0],[0,0]]`` — by Lemma 1
    this equals ``Ũᵀ Uᵗ Sᵗ Vᵗᵀ Ṽ`` exactly, so no projection is computed
    (and on a real deployment only ``Ū, V̄`` would be broadcast).
    """
    r_max = f.r_max
    if 2 * r_max > min(f.n_in, f.n_out):
        raise ValueError(
            f"augmentation needs 2*r_max <= min(n_in, n_out); got r_max={r_max} "
            f"for a {f.n_in}x{f.n_out} layer (init_factor caps this)"
        )
    m = rank_mask(f.rank, r_max, dtype=jnp.float32)
    gu = G_U.astype(jnp.float32) * m[..., None, :]
    gv = G_V.astype(jnp.float32) * m[..., None, :]
    # Normalize the gradient block for conditioning; span is invariant.
    gu = gu / (jnp.linalg.norm(gu, axis=(-2, -1), keepdims=True) + 1e-12)
    gv = gv / (jnp.linalg.norm(gv, axis=(-2, -1), keepdims=True) + 1e-12)
    U32, V32 = f.U.astype(jnp.float32), f.V.astype(jnp.float32)
    if method == "cholqr2":
        # inactive columns come out (numerically) zero; mask exactly
        ubar = _ortho_complement_cholqr2(U32, gu, spec=u_spec) * m[..., None, :]
        vbar = _ortho_complement_cholqr2(V32, gv, spec=v_spec) * m[..., None, :]
        U_t = jnp.concatenate([U32, ubar], axis=-1)
        V_t = jnp.concatenate([V32, vbar], axis=-1)
    elif method == "householder":
        am = augmented_mask(f.rank, r_max, dtype=jnp.float32)
        U_t = qr_pos(jnp.concatenate([U32, gu], axis=-1)) * am[..., None, :]
        V_t = qr_pos(jnp.concatenate([V32, gv], axis=-1)) * am[..., None, :]
    else:
        raise ValueError(method)
    S_t = jnp.zeros(f.S.shape[:-2] + (2 * r_max, 2 * r_max), dtype=f.S.dtype)
    S_t = S_t.at[..., :r_max, :r_max].set(f.S)
    return AugmentedFactor(
        U=U_t.astype(f.U.dtype), S=S_t, V=V_t.astype(f.V.dtype), rank=f.rank
    )


def coeff_grad_mask(f: AugmentedFactor) -> Array:
    """Mask restricting coefficient updates to the paper's 2r active dirs."""
    return augmented_mask(f.rank, f.r_max, dtype=f.S.dtype)


def pick_rank(sigma: Array, theta: Array, r_max: int) -> Array:
    """Smallest ``r₁`` with ``‖σ[r₁:]‖₂ < ϑ``, clipped to ``[1, r_max]``.

    ``sigma`` is the descending singular-value vector of the aggregated
    ``2r_max × 2r_max`` coefficient; batched over leading dims (per-layer
    ranks in a stacked factor), with ``theta`` broadcasting accordingly.
    """
    # tail_sq[..., k] = Σ_{j≥k} σ_j²
    tail_sq = jnp.cumsum(jnp.square(sigma[..., ::-1]), axis=-1)[..., ::-1]
    ok = tail_sq < jnp.square(jnp.asarray(theta))[..., None]
    # argmax returns first True; if none are True we need full width.
    any_ok = jnp.any(ok, axis=-1)
    first = jnp.argmax(ok, axis=-1)
    r1 = jnp.where(any_ok, first, sigma.shape[-1])
    return jnp.clip(r1, 1, r_max).astype(jnp.float32)


def truncate(
    f: AugmentedFactor,
    *,
    tau: float,
    theta_abs: float | None = None,
) -> Tuple[LowRankFactor, dict]:
    """Automatic compression (paper §3.1, "rank truncation").

    ``ϑ = τ·‖S̃*‖_F`` (relative, as in the experiments) unless an absolute
    ``theta_abs`` is given.  SVD runs on the ``2r_max × 2r_max`` coefficient
    only — server compute stays ``O(n·r²)``; the weight matrix is never
    reconstructed.
    """
    r_max = f.r_max
    S32 = f.S.astype(jnp.float32)
    P, sigma, Qt = jnp.linalg.svd(S32, full_matrices=False)
    if theta_abs is not None:
        theta = jnp.broadcast_to(jnp.float32(theta_abs), S32.shape[:-2])
    else:
        theta = tau * jnp.linalg.norm(S32, axis=(-2, -1))
    r1 = pick_rank(sigma, theta, r_max)
    keep = rank_mask(r1, r_max)
    # Rotate bases by the leading r_max singular vectors; columns ≥ r1 are
    # zeroed (the zero-columns invariant of factorization.py).
    U_new = (f.U @ P[..., :, :r_max].astype(f.U.dtype)) * keep[..., None, :]
    V_new = (
        f.V @ jnp.swapaxes(Qt[..., :r_max, :], -1, -2).astype(f.V.dtype)
    ) * keep[..., None, :]
    diag_vals = sigma[..., :r_max] * keep
    S_new = (jnp.eye(r_max, dtype=jnp.float32) * diag_vals[..., None, :]).astype(
        f.S.dtype
    )
    out = LowRankFactor(U=U_new, S=S_new, V=V_new, rank=r1)
    trunc_err = jnp.sqrt(
        jnp.maximum(
            jnp.sum(jnp.square(sigma), axis=-1)
            - jnp.sum(jnp.square(diag_vals), axis=-1),
            0.0,
        )
    )
    info = {
        "rank": r1,
        "trunc_err": trunc_err,
        "theta": theta,
        "sigma_max": sigma[..., 0],
    }
    return out, info


def bug_round_dense_loss(loss_fn, f: LowRankFactor, *, lr: float, tau: float):
    """One non-federated rank-adaptive BUG step (Schotthöfer et al. '22).

    Reference implementation used by tests to cross-check the federated
    scheme in the C=1 limit: basis-gradient augmentation, one Galerkin
    coefficient step, truncation.
    """
    def as_loss(U, S, V):
        return loss_fn(LowRankFactor(U=U, S=S, V=V, rank=f.rank))

    gU, gV = jax.grad(as_loss, argnums=(0, 2))(f.U, f.S, f.V)
    aug = augment_basis(f, gU, gV)

    def aug_loss(S):
        return loss_fn(AugmentedFactor(U=aug.U, S=S, V=aug.V, rank=aug.rank))

    m = coeff_grad_mask(aug)
    gS = mask_coeff(jax.grad(aug_loss)(aug.S), m)
    S_star = aug.S - lr * gS
    return truncate(AugmentedFactor(U=aug.U, S=S_star, V=aug.V, rank=aug.rank), tau=tau)
