"""The telemetry event schema and its validator.

Every event the :class:`repro.telemetry.TelemetryHub` emits is one flat
dict (JSONL: one JSON object per line) with a fixed key set:

==========  =========================================================
key         meaning
==========  =========================================================
``kind``    one of :data:`EVENT_KINDS`
``name``    dotted event name (``round``, ``wire.identity.bytes``, …)
``t``       wall seconds since the hub's epoch (monotonic, from
            :func:`repro.telemetry.clock.perf_seconds`)
``dur``     wall duration in seconds for spans, else ``None``
``tv``      virtual-clock seconds when a :class:`VirtualClock` is
            attached, else ``None``
``durv``    virtual duration for spans (``None`` when not simulated)
``value``   metric value for counter/gauge/hist, else ``None``
``attrs``   flat dict of scalar attributes (round, client, …)
``seq``     per-hub monotone sequence number
==========  =========================================================

The hub's first event is a ``meta`` named ``hub_start`` whose attrs carry
``wall_epoch`` (Unix seconds of ``t == 0``) — the only place absolute
wall time appears, so events stay comparable across runs.

:func:`validate_event` / :func:`validate_jsonl` are the schema gate the
tests and the CI ``bench-smoke`` job run over emitted logs (via
``python -m repro.telemetry validate``).
"""
from __future__ import annotations

import json
from typing import Iterator, List, Tuple

EVENT_KINDS = ("span", "counter", "gauge", "hist", "progress", "meta")

#: the exact key set of every event dict
EVENT_KEYS = ("kind", "name", "t", "dur", "tv", "durv", "value", "attrs", "seq")

_SCALAR = (bool, int, float, str, type(None))


def validate_event(event) -> List[str]:
    """Schema errors of one event dict (empty list = valid)."""
    errs: List[str] = []
    if not isinstance(event, dict):
        return [f"event must be a dict, got {type(event).__name__}"]
    missing = [k for k in EVENT_KEYS if k not in event]
    extra = sorted(set(event) - set(EVENT_KEYS))
    if missing:
        errs.append(f"missing key(s) {missing}")
    if extra:
        errs.append(f"unknown key(s) {extra}")
    if missing or extra:
        return errs
    if event["kind"] not in EVENT_KINDS:
        errs.append(f"kind must be one of {EVENT_KINDS}, got {event['kind']!r}")
    if not isinstance(event["name"], str) or not event["name"]:
        errs.append(f"name must be a non-empty string, got {event['name']!r}")
    if not isinstance(event["t"], (int, float)) or isinstance(event["t"], bool):
        errs.append(f"t must be a number, got {event['t']!r}")
    for opt in ("dur", "tv", "durv", "value"):
        v = event[opt]
        if v is not None and (not isinstance(v, (int, float)) or isinstance(v, bool)):
            errs.append(f"{opt} must be a number or null, got {v!r}")
    if not isinstance(event["seq"], int) or isinstance(event["seq"], bool):
        errs.append(f"seq must be an integer, got {event['seq']!r}")
    attrs = event["attrs"]
    if not isinstance(attrs, dict):
        errs.append(f"attrs must be a dict, got {attrs!r}")
    else:
        for k, v in attrs.items():
            if not isinstance(k, str):
                errs.append(f"attrs key {k!r} must be a string")
            if not isinstance(v, _SCALAR):
                errs.append(
                    f"attrs[{k!r}] must be a JSON scalar, got "
                    f"{type(v).__name__}"
                )
    if event["kind"] in ("counter", "gauge", "hist") and event["value"] is None:
        errs.append(f"{event['kind']} event carries no value")
    return errs


def iter_jsonl(path) -> Iterator[Tuple[int, dict]]:
    """``(lineno, event)`` pairs from a JSONL event log."""
    with open(path) as fh:
        for i, line in enumerate(fh, start=1):
            line = line.strip()
            if line:
                yield i, json.loads(line)


def validate_jsonl(path) -> List[str]:
    """All schema errors in a JSONL event log, prefixed with line numbers
    (empty list = the whole file is valid).  Also checks that ``seq`` is
    strictly increasing and that ``t`` never decreases across *non-span*
    events — those are stamped at emission, so they share one monotone
    timeline.  A span's ``t`` is its **start**, emitted at span end:
    events that fired inside it legitimately precede it in the file with
    larger ``t``, so spans are excluded from the ordering check (the
    Perfetto exporter orders per track instead)."""
    errs: List[str] = []
    last_seq, last_t = -1, float("-inf")
    try:
        for lineno, event in iter_jsonl(path):
            for e in validate_event(event):
                errs.append(f"line {lineno}: {e}")
                continue
            if not isinstance(event, dict) or set(event) != set(EVENT_KEYS):
                continue
            if isinstance(event["seq"], int) and event["seq"] <= last_seq:
                errs.append(
                    f"line {lineno}: seq {event['seq']} not increasing "
                    f"(previous {last_seq})"
                )
            if isinstance(event["seq"], int):
                last_seq = event["seq"]
            if event["kind"] != "span":
                if isinstance(event["t"], (int, float)) and event["t"] < last_t:
                    errs.append(
                        f"line {lineno}: t {event['t']} decreased "
                        f"(previous {last_t})"
                    )
                if isinstance(event["t"], (int, float)):
                    last_t = event["t"]
    except (OSError, json.JSONDecodeError) as e:
        errs.append(str(e))
    return errs
