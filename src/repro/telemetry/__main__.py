"""Telemetry log tooling:  python -m repro.telemetry {validate,export} ...

``validate`` checks a JSONL event log against the schema
(:mod:`repro.telemetry.events`) — the CI ``bench-smoke`` gate over emitted
logs; ``export`` renders a JSONL log as a Chrome/Perfetto
``trace_event`` JSON file for https://ui.perfetto.dev.
"""
import argparse
import json
import sys

from repro.telemetry.events import iter_jsonl, validate_jsonl
from repro.telemetry.perfetto import events_to_trace


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.telemetry")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_val = sub.add_parser("validate", help="schema-check JSONL event logs")
    p_val.add_argument("paths", nargs="+")

    p_exp = sub.add_parser("export", help="JSONL event log → Perfetto trace")
    p_exp.add_argument("events")
    p_exp.add_argument("trace")
    args = ap.parse_args(argv)

    if args.cmd == "validate":
        ok = True
        for path in args.paths:
            errs = validate_jsonl(path)
            if errs:
                ok = False
                print(f"{path}: INVALID")
                for e in errs[:20]:
                    print(f"  {e}")
                if len(errs) > 20:
                    print(f"  ... and {len(errs) - 20} more")
            else:
                n = sum(1 for _ in iter_jsonl(path))
                print(f"{path}: ok ({n} events)")
        return 0 if ok else 1

    events = [ev for _, ev in iter_jsonl(args.events)]
    with open(args.trace, "w") as fh:
        json.dump(events_to_trace(events), fh)
        fh.write("\n")
    print(f"wrote {args.trace} ({len(events)} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
