"""The telemetry hub: dual-clock spans, metric streams, progress events.

One :class:`TelemetryHub` per run fans structured events out to its sinks
(:mod:`repro.telemetry.sinks`).  Every event carries **wall** time (``t``,
monotonic seconds since the hub's epoch, read through the sanctioned
:mod:`repro.telemetry.clock` shim) and, when a simulator's
:class:`~repro.fed.sim.clock.VirtualClock` is attached, **virtual** time
(``tv``) — the dual-clock record that lets a Perfetto trace show both
what the host actually did and what the simulated fleet experienced.

API surface:

- ``with hub.span("round", round=r): ...`` — wall-duration span;
- ``hub.span_at("client_round", tv0, tv1, client=c)`` — a span on the
  *virtual* clock with explicit endpoints (the async engine's
  dispatch→arrival client rounds, priced by the simulator);
- ``hub.counter(name, inc) / hub.gauge(name, value) / hub.hist(name,
  value)`` — metric samples;
- ``hub.progress(msg)`` — a human-facing progress line, rendered by
  :class:`~repro.telemetry.sinks.ConsoleSink` (the engines' old
  ``print()`` calls, now one event kind among the rest).

The load-bearing invariant (pinned in ``tests/test_telemetry.py``):
telemetry **reads state and never writes it** — no RNG draws, no virtual
clock advances, no engine mutation — so a telemetry-enabled run is
bit-for-bit identical to a disabled one.  A disabled hub
(``enabled=False``, e.g. :data:`NULL_HUB`) short-circuits every call
before any event dict is built and hands out one cached no-op context
manager, making it near-zero overhead (pinned by
``benchmarks/bench_telemetry.py``).

Gauges and hists that carry a ``round=`` attr respect ``sample_every``:
only rounds divisible by the cadence are recorded — spans, counters and
progress are never sampled away.
"""
from __future__ import annotations

import contextlib
from typing import List, Optional

from repro.telemetry.clock import perf_seconds, wall_time
from repro.telemetry.sinks import make_sinks

_UNSET = object()


class TelemetryHub:
    """Fan structured run events out to pluggable sinks; see module doc."""

    def __init__(
        self,
        sinks=(),
        *,
        enabled: bool = True,
        clock=None,
        sample_every: int = 1,
        meta: Optional[dict] = None,
    ):
        self.enabled = bool(enabled)
        self.sinks: List[object] = list(sinks)
        self.sample_every = max(int(sample_every), 1)
        self._clock = clock  # duck-typed: anything with a float `.now`
        self._seq = 0
        self._epoch = perf_seconds()
        self._noop = contextlib.nullcontext()
        if self.enabled and self.sinks:
            self._emit(
                "meta", "hub_start",
                attrs={"wall_epoch": wall_time(), **(meta or {})},
            )

    # -- clocks ------------------------------------------------------------

    def attach_clock(self, clock) -> None:
        """Attach a virtual clock (read-only: the hub only ever looks at
        ``clock.now``; advancing it stays the simulator's job)."""
        self._clock = clock

    def virtual_now(self) -> Optional[float]:
        return None if self._clock is None else float(self._clock.now)

    # -- emission core -----------------------------------------------------

    def _emit(self, kind, name, *, t=None, dur=None, tv=_UNSET, durv=None,
              value=None, attrs=None):
        event = {
            "kind": kind,
            "name": name,
            "t": (perf_seconds() - self._epoch) if t is None else float(t),
            "dur": dur,
            "tv": self.virtual_now() if tv is _UNSET else tv,
            "durv": durv,
            "value": value,
            "attrs": attrs or {},
            "seq": self._seq,
        }
        self._seq += 1
        for sink in self.sinks:
            sink.emit(event)

    def _sampled(self, attrs: dict) -> bool:
        r = attrs.get("round")
        if r is None or self.sample_every == 1:
            return True
        return int(r) % self.sample_every == 0

    # -- spans -------------------------------------------------------------

    @contextlib.contextmanager
    def _span_cm(self, name, attrs):
        t0 = perf_seconds()
        tv0 = self.virtual_now()
        try:
            yield
        finally:
            self._emit(
                "span", name,
                t=t0 - self._epoch,
                dur=perf_seconds() - t0,
                tv=tv0,
                attrs=attrs,
            )

    def span(self, name: str, **attrs):
        """Context manager timing a wall-clock span (virtual time is
        stamped at entry for context; virtual *durations* come from
        :meth:`span_at`, which the simulators price explicitly)."""
        if not self.enabled:
            return self._noop
        return self._span_cm(name, attrs)

    def span_at(self, name: str, tv_start: float, tv_end: float, **attrs):
        """Record a completed span on the **virtual** clock with explicit
        endpoints — dispatch→arrival client rounds, straggler barriers —
        attributed to ``attrs['client']``'s track in the trace export."""
        if not self.enabled:
            return
        self._emit(
            "span", name,
            tv=float(tv_start), durv=float(tv_end) - float(tv_start),
            attrs=attrs,
        )

    def span_wall_at(self, name: str, t_start: float, t_end: float, **attrs):
        """Record a completed span on the **wall** clock from explicit
        :func:`perf_seconds` endpoints — per-request serving phases
        (queued / decode) whose boundaries interleave across requests, so
        no single context manager can bracket them."""
        if not self.enabled:
            return
        self._emit(
            "span", name,
            t=float(t_start) - self._epoch,
            dur=float(t_end) - float(t_start),
            attrs=attrs,
        )

    # -- metrics -----------------------------------------------------------

    def counter(self, name: str, inc: float = 1.0, **attrs) -> None:
        if not self.enabled:
            return
        self._emit("counter", name, value=float(inc), attrs=attrs)

    def gauge(self, name: str, value: float, **attrs) -> None:
        if not self.enabled:
            return
        if not self._sampled(attrs):
            return
        self._emit("gauge", name, value=float(value), attrs=attrs)

    def hist(self, name: str, value: float, **attrs) -> None:
        if not self.enabled:
            return
        if not self._sampled(attrs):
            return
        self._emit("hist", name, value=float(value), attrs=attrs)

    # -- progress / lifecycle ----------------------------------------------

    def progress(self, message: str, **attrs) -> None:
        """A human-facing progress line (rendered by ConsoleSink)."""
        if not self.enabled:
            return
        self._emit("progress", "progress", attrs={"message": message, **attrs})

    def flush(self) -> None:
        for sink in self.sinks:
            sink.flush()

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()


#: the no-op hub: disabled, sinkless — every call is an early return.
NULL_HUB = TelemetryHub(enabled=False)

#: process-default hub for engines constructed without one: progress
#: events render to stdout exactly like the print() calls they replaced.
_DEFAULT_HUB: Optional[TelemetryHub] = None

#: the process-global hub (kernel dispatch counters, trace-audit
#: republication — sites with no engine in reach); build() points it at
#: the experiment's hub for the duration of the run.
_GLOBAL_HUB: TelemetryHub = NULL_HUB


def default_hub() -> TelemetryHub:
    """The console-only hub engines fall back to when built without one."""
    global _DEFAULT_HUB
    if _DEFAULT_HUB is None:
        from repro.telemetry.sinks import ConsoleSink

        _DEFAULT_HUB = TelemetryHub(sinks=(ConsoleSink(),))
    return _DEFAULT_HUB


def get_hub() -> TelemetryHub:
    """The process-global hub (NULL_HUB until a build() installs one)."""
    return _GLOBAL_HUB


def set_hub(hub: TelemetryHub) -> TelemetryHub:
    """Install ``hub`` as the process-global hub; returns the previous."""
    global _GLOBAL_HUB
    prev = _GLOBAL_HUB
    _GLOBAL_HUB = hub
    return prev


def hub_from_spec(tspec, *, meta: Optional[dict] = None) -> TelemetryHub:
    """Build a hub from a ``TelemetrySpec``-shaped object (duck-typed:
    ``enabled`` / ``sinks`` / ``dir`` / ``sample_every``).

    Disabled specs return the console-only default hub — progress lines
    keep printing exactly as before telemetry existed, and no event log
    is written.
    """
    if not tspec.enabled:
        return default_hub()
    return TelemetryHub(
        make_sinks(tspec.sinks, out_dir=tspec.dir),
        sample_every=tspec.sample_every,
        meta=meta,
    )
