"""Structured telemetry: round/phase spans, metric streams, trace export.

    from repro.telemetry import TelemetryHub, MemorySink

    hub = TelemetryHub([MemorySink()])
    with hub.span("client_step", round=3, client=7):
        ...
    hub.gauge("rank_mean", 12.0, round=3)

Dual-clock aware (wall time through the one sanctioned
:mod:`repro.telemetry.clock` shim, virtual time from an attached
simulator clock), with pluggable sinks — JSONL event log, in-memory,
console progress, Chrome/Perfetto ``trace_event`` export.  The hub reads
run state and never writes it, so telemetry on ≡ off bit-for-bit.

Validate or export an event log from the shell::

    python -m repro.telemetry validate results/telemetry/events.jsonl
    python -m repro.telemetry export results/telemetry/events.jsonl trace.json
"""
from repro.telemetry.clock import perf_seconds, wall_time  # noqa: F401
from repro.telemetry.events import (  # noqa: F401
    EVENT_KEYS,
    EVENT_KINDS,
    validate_event,
    validate_jsonl,
)
from repro.telemetry.hub import (  # noqa: F401
    NULL_HUB,
    TelemetryHub,
    default_hub,
    get_hub,
    hub_from_spec,
    set_hub,
)
from repro.telemetry.perfetto import events_to_trace  # noqa: F401
from repro.telemetry.sinks import (  # noqa: F401
    SINK_NAMES,
    ConsoleSink,
    JsonlSink,
    MemorySink,
    PerfettoSink,
    Sink,
    make_sinks,
)
