"""Chrome/Perfetto ``trace_event`` export of a telemetry event stream.

Renders hub events as a JSON object Perfetto (https://ui.perfetto.dev) and
``chrome://tracing`` load directly, with the run's two clocks as two
*processes* so a straggler quarter or a FedBuff staleness pileup is
visible as per-client tracks:

- pid 1 — **wall clock**: every span that measured a host-side duration
  (``dur`` is set) becomes a complete ("X") event at ``ts = t``.
- pid 2 — **virtual clock**: every span priced on the simulator's
  :class:`VirtualClock` (``durv`` is set) becomes an "X" event at
  ``ts = tv`` — e.g. the async engine's dispatch→arrival client rounds.

Within each process, tid 0 is the server; a ``client`` attr maps the
event onto that client's own track (tid = client + 1).  Counters and
gauges become "C" events on the wall-clock process, so effective rank and
staleness render as counter tracks under the spans.  Timestamps are
microseconds (the trace_event unit); metadata ("M") events name every
process and thread.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

WALL_PID = 1
VIRTUAL_PID = 2

#: the server's track within each clock process
SERVER_TID = 0


def _tid(event: dict) -> int:
    client = event.get("attrs", {}).get("client")
    if isinstance(client, int) and not isinstance(client, bool) and client >= 0:
        return client + 1
    return SERVER_TID


def _args(event: dict) -> dict:
    args = {k: v for k, v in event.get("attrs", {}).items() if v is not None}
    if event.get("value") is not None:
        args["value"] = event["value"]
    return args


def events_to_trace(events: Iterable[dict]) -> dict:
    """Telemetry events → a ``trace_event`` JSON object.

    Returns ``{"traceEvents": [...], "displayTimeUnit": "ms"}``; the
    caller serializes it.  Events that carry neither a wall nor a virtual
    duration (progress lines, meta, plain counters without values)
    contribute no span; counters/gauges contribute "C" samples.
    """
    out: List[dict] = []
    threads: Dict[Tuple[int, int], None] = {}

    def track(pid: int, tid: int) -> Tuple[int, int]:
        threads.setdefault((pid, tid), None)
        return pid, tid

    for ev in events:
        kind = ev.get("kind")
        if kind == "span":
            if ev.get("dur") is not None:
                pid, tid = track(WALL_PID, _tid(ev))
                out.append({
                    "ph": "X", "pid": pid, "tid": tid,
                    "name": ev["name"],
                    "ts": float(ev["t"]) * 1e6,
                    "dur": float(ev["dur"]) * 1e6,
                    "args": _args(ev),
                })
            if ev.get("durv") is not None and ev.get("tv") is not None:
                pid, tid = track(VIRTUAL_PID, _tid(ev))
                out.append({
                    "ph": "X", "pid": pid, "tid": tid,
                    "name": ev["name"],
                    "ts": float(ev["tv"]) * 1e6,
                    "dur": float(ev["durv"]) * 1e6,
                    "args": _args(ev),
                })
        elif kind in ("counter", "gauge", "hist") and ev.get("value") is not None:
            pid, tid = track(WALL_PID, SERVER_TID)
            out.append({
                "ph": "C", "pid": pid, "tid": tid,
                "name": ev["name"],
                "ts": float(ev["t"]) * 1e6,
                "args": {ev["name"]: ev["value"]},
            })

    meta: List[dict] = []
    for pid, pname in ((WALL_PID, "wall clock"), (VIRTUAL_PID, "virtual clock")):
        if any(p == pid for p, _ in threads):
            meta.append({
                "ph": "M", "pid": pid, "tid": SERVER_TID,
                "name": "process_name", "args": {"name": pname},
            })
    for pid, tid in sorted(threads):
        tname = "server" if tid == SERVER_TID else f"client {tid - 1}"
        meta.append({
            "ph": "M", "pid": pid, "tid": tid,
            "name": "thread_name", "args": {"name": tname},
        })
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}
