"""Pluggable telemetry sinks: where hub events go.

A sink is anything with ``emit(event) / flush() / close()``
(:class:`Sink`).  Shipped sinks:

- :class:`MemorySink` — append to a list (tests, programmatic readers);
- :class:`JsonlSink` — one JSON object per line, the durable event log
  the schema validator (``python -m repro.telemetry validate``) checks;
- :class:`ConsoleSink` — renders ``progress`` events to stdout and drops
  everything else: it is how the engines' old ad-hoc ``print()`` progress
  lines survive byte-identically now that they are hub events;
- :class:`PerfettoSink` — buffers events and writes a Chrome/Perfetto
  ``trace_event`` JSON file on flush/close
  (:func:`repro.telemetry.perfetto.events_to_trace`).

Sinks are consumers only: they never mutate events and nothing reads them
back into the run, which is half of the telemetry-on ≡ telemetry-off
determinism invariant (the other half: the hub reads state, never
advances RNG or the virtual clock).
"""
from __future__ import annotations

import json
import os
import sys
from typing import List, Optional, Protocol, runtime_checkable


@runtime_checkable
class Sink(Protocol):
    """Event consumer: the hub fans every event out to each sink."""

    def emit(self, event: dict) -> None:
        ...

    def flush(self) -> None:
        ...

    def close(self) -> None:
        ...


class MemorySink:
    """Keep every event in a list — the test/programmatic sink."""

    name = "memory"

    def __init__(self):
        self.events: List[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class ConsoleSink:
    """Render ``progress`` events as plain lines; drop everything else.

    ``stream=None`` resolves ``sys.stdout`` at emit time (not at
    construction), so pytest's capsys and shell redirection both see the
    output — exactly like the ``print()`` calls this sink replaced.
    """

    name = "console"

    def __init__(self, stream=None):
        self.stream = stream

    def emit(self, event: dict) -> None:
        if event["kind"] == "progress":
            msg = event["attrs"].get("message", event["name"])
            print(msg, file=self.stream or sys.stdout)

    def flush(self) -> None:
        (self.stream or sys.stdout).flush()

    def close(self) -> None:
        pass


class JsonlSink:
    """Append each event as one JSON line to ``path`` (parents created)."""

    name = "jsonl"

    def __init__(self, path):
        self.path = str(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fh = open(self.path, "w")

    def emit(self, event: dict) -> None:
        self._fh.write(json.dumps(event) + "\n")

    def flush(self) -> None:
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()


class PerfettoSink:
    """Buffer events; write a Perfetto-loadable trace file on flush/close.

    ``flush`` rewrites the whole file from the buffer (idempotent), so a
    run that flushes per checkpoint always leaves a loadable trace even
    if it dies before ``close``.
    """

    name = "perfetto"

    def __init__(self, path):
        self.path = str(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self.events: List[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def flush(self) -> None:
        from repro.telemetry.perfetto import events_to_trace

        with open(self.path, "w") as fh:
            json.dump(events_to_trace(self.events), fh)
            fh.write("\n")

    def close(self) -> None:
        self.flush()


#: sink spec names accepted by :func:`make_sinks` / ``TelemetrySpec.sinks``
SINK_NAMES = ("console", "memory", "jsonl", "perfetto")


def make_sinks(spec: str, *, out_dir: Optional[str] = None) -> List[object]:
    """Comma-separated sink spec → sink instances.

    ``jsonl`` writes ``<out_dir>/events.jsonl`` and ``perfetto`` writes
    ``<out_dir>/trace.json``; both require ``out_dir``.
    """
    sinks: List[object] = []
    for name in [s.strip() for s in spec.split(",") if s.strip()]:
        if name == "console":
            sinks.append(ConsoleSink())
        elif name == "memory":
            sinks.append(MemorySink())
        elif name in ("jsonl", "perfetto"):
            if not out_dir:
                raise ValueError(
                    f"the {name!r} sink needs an output directory "
                    f"(telemetry.dir)"
                )
            fname = "events.jsonl" if name == "jsonl" else "trace.json"
            cls = JsonlSink if name == "jsonl" else PerfettoSink
            sinks.append(cls(os.path.join(out_dir, fname)))
        else:
            raise ValueError(
                f"unknown telemetry sink {name!r}; expected a comma list "
                f"of {SINK_NAMES}"
            )
    return sinks
