"""The one sanctioned wall-clock shim for library code.

Same spec + same seed must be the same run bit-for-bit, so repro-lint's
RPL003 bans wall-clock reads (``time.time`` and friends) everywhere under
``src/repro`` *except this file* — the exemption is a rule path, not a
suppression comment, so a stray ``time.time()`` anywhere else still fails
the analyzer.  Everything that legitimately needs real time (span
timestamps, ``RoundResult.seconds``, the JSONL event epoch) reads it
through these two functions, which keeps the sanctioned surface greppable
and the rest of the library provably deterministic.

Two clocks, two jobs:

- :func:`perf_seconds` — monotonic, for *durations* (``time.perf_counter``
  never steps backwards under NTP adjustments, unlike ``time.time``, which
  is exactly the bug this shim fixed in ``RoundResult.seconds``);
- :func:`wall_time` — the epoch-anchored reading, for *labelling* (the
  hub stamps one ``wall_epoch`` per run so traces can be correlated with
  external logs; never used for durations).
"""
from __future__ import annotations

import time


def perf_seconds() -> float:
    """Monotonic seconds from an arbitrary origin — duration measurement.

    Differences of :func:`perf_seconds` readings are guaranteed
    non-negative; absolute values are meaningless across processes.
    """
    return time.perf_counter()


def wall_time() -> float:
    """Seconds since the Unix epoch — timestamps for humans and log
    correlation only, never for durations (it is not monotonic)."""
    return time.time()
