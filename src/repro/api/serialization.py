"""Spec (de)serialization: dataclass ↔ dict ↔ TOML/JSON, content hashing.

Generic machinery only — no knowledge of the concrete spec classes, so
:mod:`repro.api.spec` can import this module without a cycle.  The rules
that make the round-trip lossless:

- ``to_plain_dict`` emits every field, including ``None``s, in dataclass
  field order (nested specs become nested dicts).
- ``from_plain_dict`` rejects unknown keys (typo safety), fills missing
  keys from the dataclass defaults, and coerces ints to floats where the
  field is float-typed (TOML/JSON writers drop trailing ``.0``s).
- TOML has no null, so the TOML writer *omits* ``None``-valued keys; every
  ``Optional`` spec field defaults to ``None``, so omission round-trips.

The TOML dialect is the flat subset the specs need — top-level scalars
plus one ``[table]`` per sub-spec, string/bool/int/float values.  Reading
prefers :mod:`tomllib` when the interpreter has it (3.11+) and falls back
to a small built-in parser of the same subset on 3.10.
"""
from typing import Union, get_args, get_origin

import contextlib
import dataclasses
import hashlib
import json

# ---------------------------------------------------------------------------
# dataclass ↔ plain dict
# ---------------------------------------------------------------------------


def to_plain_dict(obj) -> dict:
    """Dataclass instance → nested dict of primitives, in field order."""
    return dataclasses.asdict(obj)


def _optional_base(hint):
    """The payload type of ``Optional[T]`` (None if ``hint`` isn't one)."""
    if get_origin(hint) is Union:
        args = [a for a in get_args(hint) if a is not type(None)]
        if len(args) == 1 and len(get_args(hint)) == 2:
            return args[0]
    return None


def _coerce(hint, value, where: str):
    base = _optional_base(hint)
    if value is None:
        if base is not None:
            return None
        raise ValueError(f"{where} may not be null")
    if base is not None:
        hint = base
    if hint is float:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise ValueError(f"{where} must be a number, got {value!r}")
        return float(value)
    if hint is bool:
        if not isinstance(value, bool):
            raise ValueError(f"{where} must be a boolean, got {value!r}")
        return value
    if hint is int:
        if isinstance(value, bool) or not isinstance(value, int):
            raise ValueError(f"{where} must be an integer, got {value!r}")
        return value
    if hint is str:
        if not isinstance(value, str):
            raise ValueError(f"{where} must be a string, got {value!r}")
        return value
    raise TypeError(f"{where}: unsupported spec field type {hint!r}")


def from_plain_dict(cls, data: dict, where: str = "spec"):
    """Nested dict → ``cls`` instance (strict keys, light numeric coercion).

    Unknown keys raise (they are typos, not extensions); missing keys take
    the dataclass defaults, so hand-written TOML can stay minimal.
    """
    if not isinstance(data, dict):
        raise ValueError(f"{where} must be a table/dict, got {data!r}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - set(fields))
    if unknown:
        raise ValueError(
            f"{where} has unknown key(s) {unknown}; valid keys: "
            f"{sorted(fields)}"
        )
    kwargs = {}
    for name, value in data.items():
        f = fields[name]
        sub = f"{where}.{name}"
        if dataclasses.is_dataclass(f.type):
            kwargs[name] = from_plain_dict(f.type, value, where=sub)
        else:
            kwargs[name] = _coerce(f.type, value, where=sub)
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# content hash
# ---------------------------------------------------------------------------


def content_hash(data: dict) -> str:
    """Stable 12-hex-digit digest of a plain dict.

    Canonical JSON (sorted keys, no whitespace) makes the hash a function
    of *content* only — reordering fields in a spec file, or round-tripping
    through TOML/JSON, never changes it.
    """
    blob = json.dumps(data, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


# ---------------------------------------------------------------------------
# TOML (flat subset: top-level scalars + one level of tables)
# ---------------------------------------------------------------------------


def _fmt_toml_value(v, where: str) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, int):
        return str(v)
    if isinstance(v, float):
        r = repr(v)
        if "inf" in r or "nan" in r:
            raise ValueError(f"{where}: non-finite floats are not serializable")
        return r
    if isinstance(v, str):
        return '"' + v.replace("\\", "\\\\").replace('"', '\\"') + '"'
    raise TypeError(f"{where}: cannot serialize {type(v).__name__} to TOML")


def toml_dumps(data: dict) -> str:
    """Nested dict (one table level) → TOML.  ``None`` values are omitted
    (TOML has no null; the spec reader treats absence as the default)."""
    lines = []
    tables = []
    for k, v in data.items():
        if isinstance(v, dict):
            tables.append((k, v))
        elif v is not None:
            lines.append(f"{k} = {_fmt_toml_value(v, k)}")
    for name, table in tables:
        lines.append("")
        lines.append(f"[{name}]")
        for k, v in table.items():
            if isinstance(v, dict):
                raise TypeError(f"{name}.{k}: specs nest only one table deep")
            if v is not None:
                lines.append(f"{k} = {_fmt_toml_value(v, f'{name}.{k}')}")
    return "\n".join(lines) + "\n"


def _parse_toml_scalar(s: str, where: str):
    if s.startswith('"'):
        out, i = [], 1
        while i < len(s):
            c = s[i]
            if c == "\\":
                if i + 1 >= len(s):
                    raise ValueError(f"{where}: dangling escape in {s!r}")
                out.append(s[i + 1])
                i += 2
                continue
            if c == '"':
                return "".join(out)
            out.append(c)
            i += 1
        raise ValueError(f"{where}: unterminated string {s!r}")
    s = s.split("#", 1)[0].strip()
    if s == "true":
        return True
    if s == "false":
        return False
    with contextlib.suppress(ValueError):
        return int(s)
    try:
        return float(s)
    except ValueError:
        raise ValueError(
            f"{where}: cannot parse value {s!r} (expected string/bool/"
            f"int/float)"
        ) from None


def toml_loads(text: str) -> dict:
    """Parse the flat TOML subset ``toml_dumps`` writes (stdlib
    :mod:`tomllib` when available, built-in fallback on 3.10)."""
    with contextlib.suppress(ModuleNotFoundError):
        import tomllib

        return tomllib.loads(text)
    out: dict = {}
    current = out
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        where = f"line {lineno}"
        if line.startswith("["):
            end = line.find("]")
            if end < 0:
                raise ValueError(f"{where}: malformed table header {line!r}")
            name = line[1:end].strip()
            if not name:
                raise ValueError(f"{where}: empty table name")
            current = out.setdefault(name, {})
            continue
        key, eq, value = line.partition("=")
        if not eq:
            raise ValueError(f"{where}: expected 'key = value', got {line!r}")
        current[key.strip()] = _parse_toml_scalar(value.strip(), where)
    return out


# ---------------------------------------------------------------------------
# dotted-path overrides ("engine.kind=async")
# ---------------------------------------------------------------------------


def parse_override(item: str):
    """``"engine.kind=async"`` → ``("engine.kind", "async")``."""
    path, eq, value = item.partition("=")
    if not eq or not path.strip():
        raise ValueError(
            f"override must look like section.key=value, got {item!r}"
        )
    return path.strip(), value.strip()


def _coerce_override_str(hint, raw: str, where: str):
    base = _optional_base(hint)
    if base is not None and raw.lower() in ("none", "null", ""):
        return None
    target = base if base is not None else hint
    if target is bool:
        if raw.lower() in ("true", "1", "yes"):
            return True
        if raw.lower() in ("false", "0", "no"):
            return False
        raise ValueError(f"{where}: expected a boolean, got {raw!r}")
    if target is int:
        try:
            return int(raw)
        except ValueError:
            raise ValueError(f"{where}: expected an integer, got {raw!r}") from None
    if target is float:
        try:
            return float(raw)
        except ValueError:
            raise ValueError(f"{where}: expected a number, got {raw!r}") from None
    if raw and raw[0] == raw[-1] == '"' and len(raw) >= 2:
        raw = raw[1:-1]
    return raw


def set_dotted(cls, data: dict, path: str, value, *, parse_str: bool):
    """Set ``path`` (e.g. ``"engine.kind"``) in the plain dict ``data``,
    coercing ``value`` by the dataclass field type along the way.

    ``parse_str=True`` treats ``value`` as CLI text (``--set`` semantics:
    "none" → null, "true"/"false" → bool, numerics parsed); ``False``
    expects an already-typed value (flag aliases).
    """
    parts = path.split(".")
    node, here = data, cls
    for head in parts[:-1]:
        fields = {f.name: f for f in dataclasses.fields(here)}
        if head not in fields or not dataclasses.is_dataclass(fields[head].type):
            raise ValueError(f"unknown spec section {head!r} in {path!r}")
        node = node.setdefault(head, {})
        here = fields[head].type
    leaf = parts[-1]
    fields = {f.name: f for f in dataclasses.fields(here)}
    if leaf not in fields:
        raise ValueError(
            f"unknown spec field {path!r}; {here.__name__} has "
            f"{sorted(fields)}"
        )
    hint = fields[leaf].type
    if dataclasses.is_dataclass(hint):
        raise ValueError(f"{path!r} is a section, not a field")
    if parse_str:
        value = _coerce_override_str(hint, str(value), path)
    else:
        value = _coerce(hint, value, path)
    node[leaf] = value
