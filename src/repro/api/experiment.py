"""``build(spec) → Experiment``: the one place engines are constructed.

Every axis of the :class:`repro.api.spec.ExperimentSpec` is resolved
through the existing registries — ``repro.api.tasks`` for the model/data
task, :data:`repro.fed.engine.ROUND_METHODS` for the round method,
:meth:`repro.fed.Participation` for the cohort policy,
:func:`repro.fed.sim.make_sim_engine` for the aggregation engine,
:func:`repro.fed.wire.make_codec` for the codecs — and the resulting
:class:`Experiment` facade owns the run loop, resume and description.
The three entry-point surfaces (the train CLI, the vision example, the
benchmark drivers) all construct engines exclusively through here; the
engine-construction logic they used to copy-paste lives only in
:func:`build`.
"""
import dataclasses
import glob
import os
from typing import List, Optional

from repro.api.spec import ExperimentSpec
from repro.api.tasks import Task, build_task


def build(spec: ExperimentSpec) -> "Experiment":
    """Resolve a validated spec into a runnable :class:`Experiment`."""
    from repro.telemetry import hub_from_spec, set_hub

    hub = hub_from_spec(
        spec.telemetry,
        meta={"spec_hash": spec.spec_hash(), "spec_name": spec.name},
    )
    set_hub(hub)  # module-global observers (kernel dispatch, trace audit)
    task = build_task(spec)
    fc = spec.fed.to_fed_config()
    participation = spec.participation.build(seed=spec.seed)
    client_weights = task.client_sizes if spec.fed.weighted else None
    ckpt_meta = {"spec_hash": spec.spec_hash()}
    if spec.name:
        ckpt_meta["spec_name"] = spec.name

    if spec.engine.kind != "sync" or spec.sim.profile is not None:
        from repro.fed.sim import make_sim_engine

        # participation and checkpointing always pass through: engines
        # that can't honor them refuse loudly instead of dropping them
        kw = dict(
            sim_profile=spec.sim.profile,
            seed=spec.seed,
            method=spec.fed.method,
            wire_codec=spec.wire.codec,
            client_weights=client_weights,
            participation=participation,
            checkpoint_dir=spec.checkpoint.dir,
            checkpoint_every=spec.checkpoint.effective_every,
            checkpoint_meta=ckpt_meta,
            telemetry=hub,
        )
        # None = unset: the factory's own defaults apply (one source of
        # truth for them — make_sim_engine), never re-hardcoded here
        if spec.engine.kind == "async":
            kw["buffer_size"] = spec.engine.buffer_size
            if spec.engine.staleness_power is not None:
                kw["staleness_power"] = spec.engine.staleness_power
        elif spec.engine.kind == "hier":
            kw["edge_wire_codec"] = spec.wire.edge_codec
            if spec.engine.edges is not None:
                kw["num_edges"] = spec.engine.edges
            if spec.engine.edge_rounds is not None:
                kw["edge_rounds"] = spec.engine.edge_rounds
        engine = make_sim_engine(
            spec.engine.kind, task.loss_fn, task.params, fc, **kw
        )
    else:
        from repro.fed.engine import FederatedEngine

        engine = FederatedEngine(
            task.loss_fn, task.params, fc,
            method=spec.fed.method,
            participation=participation,
            client_weights=client_weights,
            checkpoint_dir=spec.checkpoint.dir,
            checkpoint_every=spec.checkpoint.effective_every,
            wire_codec=spec.wire.codec,
            checkpoint_meta=ckpt_meta,
            telemetry=hub,
        )
    return Experiment(spec=spec, task=task, engine=engine, hub=hub)


@dataclasses.dataclass
class Experiment:
    """A built experiment: spec + task + engine, ready to run.

    ``run()`` trains ``spec.rounds`` rounds (overridable) and returns the
    engine's round history; ``resume()`` restores the latest (or a named)
    checkpoint after verifying the stamped spec hash; ``describe()``
    renders the scenario for humans.
    """

    spec: ExperimentSpec
    task: Task
    engine: object
    hub: object = None  # the run's TelemetryHub (engines share it)

    @property
    def params(self):
        return self.engine.params

    @property
    def history(self) -> List:
        return self.engine.history

    @property
    def is_simulated(self) -> bool:
        """True when rounds are priced on the virtual clock (any non-sync
        engine, or a sync engine with a fleet profile)."""
        return self.spec.engine.kind != "sync" or self.spec.sim.profile is not None

    def run(self, rounds: Optional[int] = None, *, log_every: Optional[int] = None):
        """Train ``rounds`` (default ``spec.rounds``) aggregation rounds."""
        n = self.spec.rounds if rounds is None else rounds
        le = self.spec.log_every if log_every is None else log_every
        try:
            return self.engine.train(self.task.batcher, n, log_every=le)
        finally:
            if self.hub is not None:
                self.hub.flush()  # file sinks land even on an interrupt

    def evaluate(self) -> float:
        """The task's holdout metric (accuracy) on the current params."""
        if self.task.eval_fn is None:
            raise ValueError(
                f"the {self.spec.model.kind!r} task defines no holdout eval"
            )
        return self.task.eval_fn(self.engine.params)

    def resume(self, path: Optional[str] = None) -> dict:
        """Restore a checkpoint written by this spec's engine.

        ``path`` defaults to the latest ``round_*.npz`` under
        ``spec.checkpoint.dir``.  A checkpoint stamped with a *different*
        spec hash is refused loudly — resuming under changed hyperparameters
        silently corrupts a run; re-derive the spec or move the checkpoint.
        """
        if not hasattr(self.engine, "restore"):
            raise ValueError(
                f"the {self.spec.engine.kind} engine does not support resume"
            )
        if path is None:
            if not self.spec.checkpoint.dir:
                raise ValueError(
                    "resume() needs checkpoint.dir in the spec or an "
                    "explicit path"
                )
            ckpts = sorted(
                glob.glob(os.path.join(self.spec.checkpoint.dir, "round_*.npz"))
            )
            if not ckpts:
                raise FileNotFoundError(
                    f"no round_*.npz checkpoints under "
                    f"{self.spec.checkpoint.dir!r}"
                )
            path = ckpts[-1]
        # guard BEFORE restore touches anything: a refused resume must
        # leave params / round_idx / history / batcher state untouched
        from repro.checkpoint import load_checkpoint_meta

        stamped = load_checkpoint_meta(path).get("spec_hash")
        ours = self.spec.spec_hash()
        if stamped is not None and stamped != ours:
            raise ValueError(
                f"checkpoint {path!r} was written by spec {stamped}, but "
                f"this experiment is spec {ours} — refusing to resume a "
                f"mismatched spec (same seed ≠ same run under different "
                f"hyperparameters)"
            )
        return self.engine.restore(path, batcher=self.task.batcher)

    def comm_total_bytes(self) -> float:
        return self.engine.comm_total_bytes()

    def serve(self) -> "ServeSession":
        """Serve this experiment's *current* params in-process — the
        train→serve loop without a checkpoint round-trip (the spec's
        ``serve.checkpoint`` is ignored; everything else applies)."""
        return serve(self.spec, params=self.engine.params)

    def describe(self) -> str:
        s = self.spec
        part = s.participation.to_string()
        eng = s.engine.kind
        # unset (None) knobs stay with the engine factory's defaults; only
        # report what the spec actually pins
        if eng == "async":
            knobs = [
                f"buffer_size={s.engine.buffer_size}"
                if s.engine.buffer_size is not None
                else f"buffer_size={s.fed.clients} (cohort)",
            ]
            if s.engine.staleness_power is not None:
                knobs.append(f"staleness_power={s.engine.staleness_power:g}")
            eng += f" ({', '.join(knobs)})"
        elif eng == "hier":
            knobs = []
            if s.engine.edges is not None:
                knobs.append(f"edges={s.engine.edges}")
            if s.engine.edge_rounds is not None:
                knobs.append(f"edge_rounds={s.engine.edge_rounds}")
            if knobs:
                eng += f" ({', '.join(knobs)})"
        wire = s.wire.codec
        if s.wire.edge_codec is not None:
            wire += f" (edge: {s.wire.edge_codec})"
        ckpt = (
            f"{s.checkpoint.dir} every {s.checkpoint.effective_every}"
            if s.checkpoint.dir
            else "(off)"
        )
        tel = (
            f"{s.telemetry.sinks}"
            + (f" → {s.telemetry.dir}" if s.telemetry.dir else "")
            + (
                f" (every {s.telemetry.sample_every} rounds)"
                if s.telemetry.sample_every > 1 else ""
            )
            if s.telemetry.enabled
            else "(off)"
        )
        srv = s.serve
        srv_line = f"{srv.mode}  batch={srv.max_batch}  " \
            f"cache={srv.max_prompt}+{srv.max_new_tokens}"
        if srv.quantize != "none":
            srv_line += f"  quantize={srv.quantize}"
        if srv.rank_slice:
            srv_line += "  rank_slice"
        if srv.materialize:
            srv_line += "  materialize"
        lines = [
            f"experiment {s.name or '(unnamed)'}  [spec {s.spec_hash()}]",
            f"  task           {s.model.kind}: {self.task.description}",
            f"  fed            {s.fed.method}"
            + (
                f"/{s.fed.correction_effective}"
                if s.fed.method.startswith("fedlrt") else ""
            )
            + f"  C={s.fed.clients}  s*={s.fed.s_star}  lr={s.fed.lr:g}"
            + f"  tau={s.fed.tau:g}"
            + ("  weighted" if s.fed.weighted else ""),
            f"  participation  {part}",
            f"  engine         {eng}",
            f"  wire           {wire}",
            f"  sim            {s.sim.profile or '(no virtual clock)'}",
            f"  checkpoint     {ckpt}",
            f"  telemetry      {tel}",
            f"  serve          {srv_line}",
            f"  rounds         {s.rounds}  (seed {s.seed})",
        ]
        return "\n".join(lines)


def serve(spec: ExperimentSpec, *, params=None) -> "ServeSession":
    """Resolve a validated spec into a running :class:`ServeSession`.

    The serving twin of :func:`build` — and, like it, the one place the
    serving stack is constructed (RPL001 covers ``ServeEngine`` /
    ``ContinuousScheduler`` the way it covers the training engines).
    Params come from, in priority order: the explicit ``params`` argument
    (``Experiment.serve()``), the checkpoint named by
    ``spec.serve.checkpoint`` (a ``round_*.npz`` file or a directory whose
    latest round wins — no spec-hash refusal here: serving is read-only,
    and re-serving an old checkpoint under a tweaked serve section is
    legitimate), or fresh ``spec.seed`` initialization (smoke runs).
    """
    import jax

    from repro.api.tasks import lm_model_config
    from repro.models import build_model
    from repro.serve import ContinuousScheduler, ServeEngine
    from repro.serve.quantize import (
        materialize_params,
        quantize_params,
        rank_slice_params,
    )
    from repro.telemetry import hub_from_spec, set_hub

    if spec.model.kind != "lm":
        raise ValueError(
            f"serving decodes tokens; model.kind={spec.model.kind!r} has "
            f"no decode path (use kind='lm')"
        )
    hub = hub_from_spec(
        spec.telemetry,
        meta={"spec_hash": spec.spec_hash(), "spec_name": spec.name},
    )
    set_hub(hub)
    cfg = lm_model_config(spec.model)
    model = build_model(cfg)
    sv = spec.serve

    if params is None:
        if sv.checkpoint is not None:
            from repro.checkpoint import load_checkpoint

            path = sv.checkpoint
            if os.path.isdir(path):
                ckpts = sorted(glob.glob(os.path.join(path, "round_*.npz")))
                if not ckpts:
                    raise FileNotFoundError(
                        f"no round_*.npz checkpoints under {path!r}"
                    )
                path = ckpts[-1]
            params, _meta = load_checkpoint(path)
        else:
            params, _ = model.init(jax.random.PRNGKey(spec.seed))

    # at-rest transforms: slice first (smaller buffers to quantize), then
    # compress or densify — ServeSpec validation rejects the combinations
    # that don't compose
    if sv.rank_slice:
        params = rank_slice_params(params)
    if sv.materialize:
        params = materialize_params(params)
    elif sv.quantize != "none":
        params = quantize_params(params, sv.quantize)

    engine = ServeEngine(
        model, params,
        max_batch=sv.max_batch,
        max_prompt=sv.max_prompt,
        prompt_bucket=sv.prompt_bucket,
        max_new_tokens=sv.max_new_tokens,
        temperature=sv.temperature,
        seed=spec.seed,
        telemetry=hub,
    )
    scheduler = ContinuousScheduler(
        engine, max_queue=sv.max_queue, mode=sv.mode, telemetry=hub,
    )
    return ServeSession(spec=spec, engine=engine, scheduler=scheduler, hub=hub)


@dataclasses.dataclass
class ServeSession:
    """A built serving stack: spec + engine + scheduler.

    ``submit``/``run`` forward to the scheduler; ``generate`` is the
    convenience surface the CLI and examples use (prompts in, generated
    token arrays + per-request :class:`repro.serve.Completion` stats out).
    """

    spec: ExperimentSpec
    engine: object
    scheduler: object
    hub: object = None

    def submit(self, request) -> None:
        self.scheduler.submit(request)

    def run(self, requests) -> List:
        try:
            return self.scheduler.run(requests)
        finally:
            if self.hub is not None:
                self.hub.flush()

    def generate(self, prompts, *, max_new_tokens=None, arrival_steps=None):
        """Serve a list of 1-D token prompts; returns
        ``(outputs, completions)`` with outputs ordered like ``prompts``."""
        import numpy as np

        from repro.serve import Request

        sv = self.spec.serve
        arrivals = arrival_steps or [0] * len(prompts)
        reqs = [
            Request(
                rid=i,
                tokens=np.asarray(p, np.int32),
                max_new_tokens=max_new_tokens,
                eos_id=sv.eos_id,
                arrival_step=int(step),
            )
            for i, (p, step) in enumerate(zip(prompts, arrivals))
        ]
        comps = self.run(reqs)
        return [c.tokens for c in comps], comps

    def describe(self) -> str:
        s, sv = self.spec, self.spec.serve
        src = sv.checkpoint or "(fresh init)"
        quant = sv.quantize if not sv.materialize else "materialized-dense"
        return "\n".join([
            f"serve {s.name or '(unnamed)'}  [spec {s.spec_hash()}]",
            f"  model     {s.model.preset or s.model.arch}"
            + ("  (smoke)" if s.model.smoke else ""),
            f"  params    {src}  quantize={quant}"
            + ("  rank_slice" if sv.rank_slice else ""),
            f"  batching  {sv.mode}  slots={sv.max_batch}  "
            f"queue≤{sv.max_queue}",
            f"  shapes    prompt≤{sv.max_prompt} (bucket {sv.prompt_bucket})"
            f"  decode≤{sv.max_new_tokens}  cache={sv.cache_len}",
            f"  sampling  temperature={sv.temperature:g}"
            + (f"  eos={sv.eos_id}" if sv.eos_id is not None else "")
            + f"  (seed {s.seed})",
        ])
