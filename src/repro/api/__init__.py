"""Declarative experiment API: one typed, serializable spec per scenario.

    from repro.api import ExperimentSpec, build, load_spec

    spec = load_spec("examples/configs/async_straggler.toml")
    exp = build(spec)          # engines resolved through the registries
    print(exp.describe())
    hist = exp.run()

Or from the shell::

    python -m repro.api run examples/configs/async_straggler.toml \
        --set engine.buffer_size=4
"""
from repro.api.experiment import (  # noqa: F401
    Experiment,
    ServeSession,
    build,
    serve,
)
from repro.api.serialization import (  # noqa: F401
    content_hash,
    toml_dumps,
    toml_loads,
)
from repro.api.spec import (  # noqa: F401
    CheckpointSpec,
    DataSpec,
    EngineSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    ParticipationSpec,
    ServeSpec,
    SimSpec,
    TelemetrySpec,
    WireSpec,
    load_spec,
)
from repro.api.tasks import (  # noqa: F401
    PRESETS,
    Task,
    build_task,
    register_task,
)
