"""The declarative experiment surface: one typed, serializable spec.

An :class:`ExperimentSpec` names *every* axis of a FeDLRT scenario —
task/model, data, federated optimization, per-round participation,
aggregation engine, wire codecs, system-simulation fleet, checkpointing —
as frozen dataclasses with defaults, so a whole experiment is one value
that can be

- round-tripped losslessly (``from_dict(to_dict(spec)) == spec``, TOML or
  JSON files via :meth:`ExperimentSpec.save` / :func:`load_spec`),
- content-hashed (:meth:`ExperimentSpec.spec_hash` — stamped into
  checkpoints so ``resume()`` refuses a mismatched spec loudly),
- swept by ``dataclasses.replace`` instead of kwarg re-plumbing, and
- **validated at spec time**: incoherent combinations (an
  ``edge_codec`` without the hier engine, a cohort bigger than the
  population, …) raise here, with the field name in the message, instead
  of deep inside engine construction.

Construction of the runnable experiment lives in
:func:`repro.api.experiment.build`; this module depends only on the spec
parsers of the subsystems it names (wire codecs, fleet specs,
participation modes, the round-method registry).
"""
import dataclasses
from dataclasses import field
from typing import Optional

from repro.api.serialization import (
    content_hash,
    from_plain_dict,
    parse_override,
    set_dotted,
    to_plain_dict,
    toml_dumps,
    toml_loads,
)

ENGINE_KINDS = ("sync", "async", "hier")
KERNEL_POLICIES = ("auto", "interpret", "off")
CORRECTIONS = ("auto", "none", "simplified", "full")


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """What trains: a task family plus its model knobs.

    ``kind`` selects a registered task builder (:mod:`repro.api.tasks`):
    ``"lm"`` — a decoder LM from a named ``preset`` *or* an architecture
    registry ``arch`` (exactly one; they were silently-clobbering CLI
    flags before) on the Markov token stream; ``"mlp"`` — the fig-5-style
    CV proxy head with a FeDLRT-factorized hidden layer.
    """

    kind: str = "lm"
    # lm task: exactly one of preset / arch
    preset: Optional[str] = None
    arch: Optional[str] = None
    smoke: bool = False
    kernels: str = "auto"
    # mlp task
    dim: int = 64
    classes: int = 10
    hidden: int = 256
    r_max: int = 24
    lowrank: bool = True

    def __post_init__(self):
        if self.kernels not in KERNEL_POLICIES:
            raise ValueError(
                f"model.kernels must be one of {KERNEL_POLICIES}, "
                f"got {self.kernels!r}"
            )
        for f_ in ("dim", "classes", "hidden", "r_max"):
            if getattr(self, f_) <= 0:
                raise ValueError(f"model.{f_} must be positive")


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """The federated data pipeline feeding the task."""

    kind: str = "token_stream"  # "token_stream" (lm) | "classification" (mlp)
    batch: int = 4
    partition: str = "iid"  # "iid" | "dirichlet:ALPHA"
    # token_stream
    seq: int = 128
    tokens_per_client: int = 200_000
    stream_rank: int = 16
    # classification
    num_points: int = 12_288
    noise: float = 0.3
    planted_rank: int = 6
    holdout: int = 2048  # tail points reserved for the accuracy eval

    def __post_init__(self):
        for f_ in ("batch", "seq", "tokens_per_client", "stream_rank",
                   "num_points", "planted_rank"):
            if getattr(self, f_) <= 0:
                raise ValueError(f"data.{f_} must be positive")
        if self.holdout < 0:
            raise ValueError("data.holdout must be >= 0")
        if self.holdout >= self.num_points:
            raise ValueError(
                f"data.holdout ({self.holdout}) must leave training points "
                f"(num_points={self.num_points})"
            )
        self.partition_alpha()  # parse = validate

    def partition_alpha(self) -> Optional[float]:
        """Dirichlet α of the partition spec (None for iid)."""
        kind, _, arg = self.partition.partition(":")
        if kind == "iid":
            if arg:
                raise ValueError(
                    f"data.partition 'iid' takes no argument, got "
                    f"{self.partition!r}"
                )
            return None
        if kind == "dirichlet":
            try:
                alpha = float(arg)
            except ValueError:
                alpha = -1.0
            if alpha <= 0:
                raise ValueError(
                    f"data.partition 'dirichlet:ALPHA' needs ALPHA > 0, "
                    f"got {self.partition!r}"
                )
            return alpha
        raise ValueError(
            f"data.partition must be 'iid' or 'dirichlet:ALPHA', "
            f"got {self.partition!r}"
        )


@dataclasses.dataclass(frozen=True)
class FedSpec:
    """The federated optimization: method × correction × cohort shape.

    Wraps :class:`repro.core.FedConfig` plus the engine-level choices that
    ride with it (round method, weighted aggregation).  ``local_steps=0``
    means the fig-5 scaling ``s* = max(240 // clients, 1)``.

    ``correction="auto"`` (the default) resolves per method — FeDLRT's
    ``simplified`` variance correction for ``method="fedlrt"``, ``none``
    for everything else — so a minimal ``[fed] method = "fedavg"`` file
    stays valid; an *explicit* FeDLRT correction on a dense method is
    still rejected.
    """

    method: str = "fedlrt"
    correction: str = "auto"
    clients: int = 4
    local_steps: int = 4
    lr: float = 3e-2
    tau: float = 0.05
    weighted: bool = False
    eval_after: bool = True

    def __post_init__(self):
        if self.correction not in CORRECTIONS:
            raise ValueError(
                f"fed.correction must be one of {CORRECTIONS}, "
                f"got {self.correction!r}"
            )
        if (
            not self.method.startswith("fedlrt")
            and self.correction not in ("auto", "none")
        ):
            raise ValueError(
                f"fed.correction={self.correction!r} is a FeDLRT variance "
                f"correction; method {self.method!r} must use "
                f"correction='none'"
            )
        if self.clients <= 0:
            raise ValueError(f"fed.clients must be positive, got {self.clients}")
        if self.local_steps < 0:
            raise ValueError(
                "fed.local_steps must be >= 0 (0 = the 240/C auto scaling)"
            )
        if self.lr <= 0:
            raise ValueError(f"fed.lr must be positive, got {self.lr}")
        if not 0.0 <= self.tau < 1.0:
            raise ValueError(f"fed.tau must lie in [0, 1), got {self.tau}")

    @property
    def s_star(self) -> int:
        return self.local_steps if self.local_steps > 0 else max(240 // self.clients, 1)

    @property
    def correction_effective(self) -> str:
        """``auto`` resolved: the paper's simplified correction for
        ``fedlrt``, ``none`` for baselines (the legacy CLI's rule)."""
        if self.correction != "auto":
            return self.correction
        return "simplified" if self.method == "fedlrt" else "none"

    def to_fed_config(self):
        from repro.core import FedConfig

        return FedConfig(
            num_clients=self.clients,
            s_star=self.s_star,
            lr=self.lr,
            correction=self.correction_effective,
            tau=self.tau,
            eval_after=self.eval_after,
        )


@dataclasses.dataclass(frozen=True)
class ParticipationSpec:
    """Per-round cohort policy (mirrors :class:`repro.fed.Participation`;
    the run seed is injected at build time, not stored here)."""

    mode: str = "full"
    cohort_size: Optional[int] = None
    dropout_prob: float = 0.0
    min_cohort: int = 1

    def __post_init__(self):
        self.build(seed=0)  # constructing the policy = validating the spec

    @classmethod
    def from_string(cls, spec: str) -> "ParticipationSpec":
        """CLI alias: ``full`` | ``uniform:K`` | ``round_robin:K`` |
        ``dropout:P``."""
        from repro.fed.participation import Participation

        p = Participation.from_spec(spec)
        return cls(
            mode=p.mode, cohort_size=p.cohort_size,
            dropout_prob=p.dropout_prob, min_cohort=p.min_cohort,
        )

    def to_string(self) -> str:
        if self.mode in ("uniform", "round_robin"):
            return f"{self.mode}:{self.cohort_size}"
        if self.mode == "dropout":
            return f"dropout:{self.dropout_prob:g}"
        return self.mode

    def build(self, *, seed: int):
        from repro.fed.participation import Participation

        return Participation(
            mode=self.mode, cohort_size=self.cohort_size,
            dropout_prob=self.dropout_prob, min_cohort=self.min_cohort,
            seed=seed,
        )


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """When the server aggregates.

    ``None`` means "engine default" — and **unset**: setting an
    async-only knob (``buffer_size``, ``staleness_power``) or a hier-only
    knob (``edges``, ``edge_rounds``) with a different ``kind`` is
    rejected at spec time.
    """

    kind: str = "sync"
    buffer_size: Optional[int] = None  # async: aggregate every K arrivals
    staleness_power: Optional[float] = None  # async: (1+s)^-p discount
    edges: Optional[int] = None  # hier: edge servers
    edge_rounds: Optional[int] = None  # hier: local rounds per cloud round

    def __post_init__(self):
        if self.kind not in ENGINE_KINDS:
            raise ValueError(
                f"engine.kind must be one of {ENGINE_KINDS}, got {self.kind!r}"
            )
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError("engine.buffer_size must be >= 1")
        if self.staleness_power is not None and self.staleness_power < 0:
            raise ValueError("engine.staleness_power must be >= 0")
        if self.edges is not None and self.edges < 1:
            raise ValueError("engine.edges must be >= 1")
        if self.edge_rounds is not None and self.edge_rounds < 1:
            raise ValueError("engine.edge_rounds must be >= 1")
        if self.kind != "async":
            for f_ in ("buffer_size", "staleness_power"):
                if getattr(self, f_) is not None:
                    raise ValueError(
                        f"engine.{f_} only applies to the async engine "
                        f"(engine.kind={self.kind!r})"
                    )
        if self.kind != "hier":
            for f_ in ("edges", "edge_rounds"):
                if getattr(self, f_) is not None:
                    raise ValueError(
                        f"engine.{f_} only applies to the hier engine "
                        f"(engine.kind={self.kind!r})"
                    )


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """What crosses the wire(s): client-tier codec plus the hier engine's
    edge↔cloud backhaul codec (``None`` → same as ``codec``)."""

    codec: str = "identity"
    edge_codec: Optional[str] = None

    def __post_init__(self):
        from repro.fed.wire import make_codec

        make_codec(self.codec)  # raises with the codec menu on bad specs
        if self.edge_codec is not None:
            make_codec(self.edge_codec)


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """System-simulation fleet (:meth:`repro.fed.sim.Fleet.from_spec`
    string).  ``None`` = no virtual clock for the sync engine, the uniform
    fleet for async/hier (which always run on a clock)."""

    profile: Optional[str] = None

    def __post_init__(self):
        if self.profile is not None:
            from repro.fed.sim.profiles import Fleet

            Fleet.from_spec(self.profile, 2)  # parse = validate


@dataclasses.dataclass(frozen=True)
class CheckpointSpec:
    """Checkpointing cadence: ``every`` rounds into ``dir`` (``dir=None``
    disables; the effective cadence is 0 without a directory — previously
    the ``20 if args.checkpoint_dir else 0`` idiom copy-pasted per engine
    branch)."""

    dir: Optional[str] = None
    every: int = 20

    def __post_init__(self):
        if self.every < 0:
            raise ValueError("checkpoint.every must be >= 0")

    @property
    def effective_every(self) -> int:
        return self.every if self.dir else 0


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Structured telemetry (:mod:`repro.telemetry`): round/phase spans,
    metric streams, JSONL event logs and Perfetto traces.

    ``enabled=False`` (the default) keeps the console progress sink only —
    runs look exactly as before.  ``sinks`` is a comma-separated subset of
    ``console``, ``memory``, ``jsonl``, ``perfetto``; file sinks write
    ``events.jsonl`` / ``trace.json`` under ``dir``.  ``sample_every``
    keeps every Nth round's gauge/hist events (spans, counters, and
    progress are never sampled).  Telemetry only ever *reads* run state,
    so enabling it cannot change params or history.
    """

    enabled: bool = False
    sinks: str = "console"
    dir: Optional[str] = None
    sample_every: int = 1

    def __post_init__(self):
        from repro.telemetry.sinks import SINK_NAMES

        if self.sample_every < 1:
            raise ValueError("telemetry.sample_every must be >= 1")
        names = [s.strip() for s in self.sinks.split(",") if s.strip()]
        if not names:
            raise ValueError("telemetry.sinks must name at least one sink")
        for n in names:
            if n not in SINK_NAMES:
                raise ValueError(
                    f"unknown telemetry sink {n!r}; expected a comma list "
                    f"over {SINK_NAMES}"
                )
        if self.enabled and self.dir is None and (
            "jsonl" in names or "perfetto" in names
        ):
            raise ValueError(
                "telemetry.dir is required for the jsonl/perfetto file sinks"
            )


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """How the trained factorized model is served (:mod:`repro.serve`).

    ``checkpoint`` names a ``round_*.npz`` file or a checkpoint directory
    (latest round wins); ``None`` serves fresh ``seed``-initialized params
    — useful for smoke runs, pointless in production.  ``quantize`` picks
    the at-rest factor compression (``int8`` per-column affine / ``bf16``
    downcast), ``rank_slice`` drops exactly-zero inactive columns at load,
    and ``materialize`` densifies ``U S Vᵀ`` — the debug/baseline path,
    mutually exclusive with the compression knobs.  ``mode`` selects
    continuous batching or the static-wave baseline.  Prompts are
    right-padded to ``prompt_bucket`` multiples (one prefill executable
    per bucket), and the decode executable is fixed at
    ``(max_batch, max_prompt + max_new_tokens)``.
    """

    checkpoint: Optional[str] = None
    quantize: str = "none"
    rank_slice: bool = False
    materialize: bool = False
    mode: str = "continuous"
    max_batch: int = 4
    max_queue: int = 64
    max_prompt: int = 64
    prompt_bucket: int = 16
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None

    def __post_init__(self):
        from repro.serve.quantize import QUANT_MODES
        from repro.serve.scheduler import SCHED_MODES

        if self.quantize not in QUANT_MODES:
            raise ValueError(
                f"serve.quantize must be one of {QUANT_MODES}, "
                f"got {self.quantize!r}"
            )
        if self.mode not in SCHED_MODES:
            raise ValueError(
                f"serve.mode must be one of {SCHED_MODES}, got {self.mode!r}"
            )
        for name in (
            "max_batch", "max_queue", "max_prompt", "prompt_bucket",
            "max_new_tokens",
        ):
            if getattr(self, name) < 1:
                raise ValueError(f"serve.{name} must be >= 1")
        if self.max_queue < self.max_batch:
            raise ValueError(
                f"serve.max_queue ({self.max_queue}) must hold at least one "
                f"full slot cohort (serve.max_batch={self.max_batch})"
            )
        if self.max_prompt % self.prompt_bucket:
            raise ValueError(
                f"serve.prompt_bucket ({self.prompt_bucket}) must divide "
                f"serve.max_prompt ({self.max_prompt}) — prefill "
                f"executables are compiled per bucket"
            )
        if self.temperature < 0:
            raise ValueError("serve.temperature must be >= 0")
        if self.eos_id is not None and self.eos_id < 0:
            raise ValueError("serve.eos_id must be a token id (>= 0)")
        if self.materialize and self.quantize != "none":
            raise ValueError(
                f"serve.materialize=True densifies U S Vᵀ; "
                f"serve.quantize={self.quantize!r} compresses the factors "
                f"it would destroy — pick one"
            )
        if self.materialize and self.rank_slice:
            raise ValueError(
                "serve.rank_slice drops inactive factor columns; it has "
                "nothing to act on once serve.materialize densifies — "
                "unset one"
            )

    @property
    def cache_len(self) -> int:
        """Per-slot KV budget: longest admissible prompt + decode room."""
        return self.max_prompt + self.max_new_tokens


def _default_model():
    return ModelSpec(preset="llm-tiny")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One complete FeDLRT scenario, declaratively.

    ``build(spec)`` (:mod:`repro.api.experiment`) turns it into a runnable
    :class:`Experiment`; every entry-point surface (the train CLI, the
    vision example, the benchmark drivers) constructs engines exclusively
    through it.
    """

    name: str = ""
    seed: int = 0
    rounds: int = 40
    log_every: int = 5
    model: ModelSpec = field(default_factory=_default_model)
    data: DataSpec = field(default_factory=DataSpec)
    fed: FedSpec = field(default_factory=FedSpec)
    participation: ParticipationSpec = field(default_factory=ParticipationSpec)
    engine: EngineSpec = field(default_factory=EngineSpec)
    wire: WireSpec = field(default_factory=WireSpec)
    sim: SimSpec = field(default_factory=SimSpec)
    checkpoint: CheckpointSpec = field(default_factory=CheckpointSpec)
    telemetry: TelemetrySpec = field(default_factory=TelemetrySpec)
    serve: ServeSpec = field(default_factory=ServeSpec)

    # -- validation --------------------------------------------------------

    def __post_init__(self):
        if self.rounds < 0:
            raise ValueError("rounds must be >= 0")
        if self.log_every < 0:
            raise ValueError("log_every must be >= 0")
        self._validate_task()
        self._validate_method()
        self._validate_cross()

    def _validate_task(self):
        from repro.api.tasks import task_data_kinds

        data_kinds = task_data_kinds(self.model.kind)  # unknown kind raises
        if self.data.kind not in data_kinds:
            raise ValueError(
                f"data.kind={self.data.kind!r} does not feed the "
                f"{self.model.kind!r} task (expected one of {data_kinds})"
            )
        if self.model.kind == "lm":
            if (self.model.preset is None) == (self.model.arch is None):
                raise ValueError(
                    "the lm task needs exactly one of model.preset / "
                    "model.arch (pass --preset none to use --arch from "
                    "the CLI)"
                )
            if self.model.preset is not None:
                from repro.api.tasks import PRESETS

                if self.model.preset not in PRESETS:
                    raise ValueError(
                        f"unknown model.preset {self.model.preset!r}; "
                        f"presets: {sorted(PRESETS)}"
                    )
        if self.model.kind == "lsq":
            if self.data.partition != "iid":
                raise ValueError(
                    "the homogeneous lsq problem is generated pre-sharded "
                    f"with identical client distributions; data.partition="
                    f"{self.data.partition!r} is meaningless for it (use "
                    "'iid', or the heterogeneous problem via the core API)"
                )
            if self.data.num_points % self.fed.clients:
                raise ValueError(
                    f"data.num_points ({self.data.num_points}) must divide "
                    f"evenly across fed.clients ({self.fed.clients}) for "
                    f"the lsq task — trailing points would be dropped "
                    f"silently"
                )
        if self.data.kind == "token_stream" and self.data.partition != "iid":
            raise ValueError(
                "the token-stream pipeline partitions windows iid; "
                f"data.partition={self.data.partition!r} needs labels "
                "(use the classification data kind)"
            )

    def _validate_method(self):
        from repro.fed.engine import ROUND_METHODS

        if self.fed.method not in ROUND_METHODS:
            raise ValueError(
                f"unknown fed.method {self.fed.method!r}; registered: "
                f"{sorted(ROUND_METHODS)}"
            )

    def _validate_cross(self):
        if self.engine.kind in ("async", "hier") and self.participation.mode != "full":
            raise ValueError(
                f"the {self.engine.kind} engine derives participation from "
                f"client availability; participation.mode="
                f"{self.participation.mode!r} only composes with the sync "
                f"engine"
            )
        if self.wire.edge_codec is not None and self.engine.kind != "hier":
            raise ValueError(
                "wire.edge_codec prices the hier engine's edge↔cloud hop; "
                f"it is meaningless with engine.kind={self.engine.kind!r}"
            )
        if self.engine.kind == "hier" and self.checkpoint.dir is not None:
            raise ValueError(
                "the hier engine does not support checkpointing yet; "
                "unset checkpoint.dir"
            )
        k = self.participation.cohort_size
        if k is not None and k > self.fed.clients:
            raise ValueError(
                f"participation.cohort_size ({k}) exceeds fed.clients "
                f"({self.fed.clients})"
            )
        if (
            self.engine.buffer_size is not None
            and self.engine.buffer_size > self.fed.clients
        ):
            raise ValueError(
                f"engine.buffer_size ({self.engine.buffer_size}) exceeds "
                f"fed.clients ({self.fed.clients}) — the buffer could "
                f"never fill"
            )
        if self.engine.edges is not None and self.engine.edges > self.fed.clients:
            raise ValueError(
                f"engine.edges ({self.engine.edges}) exceeds fed.clients "
                f"({self.fed.clients})"
            )

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        return to_plain_dict(self)

    @classmethod
    def from_dict(cls, data: dict) -> "ExperimentSpec":
        return from_plain_dict(cls, data)

    def to_toml(self) -> str:
        head = (
            f"# FeDLRT experiment spec (hash {self.spec_hash()}) — "
            f"run with:  python -m repro.api run <this file>\n"
        )
        return head + toml_dumps(self.to_dict())

    @classmethod
    def from_toml(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(toml_loads(text))

    def to_json(self) -> str:
        import json

        return json.dumps(self.to_dict(), indent=2) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        import json

        return cls.from_dict(json.loads(text))

    def save(self, path) -> None:
        """Write the spec to ``path`` (.toml or .json, by extension)."""
        path = str(path)
        if path.endswith(".json"):
            text = self.to_json()
        elif path.endswith(".toml"):
            text = self.to_toml()
        else:
            raise ValueError(f"spec files are .toml or .json, got {path!r}")
        with open(path, "w") as fh:
            fh.write(text)

    def spec_hash(self) -> str:
        """12-hex-digit content hash — invariant under field reordering and
        TOML/JSON round-trips; stamped into checkpoints for resume safety."""
        return content_hash(self.to_dict())

    def replace(self, **changes) -> "ExperimentSpec":
        """``dataclasses.replace`` with sub-spec kwargs flattened:
        ``spec.replace(fed=..., rounds=10)``."""
        return dataclasses.replace(self, **changes)

    def with_overrides(self, items) -> "ExperimentSpec":
        """Apply dotted CLI overrides (``["engine.kind=async", ...]`` or a
        ``{"engine.kind": "async"}`` mapping; values are parsed by the
        target field's type, ``"none"`` clears an optional field)."""
        if isinstance(items, dict):
            pairs = list(items.items())
        else:
            pairs = [parse_override(i) for i in items]
        data = self.to_dict()
        for path, value in pairs:
            set_dotted(type(self), data, path, value, parse_str=True)
        return type(self).from_dict(data)


def load_spec(path) -> ExperimentSpec:
    """Read an :class:`ExperimentSpec` from a .toml or .json file."""
    path = str(path)
    with open(path) as fh:
        text = fh.read()
    if path.endswith(".json"):
        return ExperimentSpec.from_json(text)
    if path.endswith(".toml"):
        return ExperimentSpec.from_toml(text)
    raise ValueError(f"spec files are .toml or .json, got {path!r}")
