"""Task registry: (ModelSpec, DataSpec) → loss, params, batcher, eval.

A *task* is everything below the federated layer: the model/loss pair,
its initial parameters, the per-client data pipeline and an optional
holdout evaluation.  ``build(spec)`` resolves ``spec.model.kind`` through
this registry, so new workloads plug in with :func:`register_task` —
never by editing the builder.

Built-ins:

- ``lm`` — a decoder LM from a named preset (moved here from
  ``repro.launch.train``; the train CLI re-exports ``PRESETS``) or the
  architecture registry, trained on the planted-low-rank Markov token
  stream, windows partitioned iid across clients.
- ``mlp`` — the fig-5-style CV proxy: a 2-layer MLP head whose hidden
  layer is FeDLRT-factorized (when the method is low-rank), on synthetic
  classification data with a planted low-rank decision map, Dirichlet or
  iid split, with a held-out accuracy eval.
- ``lsq`` — the paper's §5.1 homogeneous distributed least-squares
  problem (planted low-rank target, identical client distributions): the
  convergence-theorem testbed the ablation benchmarks sweep.
"""
import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.models.config import LowRankPolicy, ModelConfig

#: named LM presets (the train CLI's ``--preset`` menu)
PRESETS = {
    # ~100M-param dense decoder for the end-to-end example (deliverable b)
    "llm-100m": ModelConfig(
        name="llm-100m", family="dense", num_layers=12, d_model=640,
        num_heads=10, num_kv_heads=10, head_dim=64, d_ff=2560,
        vocab_size=8192, compute_dtype="float32", param_dtype="float32",
        lowrank=LowRankPolicy(rank_frac=0.25, r_cap=160, min_dim=256),
        attn_q_chunk=256,
    ),
    # CPU-feasible demo (~2M params)
    "llm-tiny": ModelConfig(
        name="llm-tiny", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512,
        vocab_size=512, compute_dtype="float32", param_dtype="float32",
        lowrank=LowRankPolicy(rank_frac=0.25, r_cap=32, min_dim=32),
        attn_q_chunk=64,
    ),
}


@dataclasses.dataclass
class Task:
    """A built task: what the engine trains and how it is judged."""

    loss_fn: Callable
    params: object
    batcher: object  # FederatedBatcher
    client_sizes: np.ndarray  # |X_c| per client (weighted aggregation)
    description: str
    eval_fn: Optional[Callable] = None  # params → float (holdout accuracy)


#: kind → (builder(spec) → Task, compatible data kinds)
_TASKS: Dict[str, Tuple[Callable, Tuple[str, ...]]] = {}


def register_task(
    kind: str, builder: Callable, *, data_kinds: Tuple[str, ...],
    overwrite: bool = False,
):
    """Register a task family under ``model.kind == kind``.

    ``builder(spec: ExperimentSpec) → Task``; ``data_kinds`` lists the
    ``data.kind`` values the builder understands (spec validation rejects
    mismatches before the builder ever runs).
    """
    if not overwrite and kind in _TASKS:
        raise ValueError(
            f"task kind {kind!r} is already registered "
            f"(pass overwrite=True to replace it)"
        )
    _TASKS[kind] = (builder, tuple(data_kinds))


def task_data_kinds(kind: str) -> Tuple[str, ...]:
    """The data kinds compatible with task ``kind`` (raises for unknown)."""
    if kind not in _TASKS:
        raise ValueError(
            f"unknown model.kind {kind!r}; registered tasks: {sorted(_TASKS)}"
        )
    return _TASKS[kind][1]


def build_task(spec) -> Task:
    return _TASKS[spec.model.kind][0](spec)


def _partition(partition: str, labels, n: int, clients: int, seed: int):
    from repro.data import partition_dirichlet, partition_iid

    kind, _, arg = partition.partition(":")
    if kind == "iid":
        return partition_iid(n, clients, seed=seed)
    return partition_dirichlet(labels, clients, alpha=float(arg), seed=seed)


# ---------------------------------------------------------------------------
# lm: decoder LM on the Markov token stream (the train CLI's task)
# ---------------------------------------------------------------------------


def lm_model_config(m):
    """Resolve a ModelSpec's lm architecture (preset/arch × smoke ×
    kernels) — shared by the task builder and the serving layer, so
    train and serve agree on shapes by construction."""
    from repro.configs import get_config
    from repro.models.config import reduced

    cfg = PRESETS[m.preset] if m.preset is not None else get_config(m.arch)
    if m.smoke:
        cfg = reduced(cfg)
    if m.kernels != cfg.kernels:
        cfg = dataclasses.replace(cfg, kernels=m.kernels)
    return cfg


def _build_lm(spec) -> Task:
    import jax

    from repro.data import FederatedBatcher, make_token_stream, partition_sizes
    from repro.models import build_model

    m, d = spec.model, spec.data
    cfg = lm_model_config(m)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(spec.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))

    # data: Markov stream with planted low-rank transitions → real loss floor
    tokens = make_token_stream(
        vocab_size=cfg.vocab_size,
        num_tokens=spec.fed.clients * d.tokens_per_client,
        rank=d.stream_rank,
        seed=spec.seed,
    )
    T = d.seq
    windows = np.lib.stride_tricks.sliding_window_view(tokens, T + 1)[:: T // 2]
    parts = _partition(d.partition, None, len(windows), spec.fed.clients, spec.seed)
    batcher = FederatedBatcher(
        {"tokens": windows}, parts, batch_size=d.batch, seed=spec.seed
    )
    return Task(
        loss_fn=model.loss_fn,
        params=params,
        batcher=batcher,
        client_sizes=np.asarray(partition_sizes(parts)),
        description=f"model={cfg.name} params={n_params/1e6:.1f}M",
    )


# ---------------------------------------------------------------------------
# mlp: the fig-5-style CV proxy head (vision example / CV benchmarks)
# ---------------------------------------------------------------------------


def _mlp_init(key, m, lowrank: bool):
    import jax
    import jax.numpy as jnp

    from repro.core import init_factor

    k1, k2 = jax.random.split(key)
    w1 = (
        init_factor(k1, m.dim, m.hidden, r_max=m.r_max, init_rank=m.r_max)
        if lowrank
        else 0.18 * jax.random.normal(k1, (m.dim, m.hidden))
    )
    return {
        "w1": w1,
        "b1": jnp.zeros((m.hidden,)),
        "w2": 0.06 * jax.random.normal(k2, (m.hidden, m.classes)),
        "b2": jnp.zeros((m.classes,)),
    }


def _mlp_fwd(p, x, kernels: str):
    """First (possibly factorized) layer through the rank bottleneck —
    ``lr_matmul`` dispatches to the fused Pallas chain under a kernel
    policy, for LowRankFactor and the client loop's AugmentedFactor
    alike."""
    import jax

    from repro.core.factorization import is_factor, lr_matmul

    h = (
        lr_matmul(x, p["w1"], kernels=kernels)
        if is_factor(p["w1"])
        else x @ p["w1"]
    )
    h = jax.nn.relu(h + p["b1"])
    return h @ p["w2"] + p["b2"]


def _build_mlp(spec) -> Task:
    import jax
    import jax.numpy as jnp

    from repro.data import (
        FederatedBatcher,
        make_classification_data,
        partition_sizes,
    )

    m, d = spec.model, spec.data
    x, y = make_classification_data(
        dim=m.dim, num_classes=m.classes, rank=d.planted_rank,
        num_points=d.num_points, noise=d.noise, seed=spec.seed,
    )
    if d.holdout:
        xt, yt = jnp.asarray(x[-d.holdout:]), jnp.asarray(y[-d.holdout:])
        x, y = x[:-d.holdout], y[:-d.holdout]
    else:
        xt = yt = None
    parts = _partition(d.partition, y, len(y), spec.fed.clients, spec.seed)
    batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=d.batch, seed=spec.seed)

    kernels = m.kernels
    lowrank = m.lowrank and spec.fed.method.startswith("fedlrt")

    def loss_fn(p, batch):
        logp = jax.nn.log_softmax(_mlp_fwd(p, batch["x"], kernels))
        return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))

    eval_fn = None
    if xt is not None:
        def eval_fn(p):
            pred = jnp.argmax(_mlp_fwd(p, xt, kernels), -1)
            return float(jnp.mean(pred == yt))

    return Task(
        loss_fn=loss_fn,
        params=_mlp_init(jax.random.PRNGKey(spec.seed), m, lowrank),
        batcher=batcher,
        client_sizes=np.asarray(partition_sizes(parts)),
        description=(
            f"mlp head {m.dim}→{m.hidden}→{m.classes} "
            f"({'rank≤' + str(m.r_max) if lowrank else 'dense'})"
        ),
        eval_fn=eval_fn,
    )


# ---------------------------------------------------------------------------
# lsq: the §5.1 homogeneous least-squares convergence testbed
# ---------------------------------------------------------------------------


def _build_lsq(spec) -> Task:
    import jax
    import jax.numpy as jnp

    from repro.core import init_factor
    from repro.core.factorization import is_factor
    from repro.data import FederatedBatcher, make_homogeneous_lsq

    m, d = spec.model, spec.data
    prob = make_homogeneous_lsq(
        n=m.dim, rank=d.planted_rank, num_points=d.num_points,
        num_clients=spec.fed.clients, seed=spec.seed,
    )
    C, N_c = prob.px.shape[0], prob.px.shape[1]
    arrays = {
        "px": prob.px.reshape(-1, prob.px.shape[-1]),
        "py": prob.py.reshape(-1, prob.py.shape[-1]),
        "t": prob.target.reshape(-1),
    }
    # the problem is generated pre-sharded (homogeneous): client c owns the
    # contiguous row block [c·N_c, (c+1)·N_c)
    parts = [list(range(c * N_c, (c + 1) * N_c)) for c in range(C)]
    batcher = FederatedBatcher(
        arrays, parts, batch_size=min(d.batch, N_c), seed=spec.seed
    )

    lowrank = m.lowrank and spec.fed.method.startswith("fedlrt")
    if lowrank:
        params = init_factor(
            jax.random.PRNGKey(spec.seed), m.dim, m.dim,
            r_max=m.r_max, init_rank=m.r_max, spectrum_scale=1.0,
        )
    else:
        params = jnp.zeros((m.dim, m.dim))

    def loss_fn(p, batch):
        if is_factor(p):
            pred = jnp.sum(
                ((batch["px"] @ p.U) @ p.S) * (batch["py"] @ p.V), -1
            )
        else:
            pred = jnp.sum((batch["px"] @ p) * batch["py"], -1)
        return 0.5 * jnp.mean((pred - batch["t"]) ** 2)

    return Task(
        loss_fn=loss_fn,
        params=params,
        batcher=batcher,
        client_sizes=np.full(C, N_c),
        description=(
            f"homogeneous lsq n={m.dim} rank*={d.planted_rank} "
            f"({'rank≤' + str(m.r_max) if lowrank else 'dense'}, "
            f"{N_c}/client)"
        ),
    )


register_task("lm", _build_lm, data_kinds=("token_stream",))
register_task("mlp", _build_mlp, data_kinds=("classification",))
register_task("lsq", _build_lsq, data_kinds=("lsq",))
