"""CLI for spec files:  python -m repro.api {validate,describe,run,serve} ...

``validate`` parses + validates spec files and prints their content
hashes (the CI ``config-smoke`` job's first gate); ``describe`` renders a
built experiment without running it; ``run`` builds and trains, with the
same dotted ``--set section.key=value`` overrides the train CLI accepts;
``serve`` stands up the spec's ``[serve]`` section over seeded synthetic
prompts and prints throughput/latency stats.
"""
import argparse
import sys

from repro.api.spec import load_spec


def _load(path, overrides):
    spec = load_spec(path)
    if overrides:
        spec = spec.with_overrides(overrides)
    return spec


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.api")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_val = sub.add_parser("validate", help="parse + validate spec files")
    p_val.add_argument("paths", nargs="+")

    p_desc = sub.add_parser("describe", help="build a spec and describe it")
    p_desc.add_argument("path")
    p_desc.add_argument("--set", dest="sets", action="append", default=[],
                        metavar="SECTION.KEY=VALUE")

    p_run = sub.add_parser("run", help="build a spec and train it")
    p_run.add_argument("path")
    p_run.add_argument("--set", dest="sets", action="append", default=[],
                       metavar="SECTION.KEY=VALUE")
    p_run.add_argument("--rounds", type=int, default=None,
                       help="override spec.rounds")
    p_run.add_argument("--log-every", type=int, default=None,
                       help="override spec.log_every")

    p_srv = sub.add_parser("serve", help="build a spec's serving stack and "
                           "drive synthetic requests through it")
    p_srv.add_argument("path")
    p_srv.add_argument("--set", dest="sets", action="append", default=[],
                       metavar="SECTION.KEY=VALUE")
    p_srv.add_argument("--requests", type=int, default=8,
                       help="number of synthetic prompts")
    args = ap.parse_args(argv)

    if args.cmd == "validate":
        ok = True
        for path in args.paths:
            try:
                spec = load_spec(path)
            except (ValueError, OSError) as e:
                print(f"{path}: INVALID — {e}")
                ok = False
            else:
                print(f"{path}: ok [spec {spec.spec_hash()}]")
        return 0 if ok else 1

    spec = _load(args.path, args.sets)
    if args.cmd == "serve":
        from repro.launch.serve import run_session

        return run_session(spec, num_requests=args.requests)

    from repro.api.experiment import build

    if args.cmd == "describe":
        print(build(spec).describe())
        return 0

    exp = build(spec)
    print(exp.describe())
    hist = exp.run(rounds=args.rounds, log_every=args.log_every)
    if not hist:
        print("done: no rounds run")
        return 0
    timing = (
        f"; virtual time {hist[-1].t_virtual:.1f}s [{spec.engine.kind}]"
        if exp.is_simulated
        else ""
    )
    print(
        f"done: loss {hist[0].loss_before:.4f} → {hist[-1].loss_before:.4f}; "
        f"total comm {exp.comm_total_bytes()/1e6:.1f} MB measured "
        f"[{spec.wire.codec}]{timing}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
