"""repro-lint framework: rule plugins, suppressions, file walking.

The analyzer makes the repo's correctness conventions — the ones the
convergence guarantee actually rests on (see the ROADMAP architecture
map) — machine-checked instead of review-checked.  It is deliberately a
*small* custom AST pass, not a general linter: every rule is grounded in
one invariant of this codebase, knows the repo layout (``core/`` and
``kernels/`` are traced, ``launch/`` and ``benchmarks/`` are host-side
entry points, ``api/experiment.py`` is the one engine factory), and ships
an autofix hint pointing at the sanctioned extension seam.

Vocabulary:

- :class:`Finding` — one violation: rule id, severity, location, message,
  hint.
- :class:`Rule` — a plugin: ``id``/``title``/``severity``/``hint`` plus
  ``applies_to(ctx)`` (path-level scoping) and ``check(module)`` yielding
  findings.  Register with :func:`register_rule`.
- :class:`ModuleInfo` — one parsed file: source, AST, repo-relative
  classification (:class:`PathInfo`) and the parsed suppressions.

Suppression grammar (inline, auditable — every suppression is expected to
carry a justification after ``--``):

- ``# repro-lint: disable=RPL003`` on any line spanned by the flagged
  statement suppresses those rule ids (comma-separated, or ``all``) for
  that statement.
- ``# repro-lint: disable-file=RPL004`` anywhere in the file suppresses
  the ids for the whole file.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

#: severity levels, in increasing order of "this breaks a theorem"
SEVERITIES = ("warning", "error")

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable(?P<scope>-file)?\s*=\s*"
    r"(?P<ids>[A-Za-z0-9_]+(?:\s*,\s*[A-Za-z0-9_]+)*)"
)


@dataclasses.dataclass(frozen=True)
class TextEdit:
    """A mechanical source edit: replace the span [(line, col), (end_line,
    end_col)) — 1-based lines, 0-based columns, ast coordinates — with
    ``replacement``.  Carried on :class:`Finding.fix` and applied by
    ``repro-lint --fix`` (see :mod:`repro.analysis.fixes`)."""

    line: int
    col: int
    end_line: int
    end_col: int
    replacement: str


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    severity: str = "error"
    hint: str = ""
    #: optional mechanical autofix (compare=False: two findings are the
    #: same violation regardless of whether a fix could be synthesized)
    fix: Optional[TextEdit] = dataclasses.field(default=None, compare=False)

    def render(self, *, show_hint: bool = True) -> str:
        out = f"{self.path}:{self.line}:{self.col + 1}: {self.rule} {self.message}"
        if show_hint and self.hint:
            out += f"  [fix: {self.hint}]"
        return out


@dataclasses.dataclass(frozen=True)
class PathInfo:
    """Repo-relative classification of a file (layout-aware rule scoping).

    ``repro`` is the path inside the ``repro`` package as posix segments
    (``("repro", "fed", "engine.py")``) when the file lives under it, else
    ``()``.  The boolean surfaces name the repo's top-level directories.
    """

    path: str
    repro: Tuple[str, ...]
    is_tests: bool
    is_benchmarks: bool
    is_examples: bool

    def under(self, *segments: str) -> bool:
        """True if the file lives under ``repro/<segments...>``."""
        return self.repro[1 : 1 + len(segments)] == segments if self.repro else False

    @property
    def is_entry_point(self) -> bool:
        """Host-side entry-point surface: CLIs, benches, examples."""
        return self.is_benchmarks or self.is_examples or self.under("launch")


def classify_path(path: str) -> PathInfo:
    parts = tuple(os.path.normpath(os.path.abspath(path)).split(os.sep))
    repro: Tuple[str, ...] = ()
    if "repro" in parts:
        repro = parts[parts.index("repro"):]
    return PathInfo(
        path=path,
        repro=repro,
        is_tests="tests" in parts,
        is_benchmarks="benchmarks" in parts,
        is_examples="examples" in parts,
    )


class ModuleInfo:
    """One parsed source file plus its suppression table."""

    def __init__(self, path: str, source: str, tree: ast.AST):
        self.path = path
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        self.info = classify_path(path)
        # line number -> set of suppressed ids; "__file__" key for file-wide
        self.line_suppressions: Dict[int, set] = {}
        self.file_suppressions: set = set()
        for lineno, text in enumerate(self.lines, start=1):
            for m in _SUPPRESS_RE.finditer(text):
                ids = {i.strip() for i in m.group("ids").split(",")}
                if m.group("scope"):
                    self.file_suppressions |= ids
                    continue
                self.line_suppressions.setdefault(lineno, set()).update(ids)
                # a suppression on a standalone comment line governs the
                # next statement: carry it forward across the rest of the
                # comment block (where the justification lives) onto the
                # first code line
                if text.lstrip().startswith("#"):
                    ln = lineno + 1
                    while ln <= len(self.lines) and (
                        not self.lines[ln - 1].strip()
                        or self.lines[ln - 1].lstrip().startswith("#")
                    ):
                        self.line_suppressions.setdefault(ln, set()).update(ids)
                        ln += 1
                    if ln <= len(self.lines):
                        self.line_suppressions.setdefault(ln, set()).update(ids)

    def suppressed(self, rule_id: str, node: ast.AST) -> bool:
        if {rule_id, "all"} & self.file_suppressions:
            return True
        first = getattr(node, "lineno", 0)
        last = getattr(node, "end_lineno", first) or first
        for ln in range(first, last + 1):
            if {rule_id, "all"} & self.line_suppressions.get(ln, set()):
                return True
        return False

    def scope_source(self, node: Optional[ast.AST]) -> str:
        if node is None:
            return self.source
        return ast.get_source_segment(self.source, node) or ""


class Rule:
    """Base rule plugin.  Subclasses set the class attributes and implement
    :meth:`check`; ``applies_to`` scopes the rule by repo layout."""

    id: str = "RPL000"
    title: str = ""
    severity: str = "error"
    hint: str = ""

    def applies_to(self, info: PathInfo) -> bool:  # pragma: no cover - default
        return True

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, mod: ModuleInfo, node: ast.AST, message: str,
                *, hint: Optional[str] = None,
                severity: Optional[str] = None,
                fix: Optional[TextEdit] = None) -> Finding:
        return Finding(
            rule=self.id,
            path=mod.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            message=message,
            severity=self.severity if severity is None else severity,
            hint=self.hint if hint is None else hint,
            fix=fix,
        )


#: rule registry: id -> Rule instance (populated by repro.analysis.rules)
RULES: Dict[str, Rule] = {}


def register_rule(cls):
    """Class decorator: instantiate and register a :class:`Rule`."""
    rule = cls()
    if rule.id in RULES:
        raise ValueError(f"duplicate rule id {rule.id}")
    RULES[rule.id] = rule
    return cls


def get_rules(select: Optional[Iterable[str]] = None,
              ignore: Optional[Iterable[str]] = None) -> List[Rule]:
    import repro.analysis.rules  # noqa: F401  (registers the catalog)

    ids = sorted(RULES)
    if select:
        wanted = set(select)
        unknown = wanted - set(ids)
        if unknown:
            raise ValueError(f"unknown rule ids: {sorted(unknown)}")
        ids = [i for i in ids if i in wanted]
    if ignore:
        ids = [i for i in ids if i not in set(ignore)]
    return [RULES[i] for i in ids]


def iter_py_files(paths: Sequence[str]) -> Iterator[str]:
    """Expand files/directories into .py files (skips hidden dirs and
    ``__pycache__``), deterministic order."""
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(
                    d for d in dirs if not d.startswith(".") and d != "__pycache__"
                )
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)
        elif p.endswith(".py"):
            yield p


def parse_module(path: str) -> Tuple[Optional[ModuleInfo], Optional[Finding]]:
    try:
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
        tree = ast.parse(source, filename=path)
    except (OSError, SyntaxError) as e:
        return None, Finding(
            rule="RPL000", path=path,
            line=getattr(e, "lineno", 0) or 0, col=0,
            message=f"could not parse: {e}", severity="error",
        )
    return ModuleInfo(path, source, tree), None


def lint_file(path: str, rules: Sequence[Rule]) -> List[Finding]:
    mod, err = parse_module(path)
    if err is not None:
        return [err]
    assert mod is not None
    out: List[Finding] = []
    for rule in rules:
        if not rule.applies_to(mod.info):
            continue
        for f in rule.check(mod):
            # re-locate the node the finding anchored to for suppression:
            # Finding carries only line/col, so consult the line table
            if {f.rule, "all"} & mod.file_suppressions:
                continue
            if {f.rule, "all"} & mod.line_suppressions.get(f.line, set()):
                continue
            out.append(f)
    return out


def lint_paths(paths: Sequence[str],
               *,
               select: Optional[Iterable[str]] = None,
               ignore: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run the (selected) rule catalog over ``paths``; returns findings
    sorted by location."""
    rules = get_rules(select, ignore)
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(lint_file(path, rules))
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


# ---------------------------------------------------------------------------
# small AST helpers shared by the rule catalog
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, "" when not a plain chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(node: ast.Call) -> str:
    return dotted_name(node.func)


def base_chain_attrs(node: ast.AST) -> set:
    """Attribute names along an expression's *object* chain only.

    Walks ``value``/``func`` links (never call arguments or subscript
    indices), so ``jnp.zeros((n, f.S.dtype)).at[...]`` reports
    ``{zeros, at}`` — the ``f.S`` inside the argument list is not part of
    the updated object.
    """
    attrs = set()
    while True:
        if isinstance(node, ast.Attribute):
            attrs.add(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            return attrs


def is_simple_expr(node: ast.AST) -> bool:
    """Plumbing expressions that merely *move* an existing tensor: names,
    attribute chains, constants, and subscripts thereof."""
    if isinstance(node, (ast.Name, ast.Constant)):
        return True
    if isinstance(node, ast.Attribute):
        return is_simple_expr(node.value)
    if isinstance(node, ast.Subscript):
        return is_simple_expr(node.value)
    if isinstance(node, ast.Starred):
        return is_simple_expr(node.value)
    return False


def walk_with_scope(tree: ast.AST) -> Iterator[Tuple[ast.AST, Optional[ast.AST]]]:
    """Yield ``(node, enclosing_function)`` pairs, where the enclosing
    function is the *outermost* FunctionDef/AsyncFunctionDef containing the
    node (None at module level).  Nested defs report their outermost
    ancestor, which is the natural masking scope for RPL005."""

    def visit(node: ast.AST, scope: Optional[ast.AST]):
        for child in ast.iter_child_nodes(node):
            child_scope = scope
            if scope is None and isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef)
            ):
                child_scope = child
            yield child, child_scope
            yield from visit(child, child_scope)

    yield from visit(tree, None)


def scope_references(scope_node: Optional[ast.AST], names: set,
                     mod: ModuleInfo) -> bool:
    """True if the scope (or module, when scope is None) references any of
    ``names`` as an identifier or attribute."""
    root = scope_node if scope_node is not None else mod.tree
    for n in ast.walk(root):
        if isinstance(n, ast.Name) and n.id in names:
            return True
        if isinstance(n, ast.Attribute) and n.attr in names:
            return True
    return False
