"""Mechanical autofix application for ``repro-lint --fix``.

Rules attach a :class:`repro.analysis.core.TextEdit` to a finding when
the repair is purely mechanical and meaning-preserving:

- RPL003's ``os.listdir`` → ``sorted(os.listdir(...))``;
- RPL005's unmasked factor-constructor kwarg → re-mask with the mask
  variable that is live at the write (``mask_coeff(expr, m)`` for ``S``,
  ``(expr) * m[..., None, :]`` for ``U``/``V``).

Findings without an edit can still be *scaffolded* (``--fix
--scaffold``): a suppression comment with a ``TODO`` justification is
inserted above the flagged line, turning an un-autofixable finding into
an auditable, greppable debt marker instead of a red CI.

Edits apply bottom-up (last line first) so earlier spans never shift,
and overlapping edits are dropped deterministically.  ``--fix`` re-lints
after writing; the round trip is a fixpoint (tested on seeded mutants):
applying fixes twice changes nothing the second time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.analysis.core import Finding, TextEdit


@dataclasses.dataclass
class FixResult:
    """What ``apply_fixes`` did to one file."""

    path: str
    applied: int = 0
    scaffolded: int = 0
    skipped: int = 0  # findings with no edit (and scaffolding off)


def _span_key(e: TextEdit) -> Tuple[int, int]:
    return (e.line, e.col)


def _apply_edit(lines: List[str], e: TextEdit) -> bool:
    """Splice one edit into the line list (1-based lines, 0-based cols)."""
    if not (1 <= e.line <= len(lines) and 1 <= e.end_line <= len(lines)):
        return False
    first = lines[e.line - 1]
    last = lines[e.end_line - 1]
    if e.col > len(first) or e.end_col > len(last):
        return False
    patched = first[: e.col] + e.replacement + last[e.end_col:]
    lines[e.line - 1: e.end_line] = patched.split("\n")
    return True


def _scaffold_comment(f: Finding, indent: str) -> str:
    return (
        f"{indent}# repro-lint: disable={f.rule} -- TODO justify: "
        f"{f.message}"
    )


def apply_fixes(
    path: str,
    findings: Sequence[Finding],
    *,
    scaffold: bool = False,
) -> FixResult:
    """Apply every finding's edit for one file; optionally scaffold
    suppressions for the rest.  Returns counts; writes only on change."""
    result = FixResult(path=path)
    mine = [f for f in findings if f.path == path]
    if not mine:
        return result
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    lines = source.split("\n")

    # 1) real edits, bottom-up, overlap-free
    edits: List[TextEdit] = []
    taken: List[Tuple[int, int, int, int]] = []
    for f in sorted(
        (f for f in mine if f.fix is not None),
        key=lambda f: _span_key(f.fix),  # type: ignore[arg-type]
        reverse=True,
    ):
        e = f.fix
        assert e is not None
        span = (e.line, e.col, e.end_line, e.end_col)
        if any(
            not (span[2:] <= t[:2] or t[2:] <= span[:2]) for t in taken
        ):
            result.skipped += 1
            continue
        taken.append(span)
        edits.append(e)
    for e in edits:  # already sorted descending: later spans first
        if _apply_edit(lines, e):
            result.applied += 1
        else:
            result.skipped += 1

    # 2) suppression scaffolds for findings with no mechanical edit —
    # grouped per line, inserted bottom-up so linenos stay valid
    if scaffold:
        by_line: Dict[int, List[Finding]] = {}
        for f in mine:
            if f.fix is None and 1 <= f.line <= len(lines):
                by_line.setdefault(f.line, []).append(f)
        for line in sorted(by_line, reverse=True):
            target = lines[line - 1]
            indent = target[: len(target) - len(target.lstrip())]
            seen: set = set()
            for f in by_line[line]:
                if f.rule in seen:
                    continue
                seen.add(f.rule)
                lines.insert(line - 1, _scaffold_comment(f, indent))
                result.scaffolded += 1
    else:
        result.skipped += sum(1 for f in mine if f.fix is None)

    patched = "\n".join(lines)
    if patched != source and (result.applied or result.scaffolded):
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(patched)
    return result


def apply_all(findings: Sequence[Finding], *,
              scaffold: bool = False) -> List[FixResult]:
    """Group findings by file and fix each; deterministic path order."""
    paths = sorted({f.path for f in findings})
    return [apply_fixes(p, findings, scaffold=scaffold) for p in paths]
