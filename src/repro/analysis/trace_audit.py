"""Dynamic twin of the static pass: count jit compilations per callsite.

The engines' jit discipline is "one executable per (cohort size, weighted)
key": the dropout participation path pads every cohort to the population
size with zero-weight filler clients precisely so a whole run reuses ONE
compiled step.  A static rule can't see retracing — this context manager
can.  It monkeypatches ``jax.jit`` so every function jitted *while the
audit is active* records, per **callsite** (the ``jax.jit(...)`` source
location plus the wrapped function's identity), how many distinct traces
JAX performed.  Counting per callsite rather than per jitted object is
what makes the padding bug visible: a broken padding path builds one
executable per cohort size, each traced once, all charged to the same
``jax.jit(raw, ...)`` line in ``FederatedEngine._step_for``.

Usage::

    with trace_audit() as audit:
        engine.train(batcher, rounds)
    audit.assert_within_limit()        # ≤1 trace per callsite by default

or via the ``jit_trace_audit`` pytest fixture (tests/conftest.py), which
fails the test on exit if any callsite retraced.

This works because the engines look ``jax.jit`` up at call time
(``jax.jit(raw, donate_argnums=...)`` inside ``_step_for``), so patching
the attribute on the ``jax`` module intercepts them without any import
gymnastics.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Dict, Iterator, List, Tuple

import jax

#: (filename, firstlineno, qualname) of the function handed to jax.jit
Site = Tuple[str, int, str]


@dataclasses.dataclass
class TraceAudit:
    """Mutable audit record: trace counts per jit callsite."""

    limit: int = 1
    counts: Dict[Site, int] = dataclasses.field(default_factory=dict)

    def record(self, site: Site) -> None:
        self.counts[site] = self.counts.get(site, 0) + 1

    def total(self) -> int:
        return sum(self.counts.values())

    def violations(self) -> List[Tuple[Site, int]]:
        """Callsites that compiled more often than ``limit``."""
        return sorted(
            (s, n) for s, n in self.counts.items() if n > self.limit
        )

    def publish(self, hub=None) -> None:
        """Republish the per-callsite trace counts as ``jit.traces``
        telemetry counters (default: the session hub from
        :func:`repro.telemetry.get_hub`) — recompiles show up next to the
        round spans they stalled."""
        if hub is None:
            from repro.telemetry import get_hub

            hub = get_hub()
        for (fn, ln, qn), n in sorted(self.counts.items()):
            hub.counter("jit.traces", float(n), site=f"{fn}:{ln}", fn=qn)

    def assert_within_limit(self) -> None:
        bad = self.violations()
        if bad:
            lines = "\n".join(
                f"  {fn}:{ln} ({qn}): {n} traces (limit {self.limit})"
                for (fn, ln, qn), n in bad
            )
            raise AssertionError(
                "jit retrace audit failed — the engine recompiled where it "
                f"should reuse one executable:\n{lines}\n"
                "(dropout cohorts must be padded to a fixed size with "
                "zero-weight clients; see ROADMAP 'jit discipline')"
            )


def _site_of(fun) -> Site:
    code = getattr(fun, "__code__", None)
    if code is None:  # partial / callable object: fall back to repr
        inner = getattr(fun, "func", None)
        code = getattr(inner, "__code__", None)
    if code is None:
        return ("<unknown>", 0, getattr(fun, "__qualname__", repr(fun)))
    return (
        code.co_filename,
        code.co_firstlineno,
        getattr(fun, "__qualname__", code.co_name),
    )


@contextlib.contextmanager
def trace_audit(limit: int = 1) -> Iterator[TraceAudit]:
    """Patch ``jax.jit`` to count traces per callsite while active."""
    audit = TraceAudit(limit=limit)
    real_jit = jax.jit

    def auditing_jit(fun=None, **jit_kwargs):
        if fun is None:  # decorator-with-arguments form: @jax.jit(static_...)
            return lambda f: auditing_jit(f, **jit_kwargs)
        site = _site_of(fun)

        @functools.wraps(fun)
        def counted(*args, **kwargs):
            audit.record(site)
            return fun(*args, **kwargs)

        return real_jit(counted, **jit_kwargs)

    jax.jit = auditing_jit
    try:
        yield audit
    finally:
        jax.jit = real_jit
