"""repro-lint: invariant static analysis + jit trace auditing.

``python -m repro.analysis src/`` (or the ``repro-lint`` console script)
runs the RPL rule catalog; :func:`trace_audit` is the dynamic twin that
counts jit compilations per callsite.  See the README "Invariant checks"
section for the rule ↔ invariant map and the suppression grammar.

The dataflow tier lives in submodules: :mod:`repro.analysis.cfg` builds
intraprocedural control-flow graphs, :mod:`repro.analysis.dataflow` runs
forward fixpoints over them, :mod:`repro.analysis.taint` is the
factor-mask taint lattice behind RPL005, and :mod:`repro.analysis.shapes`
is the abstract shape/dtype interpreter behind RPL009.  SARIF emission /
baseline diffing (:mod:`repro.analysis.sarif`) and autofix application
(:mod:`repro.analysis.fixes`) back the ``--format sarif`` / ``--baseline``
/ ``--fix`` CLI flags.
"""
from repro.analysis.core import (
    Finding,
    Rule,
    TextEdit,
    get_rules,
    lint_paths,
    register_rule,
)
from repro.analysis.trace_audit import TraceAudit, trace_audit

__all__ = [
    "Finding",
    "Rule",
    "TextEdit",
    "TraceAudit",
    "get_rules",
    "lint_paths",
    "register_rule",
    "trace_audit",
]
