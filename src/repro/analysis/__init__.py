"""repro-lint: invariant static analysis + jit trace auditing.

``python -m repro.analysis src/`` (or the ``repro-lint`` console script)
runs the RPL rule catalog; :func:`trace_audit` is the dynamic twin that
counts jit compilations per callsite.  See the README "Invariant checks"
section for the rule ↔ invariant map and the suppression grammar.
"""
from repro.analysis.core import (
    Finding,
    Rule,
    get_rules,
    lint_paths,
    register_rule,
)
from repro.analysis.trace_audit import TraceAudit, trace_audit

__all__ = [
    "Finding",
    "Rule",
    "TraceAudit",
    "get_rules",
    "lint_paths",
    "register_rule",
    "trace_audit",
]
