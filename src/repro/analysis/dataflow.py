"""Generic forward abstract interpretation over :mod:`repro.analysis.cfg`.

A worklist fixpoint for *join-semilattice* domains: an analysis supplies
the entry state, a monotone per-statement transfer function and a join,
and gets back the abstract state at the head of every block (and, via
:func:`walk_states`, before every statement).  RPL005's factor-mask taint
domain and RPL004's traced-value purity domain both run on this engine —
the path sensitivity the lexical PR 7 rules lacked ("mask applied on only
one branch") falls out of the join.

Termination: states must form a finite-height lattice (both shipped
domains map variables into small enums, so height ≤ |vars| × |enum|).
A hard iteration cap guards against a buggy non-monotone transfer —
exceeding it raises :class:`FixpointDiverged` rather than hanging the
linter.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

from repro.analysis.cfg import CFG, Block


class FixpointDiverged(RuntimeError):
    """The worklist did not stabilize within the iteration budget."""


class ForwardAnalysis:
    """Interface a dataflow domain implements.  States are treated as
    immutable values: ``transfer`` and ``join`` return fresh states."""

    def initial(self):
        """State on entry to the CFG."""
        raise NotImplementedError

    def transfer(self, state, stmt):
        """State after executing ``stmt`` (an ast.stmt / BranchTest /
        LoopBind) in ``state``."""
        raise NotImplementedError

    def join(self, a, b):
        """Least upper bound of two states."""
        raise NotImplementedError

    def equals(self, a, b) -> bool:
        return a == b


def _block_out(analysis: ForwardAnalysis, state, block: Block):
    for s in block.stmts:
        state = analysis.transfer(state, s)
    return state


def run_forward(
    cfg: CFG,
    analysis: ForwardAnalysis,
    *,
    max_passes: int = 64,
) -> Dict[int, object]:
    """Fixpoint in-states: ``block.id -> state`` at the block's head.

    Only reachable blocks appear.  ``max_passes`` bounds how many times
    any single block may be reprocessed (loops converge in O(lattice
    height); 64 is far beyond any real function here).
    """
    reachable = cfg.reachable()
    in_states: Dict[int, object] = {cfg.entry.id: analysis.initial()}
    visits: Dict[int, int] = {}
    work = [cfg.entry]
    while work:
        block = work.pop(0)
        visits[block.id] = visits.get(block.id, 0) + 1
        if visits[block.id] > max_passes:
            raise FixpointDiverged(
                f"block {block.id} ({block.label!r}) reprocessed more than "
                f"{max_passes} times — non-monotone transfer?"
            )
        out = _block_out(analysis, in_states[block.id], block)
        for succ in block.succs:
            old = in_states.get(succ.id)
            new = out if old is None else analysis.join(old, out)
            if old is None or not analysis.equals(old, new):
                in_states[succ.id] = new
                if succ not in work:
                    work.append(succ)
    return {b.id: s for b, s in ((b, in_states.get(b.id)) for b in reachable)
            if s is not None}


def walk_states(
    cfg: CFG,
    analysis: ForwardAnalysis,
    in_states: Optional[Dict[int, object]] = None,
) -> Iterator[Tuple[object, object]]:
    """Yield ``(stmt, state_before_stmt)`` over every reachable statement.

    Runs (or reuses) the fixpoint, then replays each block's transfer
    chain — the per-statement view sink checks consume.
    """
    if in_states is None:
        in_states = run_forward(cfg, analysis)
    for block in cfg.reachable():
        state = in_states.get(block.id)
        if state is None:
            continue
        for s in block.stmts:
            yield s, state
            state = analysis.transfer(state, s)
