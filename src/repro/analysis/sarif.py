"""SARIF 2.1.0 emission and baseline diffing for repro-lint.

``repro-lint --format sarif`` serializes findings as a SARIF log, the
interchange format CI systems ingest natively.  The committed
``analysis-baseline.sarif`` is the grandfather file: ``--baseline``
subtracts its fingerprints from the current run, so the ``invariants``
CI job fails on **new** findings only while tracked legacy ones age out
visibly instead of blocking every PR.

Fingerprints must survive unrelated edits: they hash the rule id, the
repo-relative path, the *text* of the flagged line (whitespace-stripped),
and the occurrence index of that (rule, line-text) pair within the file —
stable under line drift and reordering, invalidated exactly when the
flagged code itself changes.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Finding

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = (
    "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/"
    "Schemata/sarif-schema-2.1.0.json"
)
TOOL_NAME = "repro-lint"

#: SARIF `level` per repro-lint severity
_LEVELS = {"error": "error", "warning": "warning"}


def _rel(path: str, root: Optional[str]) -> str:
    """Repo-relative posix path (fingerprints and SARIF URIs must not
    depend on the checkout location)."""
    p = os.path.abspath(path)
    if root:
        try:
            p = os.path.relpath(p, os.path.abspath(root))
        except ValueError:  # different drive (windows)
            pass
    return p.replace(os.sep, "/")


def _line_text(path: str, line: int, cache: Dict[str, List[str]]) -> str:
    if path not in cache:
        try:
            with open(path, encoding="utf-8") as fh:
                cache[path] = fh.read().splitlines()
        except OSError:
            cache[path] = []
    lines = cache[path]
    if 1 <= line <= len(lines):
        return lines[line - 1].strip()
    return ""


def fingerprints(findings: Sequence[Finding],
                 root: Optional[str] = None) -> List[str]:
    """One stable fingerprint per finding (order-aligned with input).

    sha256 over (rule, relative path, stripped flagged-line text,
    occurrence index of that triple within the file) — two identical
    violations on identical lines get distinct indices, and moving a
    flagged line does not change its print.
    """
    cache: Dict[str, List[str]] = {}
    counts: Dict[Tuple[str, str, str], int] = {}
    prints: List[str] = []
    for f in findings:
        rel = _rel(f.path, root)
        text = _line_text(f.path, f.line, cache)
        key = (f.rule, rel, text)
        idx = counts.get(key, 0)
        counts[key] = idx + 1
        h = hashlib.sha256(
            "\x1f".join((f.rule, rel, text, str(idx))).encode("utf-8")
        ).hexdigest()
        prints.append(h)
    return prints


def to_sarif(findings: Sequence[Finding],
             root: Optional[str] = None) -> dict:
    """A SARIF 2.1.0 log dict for one repro-lint run."""
    from repro.analysis.core import get_rules

    rules_meta = []
    for rule in get_rules():
        desc = (rule.__doc__ or rule.title).strip().splitlines()[0]
        rules_meta.append({
            "id": rule.id,
            "name": type(rule).__name__,
            "shortDescription": {"text": rule.title or rule.id},
            "fullDescription": {"text": desc},
            "help": {"text": rule.hint or ""},
            "defaultConfiguration": {
                "level": _LEVELS.get(rule.severity, "error"),
            },
        })

    prints = fingerprints(findings, root)
    results = []
    for f, fp in zip(findings, prints):
        results.append({
            "ruleId": f.rule,
            "level": _LEVELS.get(f.severity, "error"),
            "message": {"text": f.message},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {"uri": _rel(f.path, root)},
                    "region": {
                        "startLine": max(1, f.line),
                        "startColumn": f.col + 1,
                    },
                },
            }],
            "fingerprints": {"reproLint/v1": fp},
        })

    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {
                "driver": {
                    "name": TOOL_NAME,
                    "rules": rules_meta,
                },
            },
            "results": results,
        }],
    }


def dump_sarif(findings: Sequence[Finding],
               root: Optional[str] = None) -> str:
    return json.dumps(to_sarif(findings, root), indent=2, sort_keys=True)


def load_baseline(path: str) -> Set[str]:
    """Fingerprint set from a committed SARIF baseline file."""
    with open(path, encoding="utf-8") as fh:
        log = json.load(fh)
    prints: Set[str] = set()
    for run in log.get("runs", []):
        for res in run.get("results", []):
            fp = res.get("fingerprints", {}).get("reproLint/v1")
            if fp:
                prints.add(fp)
    return prints


def diff_baseline(findings: Sequence[Finding], baseline: Iterable[str],
                  root: Optional[str] = None
                  ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (new, grandfathered) against baseline prints."""
    known = set(baseline)
    prints = fingerprints(findings, root)
    new: List[Finding] = []
    old: List[Finding] = []
    for f, fp in zip(findings, prints):
        (old if fp in known else new).append(f)
    return new, old
