"""CLI for repro-lint: ``python -m repro.analysis [paths...]``.

Exit status 0 when the tree is clean, 1 when any finding survives
suppression, 2 on usage errors — so CI and pre-commit can gate on it.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis.core import get_rules, lint_paths


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="invariant analyzer for the FeDLRT reproduction",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src"],
        help="files or directories to lint (default: src)",
    )
    ap.add_argument(
        "--select", metavar="IDS",
        help="comma-separated rule ids to run (default: all)",
    )
    ap.add_argument(
        "--ignore", metavar="IDS",
        help="comma-separated rule ids to skip",
    )
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalog and exit",
    )
    ap.add_argument(
        "--no-hints", action="store_true",
        help="omit the autofix hints from output",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in get_rules():
            print(f"{rule.id}  [{rule.severity}]  {rule.title}")
            if rule.hint:
                print(f"        fix: {rule.hint}")
        return 0

    select = args.select.split(",") if args.select else None
    ignore = args.ignore.split(",") if args.ignore else None
    try:
        findings = lint_paths(args.paths, select=select, ignore=ignore)
    except ValueError as e:
        print(f"repro-lint: {e}", file=sys.stderr)
        return 2

    for f in findings:
        print(f.render(show_hint=not args.no_hints))
    if findings:
        print(
            f"repro-lint: {len(findings)} finding(s) "
            f"in {len({f.path for f in findings})} file(s)",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
