"""CLI for repro-lint: ``python -m repro.analysis [paths...]``.

Exit status 0 when the tree is clean (or every finding is grandfathered
by ``--baseline``), 1 when any gating finding survives suppression, 2 on
usage errors — so CI and pre-commit can gate on it.

- ``--format sarif`` emits a SARIF 2.1.0 log (``--output`` to a file);
- ``--baseline analysis-baseline.sarif`` subtracts known fingerprints:
  only *new* findings gate, grandfathered ones are reported as such;
- ``--fix`` applies mechanical autofixes in place and re-lints (the
  exit code reflects the post-fix tree); ``--scaffold`` additionally
  inserts TODO-suppression comments for findings with no mechanical fix.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.analysis.core import get_rules, lint_paths


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="invariant analyzer for the FeDLRT reproduction",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src"],
        help="files or directories to lint (default: src)",
    )
    ap.add_argument(
        "--select", metavar="IDS",
        help="comma-separated rule ids to run (default: all)",
    )
    ap.add_argument(
        "--ignore", metavar="IDS",
        help="comma-separated rule ids to skip",
    )
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalog and exit",
    )
    ap.add_argument(
        "--no-hints", action="store_true",
        help="omit the autofix hints from output",
    )
    ap.add_argument(
        "--format", choices=("text", "sarif"), default="text",
        help="output format (sarif = SARIF 2.1.0)",
    )
    ap.add_argument(
        "--output", metavar="FILE",
        help="write the report to FILE instead of stdout",
    )
    ap.add_argument(
        "--baseline", metavar="SARIF",
        help="SARIF baseline: findings whose fingerprint it contains are "
             "grandfathered and do not gate",
    )
    ap.add_argument(
        "--fix", action="store_true",
        help="apply mechanical autofixes in place, then re-lint",
    )
    ap.add_argument(
        "--scaffold", action="store_true",
        help="with --fix: insert TODO-suppression scaffolds for findings "
             "that have no mechanical fix",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in get_rules():
            print(f"{rule.id}  [{rule.severity}]  {rule.title}")
            if rule.hint:
                print(f"        fix: {rule.hint}")
        return 0
    if args.scaffold and not args.fix:
        print("repro-lint: --scaffold requires --fix", file=sys.stderr)
        return 2

    select = args.select.split(",") if args.select else None
    ignore = args.ignore.split(",") if args.ignore else None
    try:
        findings = lint_paths(args.paths, select=select, ignore=ignore)
    except ValueError as e:
        print(f"repro-lint: {e}", file=sys.stderr)
        return 2

    if args.fix and findings:
        from repro.analysis.fixes import apply_all

        results = apply_all(findings, scaffold=args.scaffold)
        applied = sum(r.applied for r in results)
        scaffolded = sum(r.scaffolded for r in results)
        print(
            f"repro-lint: applied {applied} fix(es), "
            f"scaffolded {scaffolded} suppression(s)",
            file=sys.stderr,
        )
        findings = lint_paths(args.paths, select=select, ignore=ignore)

    root = os.getcwd()
    gating = findings
    grandfathered = []
    if args.baseline:
        from repro.analysis.sarif import diff_baseline, load_baseline

        try:
            known = load_baseline(args.baseline)
        except (OSError, ValueError) as e:
            print(f"repro-lint: cannot read baseline: {e}", file=sys.stderr)
            return 2
        gating, grandfathered = diff_baseline(findings, known, root)

    if args.format == "sarif":
        from repro.analysis.sarif import dump_sarif

        report = dump_sarif(findings, root)
    else:
        shown = gating if args.baseline else findings
        lines = [f.render(show_hint=not args.no_hints) for f in shown]
        report = "\n".join(lines)

    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            fh.write(report + "\n")
    elif report:
        print(report)

    if grandfathered:
        print(
            f"repro-lint: {len(grandfathered)} grandfathered finding(s) "
            "tracked in the baseline",
            file=sys.stderr,
        )
    if gating:
        print(
            f"repro-lint: {len(gating)} finding(s) "
            f"in {len({f.path for f in gating})} file(s)"
            + (" beyond the baseline" if args.baseline else ""),
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
