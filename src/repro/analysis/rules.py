"""The repro-lint rule catalog: RPL001–RPL009.

Each rule guards one invariant from the ROADMAP architecture map.  The
docstring of every rule states the invariant, why it matters for the
FeDLRT reproduction specifically, and what the sanctioned alternative is
(which doubles as the autofix hint).

The semantic rules run on the dataflow engine (:mod:`repro.analysis.cfg`
+ :mod:`repro.analysis.dataflow`): RPL005 is a path-sensitive taint
analysis over the factor-mask lattice, RPL004 propagates traced-ness
through derived variables, and RPL009 delegates to the static shape
interpreter in :mod:`repro.analysis.shapes`.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.cfg import ATOMIC_DEFS, BranchTest, LoopBind, build_cfg
from repro.analysis.core import (
    Finding,
    ModuleInfo,
    PathInfo,
    Rule,
    TextEdit,
    base_chain_attrs,
    call_name,
    is_simple_expr,
    register_rule,
    scope_references,
    walk_with_scope,
)
from repro.analysis.dataflow import (
    FixpointDiverged,
    ForwardAnalysis,
    walk_states,
)
from repro.analysis.taint import (
    FRESH,
    MASKED,
    FactorTaint,
    MASK,
    nonarray_functions,
)

# ---------------------------------------------------------------------------
# RPL001 — engines are built in exactly one place
# ---------------------------------------------------------------------------

#: engine constructors / factories with one sanctioned construction site
ENGINE_NAMES = {
    "FederatedEngine",
    "SyncSimEngine",
    "AsyncFederatedEngine",
    "HierarchicalEngine",
    "make_sim_engine",
    "ServeEngine",
    "ContinuousScheduler",
}

#: files allowed to construct engines: the build()/serve() seams and the
#: engine modules themselves (internal composition, e.g. hier wraps sync)
ENGINE_HOMES = (
    ("api", "experiment.py"),
    ("fed", "engine.py"),
    ("fed", "sim", "engines.py"),
    ("serve",),
)


@register_rule
class NoAdHocEngines(Rule):
    """No engine construction outside ``api.experiment.build()``.

    PR 5 made ``build(spec)`` the single engine factory so that cohort
    policy, wire codecs, checkpoint stamping and weighting can never be
    silently dropped by a hand-rolled engine.  Constructing an engine
    anywhere else reopens exactly that hole.  The serving stack
    (``ServeEngine`` / ``ContinuousScheduler``) follows the same rule
    with ``api.experiment.serve()`` as its seam.
    """

    id = "RPL001"
    title = "engine constructed outside api.experiment.build()/serve()"
    severity = "error"
    hint = (
        "describe the scenario as an ExperimentSpec and call "
        "repro.api.build(spec) / repro.api.serve(spec)"
    )

    def applies_to(self, info: PathInfo) -> bool:
        if info.is_tests:
            return False  # tests may construct engines to probe internals
        return not any(info.under(*home) for home in ENGINE_HOMES)

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = call_name(node)
                leaf = name.rsplit(".", 1)[-1]
                if leaf in ENGINE_NAMES:
                    yield self.finding(
                        mod, node,
                        f"`{leaf}(...)` called outside the build() seam",
                    )


# ---------------------------------------------------------------------------
# RPL002 — entry points speak ExperimentSpec, not core primitives
# ---------------------------------------------------------------------------

#: core-layer constructors an entry point must not assemble by hand —
#: each has an ExperimentSpec field / registry that replaces it
SCENARIO_PRIMITIVES = {
    "FedConfig": "FedSpec fields (lr/local_steps/tau/...)",
    "Participation": "ParticipationSpec / participation string",
    "Wire": "WireSpec.codec",
    "make_codec": "WireSpec.codec",
}


@register_rule
class NoAdHocScenarios(Rule):
    """Entry points (``launch/``, ``examples/``, ``benchmarks/``) must route
    scenario axes through :class:`ExperimentSpec` fields and registries,
    never hand-assemble core config objects.

    A scenario that exists only as an ad-hoc ``FedConfig(...)`` in a CLI
    can't be hashed, stamped into checkpoints, or replayed from a JSON
    spec — it silently forks the experiment-description surface PR 5
    unified.
    """

    id = "RPL002"
    title = "ad-hoc scenario construction in an entry point"
    severity = "error"
    hint = "add/use the ExperimentSpec field and let build() resolve it"

    def applies_to(self, info: PathInfo) -> bool:
        return info.is_entry_point and not info.is_tests

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                leaf = call_name(node).rsplit(".", 1)[-1]
                if leaf in SCENARIO_PRIMITIVES:
                    yield self.finding(
                        mod, node,
                        f"`{leaf}(...)` assembled in an entry point",
                        hint=f"route through {SCENARIO_PRIMITIVES[leaf]}",
                    )


# ---------------------------------------------------------------------------
# RPL003 — library code is deterministic
# ---------------------------------------------------------------------------

#: wall-clock and global-state RNG calls that make a run irreproducible
NONDET_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
}
NONDET_NP_RANDOM = {
    "rand", "randn", "randint", "random", "choice", "permutation",
    "shuffle", "normal", "uniform", "seed",
}


@register_rule
class NoNondeterminism(Rule):
    """No nondeterminism in library code.

    Same spec + same seed must be the same run bit-for-bit on one host:
    that is what makes the convergence plots reproducible and the
    checkpoint spec-hash meaningful.  Wall-clock reads, ``random.*``,
    legacy global-state ``np.random.*``, seedless ``default_rng()`` and
    iteration over unordered containers all break that.  Library code
    reads the wall clock only through the sanctioned
    :mod:`repro.telemetry.clock` shim (the one file exempt here — the
    rule is the enforcement half of that contract); randomness comes from
    a seeded generator or a threaded PRNG key.
    """

    id = "RPL003"
    title = "nondeterminism in library code"
    severity = "error"
    hint = (
        "thread a seeded np.random.default_rng(seed) / jax PRNG key; for "
        "timing use repro.telemetry.clock.perf_seconds()"
    )

    def applies_to(self, info: PathInfo) -> bool:
        if info.is_tests or info.is_benchmarks or info.is_examples:
            return False
        if not info.repro:
            return False
        # the one sanctioned wall-clock seam: every other module times
        # through repro.telemetry.clock, so the exemption stays this narrow
        if info.under("telemetry", "clock.py"):
            return False
        return not info.under("launch")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        # calls appearing directly as sorted(...)'s argument are order-safe
        # (this is also what --fix produces, so the repair must lint clean)
        self._sorted_args = {
            id(arg)
            for node in ast.walk(mod.tree)
            if isinstance(node, ast.Call) and call_name(node) == "sorted"
            for arg in node.args
        }
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(mod, node)
            elif isinstance(node, ast.For):
                yield from self._check_loop(mod, node)

    def _check_call(self, mod: ModuleInfo, node: ast.Call) -> Iterator[Finding]:
        name = call_name(node)
        if name in NONDET_CALLS:
            yield self.finding(mod, node, f"wall-clock read `{name}()`")
            return
        parts = name.split(".")
        if parts[0] == "random" and len(parts) == 2:
            yield self.finding(
                mod, node, f"global-state stdlib RNG `{name}()`"
            )
            return
        if (
            len(parts) >= 3
            and parts[-2] == "random"
            and parts[-1] in NONDET_NP_RANDOM
            # np.random.randn / numpy.random.seed; jax.random is excluded
            # (jax.random.<fn> always takes an explicit key)
            and parts[0] in ("np", "numpy")
        ):
            yield self.finding(
                mod, node, f"legacy global-state `{name}()`"
            )
            return
        if parts[-1] == "default_rng" and not node.args and not node.keywords:
            yield self.finding(
                mod, node, "`default_rng()` without a seed is OS-entropy seeded"
            )
        if parts[-1] == "listdir":
            if id(node) in self._sorted_args:
                return
            fix = None
            src = ast.get_source_segment(mod.source, node)
            if src is not None and hasattr(node, "end_lineno"):
                fix = TextEdit(node.lineno, node.col_offset,
                               node.end_lineno, node.end_col_offset,
                               f"sorted({src})")
            yield self.finding(
                mod, node,
                "`os.listdir()` order is filesystem-dependent",
                hint="wrap in sorted(...)",
                fix=fix,
            )

    def _check_loop(self, mod: ModuleInfo, node: ast.For) -> Iterator[Finding]:
        it = node.iter
        if isinstance(it, ast.Set) or (
            isinstance(it, ast.Call) and call_name(it) == "set"
        ):
            yield self.finding(
                mod, node,
                "iterating a set: order varies across processes "
                "(PYTHONHASHSEED)",
                hint="iterate sorted(...) or keep an ordered container",
            )


# ---------------------------------------------------------------------------
# RPL004 — jit discipline in traced modules
# ---------------------------------------------------------------------------

#: modules whose functions run under jit tracing (pure-jax land)
TRACED_MODULES = (("core",), ("kernels",))


def _jitted_defs(tree: ast.AST) -> Set[str]:
    """Names of functions that are jit-decorated or passed to jax.jit
    within this module (a static under-approximation of 'traced')."""
    jitted: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dec.func if isinstance(dec, ast.Call) else dec
                name = ""
                if isinstance(d, (ast.Name, ast.Attribute)):
                    name = call_name(ast.Call(func=d, args=[], keywords=[]))
                if name.endswith("jit") or name.endswith("custom_vjp"):
                    jitted.add(node.name)
        elif (
            isinstance(node, ast.Call)
            and call_name(node).endswith("jit")
            and node.args
            and isinstance(node.args[0], ast.Name)
        ):
            jitted.add(node.args[0].id)
    return jitted


def _target_names(target: ast.AST, out: Set[str]) -> None:
    if isinstance(target, ast.Name):
        out.add(target.id)
    elif isinstance(target, (ast.Tuple, ast.List)):
        for t in target.elts:
            _target_names(t, out)
    elif isinstance(target, ast.Starred):
        _target_names(target.value, out)


def _refs_any(expr: ast.AST, names) -> bool:
    return any(
        isinstance(n, ast.Name) and n.id in names for n in ast.walk(expr)
    )


class _TracedVars(ForwardAnalysis):
    """Which locals (transitively) derive from a jitted function's
    parameters — i.e. are tracers.  State: a frozenset of names; join is
    union (traced on *any* incoming path is traced)."""

    def __init__(self, params):
        self.params = frozenset(params)

    def initial(self):
        return self.params

    def join(self, a, b):
        return a | b

    def transfer(self, state, stmt):
        if isinstance(stmt, ast.Assign):
            names: Set[str] = set()
            for t in stmt.targets:
                _target_names(t, names)
            return (state | names) if _refs_any(stmt.value, state) \
                else (state - names)
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            names = set()
            _target_names(stmt.target, names)
            return (state | names) if _refs_any(stmt.value, state) \
                else (state - names)
        if isinstance(stmt, ast.AugAssign):
            names = set()
            _target_names(stmt.target, names)
            if names & state or _refs_any(stmt.value, state):
                return state | names
            return state
        if isinstance(stmt, LoopBind):
            names = set()
            _target_names(stmt.target, names)
            return (state | names) if _refs_any(stmt.iter, state) \
                else (state - names)
        return state


@register_rule
class JitDiscipline(Rule):
    """Traced code must stay traceable: no host ``numpy`` inside traced
    functions, no Python-side branching or side effects on traced values.

    ``if x:`` or ``float(x)`` on a tracer raises ``ConcretizationError``
    at best — or silently freezes a data-dependent decision at trace time
    at worst, which is how the adaptive-rank logic would quietly become a
    constant.  Traced-ness propagates through assignments via dataflow
    (``y = x * 2; if y:`` is the same bug as ``if x:``), and the CFG walk
    sees ``while`` tests and branch-only paths too.  ``core/`` and
    ``kernels/`` are all-traced by contract, so a module-level
    ``import numpy`` there is flagged as well.
    """

    id = "RPL004"
    title = "jit-discipline violation in traced code"
    severity = "error"
    hint = (
        "use jnp/lax primitives (jnp.where, lax.cond) and keep host-side "
        "numpy out of traced modules"
    )

    def applies_to(self, info: PathInfo) -> bool:
        if info.is_tests:
            return False
        return bool(info.repro)

    def _in_traced_module(self, info: PathInfo) -> bool:
        return any(info.under(*m) for m in TRACED_MODULES)

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        traced_module = self._in_traced_module(mod.info)
        jitted = _jitted_defs(mod.tree)

        if traced_module:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        if alias.name == "numpy" or alias.name.startswith("numpy."):
                            yield self.finding(
                                mod, node,
                                "host `numpy` imported in a traced module",
                            )
                elif isinstance(node, ast.ImportFrom) and node.module and (
                    node.module == "numpy" or node.module.startswith("numpy.")
                ):
                    yield self.finding(
                        mod, node,
                        "host `numpy` imported in a traced module",
                    )

        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name not in jitted:
                continue
            params = {
                a.arg
                for a in (
                    fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
                )
            }
            # lexical pass: host numpy anywhere inside the jitted def
            # (including nested defs/lambdas, which trace with it)
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    name = call_name(node)
                    if name.split(".")[0] in ("np", "numpy"):
                        yield self.finding(
                            mod, node,
                            f"host call `{name}()` inside jitted "
                            f"`{fn.name}` will run at trace time",
                        )
                    elif name == "print":
                        yield self.finding(
                            mod, node,
                            f"`print()` inside jitted `{fn.name}` runs at "
                            "trace time only",
                            hint="use jax.debug.print for runtime output",
                        )
                elif isinstance(node, ast.Global):
                    yield self.finding(
                        mod, node,
                        f"`global` inside jitted `{fn.name}`: mutation is a "
                        "trace-time side effect",
                    )
            # dataflow pass: traced-value propagation through assignments,
            # then concretization/branching sinks per CFG statement
            yield from self._traced_sinks(mod, fn, params)

    def _traced_sinks(self, mod: ModuleInfo, fn, params) -> Iterator[Finding]:
        analysis = _TracedVars(params)
        try:
            pairs = list(walk_states(build_cfg(fn), analysis))
        except (FixpointDiverged, RecursionError):
            yield self.finding(
                mod, fn,
                f"dataflow did not converge analyzing `{fn.name}`",
                severity="warning",
            )
            return
        for stmt, state in pairs:
            if isinstance(stmt, BranchTest):
                t = stmt.node
                if (
                    isinstance(t, ast.Name)
                    and t.id in state
                    and isinstance(stmt.origin, (ast.If, ast.While))
                ):
                    kw = "if" if isinstance(stmt.origin, ast.If) else "while"
                    yield self.finding(
                        mod, stmt.origin,
                        f"Python `{kw} {t.id}:` on a traced value inside "
                        f"jitted `{fn.name}`",
                        hint="use jnp.where or lax.cond",
                    )
                continue
            if isinstance(stmt, (LoopBind,) + ATOMIC_DEFS):
                continue
            for node in ast.walk(stmt):
                if (
                    isinstance(node, ast.Call)
                    and call_name(node) in ("float", "int", "bool")
                    and node.args
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in state
                ):
                    yield self.finding(
                        mod, node,
                        f"`{call_name(node)}()` on traced value "
                        f"`{node.args[0].id}` concretizes the tracer",
                    )


# ---------------------------------------------------------------------------
# RPL005 — factor-layout writes re-mask inactive columns
# ---------------------------------------------------------------------------

FACTOR_NAMES = {"LowRankFactor", "AugmentedFactor"}
MASK_NAMES = {
    "rank_mask", "augmented_mask", "mask_coeff", "coeff_grad_mask",
    "init_factor", "check_invariants",
}
FACTOR_LEAVES = {"U", "S", "V"}


class LegacyFactorLayoutWrites(Rule):
    """PR 7's *lexical* RPL005: flags a factor write only when no mask
    name appears anywhere in the enclosing function.

    Kept (unregistered) as the comparison baseline for the dataflow rule:
    it cannot see that a mask was applied on only one branch, applied to
    the wrong variable, or overwritten before the write —
    ``tests/test_analysis.py`` demonstrates the miss explicitly.
    """

    id = "RPL005"
    title = "factor buffer written without an inactive-column re-mask"
    severity = "error"
    hint = (
        "apply rank_mask/augmented_mask/mask_coeff (or build via "
        "init_factor) in the same function"
    )

    def applies_to(self, info: PathInfo) -> bool:
        if info.is_tests:
            return False
        return bool(info.repro)

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node, scope in walk_with_scope(mod.tree):
            if isinstance(node, ast.Call):
                leaf = call_name(node).rsplit(".", 1)[-1]
                if leaf in FACTOR_NAMES:
                    fresh = [
                        kw.arg
                        for kw in node.keywords
                        if kw.arg in FACTOR_LEAVES
                        and not is_simple_expr(kw.value)
                    ]
                    if fresh and not scope_references(scope, MASK_NAMES, mod):
                        yield self.finding(
                            mod, node,
                            f"`{leaf}` built from computed "
                            f"{'/'.join(fresh)} with no mask in scope",
                        )
            elif isinstance(node, ast.Attribute) and node.attr in ("set", "add"):
                # f.U.at[...].set(...) — the base object chain must name a
                # factor leaf AND .at; args of the call are not the base
                chain = base_chain_attrs(node.value)
                if (
                    "at" in chain
                    and chain & FACTOR_LEAVES
                    and not scope_references(scope, MASK_NAMES, mod)
                ):
                    yield self.finding(
                        mod, node,
                        "in-place update of a factor leaf with no "
                        "mask in scope",
                    )


@register_rule
class FactorLayoutWrites(Rule):
    """Writes into factor buffers must re-assert the zero-inactive-columns
    layout **on every control-flow path**.

    The whole fixed-width masked-rank design (fused Pallas kernels ≡
    masked reference, lossless ``topk_rank``, sound async Galerkin
    transport) rests on U/V columns and S rows/cols beyond ``rank`` being
    *exactly* zero.  This rule runs the factor-mask taint analysis
    (:mod:`repro.analysis.taint`) over each function's CFG: factor leaves
    and sanitizer outputs are MASKED, freshly computed tensors are FRESH,
    and a write sink (factor constructor kwarg, ``.at[...].set`` on a
    leaf, attribute store to ``.U/.S/.V``) fires when a FRESH value
    reaches it on *any* path — so masking only one branch, masking the
    wrong variable, or reassigning after the mask are all distinguishable
    from genuinely sanitized writes (which PR 7's lexical check was not).
    """

    id = "RPL005"
    title = "factor buffer written without an inactive-column re-mask"
    severity = "error"
    hint = (
        "apply rank_mask/augmented_mask/mask_coeff (or build via "
        "init_factor) on every path reaching the write"
    )

    def applies_to(self, info: PathInfo) -> bool:
        if info.is_tests:
            return False
        return bool(info.repro)

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        nonarray = nonarray_functions(mod.tree)
        scopes: List[Tuple[object, Tuple[str, ...]]] = [(mod.tree, ())]
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                a = node.args
                params = [
                    p.arg
                    for p in a.posonlyargs + a.args + a.kwonlyargs
                ]
                for extra in (a.vararg, a.kwarg):
                    if extra is not None:
                        params.append(extra.arg)
                scopes.append((node, tuple(params)))
        for scope_node, params in scopes:
            analysis = FactorTaint(params, nonarray)
            try:
                pairs = list(walk_states(build_cfg(scope_node), analysis))
            except (FixpointDiverged, RecursionError) as err:
                yield self.finding(
                    mod, scope_node,
                    f"factor-mask dataflow did not converge: {err}",
                    severity="warning",
                )
                continue
            for stmt, state in pairs:
                yield from self._sinks(mod, analysis, stmt, state)

    def _sinks(self, mod: ModuleInfo, analysis: FactorTaint, stmt,
               state) -> Iterator[Finding]:
        if isinstance(stmt, ATOMIC_DEFS):
            return  # nested defs are their own scope
        if isinstance(stmt, BranchTest):
            roots: List[ast.AST] = [stmt.node]
        elif isinstance(stmt, LoopBind):
            roots = [stmt.iter]
        else:
            roots = [stmt]
        # sink: direct attribute store into a factor leaf
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Attribute) and t.attr in FACTOR_LEAVES:
                    st, _leaf = analysis.eval(state, stmt.value)
                    if st == FRESH:
                        yield self.finding(
                            mod, stmt,
                            f"freshly computed value stored into factor "
                            f"leaf `.{t.attr}` with no dominating mask",
                        )
        for root in roots:
            for call in ast.walk(root):
                if not isinstance(call, ast.Call):
                    continue
                yield from self._call_sinks(mod, analysis, call, state)

    def _call_sinks(self, mod: ModuleInfo, analysis: FactorTaint,
                    call: ast.Call, state) -> Iterator[Finding]:
        leaf = call_name(call).rsplit(".", 1)[-1]
        if leaf in FACTOR_NAMES:
            for kw in call.keywords:
                if kw.arg not in FACTOR_LEAVES:
                    continue
                st, _ = analysis.eval(state, kw.value)
                if st == FRESH:
                    yield self.finding(
                        mod, call,
                        f"`{leaf}` built with computed `{kw.arg}=` that no "
                        "mask dominates on every path to this constructor",
                        fix=self._mask_fix(mod, state, kw),
                    )
        status = analysis.at_set_sink(state, call)
        if status is not None and status > MASKED:
            yield self.finding(
                mod, call,
                "in-place update writes a value with unproven inactive "
                "columns into a factor leaf",
            )

    @staticmethod
    def _mask_fix(mod: ModuleInfo, state, kw: ast.keyword):
        """Mechanical re-mask when a live mask variable exists: wrap the
        kwarg in ``mask_coeff(..., m)`` (S) or ``(...) * m[..., None, :]``
        (U/V)."""
        masks = sorted(
            name for name, (st, _) in state.items() if st == MASK
        )
        src = ast.get_source_segment(mod.source, kw.value)
        if not masks or src is None:
            return None
        m = masks[0]
        if kw.arg == "S":
            repl = f"mask_coeff({src}, {m})"
        else:
            repl = f"(({src}) * {m}[..., None, :])"
        v = kw.value
        return TextEdit(v.lineno, v.col_offset, v.end_lineno,
                        v.end_col_offset, repl)


# ---------------------------------------------------------------------------
# RPL006 — codec protocol conformance
# ---------------------------------------------------------------------------

#: WireCodec protocol: method -> (required positional arity incl. self)
CODEC_PROTOCOL = {"encode": 2, "decode": 2, "nbytes": 2}


@register_rule
class CodecConformance(Rule):
    """Every concrete ``*Codec`` implements the full WireCodec protocol
    (``encode``/``decode``/``nbytes``, each ``(self, payload-or-msg)``),
    carries a ``name``, and is registered.

    The wire layer dispatches codecs by name through ``_CODECS`` /
    ``make_codec``; a codec missing ``nbytes`` silently reports zero
    measured communication, which corrupts every comm-cost figure.
    """

    id = "RPL006"
    title = "WireCodec protocol violation"
    severity = "error"
    hint = (
        "define encode/decode/nbytes(self, x), set `name`, and add the "
        "codec to the registry"
    )

    def applies_to(self, info: PathInfo) -> bool:
        return bool(info.repro) and not info.is_tests

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        classes = [
            n for n in ast.walk(mod.tree) if isinstance(n, ast.ClassDef)
        ]
        for cls in classes:
            if not cls.name.endswith("Codec"):
                continue
            if cls.name == "WireCodec":
                continue  # the protocol itself
            bases = {call_name(ast.Call(func=b, args=[], keywords=[]))
                     for b in cls.bases if isinstance(b, (ast.Name, ast.Attribute))}
            if "Protocol" in {b.rsplit(".", 1)[-1] for b in bases}:
                continue
            yield from self._check_codec(mod, cls)

    def _check_codec(self, mod: ModuleInfo, cls: ast.ClassDef) -> Iterator[Finding]:
        methods: Dict[str, ast.FunctionDef] = {
            n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for meth, arity in CODEC_PROTOCOL.items():
            fn = methods.get(meth)
            if fn is None:
                yield self.finding(
                    mod, cls,
                    f"codec `{cls.name}` is missing `{meth}()`",
                )
                continue
            npos = len(fn.args.posonlyargs) + len(fn.args.args)
            required = npos - len(fn.args.defaults)
            if required > arity or (npos < arity and not fn.args.vararg):
                yield self.finding(
                    mod, fn,
                    f"`{cls.name}.{meth}` signature differs from the "
                    f"protocol's ({arity - 1} argument beyond self)",
                )
        if not self._has_name(cls, methods.get("__init__")):
            yield self.finding(
                mod, cls, f"codec `{cls.name}` defines no `name`",
            )
        if not self._registered(mod, cls):
            yield self.finding(
                mod, cls,
                f"codec `{cls.name}` is never registered for "
                "make_codec dispatch",
            )

    @staticmethod
    def _has_name(cls: ast.ClassDef, init: Optional[ast.FunctionDef]) -> bool:
        for stmt in cls.body:
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name) and t.id == "name":
                        return True
            elif isinstance(stmt, ast.AnnAssign) and (
                isinstance(stmt.target, ast.Name) and stmt.target.id == "name"
            ):
                return True
        if init is not None:
            for n in ast.walk(init):
                if (
                    isinstance(n, ast.Attribute)
                    and n.attr == "name"
                    and isinstance(n.value, ast.Name)
                    and n.value.id == "self"
                    and isinstance(getattr(n, "ctx", None), ast.Store)
                ):
                    return True
        return False

    @staticmethod
    def _registered(mod: ModuleInfo, cls: ast.ClassDef) -> bool:
        span = set(range(cls.lineno, (cls.end_lineno or cls.lineno) + 1))
        for n in ast.walk(mod.tree):
            if (
                isinstance(n, ast.Name)
                and n.id == cls.name
                and n.lineno not in span
            ):
                return True
        return False


# ---------------------------------------------------------------------------
# RPL007 — pickle only behind the versioned checkpoint sidecar
# ---------------------------------------------------------------------------


@register_rule
class NoRawPickle(Rule):
    """No raw ``pickle.load`` outside the versioned checkpoint sidecar.

    Unversioned pickles are both an arbitrary-code-execution surface and
    a schema time bomb (a dataclass rename breaks every old artifact).
    Checkpoints go through the sidecar (``STATE_VERSION``-stamped,
    JSON-safe dicts); anything else should be npz/json.
    """

    id = "RPL007"
    title = "raw pickle deserialization"
    severity = "error"
    hint = "use the versioned checkpoint sidecar or npz/json"

    def applies_to(self, info: PathInfo) -> bool:
        return not info.is_tests

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            parts = name.split(".")
            if parts[0] in ("pickle", "cPickle", "dill") and parts[-1] in (
                "load", "loads", "Unpickler",
            ):
                yield self.finding(mod, node, f"raw `{name}()`")
            elif parts[-1] == "load" and parts[0] in ("np", "numpy"):
                for kw in node.keywords:
                    if (
                        kw.arg == "allow_pickle"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True
                    ):
                        yield self.finding(
                            mod, node,
                            "`np.load(..., allow_pickle=True)` "
                            "deserializes pickles",
                        )


# ---------------------------------------------------------------------------
# RPL008 — every *Spec field participates in validation or build()
# ---------------------------------------------------------------------------


@register_rule
class SpecValidationParity(Rule):
    """Every field declared on a ``*Spec`` dataclass must appear in at
    least one validation rule or ``build()`` branch.

    A spec field nothing reads is worse than dead code: two specs that
    differ only in it hash differently while running identically, so the
    checkpoint spec-hash guard rejects resumes that are actually fine —
    or, if the field was *meant* to change behavior, the scenario silently
    doesn't vary.
    """

    id = "RPL008"
    title = "*Spec field unused by validation and build()"
    severity = "error"
    hint = (
        "validate it in the spec's __post_init__ (or a _validate_* rule) "
        "or consume it in build()/tasks"
    )

    #: files consuming spec fields, relative to the spec module's directory
    SIBLINGS = ("experiment.py", "tasks.py")

    def applies_to(self, info: PathInfo) -> bool:
        return info.under("api", "spec.py")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        import os

        from repro.analysis.core import parse_module

        consumers = [mod]
        here = os.path.dirname(mod.path)
        for sib in self.SIBLINGS:
            m, _err = parse_module(os.path.join(here, sib))
            if m is not None:
                consumers.append(m)

        used: Set[str] = set()
        for c in consumers:
            for n in ast.walk(c.tree):
                if isinstance(n, ast.Attribute):
                    used.add(n.attr)
                elif isinstance(n, ast.keyword) and n.arg:
                    used.add(n.arg)
                elif isinstance(n, ast.Constant) and isinstance(n.value, str):
                    used.add(n.value)

        for cls in ast.walk(mod.tree):
            if not (isinstance(cls, ast.ClassDef) and cls.name.endswith("Spec")):
                continue
            for stmt in cls.body:
                if not (
                    isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                ):
                    continue
                field = stmt.target.id
                if field.startswith("_"):
                    continue
                # the AnnAssign target is an ast.Name, so the declaration
                # itself never lands in `used` (which collects attribute
                # accesses, keyword args, and exact string constants)
                if field not in used:
                    yield self.finding(
                        mod, stmt,
                        f"`{cls.name}.{field}` appears in no validation "
                        "rule or build() branch",
                    )


# ---------------------------------------------------------------------------
# RPL009 — kernel-path shape/dtype contracts hold statically
# ---------------------------------------------------------------------------


@register_rule
class KernelShapeContracts(Rule):
    """The Pallas kernel path must satisfy the MXU tile contracts for
    every shape the repo can feed it — proven statically.

    The static shape interpreter (:mod:`repro.analysis.shapes`)
    symbolically executes ``kernels/ops.py`` over the ModelSpec presets,
    the shipped example configs, and a synthetic stress grid (including
    the bf16 ``M % 16 == 8`` case that bit PR 2), checking every
    ``xus``/``avt``/``atb`` call against the shared constraint table in
    :mod:`repro.kernels.constraints`: sublane multiples per dtype
    itemsize, 128-lane multiples, grid divisibility, operand-shape
    agreement — plus custom-VJP cotangent dtype drift (``_bwd`` must
    return primal dtypes; mixed-precision cases expose a dropped
    ``.astype``).  No JAX executes: a padding regression is caught by
    reading the source, on any machine.
    """

    id = "RPL009"
    title = "kernel path violates a tile/shape/dtype contract"
    severity = "error"
    hint = (
        "pad via _round_up/_pad2/_pad_rank using repro.kernels.constraints "
        "(sublane per dtype itemsize, lane 128) and cast cotangents back "
        "to the primal dtypes"
    )

    def applies_to(self, info: PathInfo) -> bool:
        return info.under("kernels", "ops.py")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        from repro.analysis.shapes import check_kernel_module

        violations, errors = check_kernel_module(mod.tree)
        for v in violations:
            yield Finding(
                rule=self.id, path=mod.path, line=v.lineno, col=v.col,
                message=v.message, severity=self.severity, hint=self.hint,
            )
        for err in errors:
            yield Finding(
                rule=self.id, path=mod.path, line=1, col=0,
                message=f"static shape interpreter could not evaluate the "
                        f"kernel path ({err}) — coverage lost, not proven "
                        f"clean",
                severity="warning",
                hint="keep ops.py within the interpreted subset or extend "
                     "repro.analysis.shapes",
            )
