"""Control-flow graphs over Python AST, at function granularity.

The repro-lint rules that guard *semantic* invariants (RPL005's
zero-inactive-columns taint analysis, RPL004's jit purity) need to reason
about **paths**, not lexical scope: "is this factor write
sanitizer-dominated on every way control can reach it?" is a dataflow
question.  This module builds the graph those analyses run on.

Granularity and approximations (deliberate — this is a linter, not a
verifier):

- One CFG per statement list (a function body, or a module's top level).
  Nested ``def``/``class``/``lambda`` bodies are *atomic statements* of
  the enclosing graph; callers analyze them as their own CFGs.
- ``if``/``while``/``for`` (each with ``else``), ``break``/``continue``,
  ``return``/``raise``, ``match`` and ``with`` are modeled exactly.
  Loops get a back edge, so fixpoint iteration sees them.
- ``try`` is modeled conservatively for forward may/must analyses: every
  handler is reachable both from *before* the try body (nothing ran) and
  from its end (everything ran), so a sanitizer inside ``try`` never
  spuriously dominates a handler path.  ``finally`` is on every exit.
- ``with`` bodies execute linearly; each ``as`` target materializes as a
  synthetic assignment statement so transfer functions see the binding.

Blocks are straight-line statement lists; edges carry no conditions
(branch tests appear as a synthetic :class:`BranchTest` statement in the
block that evaluates them, so analyses may inspect the expression).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass
class BranchTest:
    """Synthetic statement: evaluation of a branch/loop test expression."""

    node: ast.expr  # the test expression
    origin: ast.stmt  # the If/While statement it came from

    @property
    def lineno(self) -> int:  # findings anchor here
        return getattr(self.node, "lineno", getattr(self.origin, "lineno", 0))

    @property
    def col_offset(self) -> int:
        return getattr(
            self.node, "col_offset", getattr(self.origin, "col_offset", 0)
        )


@dataclasses.dataclass
class LoopBind:
    """Synthetic statement: the ``for`` target binding (target ← iter)."""

    target: ast.expr
    iter: ast.expr
    origin: ast.stmt

    @property
    def lineno(self) -> int:
        return getattr(self.origin, "lineno", 0)

    @property
    def col_offset(self) -> int:
        return getattr(self.origin, "col_offset", 0)


class Block:
    """A basic block: straight-line statements plus successor edges."""

    __slots__ = ("id", "stmts", "succs", "preds", "label")

    def __init__(self, bid: int, label: str = ""):
        self.id = bid
        self.stmts: List[object] = []  # ast.stmt | BranchTest | LoopBind
        self.succs: List["Block"] = []
        self.preds: List["Block"] = []
        self.label = label

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Block {self.id} {self.label!r} -> {[s.id for s in self.succs]}>"


class CFG:
    """entry/exit blocks plus the full block list, in creation order."""

    def __init__(self):
        self.blocks: List[Block] = []
        self.entry = self.new_block("entry")
        self.exit = self.new_block("exit")

    def new_block(self, label: str = "") -> Block:
        b = Block(len(self.blocks), label)
        self.blocks.append(b)
        return b

    def add_edge(self, src: Block, dst: Block) -> None:
        if dst not in src.succs:
            src.succs.append(dst)
            dst.preds.append(src)

    def reachable(self) -> List[Block]:
        """Blocks reachable from entry, in a deterministic order."""
        seen: Dict[int, Block] = {}
        stack = [self.entry]
        while stack:
            b = stack.pop()
            if b.id in seen:
                continue
            seen[b.id] = b
            stack.extend(reversed(b.succs))
        return [self.blocks[i] for i in sorted(seen)]


#: statements that terminate a block with a jump (no fallthrough)
_TERMINATORS = (ast.Return, ast.Raise, ast.Break, ast.Continue)

#: nested definitions treated as atomic statements of the enclosing graph
ATOMIC_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


class _Builder:
    def __init__(self):
        self.cfg = CFG()
        # (continue_target, break_target) stack for loop bodies
        self.loops: List[tuple] = []

    # -- helpers ----------------------------------------------------------

    def _seal(self, cur: Optional[Block], dst: Block) -> None:
        if cur is not None:
            self.cfg.add_edge(cur, dst)

    def build(self, stmts: Sequence[ast.stmt]) -> CFG:
        body_head = self.cfg.new_block("body")
        self.cfg.add_edge(self.cfg.entry, body_head)
        tail = self._stmts(stmts, body_head)
        self._seal(tail, self.cfg.exit)
        return self.cfg

    # -- statement walkers -------------------------------------------------
    # Each _X(node, cur) appends to `cur` and returns the block where
    # control continues afterwards (None if this path cannot fall through).

    def _stmts(self, stmts: Sequence[ast.stmt], cur: Optional[Block]):
        for s in stmts:
            if cur is None:  # unreachable code after return/raise/...
                cur = self.cfg.new_block("dead")
            cur = self._stmt(s, cur)
        return cur

    def _stmt(self, s: ast.stmt, cur: Block) -> Optional[Block]:
        if isinstance(s, ast.If):
            return self._if(s, cur)
        if isinstance(s, (ast.While,)):
            return self._while(s, cur)
        if isinstance(s, (ast.For, ast.AsyncFor)):
            return self._for(s, cur)
        if isinstance(s, ast.Try):
            return self._try(s, cur)
        if isinstance(s, (ast.With, ast.AsyncWith)):
            return self._with(s, cur)
        if isinstance(s, ast.Match):
            return self._match(s, cur)
        if isinstance(s, _TERMINATORS):
            cur.stmts.append(s)
            if isinstance(s, (ast.Return, ast.Raise)):
                self.cfg.add_edge(cur, self.cfg.exit)
            elif isinstance(s, ast.Break):
                if self.loops:
                    self.cfg.add_edge(cur, self.loops[-1][1])
                else:  # malformed code: treat as exit
                    self.cfg.add_edge(cur, self.cfg.exit)
            else:  # Continue
                if self.loops:
                    self.cfg.add_edge(cur, self.loops[-1][0])
                else:
                    self.cfg.add_edge(cur, self.cfg.exit)
            return None
        # plain statement (incl. nested defs, which stay atomic)
        cur.stmts.append(s)
        return cur

    def _if(self, s: ast.If, cur: Block) -> Optional[Block]:
        cur.stmts.append(BranchTest(s.test, s))
        after = self.cfg.new_block("if.after")
        then_head = self.cfg.new_block("if.then")
        self.cfg.add_edge(cur, then_head)
        then_tail = self._stmts(s.body, then_head)
        self._seal(then_tail, after)
        if s.orelse:
            else_head = self.cfg.new_block("if.else")
            self.cfg.add_edge(cur, else_head)
            else_tail = self._stmts(s.orelse, else_head)
            self._seal(else_tail, after)
        else:
            self.cfg.add_edge(cur, after)
        return after if after.preds else None

    def _while(self, s: ast.While, cur: Block) -> Optional[Block]:
        head = self.cfg.new_block("while.head")
        self._seal(cur, head)
        head.stmts.append(BranchTest(s.test, s))
        after = self.cfg.new_block("while.after")
        body_head = self.cfg.new_block("while.body")
        self.cfg.add_edge(head, body_head)
        self.loops.append((head, after))
        body_tail = self._stmts(s.body, body_head)
        self.loops.pop()
        self._seal(body_tail, head)  # back edge
        if s.orelse:
            # else runs when the loop exits without break
            else_head = self.cfg.new_block("while.else")
            self.cfg.add_edge(head, else_head)
            else_tail = self._stmts(s.orelse, else_head)
            self._seal(else_tail, after)
        else:
            self.cfg.add_edge(head, after)
        return after if after.preds else None

    def _for(self, s, cur: Block) -> Optional[Block]:
        head = self.cfg.new_block("for.head")
        self._seal(cur, head)
        head.stmts.append(LoopBind(s.target, s.iter, s))
        after = self.cfg.new_block("for.after")
        body_head = self.cfg.new_block("for.body")
        self.cfg.add_edge(head, body_head)
        self.loops.append((head, after))
        body_tail = self._stmts(s.body, body_head)
        self.loops.pop()
        self._seal(body_tail, head)  # back edge
        if s.orelse:
            else_head = self.cfg.new_block("for.else")
            self.cfg.add_edge(head, else_head)
            else_tail = self._stmts(s.orelse, else_head)
            self._seal(else_tail, after)
        else:
            self.cfg.add_edge(head, after)
        return after if after.preds else None

    def _try(self, s: ast.Try, cur: Block) -> Optional[Block]:
        after = self.cfg.new_block("try.after")
        body_head = self.cfg.new_block("try.body")
        self.cfg.add_edge(cur, body_head)
        body_tail = self._stmts(s.body, body_head)
        # success path: orelse then after
        if s.orelse:
            else_head = self.cfg.new_block("try.else")
            self._seal(body_tail, else_head)
            else_tail = self._stmts(s.orelse, else_head)
            success_tail = else_tail
        else:
            success_tail = body_tail
        # handlers: reachable from before the body (nothing ran) and after
        # it (everything ran) — conservative bracketing of "some prefix ran"
        handler_tails: List[Optional[Block]] = []
        for h in s.handlers:
            h_head = self.cfg.new_block("try.handler")
            self.cfg.add_edge(cur, h_head)
            if body_tail is not None:
                self.cfg.add_edge(body_tail, h_head)
            if h.name:  # `except E as name:` binds name
                bind = ast.Assign(
                    targets=[ast.Name(id=h.name, ctx=ast.Store())],
                    value=h.type or ast.Constant(value=None),
                )
                ast.copy_location(bind, h)
                ast.fix_missing_locations(bind)
                h_head.stmts.append(bind)
            handler_tails.append(self._stmts(h.body, h_head))
        # finally runs on every exit path
        if s.finalbody:
            fin_head = self.cfg.new_block("try.finally")
            self._seal(success_tail, fin_head)
            for t in handler_tails:
                self._seal(t, fin_head)
            if not s.handlers:
                # an uncaught exception also reaches finally
                if body_tail is not None:
                    self.cfg.add_edge(body_tail, fin_head)
                self.cfg.add_edge(cur, fin_head)
            fin_tail = self._stmts(s.finalbody, fin_head)
            self._seal(fin_tail, after)
        else:
            self._seal(success_tail, after)
            for t in handler_tails:
                self._seal(t, after)
        return after if after.preds else None

    def _with(self, s, cur: Block) -> Optional[Block]:
        for item in s.items:
            if item.optional_vars is not None:
                bind = ast.Assign(
                    targets=[item.optional_vars], value=item.context_expr
                )
                ast.copy_location(bind, s)
                ast.fix_missing_locations(bind)
                cur.stmts.append(bind)
            else:
                expr = ast.Expr(value=item.context_expr)
                ast.copy_location(expr, s)
                cur.stmts.append(expr)
        return self._stmts(s.body, cur)

    def _match(self, s: ast.Match, cur: Block) -> Optional[Block]:
        cur.stmts.append(BranchTest(s.subject, s))
        after = self.cfg.new_block("match.after")
        exhaustive = False
        for case in s.cases:
            c_head = self.cfg.new_block("match.case")
            self.cfg.add_edge(cur, c_head)
            c_tail = self._stmts(case.body, c_head)
            self._seal(c_tail, after)
            if (
                isinstance(case.pattern, ast.MatchAs)
                and case.pattern.pattern is None
                and case.guard is None
            ):
                exhaustive = True  # bare wildcard `case _:`
        if not exhaustive:
            self.cfg.add_edge(cur, after)  # no case matched
        return after if after.preds else None


def build_cfg(node) -> CFG:
    """CFG for a function def's body, or any explicit statement list.

    ``node`` may be a ``FunctionDef``/``AsyncFunctionDef``, a ``Module``,
    or a plain list of statements.
    """
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
        stmts = node.body
    else:
        stmts = list(node)
    return _Builder().build(stmts)
