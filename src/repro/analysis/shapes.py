"""Static shape/dtype abstract interpreter for the Pallas kernel path.

Rule RPL009's engine: symbolically executes the *AST* of
``repro/kernels/ops.py`` — no JAX, no tracing, no device — over a battery
of concrete shape/dtype cases, and checks every ``xus``/``avt``/``atb``
call site against the MXU tile constraint table in
:mod:`repro.kernels.constraints` (sublane multiple per dtype itemsize,
lane multiple 128, grid divisibility, operand-shape agreement).

Why interpret the real source instead of importing and running it: the
point is to catch *mutations* of the padding logic (the PR 2 bug class —
bf16 input with ``M % 16 == 8`` handed to an 8-aligned tile) before any
test executes, including on machines where the kernels never run.  The
same pass checks the custom-VJP pair for dtype-promotion drift: ``_bwd``
must hand back cotangents in the primal dtypes (mixed-precision cases
make a dropped ``.astype`` visible).

Shape cases come from three sources (:func:`shape_cases`): a synthetic
grid that always runs (and pins the bf16 ``M % 16 == 8`` stress case), the
``ModelSpec`` presets, and ``examples/configs/*.toml`` — so the checked
shapes are the shapes the repo actually trains.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.kernels.constraints import LANE, sublane

#: interpreter recursion / loop guards
_MAX_DEPTH = 24

#: dtype attribute names recognized on the ``jnp`` module object
_DTYPE_NAMES = {
    "float32", "bfloat16", "float16", "int32", "uint32", "int8", "uint8",
    "float8_e4m3fn", "float8_e5m2",
}

#: default tile sizes per sink, mirroring the kernel signatures
_SINK_DEFAULTS = {
    "xus": {"bm": 256, "bk": 512},
    "avt": {"bm": 256, "bn": 256},
    "atb": {"bm": 512, "bka": 256},
}


class InterpError(Exception):
    """The interpreter hit a construct it cannot evaluate."""


class _Return(Exception):
    def __init__(self, value):
        self.value = value


class _CaseAbort(Exception):
    """A reachable ``raise`` aborted this shape case."""


@dataclasses.dataclass(frozen=True)
class Arr:
    """Abstract array: a concrete shape plus a dtype name."""

    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class Case:
    """One concrete activation/factor shape configuration.

    ``dtype`` is the activation (x / dy) dtype, ``fdtype`` the factor
    (U/S/V) dtype — they differ in mixed-precision cases.
    """

    label: str
    M: int
    K: int
    N: int
    R: int
    dtype: str = "float32"
    fdtype: str = "float32"


@dataclasses.dataclass
class Violation:
    """One constraint failure at a specific call site."""

    lineno: int
    col: int
    kind: str  # stable key for dedup across cases
    message: str
    case: str


# ---------------------------------------------------------------------------
# shape cases
# ---------------------------------------------------------------------------

#: always-on grid; the bf16 M % 16 == 8 entries pin the PR 2 bug class
SYNTHETIC_CASES = (
    Case("f32-tiny", M=8, K=64, N=48, R=4),
    Case("f32-odd", M=104, K=96, N=80, R=24),
    Case("bf16-m-mod-16-eq-8", M=104, K=128, N=512, R=32,
         dtype="bfloat16", fdtype="bfloat16"),
    Case("bf16-odd-dims", M=40, K=136, N=264, R=24,
         dtype="bfloat16", fdtype="bfloat16"),
    Case("bf16-act-f32-factors", M=104, K=128, N=512, R=32,
         dtype="bfloat16", fdtype="float32"),
    Case("bf16-llm-block", M=512, K=640, N=2560, R=160,
         dtype="bfloat16", fdtype="bfloat16"),
)


def _preset_cases() -> List[Case]:
    """Cases from the ModelSpec presets (guarded: presets may pull heavy
    imports in minimal environments)."""
    try:
        from repro.api.tasks import PRESETS
    except Exception:
        return []
    out: List[Case] = []
    for name, cfg in sorted(PRESETS.items()):
        try:
            lr = cfg.lowrank
            r = min(lr.r_cap, max(1, int(lr.rank_frac * cfg.d_model)))
            out.append(Case(
                f"preset-{name}", M=4 * 128, K=cfg.d_model, N=cfg.d_ff, R=r,
                dtype=cfg.compute_dtype, fdtype=cfg.param_dtype,
            ))
        except Exception:
            continue
    return out


def _config_cases() -> List[Case]:
    """Cases from ``examples/configs/*.toml``: the batch geometry each
    shipped experiment actually feeds the kernels."""
    try:
        from repro.api.serialization import toml_loads
        from repro.api.tasks import PRESETS
    except Exception:
        return []
    root = Path(__file__).resolve()
    for parent in root.parents:
        if (parent / "examples" / "configs").is_dir():
            cfg_dir = parent / "examples" / "configs"
            break
    else:
        return []
    out: List[Case] = []
    for path in sorted(cfg_dir.glob("*.toml")):
        try:
            data = toml_loads(path.read_text())
        except Exception:
            continue
        model = data.get("model", {})
        dspec = data.get("data", {})
        preset = PRESETS.get(model.get("preset", ""))
        if preset is None:
            continue
        m = int(dspec.get("batch", 4)) * int(dspec.get("seq", 128))
        lr = preset.lowrank
        r = min(lr.r_cap, max(1, int(lr.rank_frac * preset.d_model)))
        out.append(Case(
            f"config-{path.stem}", M=m, K=preset.d_model, N=preset.d_ff,
            R=r, dtype=preset.compute_dtype, fdtype=preset.param_dtype,
        ))
    return out


def shape_cases(include_derived: bool = True) -> List[Case]:
    cases = list(SYNTHETIC_CASES)
    if include_derived:
        seen = {(c.M, c.K, c.N, c.R, c.dtype, c.fdtype) for c in cases}
        for c in _preset_cases() + _config_cases():
            key = (c.M, c.K, c.N, c.R, c.dtype, c.fdtype)
            if key not in seen:
                seen.add(key)
                cases.append(c)
    return cases


# ---------------------------------------------------------------------------
# the interpreter
# ---------------------------------------------------------------------------


class ShapeInterp:
    """Abstract interpreter over one module's AST (``kernels/ops.py``)."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.functions: Dict[str, ast.FunctionDef] = {
            n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        self.violations: List[Violation] = []
        self.case = ""

    # -- public entry points ----------------------------------------------

    def run_case(self, case: Case) -> None:
        """Interpret every kernel entry point for one shape case,
        accumulating violations (never raising for constraint failures)."""
        self.case = case.label
        x = Arr((case.M, case.K), case.dtype)
        U = Arr((case.K, case.R), case.fdtype)
        S = Arr((case.R, case.R), case.fdtype)
        V = Arr((case.N, case.R), case.fdtype)
        dy = Arr((case.M, case.N), case.dtype)

        y = self._entry("lowrank_apply_kernels", [x, U, S, V],
                        {"interpret": False})
        if isinstance(y, Arr):
            if y.shape != (case.M, case.N):
                self._flag(self.functions["lowrank_apply_kernels"],
                           "fwd-shape",
                           f"forward output shape {y.shape}, expected "
                           f"{(case.M, case.N)}")
            if y.dtype != case.dtype:
                self._flag(self.functions["lowrank_apply_kernels"],
                           "fwd-dtype",
                           f"forward output dtype {y.dtype} drifts from "
                           f"activation dtype {case.dtype}")

        g = self._entry("coeff_grad_kernels", [x, dy, U, V],
                        {"interpret": False})
        if isinstance(g, Arr) and g.shape != (case.R, case.R):
            self._flag(self.functions["coeff_grad_kernels"], "coeff-shape",
                       f"coefficient gradient shape {g.shape}, expected "
                       f"{(case.R, case.R)}")

        outs = self._entry("_bwd", [True, (x, U, S, V), dy], {})
        if isinstance(outs, tuple) and len(outs) == 4:
            names = ("dx", "dU", "dS", "dV")
            primals = (x, U, S, V)
            for nm, out, prim in zip(names, outs, primals):
                if not isinstance(out, Arr):
                    continue
                if out.dtype != prim.dtype:
                    self._flag(
                        self.functions["_bwd"], f"bwd-dtype-{nm}",
                        f"custom-VJP cotangent {nm} has dtype {out.dtype} "
                        f"but the primal is {prim.dtype} — dtype promotion "
                        f"leaks out of the backward pass")
                if out.shape != prim.shape:
                    self._flag(
                        self.functions["_bwd"], f"bwd-shape-{nm}",
                        f"cotangent {nm} shape {out.shape} != primal "
                        f"{prim.shape}")

    def _entry(self, name: str, args: list, kwargs: dict):
        fn = self.functions.get(name)
        if fn is None:
            raise InterpError(f"entry point {name}() not found in module")
        try:
            return self._call_def(fn, args, kwargs, depth=0)
        except _CaseAbort:
            return None

    def _flag(self, node, kind: str, message: str) -> None:
        self.violations.append(Violation(
            lineno=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            kind=kind, message=message, case=self.case,
        ))

    # -- function application ---------------------------------------------

    def _call_def(self, fn: ast.FunctionDef, args: list, kwargs: dict,
                  depth: int):
        if depth > _MAX_DEPTH:
            raise InterpError(f"recursion depth exceeded in {fn.name}()")
        env: Dict[str, object] = {}
        a = fn.args
        pos = list(a.args)
        # positional (ops.py uses no *args/**kwargs in the kernel path)
        for i, arg in enumerate(args):
            if i < len(pos):
                env[pos[i].arg] = arg
            else:
                raise InterpError(f"too many positional args to {fn.name}()")
        # positional defaults
        for arg_node, default in zip(pos[len(pos) - len(a.defaults):],
                                     a.defaults):
            if arg_node.arg not in env:
                env[arg_node.arg] = self._eval(default, env, depth)
        # keyword-only (+ defaults)
        for arg_node, default in zip(a.kwonlyargs, a.kw_defaults):
            if arg_node.arg in kwargs:
                env[arg_node.arg] = kwargs[arg_node.arg]
            elif default is not None:
                env[arg_node.arg] = self._eval(default, env, depth)
        for k, v in kwargs.items():
            env[k] = v
        for arg_node in pos + a.kwonlyargs:
            if arg_node.arg not in env:
                raise InterpError(
                    f"missing argument {arg_node.arg!r} to {fn.name}()")
        try:
            self._exec_block(fn.body, env, depth)
        except _Return as r:
            return r.value
        return None

    # -- statements --------------------------------------------------------

    def _exec_block(self, stmts, env, depth) -> None:
        for s in stmts:
            self._exec(s, env, depth)

    def _exec(self, s: ast.stmt, env, depth) -> None:
        if isinstance(s, ast.Return):
            raise _Return(
                None if s.value is None else self._eval(s.value, env, depth))
        if isinstance(s, ast.Assign):
            val = self._eval(s.value, env, depth)
            for t in s.targets:
                self._bind(t, val, env)
            return
        if isinstance(s, ast.AnnAssign) and s.value is not None:
            self._bind(s.target, self._eval(s.value, env, depth), env)
            return
        if isinstance(s, ast.AugAssign):
            cur = self._eval(ast.copy_location(
                ast.Name(id=s.target.id, ctx=ast.Load()), s), env, depth) \
                if isinstance(s.target, ast.Name) else None
            if cur is None:
                raise InterpError("unsupported augmented assignment target")
            val = self._binop_val(s.op, cur,
                                  self._eval(s.value, env, depth), s)
            env[s.target.id] = val
            return
        if isinstance(s, ast.If):
            test = self._eval(s.test, env, depth)
            self._exec_block(s.body if test else s.orelse, env, depth)
            return
        if isinstance(s, ast.Assert):
            ok = self._eval(s.test, env, depth)
            if not ok:
                self._flag(s, f"assert-L{s.lineno}",
                           f"assertion fails statically: "
                           f"{ast.unparse(s.test)}")
            return
        if isinstance(s, ast.Raise):
            self._flag(s, f"raise-L{s.lineno}",
                       "reachable raise on the kernel path: "
                       + (ast.unparse(s.exc) if s.exc else "re-raise"))
            raise _CaseAbort()
        if isinstance(s, ast.Expr):
            self._eval(s.value, env, depth)
            return
        if isinstance(s, (ast.Pass, ast.Import, ast.ImportFrom)):
            return
        raise InterpError(
            f"unsupported statement {type(s).__name__} at line "
            f"{getattr(s, 'lineno', '?')}")

    def _bind(self, target, val, env) -> None:
        if isinstance(target, ast.Name):
            env[target.id] = val
        elif isinstance(target, (ast.Tuple, ast.List)):
            if not isinstance(val, tuple) or len(val) != len(target.elts):
                raise InterpError("tuple unpacking arity mismatch")
            for t, v in zip(target.elts, val):
                self._bind(t, v, env)
        else:
            raise InterpError(
                f"unsupported assignment target {type(target).__name__}")

    # -- expressions -------------------------------------------------------

    def _eval(self, e: ast.expr, env, depth):
        if isinstance(e, ast.Constant):
            return e.value
        if isinstance(e, ast.Name):
            if e.id in env:
                return env[e.id]
            if e.id == "LANE":
                return LANE
            if e.id in ("jnp", "jax", "ref", "functools", "pl", "pltpu"):
                return ("module", e.id)
            if e.id in self.functions:
                return ("def", e.id)
            if e.id in ("True", "False", "None"):  # pre-3.8 safety
                return {"True": True, "False": False, "None": None}[e.id]
            if e.id in ("min", "max", "len", "abs", "int"):
                return ("builtin", e.id)
            # imported kernel entry points and helpers
            if e.id in ("xus", "avt", "atb", "_sublane", "_min_sublane"):
                return ("intercept", e.id)
            raise InterpError(f"unknown name {e.id!r} at line {e.lineno}")
        if isinstance(e, ast.Tuple):
            return tuple(self._eval(v, env, depth) for v in e.elts)
        if isinstance(e, ast.Attribute):
            return self._attribute(e, env, depth)
        if isinstance(e, ast.Subscript):
            return self._subscript(e, env, depth)
        if isinstance(e, ast.BinOp):
            return self._binop_val(
                e.op, self._eval(e.left, env, depth),
                self._eval(e.right, env, depth), e)
        if isinstance(e, ast.UnaryOp):
            v = self._eval(e.operand, env, depth)
            if isinstance(e.op, ast.USub):
                return -v
            if isinstance(e.op, ast.Not):
                return not v
            if isinstance(e.op, ast.UAdd):
                return +v
            raise InterpError("unsupported unary op")
        if isinstance(e, ast.BoolOp):
            if isinstance(e.op, ast.And):
                v = True
                for sub in e.values:
                    v = self._eval(sub, env, depth)
                    if not v:
                        return v
                return v
            v = False
            for sub in e.values:
                v = self._eval(sub, env, depth)
                if v:
                    return v
            return v
        if isinstance(e, ast.Compare):
            left = self._eval(e.left, env, depth)
            for op, rhs_node in zip(e.ops, e.comparators):
                rhs = self._eval(rhs_node, env, depth)
                ok = self._compare(op, left, rhs)
                if not ok:
                    return False
                left = rhs
            return True
        if isinstance(e, ast.IfExp):
            return (self._eval(e.body, env, depth)
                    if self._eval(e.test, env, depth)
                    else self._eval(e.orelse, env, depth))
        if isinstance(e, ast.Call):
            return self._call(e, env, depth)
        raise InterpError(
            f"unsupported expression {type(e).__name__} at line "
            f"{getattr(e, 'lineno', '?')}")

    @staticmethod
    def _compare(op, a, b):
        if isinstance(op, ast.Eq):
            return a == b
        if isinstance(op, ast.NotEq):
            return a != b
        if isinstance(op, ast.Lt):
            return a < b
        if isinstance(op, ast.LtE):
            return a <= b
        if isinstance(op, ast.Gt):
            return a > b
        if isinstance(op, ast.GtE):
            return a >= b
        if isinstance(op, ast.Is):
            return a is b
        if isinstance(op, ast.IsNot):
            return a is not b
        raise InterpError("unsupported comparison")

    def _binop_val(self, op, left, right, node):
        try:
            if isinstance(op, ast.Add):
                return left + right
            if isinstance(op, ast.Sub):
                return left - right
            if isinstance(op, ast.Mult):
                return left * right
            if isinstance(op, ast.FloorDiv):
                return left // right
            if isinstance(op, ast.Mod):
                return left % right
            if isinstance(op, ast.Pow):
                return left ** right
        except TypeError:
            raise InterpError(
                f"arithmetic on abstract values at line "
                f"{getattr(node, 'lineno', '?')}")
        raise InterpError("unsupported binary operator")

    def _attribute(self, e: ast.Attribute, env, depth):
        val = self._eval(e.value, env, depth)
        if isinstance(val, Arr):
            if e.attr == "shape":
                return val.shape
            if e.attr == "dtype":
                return val.dtype
            if e.attr == "T":
                return Arr(tuple(reversed(val.shape)), val.dtype)
            if e.attr == "astype":
                return ("astype", val)
            raise InterpError(f"unknown array attribute .{e.attr}")
        if val == ("module", "jnp"):
            if e.attr in _DTYPE_NAMES:
                return e.attr
            return ("jnp", e.attr)
        if isinstance(val, tuple) and len(val) == 2 and val[0] == "module":
            return (val[1], e.attr)
        raise InterpError(f"unsupported attribute .{e.attr}")

    def _subscript(self, e: ast.Subscript, env, depth):
        base = self._eval(e.value, env, depth)
        idx = e.slice
        if isinstance(base, tuple):
            i = self._eval(idx, env, depth)
            if not isinstance(i, int):
                raise InterpError("non-integer tuple index")
            return base[i]
        if isinstance(base, Arr):
            parts = list(idx.elts) if isinstance(idx, ast.Tuple) else [idx]
            shape: List[int] = []
            for dim, part in enumerate(parts):
                size = base.shape[dim]
                if isinstance(part, ast.Slice):
                    if part.step is not None:
                        raise InterpError("strided slice unsupported")
                    lo = 0 if part.lower is None else self._eval(
                        part.lower, env, depth)
                    hi = size if part.upper is None else self._eval(
                        part.upper, env, depth)
                    if lo < 0:
                        lo += size
                    if hi < 0:
                        hi += size
                    shape.append(max(0, min(hi, size) - lo))
                else:  # integer index: dim dropped
                    self._eval(part, env, depth)
            shape.extend(base.shape[len(parts):])
            return Arr(tuple(shape), base.dtype)
        raise InterpError("unsupported subscript base")

    # -- calls -------------------------------------------------------------

    def _call(self, e: ast.Call, env, depth):
        fn = self._eval(e.func, env, depth)
        args = [self._eval(a, env, depth) for a in e.args]
        kwargs = {kw.arg: self._eval(kw.value, env, depth)
                  for kw in e.keywords if kw.arg is not None}

        if isinstance(fn, tuple) and fn and fn[0] == "def":
            return self._call_def(self.functions[fn[1]], args, kwargs,
                                  depth + 1)
        if isinstance(fn, tuple) and fn and fn[0] == "builtin":
            return {"min": min, "max": max, "len": len, "abs": abs,
                    "int": int}[fn[1]](*args)
        if isinstance(fn, tuple) and fn and fn[0] == "astype":
            arr = fn[1]
            if not isinstance(args[0], str):
                raise InterpError("astype with non-dtype argument")
            return Arr(arr.shape, args[0])
        if isinstance(fn, tuple) and fn and fn[0] == "intercept":
            return self._intercept(fn[1], e, args, kwargs)
        if isinstance(fn, tuple) and fn and fn[0] == "jnp":
            return self._jnp(fn[1], e, args, kwargs)
        if fn == ("jax", "default_backend"):
            return "tpu"  # model the compiled path: constraints active
        if fn == ("ref", "lowrank_matmul_ref"):
            x, U, S, V = args[:4]
            return Arr((x.shape[0], V.shape[0]), x.dtype)
        if isinstance(fn, tuple) and len(fn) == 2 and fn[1] == "partial":
            raise InterpError("functools.partial on the interpreted path")
        # module-level helpers referenced by bare name resolve via _eval;
        # on_tpu() lands here as ("def", ...) already
        raise InterpError(
            f"uninterpretable call at line {e.lineno}: {ast.unparse(e.func)}")

    def _jnp(self, name: str, e: ast.Call, args, kwargs):
        if name == "pad":
            x, pads = args[0], args[1]
            shape = tuple(
                d + int(lo) + int(hi) for d, (lo, hi) in zip(x.shape, pads))
            return Arr(shape, x.dtype)
        if name == "zeros":
            shape = args[0]
            if isinstance(shape, int):
                shape = (shape,)
            dtype = kwargs.get("dtype", args[1] if len(args) > 1 else
                               "float32")
            return Arr(tuple(int(d) for d in shape), dtype)
        if name == "zeros_like":
            return args[0]
        if name == "eye":
            n = int(args[0])
            dtype = kwargs.get("dtype", "float32")
            return Arr((n, n), dtype)
        if name == "transpose":
            x = args[0]
            return Arr(tuple(reversed(x.shape)), x.dtype)
        raise InterpError(f"unmodeled jnp.{name} at line {e.lineno}")

    # -- kernel sinks ------------------------------------------------------

    def _intercept(self, name: str, e: ast.Call, args, kwargs):
        if name in ("_sublane", "_min_sublane"):
            if not isinstance(args[0], str):
                raise InterpError("_sublane on a non-dtype value")
            return sublane(args[0])
        if name == "xus":
            return self._sink_xus(e, args, kwargs)
        if name == "avt":
            return self._sink_avt(e, args, kwargs)
        if name == "atb":
            return self._sink_atb(e, args, kwargs)
        raise InterpError(f"unknown intercept {name}")

    def _tile(self, e, name: str, size: int, mult: int, kind: str,
              dtype: str) -> None:
        if size % mult:
            self._flag(
                e, f"tile-{name}-L{e.lineno}",
                f"{name}={size} is not a multiple of {mult} ({kind} dim, "
                f"dtype {dtype}) at the compiled-kernel call")

    def _grid(self, e, dim_name: str, dim: int, tile_name: str,
              tile: int) -> None:
        if tile == 0 or dim % tile:
            self._flag(
                e, f"grid-{dim_name}-L{e.lineno}",
                f"{dim_name}={dim} does not tile evenly by "
                f"{tile_name}={tile} — the kernel grid truncates")

    def _sink_xus(self, e, args, kwargs):
        x, U, S = args[0], args[1], args[2]
        bm = kwargs.get("bm", _SINK_DEFAULTS["xus"]["bm"])
        bk = kwargs.get("bk", _SINK_DEFAULTS["xus"]["bk"])
        M, K = x.shape
        R = U.shape[1]
        bm, bk = min(bm, M), min(bk, K)
        sub = sublane(x.dtype)
        self._grid(e, "M", M, "bm", bm)
        self._grid(e, "K", K, "bk", bk)
        self._tile(e, "bm", bm, sub, "sublane", x.dtype)
        self._tile(e, "bk", bk, LANE, "lane", x.dtype)
        self._tile(e, "R", R, LANE, "lane", x.dtype)
        if U.shape[0] != K:
            self._flag(e, f"shape-xU-L{e.lineno}",
                       f"x is (…, {K}) but U is ({U.shape[0]}, …)")
        if S.shape != (R, R):
            self._flag(e, f"shape-S-L{e.lineno}",
                       f"S is {S.shape}, expected {(R, R)} — rank padding "
                       f"out of step between U and S")
        return Arr((M, R), x.dtype)

    def _sink_avt(self, e, args, kwargs):
        A, V = args[0], args[1]
        bm = kwargs.get("bm", _SINK_DEFAULTS["avt"]["bm"])
        bn = kwargs.get("bn", _SINK_DEFAULTS["avt"]["bn"])
        M, R = A.shape
        N = V.shape[0]
        bm, bn = min(bm, M), min(bn, N)
        sub = sublane(A.dtype)
        self._grid(e, "M", M, "bm", bm)
        self._grid(e, "N", N, "bn", bn)
        self._tile(e, "bm", bm, sub, "sublane", A.dtype)
        self._tile(e, "bn", bn, LANE, "lane", A.dtype)
        self._tile(e, "R", R, LANE, "lane", A.dtype)
        if V.shape[1] != R:
            self._flag(e, f"shape-AV-L{e.lineno}",
                       f"A is (…, {R}) but V is (…, {V.shape[1]})")
        return Arr((M, N), A.dtype)

    def _sink_atb(self, e, args, kwargs):
        A, B = args[0], args[1]
        bm = kwargs.get("bm", _SINK_DEFAULTS["atb"]["bm"])
        bka = kwargs.get("bka", _SINK_DEFAULTS["atb"]["bka"])
        M, Ka = A.shape
        Kb = B.shape[1]
        bm, bka = min(bm, M), min(bka, Ka)
        sub = sublane(A.dtype)
        self._grid(e, "M", M, "bm", bm)
        self._grid(e, "Ka", Ka, "bka", bka)
        self._tile(e, "bm", bm, sub, "sublane", A.dtype)
        self._tile(e, "bka", bka, LANE, "lane", A.dtype)
        self._tile(e, "Kb", Kb, LANE, "lane", A.dtype)
        if B.shape[0] != M:
            self._flag(e, f"shape-AB-L{e.lineno}",
                       f"A has {M} rows but B has {B.shape[0]} — the "
                       f"shared reduction dim disagrees")
        return Arr((Ka, Kb), A.dtype)


def check_kernel_module(tree: ast.Module,
                        cases: Optional[List[Case]] = None
                        ) -> Tuple[List[Violation], List[str]]:
    """Run every shape case against a kernels/ops module AST.

    Returns ``(violations, errors)``: constraint violations deduped by
    site+kind (with the witnessing cases folded into the message), and
    interpreter errors (unsupported constructs — reported as warnings so
    a refactor that breaks the interpreter is visible, not silent).
    """
    interp = ShapeInterp(tree)
    errors: List[str] = []
    for case in cases if cases is not None else shape_cases():
        try:
            interp.run_case(case)
        except InterpError as err:
            errors.append(f"[{case.label}] {err}")
    # dedupe across cases: one finding per (site, kind)
    by_key: Dict[Tuple[int, str], Violation] = {}
    witnesses: Dict[Tuple[int, str], List[str]] = {}
    for v in interp.violations:
        key = (v.lineno, v.kind)
        if key not in by_key:
            by_key[key] = v
            witnesses[key] = []
        if v.case not in witnesses[key]:
            witnesses[key].append(v.case)
    out: List[Violation] = []
    for key, v in sorted(by_key.items()):
        cases_str = ", ".join(witnesses[key][:3])
        extra = len(witnesses[key]) - 3
        if extra > 0:
            cases_str += f", +{extra} more"
        out.append(dataclasses.replace(
            v, message=f"{v.message} [cases: {cases_str}]"))
    return out, sorted(set(errors))
