"""Factor-mask taint domain: the dataflow ground for rule RPL005.

The zero-inactive-columns invariant (ROADMAP architecture map) demands
that every tensor *written into* a factor buffer has exactly-zero
inactive columns.  PR 7 checked this lexically ("a mask name is
referenced somewhere in the enclosing function"), which is both unsound
(mask applied on only one branch, or to the wrong variable) and noisy
(clean plumbing needed suppressions).  This module gives each variable a
mask *status* and pushes it through the CFG with
:mod:`repro.analysis.dataflow`, so the rule can ask the real question:
is the written value sanitizer-dominated on **every** path to the write?

Status lattice (a total order by badness; join takes the worst):

- ``MASK``   — the value *is* an inactive-column mask
  (``rank_mask``/``augmented_mask``/... output, or an ``arange``-vs-rank
  comparison).
- ``MASKED`` — a tensor whose inactive columns are provably zero here:
  sanitizer output, a factor-leaf read (``f.U`` — inductively invariant),
  an all-zeros buffer, or anything multiplied by a MASK/MASKED value
  (elementwise zero absorbs).
- ``CLEAN``  — an existing value moved verbatim (parameter, subscript,
  ``asarray``) or a known non-array (PartitionSpec templates): fine to
  *re-wrap* into a factor, but not proof that a computed write is masked.
- ``FRESH``  — computed with no dominating sanitizer: the taint.

Sinks (checked by the rule, not here): factor-constructor kwargs must
not be FRESH; ``.at[...].set`` on a factor leaf requires MASK/MASKED.
"""
from __future__ import annotations

import ast
from typing import Dict, Optional, Set, Tuple

from repro.analysis.cfg import BranchTest, LoopBind
from repro.analysis.dataflow import ForwardAnalysis

# badness-ordered statuses (join = max)
MASK, MASKED, CLEAN, FRESH = 0, 1, 2, 3
STATUS_NAMES = {MASK: "mask", MASKED: "masked", CLEAN: "clean", FRESH: "fresh"}

#: a variable's abstract value: (status, aliases-a-factor-leaf)
Val = Tuple[int, bool]

FACTOR_LEAVES = {"U", "S", "V"}
FACTOR_CTORS = {"LowRankFactor", "AugmentedFactor"}

#: calls producing a mask
MASK_MAKERS = {"rank_mask", "augmented_mask", "coeff_grad_mask"}
#: calls whose output satisfies the invariant by construction
SANITIZERS = {"mask_coeff", "init_factor", "zero_inactive", "check_invariants"}
#: all-zero constructors (vacuously invariant)
ZERO_MAKERS = {"zeros", "zeros_like"}
#: identity movers: output is the input, bit for bit
MOVERS = {"asarray", "array", "device_get", "device_put", "stop_gradient"}
#: constructors of non-tensor values (sharding templates etc.)
NONARRAY_CTORS = {
    "P", "PartitionSpec", "NamedSharding", "Mesh", "ShapeDtypeStruct",
}
#: method calls that return their receiver's data unchanged (modulo
#: dtype/layout), so its status carries over
PRESERVING_METHODS = {"astype", "reshape", "copy", "conj", "block_until_ready"}


def call_leaf(node: ast.Call) -> str:
    """Last dotted component of the callee (``a.b.c(...)`` → ``c``)."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def nonarray_functions(tree: ast.AST) -> Set[str]:
    """Module-level defs whose every ``return`` is a known non-array
    (PartitionSpec-like constructor or constant) — calls to them are CLEAN.
    """
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        returns = [
            n for n in ast.walk(node) if isinstance(n, ast.Return)
        ]
        if not returns:
            continue

        def nonarray(e: Optional[ast.expr]) -> bool:
            if e is None or isinstance(e, ast.Constant):
                return True
            return isinstance(e, ast.Call) and call_leaf(e) in NONARRAY_CTORS

        if all(nonarray(r.value) for r in returns):
            out.add(node.name)
    return out


def _has_arange(node: ast.AST) -> bool:
    return any(
        isinstance(n, ast.Call) and call_leaf(n) == "arange"
        for n in ast.walk(node)
    )


class FactorTaint(ForwardAnalysis):
    """Per-variable mask-status analysis for one scope (function/module).

    ``params`` are the scope's bindings on entry; factor-leaf names
    (``U``/``S``/``V``) enter as MASKED leaves (the invariant holds
    inductively at function boundaries), everything else as CLEAN.
    """

    def __init__(self, params: Tuple[str, ...] = (),
                 nonarray_funcs: Optional[Set[str]] = None):
        self.params = tuple(params)
        self.nonarray_funcs = nonarray_funcs or set()

    # -- lattice ----------------------------------------------------------

    def initial(self) -> Dict[str, Val]:
        state: Dict[str, Val] = {}
        for p in self.params:
            if p in FACTOR_LEAVES:
                state[p] = (MASKED, True)
            else:
                state[p] = (CLEAN, False)
        return state

    def join(self, a: Dict[str, Val], b: Dict[str, Val]) -> Dict[str, Val]:
        out = dict(a)
        for k, (st, leaf) in b.items():
            if k in out:
                st0, leaf0 = out[k]
                out[k] = (max(st0, st), leaf0 or leaf)
            else:
                out[k] = (st, leaf)
        return out

    # -- expressions -------------------------------------------------------

    def eval(self, state: Dict[str, Val], e: ast.AST) -> Val:
        """Abstract value of an expression in ``state``."""
        if isinstance(e, ast.Constant):
            return (CLEAN, False)
        if isinstance(e, ast.Name):
            if e.id in state:
                return state[e.id]
            if e.id in FACTOR_LEAVES:
                return (MASKED, True)
            return (CLEAN, False)
        if isinstance(e, ast.Attribute):
            if e.attr in FACTOR_LEAVES:
                return (MASKED, True)
            if e.attr in ("T", "mT", "at"):
                return self.eval(state, e.value)
            return (CLEAN, False)
        if isinstance(e, ast.Subscript):
            return self.eval(state, e.value)
        if isinstance(e, ast.Starred):
            return self.eval(state, e.value)
        if isinstance(e, ast.UnaryOp):
            return self.eval(state, e.operand)
        if isinstance(e, ast.BinOp):
            return self._binop(state, e)
        if isinstance(e, ast.BoolOp):
            vals = [self.eval(state, v) for v in e.values]
            st = max(v[0] for v in vals)
            return (st, False)
        if isinstance(e, ast.Compare):
            # arange-vs-rank comparisons build masks
            if _has_arange(e):
                return (MASK, False)
            return (CLEAN, False)
        if isinstance(e, ast.IfExp):
            b = self.eval(state, e.body)
            o = self.eval(state, e.orelse)
            return (max(b[0], o[0]), b[1] or o[1])
        if isinstance(e, ast.Call):
            return self._call(state, e)
        if isinstance(e, (ast.Tuple, ast.List)):
            if not e.elts:
                return (CLEAN, False)
            vals = [self.eval(state, v) for v in e.elts]
            return (max(v[0] for v in vals), any(v[1] for v in vals))
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return self.eval(state, e.elt)
        if isinstance(e, ast.DictComp):
            return self.eval(state, e.value)
        if isinstance(e, ast.Await):
            return self.eval(state, e.value)
        if isinstance(e, ast.NamedExpr):
            return self.eval(state, e.value)
        # Lambda, Dict, JoinedStr, Slice, comparators...
        return (CLEAN, False)

    def _binop(self, state: Dict[str, Val], e: ast.BinOp) -> Val:
        l = self.eval(state, e.left)
        r = self.eval(state, e.right)
        if isinstance(e.op, ast.Mult):
            # elementwise product: zeros absorb — one masked side suffices
            if l[0] <= MASKED or r[0] <= MASKED:
                return (MASKED, False)
            return (FRESH, False)
        if isinstance(e.op, (ast.BitOr, ast.BitAnd, ast.BitXor)):
            if l[0] == MASK and r[0] == MASK:
                return (MASK, False)
            return (max(l[0], r[0], MASKED), False)
        if isinstance(e.op, (ast.Add, ast.Sub)):
            # zeros + zeros stays zero; anything else can repopulate them
            if l[0] <= MASKED and r[0] <= MASKED:
                return (MASKED, False)
            return (FRESH, False)
        if isinstance(e.op, (ast.Div, ast.FloorDiv, ast.Mod)):
            # 0 / x == 0: the left side's zero columns survive
            if l[0] <= MASKED:
                return (MASKED, False)
            return (FRESH, False)
        # MatMult, Pow, shifts: computed afresh
        return (FRESH, False)

    def _call(self, state: Dict[str, Val], e: ast.Call) -> Val:
        leaf = call_leaf(e)
        if leaf in MASK_MAKERS:
            return (MASK, False)
        if leaf in SANITIZERS:
            return (MASKED, False)
        if leaf in ZERO_MAKERS:
            return (MASKED, False)
        if leaf in FACTOR_CTORS:
            # a constructed factor: its kwargs are themselves sink-checked
            return (MASKED, False)
        if leaf in NONARRAY_CTORS or leaf in self.nonarray_funcs:
            return (CLEAN, False)
        if leaf in MOVERS and e.args:
            return self.eval(state, e.args[0])
        if isinstance(e.func, ast.Attribute):
            recv = e.func.value
            if leaf in PRESERVING_METHODS:
                return self.eval(state, recv)
            if leaf in ("set", "add") and self._is_at_chain(recv):
                # buffer.at[...].set(v): worst of buffer and written value
                base = self.eval(state, self._at_base(recv))
                val = self.eval(state, e.args[0]) if e.args else (CLEAN, False)
                st = max(base[0], val[0], MASKED)  # never upgrade to MASK
                return (st, base[1])
        # unknown call: masked inputs propagate (diag/concat/qr of a
        # masked tensor stays column-masked in this codebase's idioms);
        # otherwise the result is freshly computed
        arg_vals = [self.eval(state, a) for a in e.args]
        arg_vals += [self.eval(state, kw.value) for kw in e.keywords]
        if any(v[0] <= MASKED for v in arg_vals):
            return (MASKED, False)
        return (FRESH, False)

    # -- .at[...] chains ---------------------------------------------------

    @staticmethod
    def _is_at_chain(node: ast.AST) -> bool:
        """True for ``<base>.at[...]`` expressions."""
        return (
            isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Attribute)
            and node.value.attr == "at"
        )

    @staticmethod
    def _at_base(node: ast.AST) -> ast.AST:
        """The buffer underneath ``<base>.at[...]``."""
        assert isinstance(node, ast.Subscript)
        assert isinstance(node.value, ast.Attribute)
        return node.value.value

    def at_set_sink(self, state: Dict[str, Val], call: ast.Call):
        """If ``call`` is ``<factor leaf>.at[...].set/add(v)``, return the
        written value's status, else None."""
        if not (
            isinstance(call.func, ast.Attribute)
            and call.func.attr in ("set", "add")
            and self._is_at_chain(call.func.value)
        ):
            return None
        base = self._at_base(call.func.value)
        if not self.eval(state, base)[1]:  # not a factor leaf
            return None
        if not call.args:
            return None
        return self.eval(state, call.args[0])[0]

    # -- transfer ----------------------------------------------------------

    def transfer(self, state: Dict[str, Val], stmt) -> Dict[str, Val]:
        if isinstance(stmt, BranchTest):
            return state
        if isinstance(stmt, LoopBind):
            out = dict(state)
            self._bind(out, stmt.target, (CLEAN, False))
            return out
        if isinstance(stmt, ast.Assign):
            out = dict(state)
            val = self.eval(state, stmt.value)
            for t in stmt.targets:
                self._assign(out, t, stmt.value, val)
            return out
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            out = dict(state)
            self._assign(out, stmt.target, stmt.value,
                         self.eval(state, stmt.value))
            return out
        if isinstance(stmt, ast.AugAssign):
            out = dict(state)
            synth = ast.BinOp(left=stmt.target, op=stmt.op, right=stmt.value)
            val = self.eval(state, synth)
            if isinstance(stmt.target, ast.Name):
                out[stmt.target.id] = val
            return out
        if isinstance(
            stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
        ):
            out = dict(state)
            out[stmt.name] = (CLEAN, False)
            return out
        if isinstance(stmt, (ast.Import, ast.ImportFrom)):
            out = dict(state)
            for alias in stmt.names:
                name = (alias.asname or alias.name).split(".")[0]
                out[name] = (CLEAN, False)
            return out
        if isinstance(stmt, ast.Delete):
            out = dict(state)
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    out.pop(t.id, None)
            return out
        return state

    def _assign(self, out: Dict[str, Val], target: ast.AST,
                value_expr: ast.AST, val: Val) -> None:
        if isinstance(target, (ast.Tuple, ast.List)) and isinstance(
            value_expr, (ast.Tuple, ast.List)
        ) and len(target.elts) == len(value_expr.elts):
            for t, v in zip(target.elts, value_expr.elts):
                self._assign(out, t, v, self.eval(out, v))
            return
        self._bind(out, target, val)

    def _bind(self, out: Dict[str, Val], target: ast.AST, val: Val) -> None:
        if isinstance(target, ast.Name):
            out[target.id] = val
        elif isinstance(target, (ast.Tuple, ast.List)):
            # unpacking an opaque value: every element inherits its status
            for t in target.elts:
                self._bind(out, t, (val[0], False))
        elif isinstance(target, ast.Starred):
            self._bind(out, target.value, val)
        # attribute/subscript stores don't (re)bind a local
