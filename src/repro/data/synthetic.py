"""Synthetic data generators.

The container is offline, so every experiment runs on generated data:

- :func:`make_homogeneous_lsq` / :func:`make_heterogeneous_lsq` — the
  paper's §4.1 convex least-squares problems, reproduced *exactly*
  (Legendre polynomial features, manufactured low-rank target).  These are
  the claim-validation workloads (Figs. 1 and 4).
- :func:`make_classification_data` — Gaussian-blob classification with a
  planted low-rank decision map: the CV-proxy for the Fig.-5 comparison
  (FeDLRT vs FedAvg/FedLin accuracy vs client count).
- :func:`make_token_stream` — Markov-chain language-modeling tokens with a
  planted low-rank transition structure, used by the LM examples and the
  100M-parameter end-to-end training driver.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


def legendre_basis(x: np.ndarray, n: int, *, normalized: bool = True) -> np.ndarray:
    """Evaluate Legendre polynomials P_0..P_{n-1} at ``x`` — shape (N, n).

    ``normalized=True`` rescales to the orthonormal basis on L²([-1,1])
    (``√((2k+1)/2)·P_k``); this leaves the problem class of §4.1 unchanged
    but makes the quadratic well-conditioned so gradient descent converges
    at the paper's reported pace.
    """
    out = np.zeros(x.shape + (n,), dtype=np.float32)
    out[..., 0] = 1.0
    if n > 1:
        out[..., 1] = x
    for k in range(1, n - 1):
        out[..., k + 1] = ((2 * k + 1) * x * out[..., k] - k * out[..., k - 1]) / (
            k + 1
        )
    if normalized:
        out *= np.sqrt((2 * np.arange(n) + 1) / 2.0).astype(np.float32)
    return out


@dataclasses.dataclass
class LeastSquaresProblem:
    """One federated least-squares instance (paper §4.1).

    ``px[c], py[c]`` are Legendre features of client ``c``'s samples and
    ``target[c]`` the manufactured function values; ``W_star`` the global
    minimizer (the manufactured rank-r matrix for the homogeneous case,
    the average of per-client targets for the heterogeneous one).
    """

    px: np.ndarray  # (C, N_c, n)
    py: np.ndarray  # (C, N_c, n)
    target: np.ndarray  # (C, N_c)
    W_star: np.ndarray  # (n, n)
    n: int
    rank_star: int


def _random_lowrank(rng: np.random.Generator, n: int, r: int) -> np.ndarray:
    a = rng.standard_normal((n, r)).astype(np.float32)
    b = rng.standard_normal((n, r)).astype(np.float32)
    return (a @ b.T) / np.sqrt(n)


def make_homogeneous_lsq(
    *, n: int = 20, rank: int = 4, num_points: int = 10_000, num_clients: int = 4, seed: int = 0
) -> LeastSquaresProblem:
    """Paper §4.1 homogeneous test: shared target, data split across clients."""
    rng = np.random.default_rng(seed)
    W_r = _random_lowrank(rng, n, rank)
    x = rng.uniform(-1, 1, size=num_points).astype(np.float32)
    y = rng.uniform(-1, 1, size=num_points).astype(np.float32)
    px, py = legendre_basis(x, n), legendre_basis(y, n)
    t = np.einsum("ni,ij,nj->n", px, W_r, py).astype(np.float32)
    N_c = num_points // num_clients
    sl = lambda a: a[: N_c * num_clients].reshape(num_clients, N_c, *a.shape[1:])
    return LeastSquaresProblem(
        px=sl(px), py=sl(py), target=sl(t), W_star=W_r, n=n, rank_star=rank
    )


def make_heterogeneous_lsq(
    *,
    n: int = 10,
    rank: int = 1,
    num_points: int = 10_000,
    num_clients: int = 4,
    seed: int = 0,
    shared_data: bool = False,
) -> LeastSquaresProblem:
    """Paper §4.1 heterogeneous test: per-client rank-1 target functions.

    ``shared_data=True`` reproduces the paper's setup literally (all
    clients see all sample points).  Note that with *identical* client
    features the per-client quadratics share one Hessian, local GD is an
    affine map common to all clients, and plain averaging converges to the
    global minimizer even without correction; the drift plateau of Fig. 1
    requires heterogeneous curvature.  The default therefore samples each
    client its *own* points (still uniform on [-1,1]²) — heterogeneous
    Hessians, visible client drift, correction provably needed (this is
    also FedLin's own experimental regime).

    ``W_star`` is the exact global minimizer from the normal equations of
    the pooled problem.
    """
    rng = np.random.default_rng(seed)
    # Per-client targets = common low-rank base + *zero-mean* rank-`rank`
    # perturbations (paired ±Δ).  Heterogeneity (and hence client drift) is
    # as strong as fully independent targets, but the pooled minimizer stays
    # essentially the low-rank base, so convergence-to-W* is measurable on
    # the rank-constrained manifold.
    W_base = _random_lowrank(rng, n, rank + 1)
    deltas = []
    for _ in range(num_clients // 2):
        d = _random_lowrank(rng, n, rank)
        deltas += [d, -d]
    if len(deltas) < num_clients:
        deltas.append(np.zeros((n, n), dtype=np.float32))
    W_c = np.stack([W_base + d for d in deltas[:num_clients]])
    px_c, py_c, t_c = [], [], []
    for c in range(num_clients):
        if shared_data and c > 0:
            px_c.append(px_c[0])
            py_c.append(py_c[0])
        else:
            x = rng.uniform(-1, 1, size=num_points).astype(np.float32)
            y = rng.uniform(-1, 1, size=num_points).astype(np.float32)
            px_c.append(legendre_basis(x, n))
            py_c.append(legendre_basis(y, n))
        t_c.append(
            np.einsum("ni,ij,nj->n", px_c[c], W_c[c], py_c[c]).astype(np.float32)
        )
    px_a, py_a, t_a = np.stack(px_c), np.stack(py_c), np.stack(t_c)
    # exact global minimizer: vec(W) solves the pooled normal equations
    feats = np.einsum("cni,cnj->cnij", px_a, py_a).reshape(-1, n * n)
    w_vec, *_ = np.linalg.lstsq(feats, t_a.reshape(-1), rcond=None)
    W_star = w_vec.reshape(n, n).astype(np.float32)
    return LeastSquaresProblem(
        px=px_a,
        py=py_a,
        target=t_a,
        W_star=W_star,
        n=n,
        rank_star=min(rank * num_clients, n),
    )


def make_classification_data(
    *,
    dim: int = 64,
    num_classes: int = 10,
    rank: int = 6,
    num_points: int = 8_192,
    noise: float = 0.35,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Features + labels with a planted low-rank linear decision map.

    ``logits = x @ (A B) + centers``; labels = argmax.  An MLP head needs a
    rank-≈``rank`` first layer to solve it — giving FeDLRT's rank adaption
    something real to find (Fig.-5-style CV proxy).
    """
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((dim, rank)).astype(np.float32) / np.sqrt(dim)
    B = rng.standard_normal((rank, num_classes)).astype(np.float32)
    x = rng.standard_normal((num_points, dim)).astype(np.float32)
    logits = x @ A @ B + noise * rng.standard_normal((num_points, num_classes))
    labels = np.argmax(logits, axis=-1).astype(np.int32)
    return x, labels


def make_token_stream(
    *,
    vocab_size: int = 512,
    num_tokens: int = 262_144,
    rank: int = 16,
    temperature: float = 1.0,
    seed: int = 0,
) -> np.ndarray:
    """Markov token stream with a planted low-rank transition matrix.

    Transition logits ``T = A Bᵀ`` (rank ``rank``): a model with enough
    effective rank can drive cross-entropy towards the chain's conditional
    entropy, so LM training on this stream shows genuine loss descent.
    """
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((vocab_size, rank)).astype(np.float32)
    B = rng.standard_normal((vocab_size, rank)).astype(np.float32)
    logits = (A @ B.T) / (np.sqrt(rank) * temperature)
    logits -= logits.max(axis=-1, keepdims=True)
    probs = np.exp(logits)
    probs /= probs.sum(axis=-1, keepdims=True)
    cdf = np.cumsum(probs, axis=-1)
    tokens = np.empty(num_tokens, dtype=np.int32)
    tok = int(rng.integers(vocab_size))
    u = rng.random(num_tokens)
    for i in range(num_tokens):
        tok = int(np.searchsorted(cdf[tok], u[i]))
        tokens[i] = min(tok, vocab_size - 1)
    return tokens
