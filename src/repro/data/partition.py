"""Federated dataset partitioners (horizontal FL: same features, split rows)."""
from __future__ import annotations

from typing import List

import numpy as np


def partition_iid(num_points: int, num_clients: int, *, seed: int = 0) -> List[np.ndarray]:
    """Uniform random equal-size split (the paper's CIFAR setup)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_points)
    per = num_points // num_clients
    return [perm[c * per : (c + 1) * per] for c in range(num_clients)]


def partition_sizes(partitions: List[np.ndarray]) -> np.ndarray:
    """``|X_c|`` per client — the natural aggregation weights of the
    paper's §2 weighted-average extension (pass as ``client_weights`` to
    the engine; it normalizes and slices them per active cohort)."""
    return np.asarray([len(p) for p in partitions], dtype=np.float32)


def partition_dirichlet(
    labels: np.ndarray, num_clients: int, *, alpha: float = 0.5, seed: int = 0
) -> List[np.ndarray]:
    """Label-skewed non-iid split via a Dirichlet prior (Hsu et al.).

    Lower ``alpha`` ⇒ more heterogeneity ⇒ stronger client drift — the
    regime where the paper's variance correction matters (Fig. 1 / Fig. 5).
    Client shares are rebalanced to equal sizes (the paper assumes
    ``|X_c|`` identical).
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    buckets: List[list] = [[] for _ in range(num_clients)]
    for k in classes:
        idx = np.where(labels == k)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(alpha * np.ones(num_clients))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for c, part in enumerate(np.split(idx, cuts)):
            buckets[c].extend(part.tolist())
    per = len(labels) // num_clients
    out = []
    spill: List[int] = []
    for c in range(num_clients):
        b = np.array(buckets[c], dtype=np.int64)
        rng.shuffle(b)
        out.append(b[:per])
        spill.extend(b[per:].tolist())
    rng.shuffle(spill)
    for c in range(num_clients):
        need = per - len(out[c])
        if need > 0:
            out[c] = np.concatenate([out[c], np.array(spill[:need], dtype=np.int64)])
            spill = spill[need:]
    return out
