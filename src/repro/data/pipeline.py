"""Batching pipeline for federated rounds.

Produces per-round batch pytrees with the ``(C, ...)`` or ``(C, s*, b, ...)``
client-leading layout that :func:`repro.core.fedlrt.fedlrt_round` consumes.
Deterministic, restartable (state = round index), no host-side dependency
beyond numpy.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


class FederatedBatcher:
    """Cycles through each client's shard in shuffled epochs.

    Parameters
    ----------
    arrays: dict of data arrays, first axis = sample.
    partitions: list (len C) of index arrays into the sample axis.
    batch_size: per-client per-step batch.
    steps_per_round: s* (yields ``(C, s*, b, ...)``) or None (``(C, b, ...)``
        with one batch per round reused for every local step).
    """

    def __init__(
        self,
        arrays: Dict[str, np.ndarray],
        partitions: Sequence[np.ndarray],
        *,
        batch_size: int,
        steps_per_round: int | None = None,
        seed: int = 0,
    ):
        self.arrays = arrays
        self.partitions = [np.asarray(p) for p in partitions]
        self.batch_size = batch_size
        self.steps_per_round = steps_per_round
        self.rng = np.random.default_rng(seed)
        self._cursors = [0] * len(partitions)
        self._orders: List[np.ndarray] = [
            self.rng.permutation(p) for p in self.partitions
        ]

    @property
    def num_clients(self) -> int:
        return len(self.partitions)

    def _take(self, c: int, k: int) -> np.ndarray:
        idx = np.empty(k, dtype=np.int64)
        got = 0
        while got < k:
            avail = len(self._orders[c]) - self._cursors[c]
            take = min(avail, k - got)
            idx[got : got + take] = self._orders[c][
                self._cursors[c] : self._cursors[c] + take
            ]
            got += take
            self._cursors[c] += take
            if self._cursors[c] >= len(self._orders[c]):
                self._orders[c] = self.rng.permutation(self.partitions[c])
                self._cursors[c] = 0
        return idx

    def next_round(self) -> Dict[str, np.ndarray]:
        C, b, s = self.num_clients, self.batch_size, self.steps_per_round
        k = b * (s or 1)
        idx = np.stack([self._take(c, k) for c in range(C)])  # (C, k)
        out = {}
        for name, arr in self.arrays.items():
            g = arr[idx.reshape(-1)].reshape((C, k) + arr.shape[1:])
            if s is not None:
                g = g.reshape((C, s, b) + arr.shape[1:])
            else:
                g = g.reshape((C, b) + arr.shape[1:])
            out[name] = g
        return out
