"""Batching pipeline for federated rounds.

Produces per-round batch pytrees with the ``(C, ...)`` or ``(C, s*, b, ...)``
client-leading layout that :func:`repro.core.fedlrt.fedlrt_round` consumes,
where ``C`` is the *active cohort* of the round (all clients, or the subset
chosen by a :class:`repro.fed.participation.Participation` policy).
Deterministic, restartable, no host-side dependency beyond numpy.

Cohort semantics: every client owns an independent shuffled stream over its
shard (per-client RNG seeded with ``(seed, c)``), and a client's cursor
advances **only in rounds it participates in**.  Consequently the sequence
of batches a client sees depends solely on how many rounds it has been
sampled into — not on which other clients were active — which is what makes
partial-participation runs reproducible and comparable against
full-participation baselines.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np


class FederatedBatcher:
    """Cycles through each client's shard in shuffled epochs.

    Parameters
    ----------
    arrays: dict of data arrays, first axis = sample.
    partitions: list (len C) of index arrays into the sample axis.
    batch_size: per-client per-step batch.
    steps_per_round: s* (yields ``(C, s*, b, ...)``) or None (``(C, b, ...)``
        with one batch per round reused for every local step).
    seed: base seed; client ``c`` draws from ``default_rng((seed, c))``.
    """

    def __init__(
        self,
        arrays: Dict[str, np.ndarray],
        partitions: Sequence[np.ndarray],
        *,
        batch_size: int,
        steps_per_round: int | None = None,
        seed: int = 0,
    ):
        self.arrays = arrays
        self.partitions = [np.asarray(p) for p in partitions]
        self.batch_size = batch_size
        self.steps_per_round = steps_per_round
        self.seed = seed
        C = len(self.partitions)
        self._rngs = [np.random.default_rng((seed, c)) for c in range(C)]
        self._cursors = [0] * C
        self._orders: List[np.ndarray] = [
            rng.permutation(p) for rng, p in zip(self._rngs, self.partitions)
        ]

    @property
    def num_clients(self) -> int:
        return len(self.partitions)

    def _take(self, c: int, k: int) -> np.ndarray:
        idx = np.empty(k, dtype=np.int64)
        got = 0
        while got < k:
            avail = len(self._orders[c]) - self._cursors[c]
            take = min(avail, k - got)
            idx[got : got + take] = self._orders[c][
                self._cursors[c] : self._cursors[c] + take
            ]
            got += take
            self._cursors[c] += take
            if self._cursors[c] >= len(self._orders[c]):
                self._orders[c] = self._rngs[c].permutation(self.partitions[c])
                self._cursors[c] = 0
        return idx

    def next_round(self, cohort: Optional[Sequence[int]] = None) -> Dict[str, np.ndarray]:
        """Batches for one round.  ``cohort`` (optional) selects the active
        clients; leaves come back with a leading axis of ``len(cohort)`` in
        cohort order.  Inactive clients' streams are untouched."""
        if cohort is None:
            cohort = range(self.num_clients)
        cohort = [int(c) for c in cohort]
        b, s = self.batch_size, self.steps_per_round
        k = b * (s or 1)
        idx = np.stack([self._take(c, k) for c in cohort])  # (|cohort|, k)
        K = len(cohort)
        out = {}
        for name, arr in self.arrays.items():
            g = arr[idx.reshape(-1)].reshape((K, k) + arr.shape[1:])
            if s is not None:
                g = g.reshape((K, s, b) + arr.shape[1:])
            else:
                g = g.reshape((K, b) + arr.shape[1:])
            out[name] = g
        return out

    # -- restartability ----------------------------------------------------

    def state(self) -> Dict[str, Any]:
        """Snapshot of the stream state (cursors, orders, RNG states) —
        JSON-unfriendly but npz/pickle-able; pair with the constructor args
        to resume a run mid-epoch."""
        return {
            "cursors": list(self._cursors),
            "orders": [o.copy() for o in self._orders],
            "rng_states": [rng.bit_generator.state for rng in self._rngs],
        }

    def set_state(self, state: Dict[str, Any]) -> None:
        self._cursors = list(state["cursors"])
        self._orders = [np.asarray(o) for o in state["orders"]]
        for rng, st in zip(self._rngs, state["rng_states"]):
            rng.bit_generator.state = st
