from repro.data.synthetic import (  # noqa: F401
    LeastSquaresProblem,
    make_classification_data,
    make_heterogeneous_lsq,
    make_homogeneous_lsq,
    make_token_stream,
)
from repro.data.partition import (  # noqa: F401
    partition_dirichlet,
    partition_iid,
    partition_sizes,
)
from repro.data.pipeline import FederatedBatcher  # noqa: F401
