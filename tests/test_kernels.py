"""Pallas kernel validation: shape/dtype sweeps vs the jnp oracles,
executed in interpret mode (kernel bodies run in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import coeff_grad_kernels, lowrank_apply, lowrank_apply_kernels
from repro.kernels import ref
from repro.kernels.coeff_grad import atb
from repro.kernels.lowrank_matmul import avt, xus


def _inputs(M, K, N, R, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    U = (jax.random.normal(ks[1], (K, R)) / np.sqrt(K)).astype(dtype)
    S = jax.random.normal(ks[2], (R, R), dtype)
    V = (jax.random.normal(ks[3], (N, R)) / np.sqrt(N)).astype(dtype)
    return x, U, S, V


# bf16 mantissa = 8 bits; with R=128-term dot products the oracle (f32) and
# kernel (bf16 inputs, f32 accumulate) legitimately differ by ~1e-1 absolute.
TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4), jnp.bfloat16: dict(rtol=5e-2, atol=1.5e-1)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,K,N,R",
    [
        (8, 16, 8, 8),       # tiny
        (64, 96, 80, 24),    # unaligned rank (pads to 128 lanes)
        (128, 256, 128, 128),  # aligned
        (56, 512, 40, 16),   # M,N not multiples of block
    ],
)
def test_lowrank_forward_sweep(M, K, N, R, dtype):
    x, U, S, V = _inputs(M, K, N, R, dtype)
    y_ref = ref.lowrank_matmul_ref(
        x.astype(jnp.float32), U.astype(jnp.float32),
        S.astype(jnp.float32), V.astype(jnp.float32),
    )
    y = lowrank_apply_kernels(x, U, S, V, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref), **TOL[dtype]
    )


@pytest.mark.parametrize("M,K,N,R", [(32, 48, 40, 16), (64, 128, 64, 32)])
def test_lowrank_custom_vjp_matches_reference(M, K, N, R):
    x, U, S, V = _inputs(M, K, N, R, jnp.float32)
    dy = jax.random.normal(jax.random.PRNGKey(7), (M, N))

    def loss(use_kernels):
        return jax.grad(
            lambda *a: jnp.sum(lowrank_apply(*a, use_kernels) * dy),
            argnums=(0, 1, 2, 3),
        )(x, U, S, V)

    for a, b in zip(loss(False), loss(True)):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_coeff_grad_projection():
    x, U, S, V = _inputs(64, 96, 80, 24, jnp.float32)
    dy = jax.random.normal(jax.random.PRNGKey(3), (64, 80))
    got = coeff_grad_kernels(x, dy, U, V, interpret=True)
    want = (x @ U).T @ (dy @ V)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bk", [(8, 16), (16, 128), (32, 256)])
def test_xus_tilings(bm, bk):
    x, U, S, _ = _inputs(64, 256, 8, 128, jnp.float32)
    got = xus(x, U, S, bm=bm, bk=bk, interpret=True)
    np.testing.assert_allclose(got, ref.xus_ref(x, U, S), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn", [(8, 8), (32, 64)])
def test_avt_tilings(bm, bn):
    A = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    V = jax.random.normal(jax.random.PRNGKey(1), (64, 128))
    got = avt(A, V, bm=bm, bn=bn, interpret=True)
    np.testing.assert_allclose(got, ref.avt_ref(A, V), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bka", [(8, 8), (32, 64), (64, 128)])
def test_atb_tilings(bm, bka):
    A = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    B = jax.random.normal(jax.random.PRNGKey(1), (64, 96))
    got = atb(A, B, bm=bm, bka=bka, interpret=True)
    np.testing.assert_allclose(got, ref.atb_ref(A, B), rtol=1e-4, atol=1e-4)


def test_hypothesis_random_shapes():
    """Property-style sweep: random (M,K,N,R) keep kernels == oracle."""
    rng = np.random.default_rng(0)
    for _ in range(6):
        M = int(rng.integers(1, 9)) * 8
        K = int(rng.integers(1, 9)) * 16
        N = int(rng.integers(1, 9)) * 8
        R = int(rng.integers(1, 5)) * 8
        x, U, S, V = _inputs(M, K, N, R, jnp.float32, seed=int(rng.integers(1e6)))
        y = lowrank_apply_kernels(x, U, S, V, interpret=True)
        np.testing.assert_allclose(
            y, ref.lowrank_matmul_ref(x, U, S, V), rtol=1e-4, atol=1e-4
        )
