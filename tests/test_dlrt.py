"""Unit tests for the BUG-splitting primitives (augment / truncate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dlrt import augment_basis, pick_rank, qr_pos, truncate
from repro.core.factorization import (
    AugmentedFactor,
    init_factor,
    materialize,
)


def test_qr_pos_preserves_leading_orthonormal_block(rng_key):
    f = init_factor(rng_key, 40, 40, r_max=8)
    G = jax.random.normal(jax.random.PRNGKey(1), (40, 8))
    Q = qr_pos(jnp.concatenate([f.U, G], axis=1))
    # Lemma 1 precondition: Q's leading columns equal U exactly
    np.testing.assert_allclose(Q[:, :8], f.U, atol=1e-5)
    np.testing.assert_allclose(Q.T @ Q, jnp.eye(16), atol=1e-5)


@pytest.mark.parametrize("method", ["cholqr2", "householder"])
def test_augment_lemma1(rng_key, method):
    """S̃ = [[S,0],[0,0]] must equal the explicit projection ŨᵀUSVᵀṼ (Lemma 1)."""
    f = init_factor(rng_key, 40, 30, r_max=6, init_rank=4)
    GU = jax.random.normal(jax.random.PRNGKey(1), f.U.shape)
    GV = jax.random.normal(jax.random.PRNGKey(2), f.V.shape)
    aug = augment_basis(f, GU, GV, method=method)
    explicit = aug.U.T @ materialize(f) @ aug.V
    np.testing.assert_allclose(aug.S, explicit, atol=1e-4)
    # augmented factor represents the same matrix
    np.testing.assert_allclose(materialize(aug), materialize(f), atol=1e-4)
    # active augmented columns orthonormal; inactive exactly zero
    from repro.core.factorization import augmented_mask

    am = augmented_mask(f.rank, f.r_max)
    gram = aug.U.T @ aug.U
    want = jnp.eye(12) * am[None, :] * am[:, None]
    np.testing.assert_allclose(gram * am[None] * am[:, None], want, atol=1e-4)
    np.testing.assert_allclose(aug.U * (1 - am)[None, :], 0.0, atol=1e-6)


def test_augment_contains_gradient_span(rng_key):
    """The augmented column space must contain span(U) + span(G_U) (Eq. 6)."""
    f = init_factor(rng_key, 40, 40, r_max=4, init_rank=4)
    GU = jax.random.normal(jax.random.PRNGKey(1), f.U.shape)
    aug = augment_basis(f, GU, GU)
    P = aug.U @ aug.U.T  # projector onto augmented span
    for M in (f.U, GU):
        np.testing.assert_allclose(P @ M, M, atol=1e-4)


def test_pick_rank():
    sigma = jnp.array([4.0, 2.0, 1.0, 0.1, 0.01, 0.0])
    # keep while tail-norm >= theta
    # tails: k=3 → ‖[.1,.01,0]‖≈.1005, k=2 → ≈1.005, k=1 → ≈2.24
    assert float(pick_rank(sigma, jnp.float32(0.2), r_max=3)) == 3
    assert float(pick_rank(sigma, jnp.float32(1.5), r_max=3)) == 2
    assert float(pick_rank(sigma, jnp.float32(3.0), r_max=3)) == 1
    assert float(pick_rank(sigma, jnp.float32(100.0), r_max=3)) == 1
    # never exceeds r_max
    assert float(pick_rank(sigma, jnp.float32(1e-9), r_max=4)) == 4


def test_truncate_error_bound(rng_key):
    """‖W_trunc − W̃*‖ ≤ ϑ (the singular-value tail criterion)."""
    f = init_factor(rng_key, 40, 40, r_max=8, init_rank=8)
    GU = jax.random.normal(jax.random.PRNGKey(1), f.U.shape)
    GV = jax.random.normal(jax.random.PRNGKey(2), f.V.shape)
    aug = augment_basis(f, GU, GV)
    S_star = jax.random.normal(jax.random.PRNGKey(3), aug.S.shape)
    aug = AugmentedFactor(U=aug.U, S=S_star, V=aug.V, rank=aug.rank)
    new_f, info = truncate(aug, tau=0.3)
    err = jnp.linalg.norm(materialize(new_f) - materialize(aug))
    # err equals the discarded tail; both must respect the reported values
    np.testing.assert_allclose(err, info["trunc_err"], rtol=1e-3, atol=1e-4)
    assert 1 <= float(info["rank"]) <= f.r_max


def test_truncate_keeps_invariants(rng_key):
    f = init_factor(rng_key, 32, 32, r_max=6, init_rank=6)
    GU = jax.random.normal(jax.random.PRNGKey(1), f.U.shape)
    GV = jax.random.normal(jax.random.PRNGKey(2), f.V.shape)
    aug = augment_basis(f, GU, GV)
    new_f, info = truncate(aug, tau=0.1)
    from repro.core.factorization import check_invariants

    inv = check_invariants(new_f)
    assert float(inv["u_ortho_defect"]) < 1e-3
    assert float(inv["v_ortho_defect"]) < 1e-3
    assert float(inv["s_mask_violation"]) < 1e-6
    # S is diagonal after truncation
    S = np.asarray(new_f.S)
    np.testing.assert_allclose(S, np.diag(np.diag(S)), atol=1e-6)


def test_low_rank_target_recovers_exact_rank(rng_key):
    """Truncating a noiseless rank-3 coefficient finds rank exactly 3."""
    f = init_factor(rng_key, 32, 32, r_max=8, init_rank=8)
    GU = jax.random.normal(jax.random.PRNGKey(1), f.U.shape)
    GV = jax.random.normal(jax.random.PRNGKey(2), f.V.shape)
    aug = augment_basis(f, GU, GV)
    a = jax.random.normal(jax.random.PRNGKey(3), (16, 3))
    b = jax.random.normal(jax.random.PRNGKey(4), (16, 3))
    S_star = a @ b.T
    new_f, info = truncate(
        AugmentedFactor(U=aug.U, S=S_star, V=aug.V, rank=aug.rank), tau=1e-4
    )
    assert float(info["rank"]) == 3
