"""Partial client participation: policies, engine plumbing, invariances.

The load-bearing invariance: a uniform-k policy with k == C must reproduce
the full-participation run *bit-for-bit* (sorted cohorts, same batch
stacking order, same jit executable), so partial-participation experiments
are directly comparable against the paper's full-participation results.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedConfig, init_factor, lr_matmul, materialize
from repro.data import FederatedBatcher, make_classification_data, partition_iid
from repro.fed import FederatedEngine, Participation

C, DIM, NCLS = 4, 16, 4


def _loss(f, batch):
    logits = lr_matmul(batch["x"], f)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))


def _setup(seed=0):
    x, y = make_classification_data(
        dim=DIM, num_classes=NCLS, rank=3, num_points=1024, noise=0.2, seed=seed
    )
    parts = partition_iid(len(x), C, seed=seed)
    batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=16, seed=seed)
    f = init_factor(jax.random.PRNGKey(seed), DIM, NCLS, r_max=4, init_rank=4)
    cfg = FedConfig(
        num_clients=C, s_star=3, lr=0.05, correction="simplified", tau=0.05,
        eval_after=False,
    )
    return f, cfg, batcher


# ------------------------------------------------------------------ policies
def test_full_mode_is_identity():
    p = Participation()
    np.testing.assert_array_equal(p.cohort(0, 5), np.arange(5))
    np.testing.assert_array_equal(p.cohort(99, 5), np.arange(5))


def test_uniform_mode_samples_sorted_subsets():
    p = Participation(mode="uniform", cohort_size=3, seed=1)
    seen = set()
    for r in range(20):
        c = p.cohort(r, 8)
        assert len(c) == 3 and len(set(c.tolist())) == 3
        assert np.all(np.diff(c) > 0)  # sorted, unique
        assert c.min() >= 0 and c.max() < 8
        # deterministic in (seed, round)
        np.testing.assert_array_equal(c, p.cohort(r, 8))
        seen.update(c.tolist())
    assert seen == set(range(8))  # over many rounds every client appears


def test_round_robin_covers_population_each_cycle():
    p = Participation(mode="round_robin", cohort_size=2, seed=0)
    union = set()
    for r in range(4):  # C/k = 4 rounds per cycle
        union.update(p.cohort(r, 8).tolist())
    assert union == set(range(8))


def test_dropout_excludes_stragglers_but_keeps_min_cohort():
    p = Participation(mode="dropout", dropout_prob=0.5, seed=0)
    sizes = [len(p.cohort(r, 8)) for r in range(50)]
    assert min(sizes) >= 1 and max(sizes) <= 8
    assert any(s < 8 for s in sizes)  # stragglers actually excluded
    # pathological straggling still yields a workable cohort
    p_all = Participation(mode="dropout", dropout_prob=1.0, min_cohort=2)
    assert len(p_all.cohort(0, 8)) == 2


def test_from_spec_parsing():
    assert Participation.from_spec("full").mode == "full"
    p = Participation.from_spec("uniform:3", seed=7)
    assert p.mode == "uniform" and p.cohort_size == 3 and p.seed == 7
    assert Participation.from_spec("round_robin:2").cohort_size == 2
    assert Participation.from_spec("dropout:0.25").dropout_prob == 0.25
    with pytest.raises(ValueError):
        Participation.from_spec("bogus")
    with pytest.raises(ValueError):
        Participation(mode="uniform")  # cohort_size required


def test_expected_cohort_size():
    assert Participation().expected_cohort_size(8) == 8.0
    assert Participation(mode="uniform", cohort_size=3).expected_cohort_size(8) == 3.0
    assert Participation(
        mode="dropout", dropout_prob=0.25
    ).expected_cohort_size(8) == pytest.approx(6.0)


# ------------------------------------------------------- engine invariances
def test_sampling_all_clients_matches_full_bitwise():
    """uniform-k with k == C ≡ full participation, bit-for-bit."""
    rounds = 3
    f, cfg, batcher_a = _setup()
    _, _, batcher_b = _setup()
    eng_full = FederatedEngine(_loss, f, cfg, method="fedlrt", donate=False)
    eng_samp = FederatedEngine(
        _loss, f, cfg, method="fedlrt",
        participation=Participation(mode="uniform", cohort_size=C, seed=3),
        donate=False,
    )
    eng_full.train(batcher_a, rounds, log_every=0)
    eng_samp.train(batcher_b, rounds, log_every=0)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        eng_full.params,
        eng_samp.params,
    )
    assert [r.loss_before for r in eng_full.history] == [
        r.loss_before for r in eng_samp.history
    ]
    assert all(r.cohort_size == C for r in eng_samp.history)


def test_partial_cohort_runs_and_comm_scales_with_cohort():
    f, cfg, batcher = _setup()
    eng = FederatedEngine(
        _loss, f, cfg, method="fedlrt",
        participation=Participation(mode="uniform", cohort_size=2, seed=0),
        donate=False,
    )
    hist = eng.train(batcher, 4, log_every=0)
    assert all(r.cohort_size == 2 for r in hist)
    assert all(len(r.cohort) == 2 for r in hist)
    # server comm counts only the active cohort, not the population; the
    # analytic figure agrees with the cost-model counter and the measured
    # figure with the wire layer's per-round byte counts
    from repro.core import cost_model

    per_client = hist[0].comm_bytes_per_client
    assert eng.comm_total_bytes_analytic() == pytest.approx(4 * 2 * per_client)
    assert eng.comm_total_bytes_analytic() == pytest.approx(
        4 * cost_model.round_total_comm_bytes(
            f, "fedlrt", correction=cfg.correction, cohort_size=2
        )
    )
    wire_pc = hist[0].wire_bytes_down_per_client + hist[0].wire_bytes_up_per_client
    assert wire_pc > 0
    assert eng.comm_total_bytes() == pytest.approx(4 * 2 * wire_pc)
    assert np.isfinite([r.loss_before for r in hist]).all()


def test_per_cohort_jit_cache_one_executable_per_size():
    """Static-cohort policies compile one executable per distinct size."""
    f, cfg, batcher = _setup()
    eng = FederatedEngine(
        _loss, f, cfg, method="fedlrt",
        participation=Participation(mode="uniform", cohort_size=2, seed=2),
        donate=False,
    )
    hist = eng.train(batcher, 6, log_every=0)
    assert set(eng._step_cache.keys()) == {(2, False)}


def test_dropout_cohort_padding_single_executable():
    """dropout's fluctuating cohorts are padded to the population size with
    zero-weight filler clients: one executable for the whole run."""
    f, cfg, batcher = _setup()
    eng = FederatedEngine(
        _loss, f, cfg, method="fedlrt",
        participation=Participation(mode="dropout", dropout_prob=0.4, seed=2),
        donate=False,
    )
    hist = eng.train(batcher, 6, log_every=0)
    sizes = {r.cohort_size for r in hist}
    assert len(sizes) > 1  # cohorts actually fluctuated …
    assert set(eng._step_cache.keys()) == {(C, True)}  # … one executable
    assert np.isfinite([r.loss_before for r in hist]).all()


def test_cohort_padding_matches_unpadded_round():
    """A padded round (zero-weight repeats) must equal the same cohort run
    unpadded — padding is mathematically inert."""
    f, cfg, _ = _setup()
    x, y = make_classification_data(
        dim=DIM, num_classes=NCLS, rank=3, num_points=1024, noise=0.2, seed=0
    )
    parts = partition_iid(len(x), C, seed=0)
    batch = FederatedBatcher(
        {"x": x, "y": y}, parts, batch_size=16, seed=0
    ).next_round([1, 3])
    batch = jax.tree.map(jnp.asarray, batch)

    eng_pad = FederatedEngine(
        _loss, f, cfg, method="fedlrt",
        participation=Participation(mode="dropout", dropout_prob=0.5, seed=0),
        donate=False,
    )
    res_pad = eng_pad.run_round(batch, cohort=[1, 3])
    eng_ref = FederatedEngine(_loss, f, cfg, method="fedlrt", donate=False)
    res_ref = eng_ref.run_round(batch, cohort=[1, 3])

    assert res_pad.cohort_size == res_ref.cohort_size == 2
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5
        ),
        eng_pad.params,
        eng_ref.params,
    )
    np.testing.assert_allclose(res_pad.loss_before, res_ref.loss_before, atol=1e-6)
    # comm accounting stays at the true active-cohort size
    assert eng_pad.comm_total_bytes() == eng_ref.comm_total_bytes()


def test_engine_weighted_uniform_weights_match_unweighted():
    """client_weights plumbing through the engine: uniform |X_c| weights
    agree with the unweighted mean path (equal-size iid partitions)."""
    rounds = 2
    f, cfg, batcher_a = _setup()
    _, _, batcher_b = _setup()
    eng_plain = FederatedEngine(_loss, f, cfg, method="fedlrt", donate=False)
    eng_w = FederatedEngine(
        _loss, f, cfg, method="fedlrt", client_weights=np.full(C, 256.0), donate=False
    )
    eng_plain.train(batcher_a, rounds, log_every=0)
    eng_w.train(batcher_b, rounds, log_every=0)
    np.testing.assert_allclose(
        np.asarray(materialize(eng_plain.params)),
        np.asarray(materialize(eng_w.params)),
        atol=1e-5,
    )


def test_engine_weights_sliced_per_cohort():
    """Partial participation slices the population weight vector to the
    active cohort — skewing an absent client's weight must not matter."""
    f, cfg, _ = _setup()
    x, y = make_classification_data(
        dim=DIM, num_classes=NCLS, rank=3, num_points=1024, noise=0.2, seed=0
    )
    parts = partition_iid(len(x), C, seed=0)
    batch = FederatedBatcher({"x": x, "y": y}, parts, batch_size=16, seed=0).next_round(
        [0, 2]
    )
    batch = jax.tree.map(jnp.asarray, batch)
    w = np.array([1.0, 99.0, 1.0, 7.0], np.float32)
    eng = FederatedEngine(_loss, f, cfg, method="fedlrt", client_weights=w, donate=False)
    res = eng.run_round(batch, cohort=[0, 2])
    assert res.cohort_size == 2
    # same round with the absent clients' weights perturbed: identical
    w2 = np.array([1.0, -5.0, 1.0, 0.0], np.float32)
    eng2 = FederatedEngine(_loss, f, cfg, method="fedlrt", client_weights=w2, donate=False)
    res2 = eng2.run_round(batch, cohort=[0, 2])
    np.testing.assert_array_equal(
        np.asarray(materialize(eng.params)), np.asarray(materialize(eng2.params))
    )
    assert res.loss_before == res2.loss_before


def test_engine_all_methods_run_partial():
    """Every registered round method accepts cohort-sized batches."""
    x, y = make_classification_data(
        dim=DIM, num_classes=NCLS, rank=3, num_points=512, noise=0.2, seed=1
    )
    parts = partition_iid(len(x), C, seed=1)
    part = Participation(mode="round_robin", cohort_size=2, seed=1)
    for method in ("fedlrt", "fedavg", "fedlin"):
        if method == "fedlrt":
            params = init_factor(jax.random.PRNGKey(1), DIM, NCLS, r_max=4, init_rank=4)
            loss = _loss
        else:
            params = {"w": jnp.zeros((DIM, NCLS))}
            loss = lambda p, b: -jnp.mean(
                jnp.take_along_axis(
                    jax.nn.log_softmax(b["x"] @ p["w"]), b["y"][:, None], -1
                )
            )
        batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=8, seed=1)
        cfg = FedConfig(
            num_clients=C, s_star=2, lr=0.05, correction="none", tau=0.05,
            eval_after=False,
        )
        eng = FederatedEngine(loss, params, cfg, method=method, participation=part, donate=False)
        hist = eng.train(batcher, 2, log_every=0)
        assert all(r.cohort_size == 2 for r in hist)
        assert np.isfinite([r.loss_before for r in hist]).all()
