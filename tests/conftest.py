"""Shared fixtures: least-squares problems + loss functions.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (in its own process).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_heterogeneous_lsq, make_homogeneous_lsq


def lsq_loss(f, batch):
    """Paper §4.1 loss on a LowRankFactor/AugmentedFactor (through the bottleneck)."""
    pred = jnp.sum(((batch["px"] @ f.U) @ f.S) * (batch["py"] @ f.V), -1)
    return 0.5 * jnp.mean((pred - batch["t"]) ** 2)


def lsq_dense_loss(W, batch):
    pred = jnp.einsum("ni,ij,nj->n", batch["px"], W, batch["py"])
    return 0.5 * jnp.mean((pred - batch["t"]) ** 2)


def as_batches(prob):
    return {
        "px": jnp.asarray(prob.px),
        "py": jnp.asarray(prob.py),
        "t": jnp.asarray(prob.target),
    }


def optimal_loss(prob):
    out = []
    for c in range(prob.px.shape[0]):
        pred = np.einsum("ni,ij,nj->n", prob.px[c], prob.W_star, prob.py[c])
        out.append(0.5 * np.mean((pred - prob.target[c]) ** 2))
    return float(np.mean(out))


@pytest.fixture(scope="session")
def homo_prob():
    return make_homogeneous_lsq(n=20, rank=4, num_points=2000, num_clients=4, seed=0)


@pytest.fixture(scope="session")
def hetero_prob():
    return make_heterogeneous_lsq(n=10, rank=1, num_points=1000, num_clients=4, seed=0)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def jit_trace_audit():
    """Fail the test if any jit callsite compiles more than once.

    Yields the live :class:`repro.analysis.TraceAudit` (counts per
    callsite; ``audit.limit`` is mutable for tests that legitimately
    expect N executables).  On exit, the fixture asserts every callsite
    stayed within the limit — the executable gate for the ROADMAP's
    "jit discipline" bullet (one executable per (cohort size, weighted)
    key; dropout cohorts padded with zero-weight clients).
    """
    from repro.analysis import trace_audit

    with trace_audit() as audit:
        yield audit
    audit.assert_within_limit()
