"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant (≤2 superblocks,
d_model ≤ 256, ≤4 experts) and runs, on CPU:
  - one forward/train loss (shape + finiteness),
  - one full FeDLRT aggregation round (loss must move, params stay finite),
  - prefill + decode-step consistency against a one-shot prefill.
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import FedConfig, fedlrt_round
from repro.models import build_model
from repro.models.config import reduced


def _reduced_cfg(arch):
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        # generous capacity so routing never drops tokens — makes the
        # decode-consistency check exact (capacity drops are path-dependent
        # by design; see test_moe_capacity_drops for the binding case)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    return cfg


def _batch(cfg, C=None, B=2, T=24, seed=1):
    lead = (C,) if C else ()
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], lead + (B, T + 1), 0, cfg.vocab_size)
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            ks[1], lead + (B, cfg.vision_tokens, cfg.d_model)
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], lead + (B, cfg.encoder.num_frames, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    cfg = _reduced_cfg(arch)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(specs) == jax.tree.structure(
        jax.tree.map(lambda x: 0, params)
    )

    # ---- forward loss: right magnitude, finite
    batch = _batch(cfg)
    loss = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)

    # ---- one FeDLRT round
    C = 2
    fc = FedConfig(num_clients=C, s_star=2, lr=5e-3, correction="simplified", tau=0.05)
    fbatch = _batch(cfg, C=C)
    new_params, met = jax.jit(lambda p, b: fedlrt_round(model.loss_fn, p, b, fc))(
        params, fbatch
    )
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(new_params))
    assert float(met["loss_after"]) < float(met["loss_before"]) + 0.05

    # ---- decode consistency: prefill(T) == prefill(T-2) + 2 steps
    toks = batch["tokens"][:, :-1]
    T = toks.shape[1]
    extra = {k: v for k, v in batch.items() if k != "tokens"}
    CL = T + cfg.vision_tokens + 8
    full_logits, _ = model.serve_prefill(params, {"tokens": toks, **extra}, cache_len=CL)
    lg, cache = model.serve_prefill(
        params, {"tokens": toks[:, : T - 2], **extra}, cache_len=CL
    )
    for t in range(T - 2, T):
        lg, cache = model.serve_step(params, cache, toks[:, t : t + 1])
    rel = float(jnp.abs(full_logits - lg).max()) / (
        float(jnp.abs(full_logits).max()) + 1e-9
    )
    assert rel < 1e-3, (arch, rel)


@pytest.mark.parametrize("arch", ["qwen2_7b", "jamba_15_large", "rwkv6_7b"])
def test_arch_fedlrt_training_descends(arch):
    """A few FeDLRT rounds reduce the LM loss on a fixed batch."""
    cfg = _reduced_cfg(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    C = 2
    fc = FedConfig(num_clients=C, s_star=3, lr=5e-3, correction="simplified", tau=0.05)
    fbatch = _batch(cfg, C=C)
    step = jax.jit(lambda p, b: fedlrt_round(model.loss_fn, p, b, fc))
    p, m0 = step(params, fbatch)
    for _ in range(3):
        p, m = step(p, fbatch)
    assert float(m["loss_after"]) < float(m0["loss_before"]) - 0.05
