"""Model-layer unit tests: attention masks, RWKV6 chunking oracle, Mamba
scan oracle, MoE routing properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention
from repro.models.ssm import _rwkv_chunked


# --------------------------------------------------------------- attention
def _manual_attention(q, k, v, causal=True, window=0):
    B, T, H, hd = q.shape
    s = jnp.einsum("bqhd,bthd->bhqt", q, k) / np.sqrt(hd)
    i, j = jnp.arange(T)[:, None], jnp.arange(T)[None, :]
    m = jnp.ones((T, T), bool)
    if causal:
        m &= j <= i
    if window:
        m &= j > i - window
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhqt,bthd->bqhd", jax.nn.softmax(s, -1), v)


@pytest.mark.parametrize("chunk", [4, 7, 16, 64])
def test_blockwise_attention_matches_full(chunk):
    B, T, H, hd = 2, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, T, H, hd)) for kk in ks)
    pos = jnp.arange(T)
    out = attention(q, k, v, q_positions=pos, kv_positions=pos, causal=True, q_chunk=chunk)
    ref = _manual_attention(q, k, v)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_sliding_window_mask():
    B, T, H, hd = 1, 12, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, T, H, hd)) for kk in ks)
    pos = jnp.arange(T)
    out = attention(q, k, v, q_positions=pos, kv_positions=pos, causal=True,
                    sliding_window=4, q_chunk=64)
    ref = _manual_attention(q, k, v, window=4)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_gqa_matches_repeated_heads():
    """GQA == MHA with kv heads repeated."""
    B, T, H, Hkv, hd = 1, 8, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, Hkv, hd))
    v = jax.random.normal(ks[2], (B, T, Hkv, hd))
    pos = jnp.arange(T)
    out = attention(q, k, v, q_positions=pos, kv_positions=pos, causal=True, q_chunk=64)
    k_rep = jnp.repeat(k, H // Hkv, axis=2)
    v_rep = jnp.repeat(v, H // Hkv, axis=2)
    ref = _manual_attention(q, k_rep, v_rep)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_negative_kv_positions_are_invalid():
    """Slots marked with negative positions must get zero attention weight."""
    B, T, H, hd = 1, 4, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, 8, H, hd))
    v = jax.random.normal(ks[2], (B, 8, H, hd))
    kvp = jnp.array([0, 1, 2, 3, -(10**9), -(10**9), -(10**9), -(10**9)])
    out = attention(q, k, v, q_positions=jnp.arange(T), kv_positions=kvp,
                    causal=True, q_chunk=64)
    # poison the invalid slots — output must not change
    v_bad = v.at[:, 4:].set(1e6)
    out2 = attention(q, k, v_bad, q_positions=jnp.arange(T), kv_positions=kvp,
                     causal=True, q_chunk=64)
    np.testing.assert_allclose(out, out2, atol=1e-5)


# ------------------------------------------------------------------- RWKV6
def _rwkv_naive(r, k, v, logw, u, S0):
    B, T, H, hd = r.shape
    S = S0
    outs = []
    for t in range(T):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(logw[:, t])
        bonus = jnp.einsum("bhd,hd,bhd->bh", rt, u, kt)
        o = jnp.einsum("bhd,bhde->bhe", rt, S) + bonus[..., None] * vt
        S = S * wt[..., None] + jnp.einsum("bhd,bhe->bhde", kt, vt)
        outs.append(o)
    return jnp.stack(outs, 1), S


@pytest.mark.parametrize("chunk", [1, 8, 16, 37])
def test_rwkv_chunked_matches_naive(chunk):
    B, T, H, hd = 2, 37, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r, k, v = (jax.random.normal(kk, (B, T, H, hd)) for kk in ks[:3])
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)) * 0.5 - 1.0)
    u = 0.5 * jax.random.normal(ks[4], (H, hd))
    S0 = 0.1 * jax.random.normal(ks[5], (B, H, hd, hd))
    o_ref, S_ref = _rwkv_naive(r, k, v, logw, u, S0)
    o, S = _rwkv_chunked(r, k, v, logw, u, S0, chunk)
    np.testing.assert_allclose(o, o_ref, atol=1e-4)
    np.testing.assert_allclose(S, S_ref, atol=1e-4)


def test_rwkv_state_continuation():
    """Processing [first half; second half] with carried state == one shot."""
    B, T, H, hd = 1, 24, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    r, k, v = (jax.random.normal(kk, (B, T, H, hd)) for kk in ks[:3])
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)) * 0.3 - 1.0)
    u = jnp.zeros((H, hd))
    S0 = jnp.zeros((B, H, hd, hd))
    o_full, S_full = _rwkv_chunked(r, k, v, logw, u, S0, 8)
    o1, S1 = _rwkv_chunked(r[:, :12], k[:, :12], v[:, :12], logw[:, :12], u, S0, 8)
    o2, S2 = _rwkv_chunked(r[:, 12:], k[:, 12:], v[:, 12:], logw[:, 12:], u, S1, 8)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), o_full, atol=1e-4)
    np.testing.assert_allclose(S2, S_full, atol=1e-4)


# ------------------------------------------------------------------- Mamba
def test_mamba_decode_matches_train():
    """Sequential decode through mamba_mix == full-sequence forward."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.config import reduced
    from repro.models.layers import Builder
    from repro.models.config import LowRankPolicy
    from repro.models.ssm import build_mamba, mamba_init_state, mamba_mix

    cfg = reduced(get_config("jamba_15_large"))
    b = Builder(jax.random.PRNGKey(0), LowRankPolicy(enable=False))
    build_mamba(b, "m", cfg, 1)
    params, _ = b.build()
    p = jax.tree.map(lambda x: x[0], params["m"])  # drop the stack dim

    B, T = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    y_full, _ = mamba_mix(p, x, cfg, state=None)
    state = mamba_init_state(cfg, B, x.dtype)
    ys = []
    for t in range(T):
        y_t, state = mamba_mix(p, x[:, t : t + 1], cfg, state=state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_seq, y_full, atol=2e-3)


# --------------------------------------------------------------------- MoE
def test_moe_capacity_drops_tokens_when_binding():
    import dataclasses

    from repro.configs import get_config
    from repro.models.config import reduced
    from repro.models import build_model

    cfg = reduced(get_config("olmoe_1b_7b"))
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5)
    )
    loose = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
    )
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, cfg.vocab_size)
    }
    outs = {}
    for name, c in (("tight", tight), ("loose", loose)):
        model = build_model(c)
        params, _ = model.init(jax.random.PRNGKey(0))
        outs[name] = float(model.loss_fn(params, batch))
    # same params, different capacity ⇒ different loss (tokens dropped)
    assert outs["tight"] != outs["loose"]
    assert np.isfinite(outs["tight"]) and np.isfinite(outs["loose"])


def test_moe_router_gates_sum_to_one():
    from repro.models.moe import moe_block
    from repro.configs import get_config
    from repro.models.config import reduced
    from repro.models import build_model

    cfg = reduced(get_config("olmoe_1b_7b"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    moe_params = jax.tree.map(lambda x: x[0], params["blocks"]["pos0"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))
    y, aux = moe_block(moe_params, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 0.0
