"""Unit tests for the masked adaptive-rank factor algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.factorization import (
    LowRankFactor,
    augmented_mask,
    check_invariants,
    init_factor,
    lr_matmul,
    lr_rowlookup,
    mask_coeff,
    materialize,
    rank_mask,
)


def test_init_invariants(rng_key):
    f = init_factor(rng_key, 64, 48, r_max=12, init_rank=7)
    inv = check_invariants(f)
    assert float(inv["u_ortho_defect"]) < 1e-4
    assert float(inv["v_ortho_defect"]) < 1e-4
    assert float(inv["s_mask_violation"]) == 0.0
    assert float(f.rank) == 7


def test_rank_buffer_cap(rng_key):
    # r_max is capped at min(n_in, n_out)//2 so augmentation always fits
    f = init_factor(rng_key, 10, 40, r_max=32)
    assert f.r_max == 5


def test_materialize_rank(rng_key):
    f = init_factor(rng_key, 32, 32, r_max=8, init_rank=3)
    W = materialize(f)
    s = jnp.linalg.svd(W, compute_uv=False)
    assert float(s[3]) < 1e-5 * float(s[0])  # numerically rank 3


def test_lr_matmul_matches_materialized(rng_key):
    f = init_factor(rng_key, 32, 24, r_max=8, init_rank=5)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 32))
    np.testing.assert_allclose(
        lr_matmul(x, f), x @ materialize(f), rtol=1e-4, atol=1e-4
    )


def test_rowlookup_matches_materialized(rng_key):
    f = init_factor(rng_key, 50, 16, r_max=6)
    idx = jnp.array([0, 3, 49, 7])
    np.testing.assert_allclose(
        lr_rowlookup(idx, f), materialize(f)[idx], rtol=1e-4, atol=1e-4
    )


def test_masks():
    m = rank_mask(jnp.float32(3), 8)
    assert m.tolist() == [1, 1, 1, 0, 0, 0, 0, 0]
    am = augmented_mask(jnp.float32(2), 4)
    assert am.tolist() == [1, 1, 0, 0, 1, 1, 0, 0]
    S = jnp.ones((8, 8))
    Sm = mask_coeff(S, am)
    assert float(Sm.sum()) == 16.0  # 4x4 active entries


def test_inactive_columns_do_not_leak(rng_key):
    """Garbage in inactive U/V columns must not change W (S-mask invariant)."""
    f = init_factor(rng_key, 32, 32, r_max=8, init_rank=4)
    noise = jax.random.normal(jax.random.PRNGKey(2), f.U.shape)
    m = rank_mask(f.rank, f.r_max)
    U_dirty = f.U * m + noise * (1 - m)
    f_dirty = LowRankFactor(U=U_dirty, S=f.S, V=f.V, rank=f.rank)
    np.testing.assert_allclose(materialize(f_dirty), materialize(f), atol=1e-5)


def test_factor_is_pytree(rng_key):
    f = init_factor(rng_key, 16, 16, r_max=4)
    leaves = jax.tree.leaves(f)
    assert len(leaves) == 4  # U, S, V, rank
    f2 = jax.tree.map(lambda x: x * 1.0, f)
    assert isinstance(f2, LowRankFactor)


def test_grad_through_factor(rng_key):
    f = init_factor(rng_key, 16, 16, r_max=4)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16))

    g = jax.grad(lambda f_: jnp.sum(lr_matmul(x, f_) ** 2))(f)
    assert g.U.shape == f.U.shape and g.S.shape == f.S.shape
    # analytic: dL/dS = Uᵀ Gw V with Gw = xᵀ·2y
    y = lr_matmul(x, f)
    Gw = x.T @ (2 * y)
    np.testing.assert_allclose(g.S, f.U.T @ Gw @ f.V, rtol=1e-3, atol=1e-3)
