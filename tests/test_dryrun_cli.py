"""End-to-end dry-run CLI test (subprocess — owns its 512-device env).

Runs the fastest real combo (rwkv6-7b × long_500k, ~10 s compile) through
``python -m repro.launch.dryrun`` and validates the emitted JSON artifact:
roofline terms present and positive, memory analysis populated, and the
documented-skip path for a full-attention arch.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, out_dir):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--out", str(out_dir)] + args
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run(
        cmd, capture_output=True, text=True, env=env, cwd=ROOT, timeout=420
    )


@pytest.mark.slow
def test_dryrun_cli_compiles_and_reports(tmp_path):
    p = _run(["--arch", "rwkv6-7b", "--shape", "long_500k"], tmp_path)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "OK" in p.stdout
    path = tmp_path / "16x16__rwkv6-7b__long_500k.json"
    with open(path) as f:
        res = json.load(f)
    assert res["devices"] == 256
    rf = res["roofline"]
    assert rf["collective_bytes_per_device"] > 0
    assert rf["memory_s"] > 0
    assert rf["dominant"] in ("compute", "memory", "collective")
    assert res["memory"]["temp_bytes"] > 0
    assert res["compile_s"] > 0


@pytest.mark.slow
def test_dryrun_cli_documented_skip(tmp_path):
    p = _run(["--arch", "qwen2-7b", "--shape", "long_500k"], tmp_path)
    assert p.returncode == 0
    assert "SKIP" in p.stdout
    with open(tmp_path / "skip__qwen2-7b__long_500k.json") as f:
        res = json.load(f)
    assert "sub-quadratic" in res["skipped"]
