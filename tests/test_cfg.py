"""The dataflow tier under repro-lint: CFG shape on the Python constructs
the rules must model exactly (branches, loops with else, try/except/finally,
with-as, match, nested defs), fixpoint termination on loopy graphs, and the
FixpointDiverged guard against non-monotone transfer functions."""
from __future__ import annotations

import ast
import textwrap

import pytest

from repro.analysis.cfg import (
    ATOMIC_DEFS,
    BranchTest,
    LoopBind,
    build_cfg,
    CFG,
)
from repro.analysis.dataflow import (
    FixpointDiverged,
    ForwardAnalysis,
    run_forward,
    walk_states,
)


def cfg_of(code: str) -> CFG:
    return build_cfg(ast.parse(textwrap.dedent(code)))


def stmts_of(cfg: CFG):
    return [s for b in cfg.reachable() for s in b.stmts]


# ---------------------------------------------------------------------------
# CFG construction
# ---------------------------------------------------------------------------


def test_if_else_joins_and_branch_test_is_synthetic():
    cfg = cfg_of(
        """
        if cond:
            a = 1
        else:
            a = 2
        b = a
        """
    )
    tests = [s for s in stmts_of(cfg) if isinstance(s, BranchTest)]
    assert len(tests) == 1
    assert isinstance(tests[0].origin, ast.If)
    # the block holding the test has two successors (then / else)
    (test_block,) = [b for b in cfg.blocks if tests[0] in b.stmts]
    assert len(test_block.succs) == 2
    # both arms reconverge before `b = a`
    (join,) = [
        b for b in cfg.reachable()
        if any(isinstance(s, ast.Assign)
               and isinstance(s.targets[0], ast.Name)
               and s.targets[0].id == "b" for s in b.stmts)
    ]
    assert len(join.preds) == 2


def test_while_else_runs_only_on_normal_exit():
    cfg = cfg_of(
        """
        while cond:
            body = 1
        else:
            tail = 2
        after = 3
        """
    )
    head = next(b for b in cfg.blocks if b.label == "while.head")
    # head branches into body and else (NOT straight to after)
    labels = sorted(s.label for s in head.succs)
    assert labels == ["while.body", "while.else"]
    body = next(b for b in cfg.blocks if b.label == "while.body")
    assert head in body.succs  # the back edge the fixpoint needs


def test_for_else_and_loop_bind():
    cfg = cfg_of(
        """
        for x in xs:
            use(x)
        else:
            done = 1
        """
    )
    binds = [s for s in stmts_of(cfg) if isinstance(s, LoopBind)]
    assert len(binds) == 1
    assert isinstance(binds[0].target, ast.Name) and binds[0].target.id == "x"
    head = next(b for b in cfg.blocks if b.label == "for.head")
    assert sorted(s.label for s in head.succs) == ["for.body", "for.else"]


def test_break_exits_to_after_not_else():
    cfg = cfg_of(
        """
        while cond:
            if stop:
                break
            step = 1
        after = 2
        """
    )
    after = next(b for b in cfg.blocks if b.label == "while.after")
    # one pred is the break block, distinct from the loop head
    head = next(b for b in cfg.blocks if b.label == "while.head")
    assert any(p is not head for p in after.preds)
    assert head in after.preds  # and normal exhaustion still reaches it


def test_try_handler_reachable_from_before_and_after_body():
    cfg = cfg_of(
        """
        pre = 1
        try:
            mid = 2
        except ValueError:
            caught = 3
        post = 4
        """
    )
    handler = next(b for b in cfg.blocks if b.label == "try.handler")
    body = next(b for b in cfg.blocks if b.label == "try.body")
    # conservative bracketing: the handler sees the state both where the
    # body ran to completion and where it never ran at all
    assert body in handler.preds
    assert any(p is not body for p in handler.preds)


def test_try_finally_on_every_exit_and_as_binding():
    cfg = cfg_of(
        """
        try:
            x = open_thing()
        except OSError as e:
            log(e)
        finally:
            cleanup()
        """
    )
    fin = next(b for b in cfg.blocks if b.label == "try.finally")
    assert len(fin.preds) >= 2  # success path + handler path
    # `as e` materialized as an assignment the transfer functions see
    handler = next(b for b in cfg.blocks if b.label == "try.handler")
    first = handler.stmts[0]
    assert isinstance(first, ast.Assign)
    assert first.targets[0].id == "e"


def test_with_as_materializes_assignment():
    cfg = cfg_of(
        """
        with open(p) as fh:
            data = fh.read()
        """
    )
    assigns = [
        s for s in stmts_of(cfg)
        if isinstance(s, ast.Assign) and isinstance(s.targets[0], ast.Name)
    ]
    assert any(a.targets[0].id == "fh" for a in assigns)


def test_match_non_exhaustive_falls_through():
    cfg = cfg_of(
        """
        match v:
            case 1:
                a = 1
            case 2:
                a = 2
        after = 3
        """
    )
    after = next(b for b in cfg.blocks if b.label == "match.after")
    # two case tails + the no-case-matched edge from the subject block
    assert len(after.preds) == 3
    cfg2 = cfg_of(
        """
        match v:
            case 1:
                a = 1
            case _:
                a = 2
        after = 3
        """
    )
    after2 = next(b for b in cfg2.blocks if b.label == "match.after")
    assert len(after2.preds) == 2  # wildcard: no fallthrough edge


def test_nested_defs_are_atomic_and_comprehensions_are_plain():
    cfg = cfg_of(
        """
        def outer():
            if x:
                return 1
            return 2

        ys = [f(v) for v in vs if v]
        """
    )
    stmts = stmts_of(cfg)
    defs = [s for s in stmts if isinstance(s, ATOMIC_DEFS)]
    assert len(defs) == 1  # the nested def is ONE statement here
    # the comprehension's internal if/for did not leak branch tests
    assert [s for s in stmts if isinstance(s, BranchTest)] == []


def test_code_after_return_is_not_reachable():
    cfg = cfg_of(
        """
        def f():
            return 1
            dead = 2
        """
    )
    fn = ast.parse(textwrap.dedent(
        """
        def f():
            return 1
            dead = 2
        """
    )).body[0]
    fcfg = build_cfg(fn)
    reached = stmts_of(fcfg)
    assert not any(
        isinstance(s, ast.Assign) and s.targets[0].id == "dead"
        for s in reached
    ), cfg


# ---------------------------------------------------------------------------
# fixpoint engine
# ---------------------------------------------------------------------------


class _Consts(ForwardAnalysis):
    """Tiny constant-ness domain: var -> 'const' | 'var'; join demotes."""

    def initial(self):
        return {}

    def join(self, a, b):
        out = dict(a)
        for k, v in b.items():
            out[k] = v if out.get(k, v) == v else "var"
        return out

    def transfer(self, state, stmt):
        if isinstance(stmt, ast.Assign) and isinstance(
            stmt.targets[0], ast.Name
        ):
            new = dict(state)
            if isinstance(stmt.value, ast.Constant):
                new[stmt.targets[0].id] = "const"
            else:
                new[stmt.targets[0].id] = "var"
            return new
        if isinstance(stmt, LoopBind) and isinstance(stmt.target, ast.Name):
            new = dict(state)
            new[stmt.target.id] = "var"
            return new
        return state


def test_fixpoint_terminates_on_loops_and_joins_branches():
    cfg = cfg_of(
        """
        x = 1
        while cond:
            x = compute()
        y = x
        """
    )
    states = run_forward(cfg, _Consts())
    # at the loop head x is the JOIN of const (entry) and var (back edge)
    final = [st for s, st in walk_states(cfg, _Consts(), states)
             if isinstance(s, ast.Assign)
             and isinstance(s.targets[0], ast.Name)
             and s.targets[0].id == "y"]
    assert final == [{"x": "var"}]


def test_branch_join_is_least_upper_bound():
    cfg = cfg_of(
        """
        if cond:
            x = 1
        else:
            x = 2
        y = x
        """
    )
    final = [st for s, st in walk_states(cfg, _Consts())
             if isinstance(s, ast.Assign)
             and isinstance(s.targets[0], ast.Name)
             and s.targets[0].id == "y"]
    assert final == [{"x": "const"}]  # const ⊔ const = const


def test_non_monotone_transfer_raises_fixpoint_diverged():
    class Oscillator(_Consts):
        def __init__(self):
            self.n = 0

        def transfer(self, state, stmt):  # deliberately never stabilizes
            self.n += 1
            return {"tick": str(self.n)}

        def join(self, a, b):  # not a lub: last writer wins, so no fixpoint
            return b

    cfg = cfg_of(
        """
        while cond:
            x = 1
        """
    )
    with pytest.raises(FixpointDiverged):
        run_forward(cfg, Oscillator(), max_passes=8)


def test_walk_states_covers_every_reachable_statement():
    src = """
    a = 1
    if a:
        b = 2
    for i in xs:
        c = 3
    """
    cfg = cfg_of(src)
    kinds = [type(s).__name__ for s, _ in walk_states(cfg, _Consts())]
    assert kinds.count("Assign") == 3
    assert "BranchTest" in kinds and "LoopBind" in kinds
