"""Event-driven system simulator (repro.fed.sim).

Pins the subsystem's contracts:

- profile/fleet pricing arithmetic and seeded determinism,
- the event queue's (time, client_id, push-order) tie-break,
- the **participation-style invariant of asynchrony**: identical profiles
  + buffer K = cohort size reproduce the synchronous engine bit-for-bit,
- determinism: same seed ⇒ identical event timelines and final params,
- the straggler headline: async reaches the sync engine's loss in
  strictly less virtual wall-clock under a 10×-slow straggler,
- hierarchical: a single edge's cloud refactorization preserves the
  aggregated weights,
- the round-method registry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedConfig, init_factor, lr_matmul
from repro.data import FederatedBatcher, partition_iid
from repro.fed import FederatedEngine, Participation
from repro.fed.engine import (
    ROUND_METHODS,
    register_round_method,
    round_program_for,
)
from repro.fed.sim import (
    AsyncFederatedEngine,
    ClientFinished,
    EventQueue,
    Fleet,
    HierarchicalEngine,
    SyncSimEngine,
    SystemProfile,
)
from repro.core.factorization import materialize

C, DIM, DOUT = 4, 16, 8


def _loss(f, batch):
    pred = lr_matmul(batch["x"], f)
    return jnp.mean(jnp.square(pred - batch["y"]))


def _make(seed=0, lr=0.05):
    """Planted low-rank least squares: strongly convex in the coefficients,
    so losses decrease reliably under every engine."""
    rng = np.random.default_rng(seed)
    w_star = (
        rng.normal(size=(DIM, 3)) @ rng.normal(size=(3, DOUT))
    ).astype(np.float32) / np.sqrt(DIM)
    x = rng.normal(size=(1024, DIM)).astype(np.float32)
    y = x @ w_star
    parts = partition_iid(len(x), C, seed=seed)
    batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=32, seed=seed)
    f = init_factor(jax.random.PRNGKey(seed), DIM, DOUT, r_max=6, init_rank=6)
    cfg = FedConfig(
        num_clients=C, s_star=3, lr=lr, correction="simplified", tau=0.05,
        eval_after=False,
    )
    return f, cfg, batcher


# ---------------------------------------------------------------------------
# profiles / fleet
# ---------------------------------------------------------------------------


def test_profile_pricing_arithmetic():
    p = SystemProfile(
        flops_per_sec=1e9, up_bytes_per_sec=1e6, down_bytes_per_sec=2e6,
        latency_sec=0.1,
    )
    assert p.compute_seconds(2e9) == pytest.approx(2.0)
    assert p.down_seconds(2e6) == pytest.approx(0.1 + 1.0)
    assert p.up_seconds(1e6) == pytest.approx(0.1 + 1.0)
    assert p.round_seconds(2e9, 2e6, 1e6) == pytest.approx(1.1 + 2.0 + 1.1)
    slow = p.slowed(10.0)
    assert slow.round_seconds(2e9, 2e6, 1e6) == pytest.approx(10 * (1.1 + 2.0 + 1.1))


def test_fleet_from_spec():
    flat = Fleet.from_spec("uniform", 4)
    assert len(flat) == 4 and flat.is_uniform()
    strag = Fleet.from_spec("straggler:0.25,10", 4)
    assert not strag.is_uniform()
    # the last ceil(0.25·4)=1 client is the straggler, deterministically
    assert strag[3].flops_per_sec == pytest.approx(strag[0].flops_per_sec / 10)
    assert all(strag[c] == strag[0] for c in range(3))
    # lognormal draws are seeded: same seed ⇒ same fleet
    a = Fleet.from_spec("lognormal:0.6", 8, seed=3)
    b = Fleet.from_spec("lognormal:0.6", 8, seed=3)
    assert [p.flops_per_sec for p in a.profiles] == [
        p.flops_per_sec for p in b.profiles
    ]
    # dropout prefix modifies the base profile; draws are seeded
    d = Fleet.from_spec("dropout:0.5,uniform", 4, seed=1)
    assert d[0].drop_prob == 0.5
    assert d.drop_draw(2, 7) == d.drop_draw(2, 7)
    with pytest.raises(ValueError):
        Fleet.from_spec("warp_drive", 4)


def test_event_queue_tiebreak_time_then_client():
    q = EventQueue()
    # pushed in reverse client order at the same timestamp
    for c in (3, 1, 2, 0):
        q.push(ClientFinished(time=1.0, client_id=c))
    q.push(ClientFinished(time=0.5, client_id=9))
    order = [(e.time, e.client_id) for e in (q.pop() for _ in range(5))]
    assert order == [(0.5, 9), (1.0, 0), (1.0, 1), (1.0, 2), (1.0, 3)]
    # same (time, client): FIFO by push order
    q.push(ClientFinished(time=2.0, client_id=5, dispatch_idx=0))
    q.push(ClientFinished(time=2.0, client_id=5, dispatch_idx=1))
    assert [q.pop().dispatch_idx, q.pop().dispatch_idx] == [0, 1]


# ---------------------------------------------------------------------------
# async engine invariants
# ---------------------------------------------------------------------------


def test_async_uniform_full_buffer_matches_sync_bit_for_bit():
    f, cfg, b_sync = _make()
    sync = FederatedEngine(_loss, f, cfg, method="fedlrt", donate=False)
    sync.train(b_sync, 4, log_every=0)

    f2, cfg2, b_async = _make()
    anc = AsyncFederatedEngine(
        _loss, f2, cfg2, method="fedlrt",
        fleet=Fleet.uniform(C), buffer_size=C,
    )
    anc.train(b_async, 4, log_every=0)

    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        sync.params, anc.params,
    )
    assert [r.loss_before for r in anc.history] == [
        r.loss_before for r in sync.history
    ]
    assert all(r.staleness_mean == 0.0 for r in anc.history)
    # and the async run carries virtual timing the sync engine doesn't
    assert anc.history[-1].t_virtual > 0.0


def test_async_same_seed_identical_timeline_and_params():
    def run():
        f, cfg, batcher = _make(seed=2)
        fleet = Fleet.from_spec("dropout:0.15,straggler:0.5,4", C, seed=11)
        eng = AsyncFederatedEngine(
            _loss, f, cfg, method="fedlrt", fleet=fleet, buffer_size=2,
        )
        eng.train(batcher, 6, log_every=0)
        return eng

    a, b = run(), run()
    assert a.timeline.keys() == b.timeline.keys()
    assert len(a.timeline.of_kind("aggregate")) == 6
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a.params, b.params,
    )
    assert [r.t_virtual for r in a.history] == [r.t_virtual for r in b.history]


def _time_to(hist, target):
    t_prev = 0.0
    for r in hist:
        if r.loss_before <= target:
            return t_prev
        t_prev = r.t_virtual
    return float("inf")


def test_async_beats_sync_under_straggler():
    """The acceptance headline: with a 10×-slow straggler, buffered async
    reaches the sync engine's final loss in strictly less virtual time."""
    fleet = Fleet.from_spec("straggler:0.25,10", C)
    f, cfg, b_sync = _make(seed=1)
    sync = SyncSimEngine(_loss, f, cfg, method="fedlrt", fleet=fleet, donate=False)
    sync.train(b_sync, 6, log_every=0)
    target = sync.history[-1].loss_before
    assert target < sync.history[0].loss_before  # the problem does train

    f2, cfg2, b_async = _make(seed=1)
    anc = AsyncFederatedEngine(
        _loss, f2, cfg2, method="fedlrt",
        fleet=Fleet.from_spec("straggler:0.25,10", C), buffer_size=2,
    )
    anc.train(b_async, 12, log_every=0)

    t_sync = _time_to(sync.history, target)
    t_async = _time_to(anc.history, target)
    assert t_async < t_sync, (t_async, t_sync)


def test_async_rejects_partial_participation():
    f, cfg, _ = _make()
    with pytest.raises(ValueError, match="availability"):
        AsyncFederatedEngine(
            _loss, f, cfg, method="fedlrt",
            participation=Participation(mode="uniform", cohort_size=2),
        )


def test_async_stale_rounds_keep_invariants():
    """Mixed-staleness flushes preserve the factor invariant: coefficients
    zero outside the active block, basis columns beyond rank zero."""
    f, cfg, batcher = _make(seed=3)
    eng = AsyncFederatedEngine(
        _loss, f, cfg, method="fedlrt",
        fleet=Fleet.from_spec("straggler:0.25,10", C), buffer_size=2,
    )
    eng.train(batcher, 8, log_every=0)
    assert any(r.staleness_mean > 0 for r in eng.history)
    p = eng.params
    r = int(p.rank)
    S = np.asarray(p.S)
    np.testing.assert_allclose(S[r:, :], 0.0, atol=1e-6)
    np.testing.assert_allclose(S[:, r:], 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(p.U)[:, r:], 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(p.V)[:, r:], 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# hierarchical engine
# ---------------------------------------------------------------------------


def test_hier_single_edge_refactorization_preserves_weights():
    """E=1: one cloud round = one sync round + an SVD re-factorization of
    the same model — the materialized weights must agree."""
    f, cfg, b_hier = _make()
    hier = HierarchicalEngine(
        _loss, f, cfg, method="fedlrt", num_edges=1, edge_rounds=1,
        fleet=Fleet.uniform(C),
    )
    hier.train(b_hier, 1, log_every=0)

    f2, cfg2, b_sync = _make()
    sync = FederatedEngine(_loss, f2, cfg2, method="fedlrt", donate=False)
    sync.train(b_sync, 1, log_every=0)

    np.testing.assert_allclose(
        np.asarray(materialize(hier.params)),
        np.asarray(materialize(sync.params)),
        atol=1e-5,
    )
    assert hier.history[0].loss_before == sync.history[0].loss_before
    assert hier.comm_total_bytes() > sync.comm_total_bytes()  # + the backhaul
    assert hier.history[-1].t_virtual > 0.0


def test_hier_edges_partition_population():
    f, cfg, batcher = _make()
    hier = HierarchicalEngine(
        _loss, f, cfg, method="fedlrt", num_edges=2, edge_rounds=2,
        fleet=Fleet.uniform(C),
    )
    assert sorted(np.concatenate(hier.edge_cohorts).tolist()) == list(range(C))
    hier.train(batcher, 2, log_every=0)
    assert len(hier.history) == 2
    # every edge ran edge_rounds local rounds per cloud round
    assert all(len(e.history) == 4 for e in hier.edge_engines)


# ---------------------------------------------------------------------------
# round-method registry
# ---------------------------------------------------------------------------


def test_round_method_registry():
    assert set(ROUND_METHODS) >= {"fedlrt", "fedavg", "fedlin", "fedlrt_naive"}
    with pytest.raises(ValueError, match="already registered"):
        register_round_method("fedlrt", ROUND_METHODS["fedlrt"])

    def custom_round(loss_fn, params, batches, cfg, **kw):
        kw.pop("wire", None)
        return ROUND_METHODS["fedavg"](loss_fn, params, batches, cfg, **kw)

    register_round_method("custom_avg", custom_round)
    try:
        f, cfg, batcher = _make()
        dense = {"w": 0.1 * np.eye(DIM, DOUT, dtype=np.float32)}

        def dense_loss(p, batch):
            return jnp.mean(jnp.square(batch["x"] @ p["w"] - batch["y"]))

        eng = FederatedEngine(
            dense_loss, jax.tree.map(jnp.asarray, dense),
            dataclasses.replace(cfg, correction="none"),
            method="custom_avg", wire_codec=None, donate=False,
        )
        eng.train(batcher, 1, log_every=0)
        assert len(eng.history) == 1
        # no program registered → phase-level engines must refuse
        with pytest.raises(ValueError, match="no registered RoundProgram"):
            round_program_for("custom_avg")
    finally:
        del ROUND_METHODS["custom_avg"]


def test_unknown_method_error_lists_registry():
    f, cfg, _ = _make()
    with pytest.raises(ValueError, match="method must be one of"):
        FederatedEngine(_loss, f, cfg, method="nope")
