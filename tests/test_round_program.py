"""The RoundProgram abstraction: legacy wrappers, shared helpers, weights.

Covers the refactor contract: each legacy ``*_round`` entry point is a thin
wrapper over ``run_round(<Program>(), ...)`` and must match it bit-for-bit;
the shared variance-correction helper has the control-variate zero-mean
property; weighted aggregation works uniformly across methods (including
the previously fedlrt-only ``client_weights`` path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedAvgProgram,
    FedConfig,
    FedLinProgram,
    FedLRTNaiveProgram,
    FedLRTProgram,
    fedavg_round,
    fedlin_round,
    fedlrt_naive_round,
    fedlrt_round,
    init_factor,
    lr_matmul,
    materialize,
    run_round,
    variance_correction,
)

from conftest import as_batches, lsq_dense_loss, lsq_loss


@pytest.fixture()
def cfg():
    return FedConfig(num_clients=4, s_star=3, lr=0.05, correction="simplified", tau=0.05)


def _factor_loss(p, batch):
    return jnp.mean((lr_matmul(batch["x"], p) - batch["y"]) ** 2)


def _factor_setup(C=4):
    f = init_factor(jax.random.PRNGKey(0), 12, 12, r_max=4, init_rank=4)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {
        "x": jax.random.normal(ks[0], (C, 16, 12)),
        "y": jax.random.normal(ks[1], (C, 16, 12)),
    }
    return f, batch


def test_legacy_wrappers_match_run_round(homo_prob, cfg):
    """``fedavg_round``/``fedlin_round``/``fedlrt_round`` ≡ explicit
    run_round on the corresponding program, bit-for-bit."""
    batches = as_batches(homo_prob)
    W0 = jnp.zeros((20, 20))
    for wrapper, program, loss, p0 in (
        (fedavg_round, FedAvgProgram(), lsq_dense_loss, W0),
        (fedlin_round, FedLinProgram(), lsq_dense_loss, W0),
        (
            fedlrt_round,
            FedLRTProgram(),
            lsq_loss,
            init_factor(jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10),
        ),
    ):
        p_a, m_a = wrapper(loss, p0, batches, cfg)
        p_b, m_b = run_round(program, loss, p0, batches, cfg)
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            p_a,
            p_b,
        )
        np.testing.assert_array_equal(
            np.asarray(m_a["loss_before"]), np.asarray(m_b["loss_before"])
        )


def test_naive_wrapper_matches_run_round(homo_prob, cfg):
    batches = as_batches(homo_prob)
    f = init_factor(jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10)
    f_a, _ = fedlrt_naive_round(lsq_loss, f, batches, cfg)
    f_b, _ = run_round(FedLRTNaiveProgram(), lsq_loss, f, batches, cfg)
    np.testing.assert_array_equal(np.asarray(f_a.S), np.asarray(f_b.S))


def test_variance_correction_zero_mean():
    """corr_c = ḡ − g_c: the control variates cancel in the plain-mean
    aggregate, so they change no expected update direction."""
    g_c = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (5, 7, 3)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (5, 3)),
    }
    g = jax.tree.map(lambda x: jnp.mean(x, axis=0), g_c)
    corr = variance_correction(g, g_c)
    for leaf in jax.tree.leaves(corr):
        np.testing.assert_allclose(np.mean(np.asarray(leaf), axis=0), 0.0, atol=1e-6)


def _dense_loss(p, batch):
    return jnp.mean((batch["x"] @ p["w"] + p["b"] - batch["y"]) ** 2)


@pytest.mark.parametrize("round_fn", [fedavg_round, fedlin_round])
def test_weighted_aggregation_onehot_picks_client(round_fn, cfg):
    """Baselines now share the weighted-aggregation path: a one-hot weight
    vector must reproduce the single-client round on that client's data."""
    loss = _dense_loss
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    f = {
        "w": 0.1 * jax.random.normal(ks[0], (12, 12)),
        "b": jnp.zeros((12,)),
    }
    batch = {
        "x": jax.random.normal(ks[1], (4, 16, 12)),
        "y": jax.random.normal(ks[2], (4, 16, 12)),
    }
    w = jnp.array([1.0, 0.0, 0.0, 0.0])
    p_w, _ = round_fn(loss, f, batch, cfg, client_weights=w)
    cfg1 = FedConfig(
        num_clients=1, s_star=cfg.s_star, lr=cfg.lr,
        correction=cfg.correction, tau=cfg.tau,
    )
    one = {k: v[:1] for k, v in batch.items()}
    p_1, _ = round_fn(loss, f, one, cfg1)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5
        ),
        p_w,
        p_1,
    )


def test_fedlrt_weighted_uniform_equals_mean(cfg):
    """Uniform explicit weights take the tensordot path yet must agree with
    the default mean aggregation (the fedlrt client_weights contract)."""
    f, batch = _factor_setup(C=4)
    p_mean, m_mean = fedlrt_round(_factor_loss, f, batch, cfg)
    p_w, m_w = fedlrt_round(
        _factor_loss, f, batch, cfg, client_weights=jnp.full((4,), 0.25)
    )
    np.testing.assert_allclose(
        np.asarray(materialize(p_mean)), np.asarray(materialize(p_w)), atol=1e-5
    )
    np.testing.assert_allclose(
        float(m_mean["loss_after"]), float(m_w["loss_after"]), atol=1e-5
    )


def test_fedlrt_skewed_weights_change_result(cfg):
    """Non-uniform weights must actually flow through every aggregate."""
    f, batch = _factor_setup(C=4)
    p_mean, _ = fedlrt_round(_factor_loss, f, batch, cfg)
    p_skew, _ = fedlrt_round(
        _factor_loss, f, batch, cfg, client_weights=jnp.array([8.0, 1.0, 1.0, 1.0])
    )
    assert not np.allclose(
        np.asarray(materialize(p_mean)), np.asarray(materialize(p_skew)), atol=1e-6
    )


def test_naive_round_accepts_weights(homo_prob, cfg):
    batches = as_batches(homo_prob)
    f = init_factor(jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10)
    f_u, _ = fedlrt_naive_round(lsq_loss, f, batches, cfg, client_weights=jnp.ones(4))
    f_m, _ = fedlrt_naive_round(lsq_loss, f, batches, cfg)
    np.testing.assert_allclose(np.asarray(f_u.S), np.asarray(f_m.S), atol=1e-5)
