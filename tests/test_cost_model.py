"""Cost-model unit tests (paper Table 1 / Fig. 3)."""
import jax
import jax.numpy as jnp

from repro.core import init_factor
from repro.core import cost_model as cm


def test_table1_rows_exist():
    for method in ("fedavg", "fedlin", "fedlrt", "fedlrt_simplified", "fedlrt_full", "fedlr"):
        row = cm.table1(method, n=512, r=32, s_star=4, b=2)
        assert row["comm"] > 0 and row["client_compute"] > 0


def test_fedlrt_beats_fedlin_below_amortization():
    n = 512
    r_am = cm.amortization_rank(n)
    assert 0.3 * n < r_am < 0.5 * n  # paper: ≈ 40% of full rank at n=512
    lo = cm.table1("fedlrt_simplified", n=n, r=int(r_am * 0.5))["comm"]
    hi = cm.table1("fedlrt_simplified", n=n, r=int(r_am * 1.5))["comm"]
    ref = cm.table1("fedlin", n=n, r=0)["comm"]
    assert lo < ref < hi


def test_exact_counter_matches_manual():
    f = init_factor(jax.random.PRNGKey(0), 100, 60, r_max=8)
    r = 8
    nr = (100 + 60) * r
    expect = (nr + r * r) + nr + nr + 2 * r * r + (2 * r) ** 2
    got = cm.fedlrt_round_comm_bytes({"w": f}, "simplified")
    assert got == expect * cm.BYTES


def test_dense_counter():
    params = {"w": jnp.zeros((64, 64)), "b": jnp.zeros((64,))}
    assert cm.dense_round_comm_bytes(params, "fedavg") == 2 * (64 * 64 + 64) * 4
    assert cm.dense_round_comm_bytes(params, "fedlin") == 4 * (64 * 64 + 64) * 4


def test_client_flops_scale_linearly_in_n():
    f1 = init_factor(jax.random.PRNGKey(0), 256, 256, r_max=16)
    f2 = init_factor(jax.random.PRNGKey(0), 512, 512, r_max=16)
    a = cm.client_flops_per_local_step({"w": f1}, batch_tokens=32)
    b = cm.client_flops_per_local_step({"w": f2}, batch_tokens=32)
    assert 1.8 < b / a < 2.2


def test_round_total_comm_scales_with_cohort():
    f = init_factor(jax.random.PRNGKey(0), 100, 60, r_max=8)
    params = {"w": f}
    per = cm.fedlrt_round_comm_bytes(params, "simplified")
    assert cm.round_total_comm_bytes(
        params, "fedlrt", correction="simplified", cohort_size=3
    ) == 3 * per
    dense = {"w": jnp.zeros((64, 64))}
    assert cm.round_total_comm_bytes(
        dense, "fedavg", cohort_size=5
    ) == 5 * cm.dense_round_comm_bytes(dense, "fedavg")
