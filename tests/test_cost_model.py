"""Cost-model unit tests (paper Table 1 / Fig. 3)."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import init_factor
from repro.core import cost_model as cm


def test_table1_rows_exist():
    for method in ("fedavg", "fedlin", "fedlrt", "fedlrt_simplified", "fedlrt_full", "fedlr"):
        row = cm.table1(method, n=512, r=32, s_star=4, b=2)
        assert row["comm"] > 0 and row["client_compute"] > 0


def test_fedlrt_beats_fedlin_below_amortization():
    n = 512
    r_am = cm.amortization_rank(n)
    assert 0.3 * n < r_am < 0.5 * n  # paper: ≈ 40% of full rank at n=512
    lo = cm.table1("fedlrt_simplified", n=n, r=int(r_am * 0.5))["comm"]
    hi = cm.table1("fedlrt_simplified", n=n, r=int(r_am * 1.5))["comm"]
    ref = cm.table1("fedlin", n=n, r=0)["comm"]
    assert lo < ref < hi


def test_exact_counter_matches_manual():
    f = init_factor(jax.random.PRNGKey(0), 100, 60, r_max=8)
    r = 8
    nr = (100 + 60) * r
    expect = (nr + r * r) + nr + nr + 2 * r * r + (2 * r) ** 2
    got = cm.fedlrt_round_comm_bytes({"w": f}, "simplified")
    assert got == expect * cm.BYTES


def test_dense_counter():
    params = {"w": jnp.zeros((64, 64)), "b": jnp.zeros((64,))}
    assert cm.dense_round_comm_bytes(params, "fedavg") == 2 * (64 * 64 + 64) * 4
    assert cm.dense_round_comm_bytes(params, "fedlin") == 4 * (64 * 64 + 64) * 4


def test_client_flops_scale_linearly_in_n():
    f1 = init_factor(jax.random.PRNGKey(0), 256, 256, r_max=16)
    f2 = init_factor(jax.random.PRNGKey(0), 512, 512, r_max=16)
    a = cm.client_flops_per_local_step({"w": f1}, batch_tokens=32)
    b = cm.client_flops_per_local_step({"w": f2}, batch_tokens=32)
    assert 1.8 < b / a < 2.2


def _at_rank(f, r):
    return dataclasses.replace(f, rank=jnp.float32(r))


def test_effective_comm_equals_static_at_full_rank():
    """With rank == r_max the effective-rank counter must reproduce the
    static bound exactly, for every correction mode."""
    f = init_factor(jax.random.PRNGKey(0), 100, 60, r_max=8, init_rank=8)
    params = {"w": f, "b": jnp.zeros((60,))}
    for corr in ("none", "simplified", "full"):
        assert float(
            cm.fedlrt_round_comm_bytes_effective(params, corr)
        ) == cm.fedlrt_round_comm_bytes(params, corr)


def test_effective_comm_monotone_as_truncation_shrinks_rank():
    """Reported comm must actually shrink as the adaptive rank drops —
    the bug was pricing every round at r_max forever."""
    f = init_factor(jax.random.PRNGKey(1), 128, 96, r_max=16, init_rank=16)
    static = cm.fedlrt_round_comm_bytes({"w": f}, "simplified")
    vals = [
        float(cm.fedlrt_round_comm_bytes_effective({"w": _at_rank(f, r)}))
        for r in (16, 12, 8, 4, 1)
    ]
    assert vals[0] == static
    assert all(a > b for a, b in zip(vals, vals[1:]))
    assert all(v <= static for v in vals)


def test_effective_comm_stacked_factor_sums_slices():
    """Batched (layer-stacked) factors price every slice; per-slice ranks
    contribute independently and stay below the static stacked bound."""
    f = init_factor(
        jax.random.PRNGKey(2), 64, 64, r_max=8, init_rank=8, batch_shape=(3,)
    )
    static = cm.fedlrt_round_comm_bytes({"w": f}, "simplified")
    assert float(cm.fedlrt_round_comm_bytes_effective({"w": f})) == static
    f_mixed = dataclasses.replace(f, rank=jnp.asarray([8.0, 4.0, 2.0]))
    eff = float(cm.fedlrt_round_comm_bytes_effective({"w": f_mixed}))
    assert eff < static
    # equals the sum of three single-slice factors at those ranks
    singles = sum(
        float(
            cm.fedlrt_round_comm_bytes_effective(
                {
                    "w": dataclasses.replace(
                        f_mixed,
                        U=f.U[i], S=f.S[i], V=f.V[i],
                        rank=f_mixed.rank[i],
                    )
                }
            )
        )
        for i in range(3)
    )
    assert eff == singles


def test_effective_comm_traces_under_jit():
    f = init_factor(jax.random.PRNGKey(3), 64, 48, r_max=8, init_rank=6)

    @jax.jit
    def eff(params):
        return cm.fedlrt_round_comm_bytes_effective(params)

    assert float(eff({"w": f})) == float(
        cm.fedlrt_round_comm_bytes_effective({"w": f})
    )


def test_round_total_comm_scales_with_cohort():
    f = init_factor(jax.random.PRNGKey(0), 100, 60, r_max=8)
    params = {"w": f}
    per = cm.fedlrt_round_comm_bytes(params, "simplified")
    assert cm.round_total_comm_bytes(
        params, "fedlrt", correction="simplified", cohort_size=3
    ) == 3 * per
    dense = {"w": jnp.zeros((64, 64))}
    assert cm.round_total_comm_bytes(
        dense, "fedavg", cohort_size=5
    ) == 5 * cm.dense_round_comm_bytes(dense, "fedavg")
