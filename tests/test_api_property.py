"""Hypothesis property: randomized valid ExperimentSpecs round-trip
losslessly through dict, TOML and JSON, with a serialization-invariant
content hash.  (Skipped when hypothesis isn't installed — like
tests/test_property.py.)"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import json

from hypothesis import given, settings, strategies as st

from repro.api import (
    CheckpointSpec,
    DataSpec,
    EngineSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    ParticipationSpec,
    SimSpec,
    WireSpec,
)

NAME_ALPHABET = "abcdefghijklmnopqrstuvwxyz0123456789 -_:.\"\\"


@st.composite
def spec_strategy(draw):
    clients = draw(st.integers(2, 16))
    kind = draw(st.sampled_from(["sync", "async", "hier"]))
    method, correction = draw(st.sampled_from([
        ("fedlrt", "simplified"), ("fedlrt", "none"), ("fedlrt", "full"),
        ("fedlrt", "auto"), ("fedavg", "auto"), ("fedavg", "none"),
        ("fedlin", "none"), ("fedlrt_naive", "none"),
    ]))
    if kind == "sync":
        mode = draw(st.sampled_from(["full", "uniform", "round_robin", "dropout"]))
    else:
        mode = "full"
    participation = ParticipationSpec(
        mode=mode,
        cohort_size=(
            draw(st.integers(1, clients))
            if mode in ("uniform", "round_robin") else None
        ),
        dropout_prob=(
            draw(st.floats(0.0, 0.9, allow_nan=False))
            if mode == "dropout" else 0.0
        ),
    )
    engine = EngineSpec(
        kind=kind,
        buffer_size=(
            draw(st.none() | st.integers(1, clients)) if kind == "async" else None
        ),
        staleness_power=(
            draw(st.none() | st.floats(0.0, 4.0, allow_nan=False))
            if kind == "async" else None
        ),
        edges=(
            draw(st.none() | st.integers(1, clients)) if kind == "hier" else None
        ),
        edge_rounds=(
            draw(st.none() | st.integers(1, 3)) if kind == "hier" else None
        ),
    )
    codec = st.sampled_from(
        ["identity", "downcast", "downcast:float16", "int8_affine", "topk_rank"]
    )
    wire = WireSpec(
        codec=draw(codec),
        edge_codec=draw(st.none() | codec) if kind == "hier" else None,
    )
    if draw(st.booleans()):
        model = ModelSpec(
            kind="mlp",
            dim=draw(st.integers(4, 64)),
            classes=draw(st.integers(2, 10)),
            hidden=draw(st.integers(4, 64)),
            r_max=draw(st.integers(1, 16)),
            lowrank=draw(st.booleans()),
            kernels=draw(st.sampled_from(["auto", "interpret", "off"])),
        )
        data = DataSpec(
            kind="classification",
            batch=draw(st.integers(1, 64)),
            num_points=draw(st.integers(64, 4096)),
            noise=draw(st.floats(0.0, 1.0, allow_nan=False)),
            planted_rank=draw(st.integers(1, 8)),
            partition=draw(st.sampled_from(["iid", "dirichlet:0.3", "dirichlet:100"])),
            holdout=draw(st.integers(0, 63)),
        )
    else:
        preset = draw(st.none() | st.sampled_from(["llm-tiny", "llm-100m"]))
        model = ModelSpec(
            kind="lm",
            preset=preset,
            arch=None if preset else "qwen2-7b",
            smoke=draw(st.booleans()),
            kernels=draw(st.sampled_from(["auto", "interpret", "off"])),
        )
        data = DataSpec(
            kind="token_stream",
            batch=draw(st.integers(1, 16)),
            seq=draw(st.integers(2, 256)),
            tokens_per_client=draw(st.integers(1000, 300_000)),
            stream_rank=draw(st.integers(1, 32)),
        )
    return ExperimentSpec(
        name=draw(st.text(alphabet=NAME_ALPHABET, max_size=20)),
        seed=draw(st.integers(0, 2**31 - 1)),
        rounds=draw(st.integers(0, 1000)),
        log_every=draw(st.integers(0, 100)),
        model=model,
        data=data,
        fed=FedSpec(
            method=method, correction=correction, clients=clients,
            local_steps=draw(st.integers(0, 64)),
            lr=draw(st.floats(1e-6, 10.0, allow_nan=False)),
            tau=draw(st.floats(0.0, 0.999, allow_nan=False)),
            weighted=draw(st.booleans()),
            eval_after=draw(st.booleans()),
        ),
        participation=participation,
        engine=engine,
        wire=wire,
        sim=SimSpec(profile=draw(st.none() | st.sampled_from([
            "uniform", "straggler:0.25,10", "lognormal:0.6", "dropout:0.1,uniform",
        ]))),
        checkpoint=(
            CheckpointSpec(dir=draw(st.none() | st.just("/tmp/ck")),
                           every=draw(st.integers(0, 50)))
            if kind != "hier" else CheckpointSpec()
        ),
    )


@given(spec=spec_strategy())
@settings(max_examples=60, deadline=None)
def test_property_roundtrip(spec):
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_toml(spec.to_toml()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert json.loads(spec.to_json()) == spec.to_dict()
    # hash survives every serialization path
    h = spec.spec_hash()
    assert ExperimentSpec.from_toml(spec.to_toml()).spec_hash() == h
