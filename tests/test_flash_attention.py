"""Flash-attention Pallas kernel vs the materialized-scores oracle.

Sweeps GQA ratios, causal/cross, sliding windows, ragged cache layouts and
dtypes — all in interpret mode (kernel body runs in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import mha_ref


def _qkv(B, Tq, Tk, H, Hkv, d, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (B, Tq, H, d), dtype),
        jax.random.normal(ks[1], (B, Tk, Hkv, d), dtype),
        jax.random.normal(ks[2], (B, Tk, Hkv, d), dtype),
    )


@pytest.mark.parametrize(
    "B,Tq,Tk,H,Hkv,d,causal,window",
    [
        (2, 16, 16, 4, 2, 8, True, 0),     # GQA self-attn
        (1, 32, 32, 2, 2, 16, True, 8),    # sliding window
        (2, 8, 24, 4, 4, 8, False, 0),     # cross-attention, Tq != Tk
        (1, 16, 16, 4, 1, 8, True, 0),     # MQA
        (1, 64, 64, 2, 2, 32, True, 0),    # bigger tiles
    ],
)
def test_flash_matches_oracle(B, Tq, Tk, H, Hkv, d, causal, window):
    q, k, v = _qkv(B, Tq, Tk, H, Hkv, d, seed=B * Tq + H)
    qpos = jnp.arange(Tq) + (Tk - Tq if causal else 0)
    kpos = jnp.arange(Tk)
    out = flash_attention(
        q, k, v, q_positions=qpos, kv_positions=kpos, causal=causal,
        sliding_window=window, bq=8, bk=8, interpret=True,
    )
    ref = mha_ref(
        q, k, v, q_positions=qpos, kv_positions=kpos, causal=causal,
        sliding_window=window,
    )
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(4, 4), (8, 16), (16, 8)])
def test_flash_tiling_invariance(bq, bk):
    q, k, v = _qkv(1, 16, 32, 2, 2, 8, seed=3)
    qpos = jnp.arange(16) + 16
    kpos = jnp.arange(32)
    out = flash_attention(
        q, k, v, q_positions=qpos, kv_positions=kpos, bq=bq, bk=bk,
        interpret=True,
    )
    ref = mha_ref(q, k, v, q_positions=qpos, kv_positions=kpos)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_flash_ring_cache_layout():
    """Decode against a ring cache: invalid (negative-position) slots and
    wrapped ordering must not leak values."""
    B, H, d, S = 1, 2, 8, 16
    q, k, v = _qkv(B, 4, S, H, H, d, seed=5)
    # slots 0..7 valid (positions 8..15 wrapped order), rest invalid
    kpos = jnp.array([8, 9, 10, 11, 12, 13, 14, 15] + [-(10**9)] * 8)
    qpos = jnp.arange(4) + 12
    out = flash_attention(
        q, k, v, q_positions=qpos, kv_positions=kpos, bq=4, bk=8,
        interpret=True,
    )
    # poison only the INVALID slots of k and v — output must be unchanged
    k_bad = k.at[:, 8:].set(1e6)
    v_bad = v.at[:, 8:].set(1e6)
    out2 = flash_attention(
        q, k_bad, v_bad, q_positions=qpos, kv_positions=kpos, bq=4, bk=8,
        interpret=True,
    )
    ref = mha_ref(q, k, v, q_positions=qpos, kv_positions=kpos)
    np.testing.assert_allclose(out, ref, atol=2e-5)
    np.testing.assert_allclose(out2, ref, atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 16, 16, 2, 2, 16, dtype=jnp.bfloat16, seed=7)
    qpos = kpos = jnp.arange(16)
    out = flash_attention(
        q, k, v, q_positions=qpos, kv_positions=kpos, bq=8, bk=8,
        interpret=True,
    )
    ref = mha_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        q_positions=qpos, kv_positions=kpos,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=5e-2
    )


def test_flash_fully_masked_rows_are_finite():
    """Query rows with no visible keys must produce zeros, not NaNs."""
    q, k, v = _qkv(1, 8, 8, 1, 1, 8, seed=9)
    kpos = jnp.full((8,), -(10**9))  # nothing valid
    out = flash_attention(
        q, k, v, q_positions=jnp.arange(8), kv_positions=kpos,
        bq=8, bk=8, interpret=True,
    )
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(out, 0.0, atol=1e-6)
