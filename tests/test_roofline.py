"""Roofline parser + cost-analysis plumbing tests (no 512-device mesh)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.roofline import Roofline, collective_bytes


HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  %ag = f32[32,128]{1,0} all-gather(%p0), dimensions={0}
  %rs = bf16[8,128]{1,0} reduce-scatter(%p0), dimensions={0}, to_apply=%add
  %a2a = f32[16,128]{1,0} all-to-all(%p0), dimensions={0}
  %cp = f32[16,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %t = (f32[16,128]{1,0}) tuple(%ar)
}
"""


def test_collective_bytes_parsing():
    out = collective_bytes(HLO_SAMPLE)
    ar = 16 * 128 * 4
    assert out["all-reduce"] == 2 * ar
    assert out["all-gather"] == 32 * 128 * 4
    assert out["reduce-scatter"] == 8 * 128 * 2
    assert out["all-to-all"] == ar
    assert out["collective-permute"] == ar
    assert out["total"] == sum(
        v for k, v in out.items() if k != "total"
    )


def test_collective_bytes_ignores_non_collectives():
    hlo = "%d = f32[64,64]{1,0} dot(%a, %b)\n%c = f32[4096]{0} convolution(%x, %y)"
    out = collective_bytes(hlo)
    assert out["total"] == 0.0


def test_roofline_terms_and_dominance():
    r = Roofline(
        flops_per_device=197e12,  # exactly 1 s of compute
        bytes_per_device=819e9,  # exactly 1 s of HBM
        collective_bytes_per_device=100e9,  # 2 s of ICI
        collectives={},
    )
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 1.0)
    assert np.isclose(r.collective_s, 2.0)
    assert r.dominant == "collective"
    d = r.to_dict()
    assert d["dominant"] == "collective"


def test_real_compiled_module_collectives():
    """An actual psum lowering must be detected by the parser."""
    from repro.launch.mesh import mesh_kwargs

    mesh = jax.make_mesh((1,), ("x",), **mesh_kwargs(1))

    def f(a):
        return jax.lax.psum(a, "x")

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    g = jax.jit(
        shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P())
    )
    c = g.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    out = collective_bytes(c.as_text())
    # single-device meshes may fold the collective away; parser must not crash
    assert out["total"] >= 0.0


def test_model_flops_counts_active_moe():
    from repro.configs import get_config
    from repro.launch.roofline import model_flops
    from repro.models.config import reduced

    cfg = reduced(get_config("olmoe_1b_7b"))
    fl_fwd = model_flops(cfg, tokens=1000, backward=False)
    fl_bwd = model_flops(cfg, tokens=1000, backward=True)
    assert fl_bwd == 3 * fl_fwd
    # MoE active params < total params
    dense_like = model_flops(
        reduced(get_config("qwen2_7b")), tokens=1000, backward=False
    )
    assert fl_fwd > 0 and dense_like > 0
