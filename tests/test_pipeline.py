"""FederatedBatcher: cohort-aware batching, determinism, restartability."""
import numpy as np

from repro.data import FederatedBatcher, partition_iid


def _batcher(seed=0, steps=None, C=4, N=128, batch=4):
    x = np.arange(N, dtype=np.float32)[:, None]
    parts = partition_iid(N, C, seed=seed)
    return FederatedBatcher(
        {"x": x}, parts, batch_size=batch, steps_per_round=steps, seed=seed
    )


def test_cohort_shapes():
    b = _batcher(steps=3)
    r = b.next_round([1, 3])
    assert r["x"].shape == (2, 3, 4, 1)
    r = b.next_round()  # default: full population
    assert r["x"].shape == (4, 3, 4, 1)


def test_cohort_rows_in_cohort_order():
    """Row i of the batch belongs to cohort[i]'s shard."""
    b = _batcher()
    parts = [set(p.tolist()) for p in b.partitions]
    r = b.next_round([2, 0])
    assert set(r["x"][0, :, 0].astype(int).tolist()) <= parts[2]
    assert set(r["x"][1, :, 0].astype(int).tolist()) <= parts[0]


def test_determinism_same_seed_same_cohorts():
    b1, b2 = _batcher(seed=7), _batcher(seed=7)
    cohorts = [[0, 1, 2, 3], [1, 2], [0], [2, 3], None]
    for c in cohorts:
        np.testing.assert_array_equal(b1.next_round(c)["x"], b2.next_round(c)["x"])


def test_client_stream_independent_of_other_clients():
    """A client's batch sequence depends only on its own participation
    count, not on which other clients were active — the property that makes
    partial-participation runs comparable."""
    b_solo = _batcher(seed=3)
    solo = [b_solo.next_round([0])["x"][0] for _ in range(3)]
    b_mixed = _batcher(seed=3)
    mixed = [
        b_mixed.next_round([0, 1])["x"][0],
        b_mixed.next_round([0, 2, 3])["x"][0],
        b_mixed.next_round([0])["x"][0],
    ]
    for a, m in zip(solo, mixed):
        np.testing.assert_array_equal(a, m)


def test_epoch_reshuffle_covers_shard_without_duplicates():
    C, N = 4, 128
    b = _batcher(C=C, N=N, batch=8)
    per_client = N // C  # 32 samples, batch 8 → epoch = 4 rounds
    seen = np.concatenate([b.next_round([1])["x"][0, :, 0] for _ in range(4)])
    assert len(set(seen.tolist())) == per_client  # full epoch, no repeats
    seen2 = np.concatenate([b.next_round([1])["x"][0, :, 0] for _ in range(4)])
    assert set(seen2.tolist()) == set(seen.tolist())  # same shard, new order


def test_state_snapshot_restores_mid_stream():
    b = _batcher(seed=5, steps=2)
    for _ in range(3):
        b.next_round([0, 2])
    snap = b.state()
    expect = [b.next_round([1, 2])["x"], b.next_round()["x"]]
    b2 = _batcher(seed=5, steps=2)
    b2.set_state(snap)
    np.testing.assert_array_equal(b2.next_round([1, 2])["x"], expect[0])
    np.testing.assert_array_equal(b2.next_round()["x"], expect[1])
