"""End-to-end Pallas kernel-path equivalence (ModelConfig.kernels).

The fused ``xus``/``avt``/``atb`` chain must be a drop-in for the jnp
reference everywhere it is dispatched: ``kernels="interpret"`` runs the
*kernel* code path through the Pallas interpreter on CPU, so these tests
pin kernel-path ≡ reference-path through a **full fedlrt_round** — client
basis gradients, the s*-step AugmentedFactor client loop (2r active-
direction masking), aggregation, truncation, metrics — not just a single
matmul.  Includes the bf16 sublane case ``M % 16 == 8`` that used to
produce misaligned tiles.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedConfig, fedlrt_round, init_factor
from repro.core.factorization import is_factor, lr_matmul, materialize
from repro.data import make_classification_data, partition_iid
from repro.models import build_model
from repro.models.config import LowRankPolicy, ModelConfig
from repro.models.moe import _stacked_linear

C, DIM, NCLS = 4, 32, 4


def _loss(kernels):
    def loss_fn(f, batch):
        logits = lr_matmul(batch["x"], f, kernels=kernels)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))

    return loss_fn


def _client_batches(seed=0):
    x, y = make_classification_data(
        dim=DIM, num_classes=NCLS, rank=3, num_points=512, noise=0.2, seed=seed
    )
    parts = partition_iid(len(x), C, seed=seed)
    xb = np.stack([x[p[:64]] for p in parts])
    yb = np.stack([y[p[:64]] for p in parts])
    return {"x": jnp.asarray(xb), "y": jnp.asarray(yb)}


def _tree_close(a, b, atol):
    jax.tree.map(
        lambda u, v: np.testing.assert_allclose(
            np.asarray(u, np.float32), np.asarray(v, np.float32), atol=atol
        ),
        a,
        b,
    )


@pytest.mark.parametrize("correction", ["simplified", "full"])
def test_fedlrt_round_kernel_path_matches_reference(correction):
    """One full FeDLRT round through the interpret-mode kernels equals the
    reference round: params, losses, and every metric to 1e-4."""
    f = init_factor(jax.random.PRNGKey(0), DIM, NCLS, r_max=8, init_rank=8)
    batch = _client_batches()
    cfg = FedConfig(
        num_clients=C, s_star=3, lr=0.05, correction=correction, tau=0.05,
        eval_after=True,
    )
    p_ref, m_ref = jax.jit(
        lambda f, b: fedlrt_round(_loss("off"), f, b, cfg)
    )(f, batch)
    p_ker, m_ker = jax.jit(
        lambda f, b: fedlrt_round(_loss("interpret"), f, b, cfg)
    )(f, batch)
    _tree_close(p_ref, p_ker, 1e-4)
    assert set(m_ref) == set(m_ker)
    _tree_close(m_ref, m_ker, 1e-4)


def _model_cfg(**overrides):
    base = dict(
        name="kernel-path-tiny", family="dense", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, d_ff=96, vocab_size=64,
        compute_dtype="float32", param_dtype="float32", attn_q_chunk=16,
        rope_theta=1e4,
        lowrank=LowRankPolicy(min_dim=32, rank_frac=0.25, r_cap=16),
    )
    base.update(overrides)
    return ModelConfig(**base)


def test_model_loss_and_grads_kernel_path_bitwise_f32():
    """Model forward/backward: interpret-mode kernels vs reference, through
    embedding, attention, MLP, and lm_head factor dispatch."""
    cfg_ref = _model_cfg(kernels="off")
    cfg_ker = _model_cfg(kernels="interpret")
    m_ref, m_ker = build_model(cfg_ref), build_model(cfg_ker)
    params, _ = m_ref.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, 64)}
    l_ref = m_ref.loss_fn(params, batch)
    l_ker = m_ker.loss_fn(params, batch)
    np.testing.assert_allclose(float(l_ref), float(l_ker), atol=1e-5)
    g_ref = jax.grad(m_ref.loss_fn)(params, batch)
    g_ker = jax.grad(m_ker.loss_fn)(params, batch)
    _tree_close(g_ref, g_ker, 1e-5)


@pytest.mark.slow
def test_model_fedlrt_round_kernel_path_matches_reference():
    """Full fedlrt_round over a real (tiny) transformer: the client
    local_sgd_scan's forward/backward runs through xus/avt/atb on
    AugmentedFactor leaves and must reproduce the reference round."""
    cfg_ref = _model_cfg(kernels="off")
    cfg_ker = _model_cfg(kernels="interpret")
    m_ref, m_ker = build_model(cfg_ref), build_model(cfg_ker)
    params, _ = m_ref.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (C, 2, 17), 0, 64)
    batch = {"tokens": tokens}
    fc = FedConfig(
        num_clients=C, s_star=2, lr=0.05, correction="simplified", tau=0.05,
        eval_after=True,
    )
    p_ref, met_ref = jax.jit(
        lambda p, b: fedlrt_round(m_ref.loss_fn, p, b, fc)
    )(params, batch)
    p_ker, met_ker = jax.jit(
        lambda p, b: fedlrt_round(m_ker.loss_fn, p, b, fc)
    )(params, batch)
    _tree_close(p_ref, p_ker, 1e-4)
    _tree_close(met_ref, met_ker, 1e-4)


@pytest.mark.slow
def test_model_fedlrt_round_bf16_sublane_case():
    """bf16 with per-client M = B·T ≡ 8 (mod 16) — the misaligned-tile
    regression: the round must run through the dtype-aware padding and
    stay close to the reference path (bf16 rounding differs between the
    fused f32-accumulating kernels and the per-op bf16 reference)."""
    cfg_ref = _model_cfg(kernels="off", compute_dtype="bfloat16")
    cfg_ker = _model_cfg(kernels="interpret", compute_dtype="bfloat16")
    m_ref, m_ker = build_model(cfg_ref), build_model(cfg_ker)
    params, _ = m_ref.init(jax.random.PRNGKey(0))
    # B=1, T=24 tokens per client ⇒ M = 24, 24 % 16 == 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (C, 1, 25), 0, 64)
    batch = {"tokens": tokens}
    fc = FedConfig(
        num_clients=C, s_star=2, lr=0.05, correction="simplified", tau=0.05,
        eval_after=True,
    )
    p_ref, met_ref = jax.jit(
        lambda p, b: fedlrt_round(m_ref.loss_fn, p, b, fc)
    )(params, batch)
    p_ker, met_ker = jax.jit(
        lambda p, b: fedlrt_round(m_ker.loss_fn, p, b, fc)
    )(params, batch)
    assert np.isfinite(float(met_ker["loss_before"]))
    np.testing.assert_allclose(
        float(met_ref["loss_before"]), float(met_ker["loss_before"]), atol=5e-2
    )
    np.testing.assert_allclose(
        float(met_ref["loss_after"]), float(met_ker["loss_after"]), atol=5e-2
    )
    # compare the *represented weights*: basis columns are only defined up
    # to rotation, and orthonormalization amplifies bf16 rounding
    # differences into O(1) direction changes of near-null columns while
    # W = U S Vᵀ stays put
    w_ref = jax.tree.map(
        lambda f: materialize(f) if is_factor(f) else f, p_ref, is_leaf=is_factor
    )
    w_ker = jax.tree.map(
        lambda f: materialize(f) if is_factor(f) else f, p_ker, is_leaf=is_factor
    )
    _tree_close(w_ref, w_ker, 7e-2)


def test_stacked_expert_factors_kernel_path():
    """MoE-style stacked factors: the kernel path vmaps over the expert
    axis and must match the einsum reference, forward and backward."""
    E, cap, d, dff = 3, 24, 32, 48
    w = init_factor(
        jax.random.PRNGKey(4), d, dff, r_max=8, init_rank=8, batch_shape=(E,)
    )
    x = jax.random.normal(jax.random.PRNGKey(5), (E, cap, d))
    y_ref = _stacked_linear(w, x, "off")
    y_ker = _stacked_linear(w, x, "interpret")
    np.testing.assert_allclose(
        np.asarray(y_ref), np.asarray(y_ker), rtol=1e-5, atol=1e-5
    )

    def loss(kernels):
        def f(US):
            w2 = dataclasses.replace(w, U=US[0], S=US[1])
            return jnp.sum(_stacked_linear(w2, x, kernels) ** 2)

        return jax.grad(f)((w.U, w.S))

    g_ref, g_ker = loss("off"), loss("interpret")
    _tree_close(g_ref, g_ker, 1e-3)


def test_kernel_policy_validation():
    with pytest.raises(ValueError, match="kernels policy"):
        from repro.kernels import use_kernels_for

        use_kernels_for("bogus")
