"""Baseline round functions: FedAvg, FedLin, naive low-rank (Alg. 6)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, init_factor, materialize
from repro.core.baselines import fedavg_round, fedlin_round, fedlrt_naive_round

from conftest import as_batches, lsq_dense_loss, lsq_loss, optimal_loss


def _run(round_fn, loss, params, batches, cfg, rounds):
    step = jax.jit(lambda p, b: round_fn(loss, p, b, cfg))
    m = None
    for _ in range(rounds):
        params, m = step(params, batches)
    return params, m


def test_fedlin_converges_heterogeneous(hetero_prob):
    batches = as_batches(hetero_prob)
    cfg = FedConfig(num_clients=4, s_star=100, lr=0.02, tau=0.01, eval_after=False)
    W, m = _run(fedlin_round, lsq_dense_loss, jnp.zeros((10, 10)), batches, cfg, 150)
    excess = float(m["loss_before"]) - optimal_loss(hetero_prob)
    assert excess < 1e-4
    assert float(jnp.linalg.norm(W - hetero_prob.W_star)) < 1e-2


def test_fedavg_plateaus_heterogeneous(hetero_prob):
    """Client drift: FedAvg's fixed point is biased away from the minimizer."""
    batches = as_batches(hetero_prob)
    cfg = FedConfig(num_clients=4, s_star=100, lr=0.02, tau=0.01, eval_after=False)
    _, m_avg = _run(fedavg_round, lsq_dense_loss, jnp.zeros((10, 10)), batches, cfg, 150)
    _, m_lin = _run(fedlin_round, lsq_dense_loss, jnp.zeros((10, 10)), batches, cfg, 150)
    opt = optimal_loss(hetero_prob)
    assert (float(m_avg["loss_before"]) - opt) > 10 * (
        float(m_lin["loss_before"]) - opt
    )


def test_fedavg_homogeneous_ok(homo_prob):
    # split data ⇒ mildly heterogeneous sample Hessians ⇒ small FedAvg bias;
    # near-convergence (not exact) is the expected behavior.
    batches = as_batches(homo_prob)
    cfg = FedConfig(num_clients=4, s_star=20, lr=0.1, tau=0.01, eval_after=False)
    _, m = _run(fedavg_round, lsq_dense_loss, jnp.zeros((20, 20)), batches, cfg, 100)
    assert float(m["loss_before"]) < 5e-3


def test_naive_fedlrt_round_runs(homo_prob, rng_key):
    """Alg. 6 makes progress and adapts rank (at full-matrix comm cost)."""
    batches = as_batches(homo_prob)
    f = init_factor(rng_key, 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0)
    cfg = FedConfig(num_clients=4, s_star=1, lr=0.1, tau=0.05, eval_after=True)
    step = jax.jit(lambda p, b: fedlrt_naive_round(lsq_loss, p, b, cfg))
    m0 = None
    for _ in range(50):
        f, m = step(f, batches)
        m0 = m0 or m
    assert float(m["loss_after"]) < float(m0["loss_before"])
    assert 1 <= float(f.rank) <= 10


def test_comm_cost_ordering(homo_prob, rng_key):
    """FeDLRT communicates less than FedLin per round on the same layer."""
    from repro.core import fedlrt_round

    batches = as_batches(homo_prob)
    n = 20
    f = init_factor(rng_key, n, n, r_max=5, init_rank=5, spectrum_scale=1.0)
    cfg = FedConfig(num_clients=4, s_star=5, lr=0.05, correction="simplified", tau=0.1)
    _, m_lrt = fedlrt_round(lsq_loss, f, batches, cfg)
    _, m_lin = fedlin_round(lsq_dense_loss, jnp.zeros((n, n)), batches, cfg)
    assert float(m_lrt["comm_bytes_per_client"]) < float(m_lin["comm_bytes_per_client"])
