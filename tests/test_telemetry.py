"""The structured telemetry subsystem (repro.telemetry).

Pins, in order:

- the load-bearing invariant: a telemetry-enabled run is **bit-for-bit**
  identical to a disabled one — same params, same round history (modulo
  ``RoundResult.seconds``, which is host wall time by definition);
- the event schema: everything a real run emits validates, the JSONL
  sink round-trips losslessly against an in-memory sink, and
  ``validate_event`` rejects malformed events;
- the Perfetto exporter: a 3-round async straggler run exports valid
  ``trace_event`` JSON with per-client tracks on both the wall and the
  virtual clock, monotone timestamps per track;
- hub mechanics: a disabled hub emits nothing and hands out a no-op
  span; ``sample_every`` drops only gauge/hist events off-cadence.
"""
import dataclasses
import json

import numpy as np
import pytest

import jax

from repro.api import (
    DataSpec,
    EngineSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    SimSpec,
    TelemetrySpec,
    build,
)
from repro.telemetry import (
    NULL_HUB,
    MemorySink,
    TelemetryHub,
    events_to_trace,
    validate_event,
    validate_jsonl,
)
from repro.telemetry.perfetto import SERVER_TID, VIRTUAL_PID, WALL_PID

# ---------------------------------------------------------------------------
# fixtures: a tiny async straggler scenario
# ---------------------------------------------------------------------------


def async_spec(**telemetry) -> ExperimentSpec:
    return ExperimentSpec(
        name="telemetry-pin",
        rounds=3,
        log_every=0,
        model=ModelSpec(kind="mlp", dim=16, classes=4, hidden=32, r_max=8,
                        kernels="off"),
        data=DataSpec(kind="classification", batch=16, num_points=512,
                      holdout=128),
        fed=FedSpec(method="fedlrt", correction="simplified", clients=4,
                    local_steps=2, lr=5e-2, tau=0.03, eval_after=False),
        engine=EngineSpec(kind="async", buffer_size=2),
        sim=SimSpec(profile="straggler:0.25,10"),
        telemetry=TelemetrySpec(**telemetry) if telemetry else TelemetrySpec(),
    )


def run_spec(spec):
    exp = build(spec)
    hist = exp.run()
    exp.hub.close()
    return exp, hist


# ---------------------------------------------------------------------------
# telemetry on ≡ off, bit-for-bit
# ---------------------------------------------------------------------------


def test_enabled_matches_disabled_bit_for_bit():
    exp_off, hist_off = run_spec(async_spec())
    exp_on, hist_on = run_spec(
        async_spec(enabled=True, sinks="memory")
    )
    # params: exact equality, leaf by leaf
    la, lb = jax.tree.leaves(exp_off.params), jax.tree.leaves(exp_on.params)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # history: every field except the wall-clock `seconds`
    assert len(hist_off) == len(hist_on)
    for ra, rb in zip(hist_off, hist_on):
        for f in dataclasses.fields(ra):
            if f.name == "seconds":
                continue
            va, vb = getattr(ra, f.name), getattr(rb, f.name)
            if f.name == "ranks":
                assert sorted(va) == sorted(vb)
                for k in va:
                    np.testing.assert_array_equal(va[k], vb[k])
            elif isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
                np.testing.assert_array_equal(va, vb)
            else:
                assert va == vb, (f.name, va, vb)
    # and the enabled run actually observed something
    [sink] = [s for s in exp_on.hub.sinks if isinstance(s, MemorySink)]
    assert len(sink.events) > 0


# ---------------------------------------------------------------------------
# event schema + JSONL round-trip
# ---------------------------------------------------------------------------


def test_jsonl_sink_schema_roundtrip(tmp_path):
    out = tmp_path / "telemetry"
    spec = async_spec(enabled=True, sinks="memory,jsonl", dir=str(out))
    exp, _ = run_spec(spec)
    path = out / "events.jsonl"
    assert path.exists()
    assert validate_jsonl(path) == []
    [mem] = [s for s in exp.hub.sinks if isinstance(s, MemorySink)]
    with open(path) as fh:
        from_disk = [json.loads(line) for line in fh]
    # JSONL round-trips the in-memory stream losslessly (json floats are
    # repr-exact), and every event validates individually
    assert from_disk == mem.events
    for ev in mem.events:
        assert validate_event(ev) == []
    # the hot seams all showed up
    names = {(e["kind"], e["name"]) for e in mem.events}
    assert ("meta", "hub_start") in names
    assert ("span", "client_round") in names
    assert ("span", "aggregate") in names
    assert ("counter", "sim.events_popped") in names
    assert ("gauge", "rank.effective_mean") in names
    assert ("gauge", "staleness_mean") in names


def test_validate_event_rejects_malformed():
    ok = {
        "kind": "gauge", "name": "x", "t": 0.0, "dur": None, "tv": None,
        "durv": None, "value": 1.0, "attrs": {"round": 0}, "seq": 0,
    }
    assert validate_event(ok) == []
    assert validate_event("nope") != []
    assert validate_event({**ok, "kind": "bogus"}) != []
    assert validate_event({**ok, "value": None}) != []  # gauge needs a value
    assert validate_event({**ok, "attrs": {"x": [1, 2]}}) != []
    assert validate_event({**ok, "extra": 1}) != []
    missing = dict(ok)
    del missing["seq"]
    assert validate_event(missing) != []


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------


def test_perfetto_export_async_straggler(tmp_path):
    out = tmp_path / "telemetry"
    spec = async_spec(
        enabled=True, sinks="memory,perfetto", dir=str(out)
    )
    exp, _ = run_spec(spec)
    trace_path = out / "trace.json"
    assert trace_path.exists()
    with open(trace_path) as fh:
        trace = json.load(fh)
    evs = trace["traceEvents"]
    assert evs, "empty trace"
    for ev in evs:
        assert ev["ph"] in ("X", "C", "M")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    # per-client tracks on the virtual clock (tid = client + 1); the 10×
    # straggler's first round may still be in flight after 3 aggregates,
    # so expect the three fast clients at least
    client_tids = {
        ev["tid"] for ev in evs
        if ev["ph"] == "X" and ev["pid"] == VIRTUAL_PID
        and ev["tid"] != SERVER_TID
    }
    assert len(client_tids) >= 3
    assert client_tids <= {c + 1 for c in range(4)}
    # ... and the server aggregate track exists on the virtual clock too
    assert any(
        ev["ph"] == "X" and ev["pid"] == VIRTUAL_PID
        and ev["tid"] == SERVER_TID
        for ev in evs
    )
    # monotone timestamps per (pid, tid) track, in emission order
    last = {}
    for ev in evs:
        if ev["ph"] != "X":
            continue
        key = (ev["pid"], ev["tid"])
        assert ev["ts"] >= last.get(key, float("-inf")), (
            f"track {key} went backwards at {ev['name']!r}"
        )
        last[key] = ev["ts"]
    # track metadata names both clock processes
    meta_names = {
        ev["args"]["name"] for ev in evs if ev["ph"] == "M"
        and ev["name"] == "process_name"
    }
    assert meta_names == {"wall clock", "virtual clock"}
    # the in-memory stream exports to the identical trace
    [mem] = [s for s in exp.hub.sinks if isinstance(s, MemorySink)]
    assert events_to_trace(mem.events) == trace
    assert WALL_PID in {ev["pid"] for ev in evs}


# ---------------------------------------------------------------------------
# hub mechanics
# ---------------------------------------------------------------------------


def test_disabled_hub_is_noop():
    sink = MemorySink()
    hub = TelemetryHub([sink], enabled=False)
    with hub.span("x", round=0):
        pass
    hub.span_at("y", 0.0, 1.0)
    hub.counter("c")
    hub.gauge("g", 1.0)
    hub.hist("h", 1.0)
    hub.progress("hello")
    assert sink.events == []
    # the disabled span context manager is one cached object
    assert hub.span("a") is hub.span("b")
    assert NULL_HUB.enabled is False


def test_sample_every_drops_offcadence_gauges():
    sink = MemorySink()
    hub = TelemetryHub([sink], sample_every=2)
    for r in range(4):
        hub.gauge("g", float(r), round=r)
        hub.hist("h", float(r), round=r)
        hub.counter("c", 1.0, round=r)  # counters are never sampled
        with hub.span("s", round=r):  # spans are never sampled
            pass
    kinds = [(e["kind"], e["attrs"].get("round")) for e in sink.events
             if e["kind"] != "meta"]
    gauges = [r for k, r in kinds if k == "gauge"]
    hists = [r for k, r in kinds if k == "hist"]
    counters = [r for k, r in kinds if k == "counter"]
    spans = [r for k, r in kinds if k == "span"]
    assert gauges == [0, 2] and hists == [0, 2]
    assert counters == [0, 1, 2, 3] and spans == [0, 1, 2, 3]


def test_console_sink_renders_progress_only(capsys):
    from repro.telemetry import ConsoleSink

    hub = TelemetryHub([ConsoleSink()])
    hub.gauge("g", 1.0)
    hub.progress("round 3 done")
    out = capsys.readouterr().out
    assert "round 3 done" in out
    assert "g" not in out.replace("round 3 done", "")


def test_virtual_clock_attaches():
    from repro.fed.sim.clock import VirtualClock

    sink = MemorySink()
    hub = TelemetryHub([sink])
    clock = VirtualClock()
    hub.attach_clock(clock)
    clock.advance_to(2.5)
    hub.counter("c")
    ev = [e for e in sink.events if e["kind"] == "counter"][-1]
    assert ev["tv"] == 2.5
    hub.span_at("s", 1.0, 2.0)
    sp = [e for e in sink.events if e["kind"] == "span"][-1]
    assert sp["tv"] == 1.0 and sp["durv"] == 1.0


def test_trace_audit_publishes_counters():
    from repro.analysis.trace_audit import TraceAudit

    audit = TraceAudit()
    audit.record(("eng.py", 10, "step"))
    audit.record(("eng.py", 10, "step"))
    audit.record(("eng.py", 40, "phase"))
    sink = MemorySink()
    audit.publish(TelemetryHub([sink]))
    evs = [e for e in sink.events if e["name"] == "jit.traces"]
    assert [(e["value"], e["attrs"]["site"]) for e in evs] == [
        (2.0, "eng.py:10"), (1.0, "eng.py:40"),
    ]


def test_telemetry_spec_validates():
    with pytest.raises(ValueError, match="sample_every"):
        TelemetrySpec(sample_every=0)
    with pytest.raises(ValueError, match="unknown telemetry sink"):
        TelemetrySpec(sinks="console,bogus")
    with pytest.raises(ValueError, match="telemetry.dir"):
        TelemetrySpec(enabled=True, sinks="jsonl")
    # disabled spec may name file sinks without a dir (nothing is opened)
    TelemetrySpec(enabled=False, sinks="jsonl")


def test_spec_toml_roundtrip_with_telemetry(tmp_path):
    spec = async_spec(enabled=True, sinks="memory", sample_every=3)
    assert ExperimentSpec.from_toml(spec.to_toml()) == spec
    # old configs without a [telemetry] table stay valid (defaults)
    plain = async_spec()
    d = plain.to_dict()
    d.pop("telemetry")
    assert ExperimentSpec.from_dict(d) == plain
