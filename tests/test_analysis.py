"""repro-lint: one violating + one clean sample per rule, the exit-0 pin
on the shipped tree, suppression semantics, and the jit retrace audit
(including the demonstration that breaking the dropout zero-weight-padding
path is caught)."""
from __future__ import annotations

import textwrap
from pathlib import Path

import pytest

from repro.analysis import TraceAudit, get_rules, lint_paths, trace_audit
from repro.analysis.__main__ import main as lint_main
from repro.analysis.core import lint_file

REPO = Path(__file__).resolve().parents[1]


def check(tmp_path, rel, code, rule_id):
    """Lint ``code`` placed at ``rel`` (repo-layout-relative) with one rule."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    rules = [r for r in get_rules() if r.id == rule_id]
    return lint_file(str(path), rules)


# ---------------------------------------------------------------------------
# per-rule fixtures: positive (violating) and negative (clean)
# ---------------------------------------------------------------------------


def test_rpl001_flags_engine_outside_build(tmp_path):
    bad = check(
        tmp_path, "tools/driver.py",
        """
        from repro.fed import FederatedEngine

        eng = FederatedEngine(loss, params, cfg, method="fedlrt")
        """,
        "RPL001",
    )
    assert [f.rule for f in bad] == ["RPL001"]
    assert "build() seam" in bad[0].message


def test_rpl001_allows_the_build_seam(tmp_path):
    ok = check(
        tmp_path, "src/repro/api/experiment.py",
        """
        from repro.fed.engine import FederatedEngine

        def build(spec):
            return FederatedEngine(spec.loss, spec.params, spec.cfg)
        """,
        "RPL001",
    )
    assert ok == []


def test_rpl002_flags_adhoc_scenario_in_entry_point(tmp_path):
    bad = check(
        tmp_path, "benchmarks/bench_x.py",
        """
        from repro.core import FedConfig

        cfg = FedConfig(num_clients=4, s_star=4, lr=0.1)
        """,
        "RPL002",
    )
    assert [f.rule for f in bad] == ["RPL002"]


def test_rpl002_library_code_may_assemble_config(tmp_path):
    # FedConfig construction in library code (e.g. spec.to_fed_config) is
    # exactly where it belongs; only entry points are restricted
    ok = check(
        tmp_path, "src/repro/api/spec.py",
        """
        from repro.core import FedConfig

        def to_fed_config(self):
            return FedConfig(num_clients=self.clients, s_star=4, lr=self.lr)
        """,
        "RPL002",
    )
    assert ok == []


def test_rpl003_flags_nondeterminism_in_library(tmp_path):
    bad = check(
        tmp_path, "src/repro/fed/sched.py",
        """
        import time
        import numpy as np

        def pick(clients):
            t = time.time()
            rng = np.random.default_rng()
            np.random.shuffle(clients)
            for c in {1, 2, 3}:
                pass
            return t
        """,
        "RPL003",
    )
    assert {f.rule for f in bad} == {"RPL003"}
    assert len(bad) == 4  # wall clock, seedless rng, legacy shuffle, set loop


def test_rpl003_seeded_and_launch_are_clean(tmp_path):
    ok = check(
        tmp_path, "src/repro/fed/sched.py",
        """
        import numpy as np

        def pick(clients, seed):
            rng = np.random.default_rng(seed)
            return rng.permutation(clients)
        """,
        "RPL003",
    )
    assert ok == []
    # identical nondeterminism is fine in the launch/ surface
    ok = check(
        tmp_path, "src/repro/launch/cli.py",
        """
        import time

        def main():
            return time.time()
        """,
        "RPL003",
    )
    assert ok == []


def test_rpl003_telemetry_clock_is_the_one_sanctioned_wall_clock(tmp_path):
    # the shim itself may read the wall clock — it IS the sanctioned seam
    ok = check(
        tmp_path, "src/repro/telemetry/clock.py",
        """
        import time

        def perf_seconds():
            return time.perf_counter()

        def wall_time():
            return time.time()
        """,
        "RPL003",
    )
    assert ok == []
    # the exemption is the one file, not the package: a sibling telemetry
    # module timing on its own is still flagged
    bad = check(
        tmp_path, "src/repro/telemetry/hub.py",
        """
        import time

        def stamp():
            return time.perf_counter()
        """,
        "RPL003",
    )
    assert {f.rule for f in bad} == {"RPL003"}
    # ... and so is any other library module (perf_counter included — the
    # old suppression-comment escape hatch is gone; route through
    # repro.telemetry.clock.perf_seconds instead)
    bad = check(
        tmp_path, "src/repro/fed/foo.py",
        """
        import time

        def dur():
            return time.perf_counter()
        """,
        "RPL003",
    )
    assert len(bad) == 1
    assert "repro.telemetry.clock" in bad[0].hint


def test_rpl004_flags_numpy_and_python_branching_in_traced_code(tmp_path):
    bad = check(
        tmp_path, "src/repro/core/stepper.py",
        """
        import numpy as np
        import jax

        @jax.jit
        def step(x):
            if x:
                y = np.linalg.svd(x)
            return float(x)
        """,
        "RPL004",
    )
    assert len(bad) == 4  # numpy import, if x, np call, float(x)
    assert any("if x" in f.message for f in bad)


def test_rpl004_jnp_and_lax_are_clean(tmp_path):
    ok = check(
        tmp_path, "src/repro/core/stepper.py",
        """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            return jnp.where(x > 0, jnp.linalg.norm(x), 0.0)
        """,
        "RPL004",
    )
    assert ok == []


def test_rpl005_flags_unmasked_factor_write(tmp_path):
    bad = check(
        tmp_path, "src/repro/core/update.py",
        """
        def apply(f, g, lr):
            S_new = f.S - lr * g
            out = LowRankFactor(U=f.U, S=S_new - 0, V=f.V, rank=f.rank)
            patched = f.U.at[:, 0].set(g[:, 0])
            return out, patched
        """,
        "RPL005",
    )
    assert len(bad) == 2  # the constructor and the .at[].set
    assert all(f.rule == "RPL005" for f in bad)


def test_rpl005_mask_in_scope_is_clean(tmp_path):
    ok = check(
        tmp_path, "src/repro/core/update.py",
        """
        def apply(f, g, lr):
            m = rank_mask(f.rank, f.r_max, dtype=f.S.dtype)
            S_new = mask_coeff(f.S - lr * g, m)
            out = LowRankFactor(U=f.U, S=S_new, V=f.V, rank=f.rank)
            patched = f.U.at[:, 0].set(g[:, 0] * m[0])
            return out, patched
        """,
        "RPL005",
    )
    assert ok == []
    # read-only reconstruction (plumbing existing leaves) needs no mask
    ok = check(
        tmp_path, "src/repro/core/reader.py",
        """
        def rewrap(f):
            return LowRankFactor(U=f.U, S=f.S, V=f.V, rank=f.rank)
        """,
        "RPL005",
    )
    assert ok == []


def test_rpl006_flags_incomplete_unregistered_codec(tmp_path):
    bad = check(
        tmp_path, "src/repro/fed/mycodec.py",
        """
        class HalfCodec:
            def encode(self, payload):
                return payload

            def decode(self, msg, extra):
                return msg
        """,
        "RPL006",
    )
    msgs = "\n".join(f.message for f in bad)
    assert "missing `nbytes()`" in msgs
    assert "decode` signature differs" in msgs
    assert "defines no `name`" in msgs
    assert "never registered" in msgs


def test_rpl006_conforming_codec_is_clean(tmp_path):
    ok = check(
        tmp_path, "src/repro/fed/mycodec.py",
        """
        class GoodCodec:
            name = "good"

            def encode(self, payload):
                return payload

            def decode(self, msg):
                return msg

            def nbytes(self, msg):
                return 0

        _CODECS = {"good": GoodCodec()}
        """,
        "RPL006",
    )
    assert ok == []


def test_rpl007_flags_raw_pickle(tmp_path):
    bad = check(
        tmp_path, "src/repro/fed/state.py",
        """
        import pickle
        import numpy as np

        def load(path):
            with open(path, "rb") as fh:
                a = pickle.load(fh)
            b = np.load(path, allow_pickle=True)
            return a, b
        """,
        "RPL007",
    )
    assert len(bad) == 2
    assert all(f.rule == "RPL007" for f in bad)


def test_rpl007_plain_npz_is_clean(tmp_path):
    ok = check(
        tmp_path, "src/repro/fed/state.py",
        """
        import numpy as np

        def load(path):
            return np.load(path, allow_pickle=False)
        """,
        "RPL007",
    )
    assert ok == []


def test_rpl008_flags_spec_field_nothing_reads(tmp_path):
    bad = check(
        tmp_path, "src/repro/api/spec.py",
        """
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class FooSpec:
            used: int = 1
            orphan: int = 2

            def __post_init__(self):
                if self.used < 0:
                    raise ValueError("used")
        """,
        "RPL008",
    )
    assert [f.rule for f in bad] == ["RPL008"]
    assert "orphan" in bad[0].message


def test_rpl008_validated_fields_are_clean(tmp_path):
    ok = check(
        tmp_path, "src/repro/api/spec.py",
        """
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class FooSpec:
            used: int = 1
            other: int = 2

            def __post_init__(self):
                if self.used < 0 or self.other < 0:
                    raise ValueError("bad")
        """,
        "RPL008",
    )
    assert ok == []


# ---------------------------------------------------------------------------
# the dataflow tier: path sensitivity the lexical rules lacked
# ---------------------------------------------------------------------------

# a mask is computed and applied — but only on one branch.  Every path
# must be sanitizer-dominated, so this is a genuine violation.
BRANCH_ONLY_MASKED = """
def apply(f, g, lr, flag):
    m = rank_mask(f.rank, f.r_max, dtype=f.S.dtype)
    S_new = f.S - lr * g
    if flag:
        S_new = mask_coeff(S_new, m)
    return LowRankFactor(U=f.U, S=S_new, V=f.V, rank=f.rank)
"""


def test_rpl005_dataflow_flags_branch_only_mask(tmp_path):
    bad = check(
        tmp_path, "src/repro/core/update.py", BRANCH_ONLY_MASKED, "RPL005"
    )
    assert len(bad) == 1
    assert "S=" in bad[0].message


def test_rpl005_legacy_lexical_rule_misses_branch_only_mask(tmp_path):
    """The regression the CFG rewrite exists for: PR 7's lexical rule sees
    `mask_coeff` somewhere in the function and calls it clean — it cannot
    ask *on which paths* the sanitizer dominates the write."""
    from repro.analysis.rules import LegacyFactorLayoutWrites

    path = tmp_path / "src" / "repro" / "core" / "update.py"
    path.parent.mkdir(parents=True)
    path.write_text(textwrap.dedent(BRANCH_ONLY_MASKED))
    assert lint_file(str(path), [LegacyFactorLayoutWrites()]) == []


def test_rpl005_mask_on_every_branch_is_clean(tmp_path):
    ok = check(
        tmp_path, "src/repro/core/update.py",
        """
        def apply(f, g, lr, flag):
            m = rank_mask(f.rank, f.r_max, dtype=f.S.dtype)
            if flag:
                S_new = mask_coeff(f.S - lr * g, m)
            else:
                S_new = jnp.zeros_like(f.S)
            return LowRankFactor(U=f.U, S=S_new, V=f.V, rank=f.rank)
        """,
        "RPL005",
    )
    assert ok == []


def test_rpl005_loop_reassignment_is_path_sensitive(tmp_path):
    # masked before the loop, overwritten unmasked inside it: the back
    # edge carries FRESH into the write on the second iteration
    bad = check(
        tmp_path, "src/repro/core/update.py",
        """
        def apply(f, gs, lr):
            m = rank_mask(f.rank, f.r_max, dtype=f.S.dtype)
            S_new = mask_coeff(f.S, m)
            for g in gs:
                out = LowRankFactor(U=f.U, S=S_new, V=f.V, rank=f.rank)
                S_new = S_new - lr * g
            return out
        """,
        "RPL005",
    )
    assert len(bad) == 1


# ---------------------------------------------------------------------------
# RPL009: the static shape/dtype interpreter over the kernel entry points
# ---------------------------------------------------------------------------

OPS = REPO / "src" / "repro" / "kernels" / "ops.py"


def _lint_ops_variant(tmp_path, source: str):
    path = tmp_path / "src" / "repro" / "kernels" / "ops.py"
    path.parent.mkdir(parents=True)
    path.write_text(source)
    rules = [r for r in get_rules() if r.id == "RPL009"]
    return lint_file(str(path), rules)


def test_rpl009_shipped_kernels_are_clean(tmp_path):
    assert _lint_ops_variant(tmp_path, OPS.read_text()) == []


def test_rpl009_catches_sublane_padding_removal_statically(tmp_path):
    """The PR 2 bug class: hard-coding the f32 sublane (8) breaks bf16
    shapes with M % 16 == 8.  No JAX execution — the interpreter rejects
    the mutant from the constraint table alone."""
    src = OPS.read_text()
    mutant = src.replace(
        "bm = _block(256, M, _sublane(x.dtype))",
        "bm = _block(256, M, 8)",
    )
    assert mutant != src
    bad = _lint_ops_variant(tmp_path, mutant)
    assert len(bad) >= 1
    msgs = "\n".join(f.message for f in bad)
    assert "sublane" in msgs and "bfloat16" in msgs
    # the witness cases that expose it ride along in the message
    assert "bf16-m-mod-16-eq-8" in msgs


def test_rpl009_catches_dropped_cotangent_cast(tmp_path):
    """Mixed-precision custom-VJP drift: dropping the dS cast leaves an
    f32 cotangent against a bf16 primal."""
    src = OPS.read_text()
    mutant = src.replace(
        "dS[:R, :R].astype(S.dtype),",
        "dS[:R, :R],",
    )
    assert mutant != src
    bad = _lint_ops_variant(tmp_path, mutant)
    assert any("dS" in f.message and "dtype" in f.message for f in bad)


# ---------------------------------------------------------------------------
# autofix: --fix applies mechanical repairs; the round trip is a fixpoint
# ---------------------------------------------------------------------------

MUTANT_TREE = {
    # RPL003: unsorted listdir (mechanical sorted() wrap)
    "src/repro/fed/sweep.py": """
        import os

        def shards(d):
            return [f for f in os.listdir(d) if f.endswith(".npz")]
        """,
    # RPL005: mask computed but not applied at the ctor (mechanical re-mask)
    "src/repro/core/mutant.py": """
        def apply(f, g, lr):
            m = rank_mask(f.rank, f.r_max, dtype=f.S.dtype)
            S_new = f.S - lr * g
            return LowRankFactor(U=f.U, S=S_new, V=f.V, rank=f.rank)
        """,
}


def _seed_mutants(tmp_path):
    paths = []
    for rel, code in MUTANT_TREE.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(code))
        paths.append(str(p))
    return paths


def test_fix_round_trip_is_a_fixpoint(tmp_path):
    paths = _seed_mutants(tmp_path)
    select = ["--select", "RPL003,RPL005"]
    assert lint_main(paths + select) == 1

    # first --fix pass repairs both files and re-lints clean
    assert lint_main(paths + select + ["--fix"]) == 0
    fixed = (tmp_path / "src/repro/fed/sweep.py").read_text()
    assert "sorted(os.listdir(d))" in fixed
    fixed = (tmp_path / "src/repro/core/mutant.py").read_text()
    assert "mask_coeff(S_new, m)" in fixed
    before = {p: Path(p).read_text() for p in paths}

    # second pass: nothing left to fix, no file churn
    assert lint_main(paths + select + ["--fix"]) == 0
    assert {p: Path(p).read_text() for p in paths} == before


def test_fix_scaffold_inserts_auditable_suppression(tmp_path):
    p = tmp_path / "src" / "repro" / "fed" / "t.py"
    p.parent.mkdir(parents=True)
    p.write_text("import time\n\n\ndef a():\n    return time.time()\n")
    # time.time() has no mechanical fix; --scaffold turns it into tracked debt
    assert lint_main([str(p), "--select", "RPL003", "--fix"]) == 1
    assert (
        lint_main([str(p), "--select", "RPL003", "--fix", "--scaffold"]) == 0
    )
    text = p.read_text()
    assert "# repro-lint: disable=RPL003 -- TODO justify:" in text
    # and the scaffolded suppression actually governs the finding
    assert lint_main([str(p), "--select", "RPL003"]) == 0


# ---------------------------------------------------------------------------
# SARIF emission, fingerprint stability, and the CI baseline gate
# ---------------------------------------------------------------------------


def test_sarif_log_shape_and_fingerprints(tmp_path):
    import json

    from repro.analysis.sarif import fingerprints, to_sarif

    p = tmp_path / "src" / "repro" / "fed" / "t.py"
    p.parent.mkdir(parents=True)
    p.write_text("import time\nT0 = time.time()\n")
    findings = lint_paths([str(p)], select=["RPL003"])
    assert findings
    log = to_sarif(findings, str(tmp_path))
    assert log["version"] == "2.1.0"
    (run,) = log["runs"]
    assert any(r["id"] == "RPL009" for r in run["tool"]["driver"]["rules"])
    (res,) = run["results"]
    assert res["ruleId"] == "RPL003"
    assert res["locations"][0]["physicalLocation"]["artifactLocation"][
        "uri"
    ] == "src/repro/fed/t.py"
    assert res["fingerprints"]["reproLint/v1"]
    json.dumps(log)  # serializable

    # line drift must NOT change the fingerprint (else every unrelated
    # edit invalidates the committed baseline)
    fp_before = fingerprints(findings, str(tmp_path))
    p.write_text("# a comment pushed everything down\nimport time\nT0 = time.time()\n")
    drifted = lint_paths([str(p)], select=["RPL003"])
    assert [f.line for f in drifted] != [f.line for f in findings]
    assert fingerprints(drifted, str(tmp_path)) == fp_before


def test_baseline_grandfathers_old_findings_only(tmp_path):
    from repro.analysis.sarif import (
        diff_baseline,
        dump_sarif,
        load_baseline,
    )

    p = tmp_path / "src" / "repro" / "fed" / "t.py"
    p.parent.mkdir(parents=True)
    p.write_text("import time\nT0 = time.time()\n")
    old = lint_paths([str(p)], select=["RPL003"])
    baseline_file = tmp_path / "baseline.sarif"
    baseline_file.write_text(dump_sarif(old, str(tmp_path)))

    # same tree: everything grandfathered, nothing gates
    new, grand = diff_baseline(
        old, load_baseline(str(baseline_file)), str(tmp_path)
    )
    assert new == [] and len(grand) == len(old)

    # a fresh violation gates even though the old one is still present
    p.write_text("import time\nT0 = time.time()\nT1 = time.monotonic()\n")
    now = lint_paths([str(p)], select=["RPL003"])
    new, grand = diff_baseline(
        now, load_baseline(str(baseline_file)), str(tmp_path)
    )
    assert len(grand) == 1 and len(new) == 1
    assert "monotonic" not in grand[0].message


def test_cli_sarif_output_and_baseline_gate(tmp_path, capsys):
    import json

    p = tmp_path / "src" / "repro" / "fed" / "t.py"
    p.parent.mkdir(parents=True)
    p.write_text("import time\nT0 = time.time()\n")
    out = tmp_path / "report.sarif"

    # --format sarif --output writes the log; findings still set exit 1
    assert lint_main(
        [str(p), "--select", "RPL003", "--format", "sarif",
         "--output", str(out)]
    ) == 1
    log = json.loads(out.read_text())
    assert len(log["runs"][0]["results"]) == 1

    # adopting that log as the baseline grandfathers the finding: exit 0
    assert lint_main(
        [str(p), "--select", "RPL003", "--baseline", str(out)]
    ) == 0
    # a new violation beyond the baseline gates again
    p.write_text("import time\nT0 = time.time()\nT1 = time.monotonic()\n")
    assert lint_main(
        [str(p), "--select", "RPL003", "--baseline", str(out)]
    ) == 1
    capsys.readouterr()

    assert lint_main([str(p), "--scaffold"]) == 2  # requires --fix
    assert lint_main([str(p), "--baseline", str(tmp_path / "nope.sarif")]) == 2


def test_committed_baseline_matches_clean_tree():
    """The shipped gate: lint the real tree against the real committed
    baseline exactly as CI does."""
    import os

    from repro.analysis.sarif import diff_baseline, load_baseline

    findings = lint_paths(
        [str(REPO / "src"), str(REPO / "benchmarks"), str(REPO / "examples")]
    )
    known = load_baseline(str(REPO / "analysis-baseline.sarif"))
    new, _ = diff_baseline(findings, known, str(REPO))
    assert new == [], "\n".join(f.render() for f in new)
    assert os.path.exists(str(REPO / "analysis-baseline.sarif"))


# ---------------------------------------------------------------------------
# suppressions, CLI, and the shipped-tree pin
# ---------------------------------------------------------------------------


def test_inline_and_next_line_suppressions(tmp_path):
    code = """
    import time

    def a():
        return time.time()  # repro-lint: disable=RPL003 -- telemetry

    def b():
        # repro-lint: disable=RPL003 -- justification on the
        # line above the statement it governs
        return time.time()

    def c():
        return time.time()
    """
    bad = check(tmp_path, "src/repro/fed/t.py", code, "RPL003")
    assert len(bad) == 1  # only c() survives
    assert bad[0].line == max(f.line for f in bad)


def test_file_wide_suppression(tmp_path):
    bad = check(
        tmp_path, "src/repro/fed/t.py",
        """
        # repro-lint: disable-file=RPL003 -- timing shim module
        import time

        def a():
            return time.time()
        """,
        "RPL003",
    )
    assert bad == []


def test_cli_list_rules_and_exit_codes(tmp_path, capsys):
    assert lint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    listed = [ln for ln in out.splitlines() if ln.startswith("RPL")]
    assert len(listed) >= 8  # the issue's "≥ 8 active rules" gate

    bad = tmp_path / "src" / "repro" / "fed" / "t.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nT0 = time.time()\n")
    assert lint_main([str(bad)]) == 1
    assert lint_main([str(bad), "--select", "RPL007"]) == 0
    assert lint_main([str(bad), "--select", "NOPE"]) == 2


def test_shipped_tree_is_clean():
    findings = lint_paths(
        [str(REPO / "src"), str(REPO / "benchmarks"), str(REPO / "examples")]
    )
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# the dynamic twin: jit retrace audit
# ---------------------------------------------------------------------------


def _dropout_spec(rounds=6):
    from repro.api import (
        DataSpec,
        ExperimentSpec,
        FedSpec,
        ModelSpec,
        ParticipationSpec,
    )

    return ExperimentSpec(
        name="trace-audit-dropout",
        seed=3,
        rounds=rounds,
        log_every=0,
        model=ModelSpec(kind="lsq", dim=12, r_max=6),
        data=DataSpec(
            kind="lsq", num_points=240, planted_rank=3, batch=40, holdout=0
        ),
        fed=FedSpec(
            method="fedlrt", correction="full", clients=6, local_steps=2,
            lr=0.05, tau=0.1, eval_after=False,
        ),
        participation=ParticipationSpec(mode="dropout", dropout_prob=0.4),
    )


@pytest.mark.trace_audit
def test_dropout_padding_keeps_one_executable(jit_trace_audit):
    """The shipped padding path: varying dropout cohorts, ONE trace."""
    from repro.api import build

    exp = build(_dropout_spec())
    hist = exp.run()
    sizes = {r.cohort_size for r in hist}
    assert len(sizes) >= 2, "dropout draw produced a constant cohort"
    assert jit_trace_audit.total() == 1
    assert jit_trace_audit.violations() == []
    # the fixture's exit-time assert_within_limit() is the actual gate


@pytest.mark.trace_audit
def test_broken_dropout_padding_is_caught(monkeypatch):
    """Deliberately disable zero-weight padding: the engine falls back to
    one executable per cohort size and the audit must flag the retraces."""
    from repro.api import build
    from repro.fed.participation import Participation

    monkeypatch.setattr(
        Participation, "padded_size", lambda self, num_clients: None
    )
    with trace_audit() as audit:
        exp = build(_dropout_spec())
        hist = exp.run()
    sizes = {r.cohort_size for r in hist}
    assert len(sizes) >= 2, "dropout draw produced a constant cohort"
    assert audit.violations(), "retraces went undetected"
    ((site, n),) = audit.violations()
    assert n == len(sizes)  # one trace per distinct cohort size
    assert "engine.py" in site[0]
    with pytest.raises(AssertionError, match="retrace audit failed"):
        audit.assert_within_limit()


def test_trace_audit_counts_per_callsite():
    import jax
    import jax.numpy as jnp

    with trace_audit() as audit:

        def f(x):
            return x * 2

        g = jax.jit(f)
        g(jnp.ones(3))
        g(jnp.ones(3))  # cached: no retrace
        g(jnp.ones(4))  # new shape: retrace at the same site
    assert audit.total() == 2
    assert audit.violations() and audit.limit == 1
    audit.limit = 2
    assert audit.violations() == []
    # and jax.jit is restored on exit
    assert isinstance(audit, TraceAudit)
    assert jax.jit is not None and not hasattr(jax.jit, "__wrapped_audit__")
