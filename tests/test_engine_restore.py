"""Engine checkpoint/restore: a resumed run replays bit-identical rounds.

Checkpoints carry params + round_idx + a sidecar snapshot of the
FederatedBatcher stream state, so restoring mid-run and continuing must
reproduce the uninterrupted run exactly — same cohorts (participation is
seeded by ``(seed, round_idx)``), same batches (restored shuffle cursors /
RNG states), same parameters.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, init_factor, lr_matmul
from repro.data import FederatedBatcher, make_classification_data, partition_iid
from repro.fed import FederatedEngine, Participation

C, DIM, NCLS = 4, 16, 4


def _loss(f, batch):
    logits = lr_matmul(batch["x"], f)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))


def _make(seed=0):
    x, y = make_classification_data(
        dim=DIM, num_classes=NCLS, rank=3, num_points=1024, noise=0.2, seed=seed
    )
    parts = partition_iid(len(x), C, seed=seed)
    batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=16, seed=seed)
    f = init_factor(jax.random.PRNGKey(seed), DIM, NCLS, r_max=4, init_rank=4)
    cfg = FedConfig(
        num_clients=C, s_star=3, lr=0.05, correction="simplified", tau=0.05,
        eval_after=False,
    )
    return f, cfg, batcher


def _engine(f, cfg, ckpt_dir, participation):
    return FederatedEngine(
        _loss, f, cfg, method="fedlrt",
        participation=participation,
        checkpoint_dir=str(ckpt_dir), checkpoint_every=2, donate=False,
    )


def test_restore_replays_bit_identical_rounds(tmp_path):
    part = Participation(mode="uniform", cohort_size=2, seed=5)

    # uninterrupted 4-round reference run
    f, cfg, batcher_a = _make()
    eng_a = _engine(f, cfg, tmp_path / "a", part)
    eng_a.train(batcher_a, 4, log_every=0)

    # interrupted run: 2 rounds, then a fresh engine + batcher restored
    # from the round-2 checkpoint finishes the remaining 2
    f_b, cfg_b, batcher_b1 = _make()
    eng_b1 = _engine(f_b, cfg_b, tmp_path / "b", part)
    eng_b1.train(batcher_b1, 2, log_every=0)

    f_c, cfg_c, batcher_b2 = _make()  # fresh objects, pristine stream state
    eng_b2 = _engine(f_c, cfg_c, tmp_path / "b", part)
    meta = eng_b2.restore(str(tmp_path / "b" / "round_000002.npz"), batcher=batcher_b2)
    assert meta["round"] == 2 and eng_b2.round_idx == 2
    eng_b2.train(batcher_b2, 2, log_every=0)

    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        eng_a.params,
        eng_b2.params,
    )
    # restore carries the pre-restart history, so the resumed engine holds
    # the full 4-round record and cumulative accounting matches
    assert [r.round_idx for r in eng_b2.history] == [0, 1, 2, 3]
    ref = {r.round_idx: r for r in eng_a.history}
    for r in eng_b2.history:
        assert r.loss_before == ref[r.round_idx].loss_before
        np.testing.assert_array_equal(r.cohort, ref[r.round_idx].cohort)
    assert eng_b2.comm_total_bytes() == eng_a.comm_total_bytes()


def test_state_sidecar_is_versioned_and_json_safe(tmp_path):
    """The sidecar stores the history as versioned JSON-safe dicts — no
    pickled RoundResult objects, so ``restore()`` survives dataclass
    refactors (field additions like the sim timing fields)."""
    import json

    from repro.fed.engine import STATE_VERSION

    f, cfg, batcher = _make()
    eng = _engine(f, cfg, tmp_path, Participation())
    eng.train(batcher, 2, log_every=0)
    state = np.load(
        str(tmp_path / "round_000002.npz.state.npy"), allow_pickle=True
    ).item()
    assert state["version"] == STATE_VERSION
    json.dumps(state["history"])  # would raise on any non-JSON-safe entry
    assert all(isinstance(r, dict) for r in state["history"])


def test_history_state_tolerates_field_drift():
    """A sidecar written by a different RoundResult vintage still loads:
    unknown fields are dropped, missing fields take defaults."""
    from repro.fed import RoundResult
    from repro.fed.engine import history_from_state, history_to_state

    r = RoundResult(
        round_idx=3, loss_before=1.5, loss_after=1.2,
        comm_bytes_per_client=10.0, ranks={"w": np.asarray(4.0)},
        seconds=0.1, cohort_size=2, cohort=np.asarray([0, 2]),
        t_virtual=7.5,
    )
    state = history_to_state([r])
    # a field from a future vintage + one this vintage never wrote
    state[0]["from_the_future"] = 42
    del state[0]["staleness_mean"]
    (restored,) = history_from_state(state)
    assert restored.round_idx == 3
    assert restored.loss_before == 1.5
    assert restored.t_virtual == 7.5
    assert restored.staleness_mean == 0.0  # default back-filled
    np.testing.assert_array_equal(restored.cohort, r.cohort)
    np.testing.assert_array_equal(restored.ranks["w"], r.ranks["w"])


def test_restore_loads_legacy_pickled_sidecar(tmp_path):
    """Pre-versioned checkpoints (history pickled as RoundResult objects)
    still restore."""
    from repro.fed import RoundResult

    f, cfg, batcher = _make()
    eng = _engine(f, cfg, tmp_path, Participation())
    eng.train(batcher, 2, log_every=0)
    legacy_history = [
        RoundResult(
            round_idx=i, loss_before=2.0 - i, loss_after=None,
            comm_bytes_per_client=10.0, ranks={}, seconds=0.0, cohort_size=C,
        )
        for i in range(2)
    ]
    ckpt = str(tmp_path / "round_000002.npz")
    np.save(  # the legacy format: no version tag, pickled dataclasses
        ckpt + ".state.npy",
        np.asarray({"history": legacy_history}, dtype=object),
        allow_pickle=True,
    )
    f2, cfg2, _ = _make()
    eng2 = FederatedEngine(_loss, f2, cfg2, method="fedlrt", donate=False)
    eng2.restore(ckpt)
    assert [r.round_idx for r in eng2.history] == [0, 1]
    assert eng2.comm_total_bytes() == 10.0 * C * 2


def test_restore_without_state_file_still_sets_round(tmp_path):
    f, cfg, batcher = _make()
    eng = _engine(f, cfg, tmp_path, Participation())
    eng.train(batcher, 2, log_every=0)
    ckpt = str(tmp_path / "round_000002.npz")

    f2, cfg2, _ = _make()
    eng2 = FederatedEngine(_loss, f2, cfg2, method="fedlrt", donate=False)
    eng2.restore(ckpt)  # no batcher: params + round_idx only
    assert eng2.round_idx == 2
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        eng.params,
        eng2.params,
    )
