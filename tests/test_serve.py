"""Batched serving driver tests."""
import jax
import numpy as np
import pytest

from repro.launch.serve import BatchedServer
from repro.launch.train import PRESETS
from repro.models import build_model


@pytest.fixture(scope="module")
def tiny_server():
    model = build_model(PRESETS["llm-tiny"])
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_generate_shapes_and_determinism(tiny_server):
    model, params = tiny_server
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 512, size=n).astype(np.int32) for n in (5, 9, 3)]
    srv = BatchedServer(model, params, max_new_tokens=8, temperature=0.0)
    out1, stats = srv.generate(prompts)
    out2, _ = srv.generate(prompts)
    assert out1.shape == (3, 8)
    assert stats.tokens_generated == 24
    np.testing.assert_array_equal(out1, out2)  # greedy is deterministic
    assert out1.min() >= 0 and out1.max() < 512


def test_generate_eos_early_stop(tiny_server):
    model, params = tiny_server
    srv = BatchedServer(model, params, max_new_tokens=16, temperature=0.0)
    prompts = [np.arange(4, dtype=np.int32)]
    out, _ = srv.generate(prompts)
    # pick whatever greedy emits first as a fake EOS; rerun must stop at 1
    eos = int(out[0, 0])
    out2, _ = srv.generate(prompts, eos_id=eos)
    assert out2.shape[1] == 1


def test_temperature_sampling_varies(tiny_server):
    model, params = tiny_server
    prompts = [np.arange(6, dtype=np.int32)]
    srv = BatchedServer(model, params, max_new_tokens=12, temperature=1.5, seed=0)
    outs = {tuple(srv.generate(prompts)[0][0].tolist()) for _ in range(3)}
    assert len(outs) > 1  # sampling with fresh keys differs across calls
