"""Serving subsystem tests: engine, scheduler, quantization, spec path.

The heavy pins: a 2-round-trained checkpoint served factor-resident is
token-identical to the materialized dense path; rank-sliced load ≡ full
load; continuous batching ≡ the single-sequence reference (admission
order and batch composition never change a request's tokens).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CheckpointSpec,
    DataSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    ServeSpec,
    build,
    serve,
)
from repro.core.factorization import LowRankFactor, is_factor, materialize
from repro.serve import (
    Completion,
    ContinuousScheduler,
    QuantizedFactor,
    Request,
    ServeEngine,
    decode_matmul_flops,
    quantization_error_bound,
    quantize_params,
    rank_slice_params,
    resident_bytes,
)
from repro.serve.quantize import (
    dequantize_factor,
    materialize_params,
    quantize_factor,
)


def tiny_spec(**serve_kw) -> ExperimentSpec:
    sv = dict(max_batch=3, max_prompt=16, prompt_bucket=8, max_new_tokens=6)
    sv.update(serve_kw)
    return ExperimentSpec(
        name="serve-test",
        model=ModelSpec(kind="lm", preset="llm-tiny", smoke=True),
        serve=ServeSpec(**sv),
    )


def prompts_for(spec, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(1, 256, size=int(rng.integers(3, spec.serve.max_prompt)))
        .astype(np.int32)
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def session():
    return serve(tiny_spec())


# ---------------------------------------------------------------------------
# engine ≡ single-sequence reference
# ---------------------------------------------------------------------------


def ref_greedy(session, prompt, n):
    """Unbatched, unpadded, unbucketed decode through the raw model."""
    model, params = session.engine.model, session.engine.params
    logits, cache = model.serve_prefill(
        params, {"tokens": jnp.asarray(prompt)[None]},
        cache_len=len(prompt) + n,
    )
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(n - 1):
        logits, cache = model.serve_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32)
        )
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def test_continuous_matches_single_sequence_reference(session):
    spec = session.spec
    prompts = prompts_for(spec)
    outs, comps = session.generate(prompts, arrival_steps=[0, 0, 1, 3])
    for out, p in zip(outs, prompts):
        assert out.tolist() == ref_greedy(session, p, 6)
    # staggered arrivals really were admitted into freed slots mid-run
    assert any(c.admit_step > 0 for c in comps)


def test_greedy_deterministic_across_runs(session):
    prompts = prompts_for(session.spec)
    outs1, _ = session.generate(prompts)
    outs2, _ = session.generate(prompts)
    for a, b in zip(outs1, outs2):
        np.testing.assert_array_equal(a, b)


def test_batching_invariance(session):
    """A request's tokens don't depend on who shares the batch."""
    prompts = prompts_for(session.spec)
    together, _ = session.generate(prompts)
    for i, p in enumerate(prompts):
        alone, _ = session.generate([p])
        np.testing.assert_array_equal(together[i], alone[0])


def test_eos_early_stop(session):
    [out], _ = session.generate([np.arange(1, 5, dtype=np.int32)])
    eos = int(out[0])
    comps = session.run([Request(
        rid=0, tokens=np.arange(1, 5, dtype=np.int32), eos_id=eos,
    )])
    assert comps[0].tokens.tolist() == [eos]  # stopped at the first token


def test_temperature_sampling_reproducible_and_batching_invariant():
    spec = tiny_spec(temperature=1.3)
    sess = serve(spec)
    prompts = prompts_for(spec, n=3, seed=1)
    outs1, _ = sess.generate(prompts)
    outs2, _ = sess.generate(prompts)
    for a, b in zip(outs1, outs2):
        np.testing.assert_array_equal(a, b)  # keyed on (seed, rid, index)
    # batching invariance under sampling: keep the rid, drop the batchmates
    comps = sess.run([Request(rid=1, tokens=prompts[1])])
    np.testing.assert_array_equal(outs1[1], comps[0].tokens)
    greedy, _ = serve(tiny_spec()).generate(prompts)
    assert any(
        o.tolist() != g.tolist() for o, g in zip(outs1, greedy)
    )  # temperature actually changes something


# ---------------------------------------------------------------------------
# train → serve round trip
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def trained_spec(tmp_path_factory):
    ckpt = str(tmp_path_factory.mktemp("serve_ckpt"))
    spec = ExperimentSpec(
        name="serve-roundtrip",
        rounds=2,
        model=ModelSpec(kind="lm", preset="llm-tiny", smoke=True),
        data=DataSpec(kind="token_stream", tokens_per_client=2048, batch=4,
                      seq=32),
        fed=FedSpec(method="fedlrt", clients=2, local_steps=2),
        checkpoint=CheckpointSpec(dir=ckpt, every=1),
        serve=ServeSpec(checkpoint=ckpt, max_batch=2, max_prompt=16,
                        prompt_bucket=8, max_new_tokens=5),
    )
    exp = build(spec)
    exp.run()
    return spec


def test_trained_checkpoint_factor_resident_equals_dense(trained_spec):
    """The acceptance pin: factor-resident decode of a trained checkpoint
    is token-identical to the materialized U S Vᵀ path, at strictly fewer
    cost-model decode FLOPs."""
    prompts = prompts_for(trained_spec, n=3, seed=2)
    factor_sess = serve(trained_spec)
    dense_sess = serve(dataclasses.replace(
        trained_spec,
        serve=dataclasses.replace(trained_spec.serve, materialize=True),
    ))
    f_outs, _ = factor_sess.generate(prompts)
    d_outs, _ = dense_sess.generate(prompts)
    for a, b in zip(f_outs, d_outs):
        np.testing.assert_array_equal(a, b)
    params = factor_sess.engine.params
    assert decode_matmul_flops(params, factor_resident=True) < \
        decode_matmul_flops(params, factor_resident=False)
    assert factor_sess.engine.decode_flops_per_token() is not None
    assert dense_sess.engine.decode_flops_per_token() is None


def test_rank_sliced_load_equals_full_load(trained_spec):
    prompts = prompts_for(trained_spec, n=3, seed=3)
    full, _ = serve(trained_spec).generate(prompts)
    sliced_sess = serve(dataclasses.replace(
        trained_spec,
        serve=dataclasses.replace(trained_spec.serve, rank_slice=True),
    ))
    sliced, _ = sliced_sess.generate(prompts)
    for a, b in zip(full, sliced):
        np.testing.assert_array_equal(a, b)


def test_experiment_serve_inprocess(trained_spec):
    """Experiment.serve() serves the live params — same tokens as the
    checkpoint round-trip (the engine checkpoints every round here)."""
    exp = build(trained_spec)
    exp.resume()
    prompts = prompts_for(trained_spec, n=2, seed=4)
    live, _ = exp.serve().generate(prompts)
    ckpt, _ = serve(trained_spec).generate(prompts)
    for a, b in zip(live, ckpt):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------


def _factor(rng, n, m, w, rank):
    u = rng.standard_normal((n, w)).astype(np.float32)
    v = rng.standard_normal((m, w)).astype(np.float32)
    s = rng.standard_normal((w, w)).astype(np.float32)
    mask = (np.arange(w) < rank).astype(np.float32)
    return LowRankFactor(
        U=jnp.asarray(u * mask), S=jnp.asarray(s * mask[:, None] * mask[None]),
        V=jnp.asarray(v * mask), rank=jnp.float32(rank),
    )


def test_quantization_error_bound():
    f = _factor(np.random.default_rng(0), 48, 40, 16, 11)
    qf = quantize_factor(f)
    bound = quantization_error_bound(qf)
    back = dequantize_factor(qf)
    assert float(jnp.max(jnp.abs(back.U - f.U))) <= bound + 1e-7
    assert float(jnp.max(jnp.abs(back.V - f.V))) <= bound + 1e-7
    np.testing.assert_array_equal(back.S, f.S)  # S rides through in f32
    # per-column scales: bound is the wire formula, scale/2
    assert bound <= float(
        (jnp.max(jnp.abs(f.U)) - jnp.min(f.U)) / 255.0
    ) * 260  # sanity: same order as range/255


def test_quantized_inactive_columns_exactly_zero():
    f = _factor(np.random.default_rng(1), 32, 32, 12, 5)
    back = dequantize_factor(quantize_factor(f))
    np.testing.assert_array_equal(np.asarray(back.U[:, 5:]), 0.0)
    np.testing.assert_array_equal(np.asarray(back.V[:, 5:]), 0.0)
    # materialization therefore unaffected by the inactive block
    w_full = materialize(f)
    w_back = materialize(back)
    assert float(jnp.max(jnp.abs(w_full - w_back))) < 1.0  # finite, no junk


def test_int8_shrinks_resident_bytes_and_serves(trained_spec):
    base = serve(trained_spec)
    q_sess = serve(dataclasses.replace(
        trained_spec,
        serve=dataclasses.replace(trained_spec.serve, quantize="int8"),
    ))
    assert resident_bytes(q_sess.engine.params) < \
        resident_bytes(base.engine.params)
    assert any(
        isinstance(x, QuantizedFactor)
        for x in jax.tree.leaves(
            q_sess.engine.params,
            is_leaf=lambda x: isinstance(x, QuantizedFactor),
        )
    )
    outs, _ = q_sess.generate(prompts_for(trained_spec, n=2, seed=5))
    for o in outs:
        assert o.dtype == np.int32 and len(o) == 5


def test_bf16_mode_serves(trained_spec):
    sess = serve(dataclasses.replace(
        trained_spec,
        serve=dataclasses.replace(trained_spec.serve, quantize="bf16"),
    ))
    factors = [
        x for x in jax.tree.leaves(sess.engine.params, is_leaf=is_factor)
        if is_factor(x)
    ]
    assert factors and all(f.U.dtype == jnp.bfloat16 for f in factors)
    outs, _ = sess.generate(prompts_for(trained_spec, n=2, seed=6))
    assert all(len(o) == 5 for o in outs)


def test_rank_slice_shrinks_buffers():
    params = {"w": _factor(np.random.default_rng(2), 64, 48, 32, 9)}
    sliced = rank_slice_params(params)
    assert sliced["w"].r_max == 16  # 9 → next multiple of 8
    np.testing.assert_array_equal(
        np.asarray(materialize(sliced["w"])),
        np.asarray(materialize(params["w"])),
    )
    assert resident_bytes(sliced) < resident_bytes(params)
    # quantize composes after slicing
    q = quantize_params(sliced, "int8")
    assert q["w"].r_max == 16


# ---------------------------------------------------------------------------
# scheduler behavior
# ---------------------------------------------------------------------------


def test_queue_overflow_raises(session):
    spec = tiny_spec(max_batch=2, max_queue=2)
    sess = serve(spec)
    sched = sess.scheduler
    p = np.arange(1, 5, dtype=np.int32)
    sched.submit(Request(rid=0, tokens=p))
    sched.submit(Request(rid=1, tokens=p))
    with pytest.raises(RuntimeError, match="queue full"):
        sched.submit(Request(rid=2, tokens=p))


def test_static_mode_admits_in_waves():
    spec = tiny_spec(mode="static", max_batch=2, max_new_tokens=4)
    sess = serve(spec)
    p = np.arange(1, 6, dtype=np.int32)
    comps = sess.run([Request(rid=i, tokens=p) for i in range(4)])
    admits = sorted(c.admit_step for c in comps)
    # two waves of two; second wave waits for the first to fully drain
    assert admits[0] == admits[1] and admits[2] == admits[3]
    assert admits[2] > admits[0]


def test_continuous_backfills_freed_slots():
    spec = tiny_spec(mode="continuous", max_batch=2, max_new_tokens=8)
    sess = serve(spec)
    p = np.arange(1, 6, dtype=np.int32)
    reqs = [
        Request(rid=0, tokens=p, max_new_tokens=2),
        Request(rid=1, tokens=p, max_new_tokens=8),
        Request(rid=2, tokens=p, max_new_tokens=2),
    ]
    comps = sess.run(reqs)
    by = {c.rid: c for c in comps}
    # rid 2 entered the slot rid 0 freed, while rid 1 was still decoding
    assert by[2].admit_step > by[0].admit_step
    assert by[2].admit_step <= by[1].finish_step
    assert [len(by[i].tokens) for i in range(3)] == [2, 8, 2]


def test_completion_phases_and_stats(session):
    comps = session.run([Request(
        rid=7, tokens=np.arange(1, 8, dtype=np.int32), arrival_step=0,
    )])
    c = comps[0]
    assert isinstance(c, Completion) and c.rid == 7 and c.prompt_len == 7
    assert c.queued_s >= 0 and c.prefill_s > 0 and c.decode_s > 0
    assert c.tokens_per_s > 0
    assert c.finish_step >= c.admit_step >= c.submit_step


def test_prompt_bucketing_is_transparent(session):
    """Prompt lengths sharing a bucket and lengths in different buckets
    all agree with the unpadded reference; executables stay bounded."""
    eng = session.engine
    for length in (3, 8, 9, 16):
        p = np.arange(1, length + 1, dtype=np.int32)
        [out], _ = session.generate([p])
        assert out.tolist() == ref_greedy(session, p, 6)
    assert set(eng._prefill_fns) == {8, 16}
    assert eng.num_executables() == 4  # 2 buckets + insert + step


def test_prompt_too_long_rejected(session):
    with pytest.raises(ValueError, match="exceeds max_prompt"):
        session.engine.prefill(np.arange(99, dtype=np.int32))
    with pytest.raises(ValueError, match="empty prompt"):
        session.engine.prefill(np.zeros(0, dtype=np.int32))


# ---------------------------------------------------------------------------
# spec path / construction seam
# ---------------------------------------------------------------------------


def test_serve_requires_lm():
    spec = ExperimentSpec(
        model=ModelSpec(kind="mlp"),
        fed=FedSpec(clients=2),
        data=DataSpec(kind="classification"),
    )
    with pytest.raises(ValueError, match="no decode path"):
        serve(spec)


def test_serve_rejects_encdec():
    spec = ExperimentSpec(
        model=ModelSpec(kind="lm", arch="whisper-large-v3", smoke=True),
    )
    with pytest.raises(ValueError, match="enc-dec"):
        serve(spec)


def test_serve_missing_checkpoint_dir(tmp_path):
    spec = tiny_spec(checkpoint=str(tmp_path))
    with pytest.raises(FileNotFoundError, match="round_"):
        serve(spec)


def test_session_describe(session):
    text = session.describe()
    assert "continuous" in text and "spec" in text


# ---------------------------------------------------------------------------
# telemetry threading
# ---------------------------------------------------------------------------


def test_serve_telemetry_spans_and_counters():
    from repro.api.spec import TelemetrySpec
    from repro.telemetry import get_hub

    spec = dataclasses.replace(
        tiny_spec(),
        telemetry=TelemetrySpec(enabled=True, sinks="memory"),
    )
    sess = serve(spec)
    sess.generate(prompts_for(spec, n=3, seed=7), arrival_steps=[0, 1, 2])
    [sink] = [s for s in get_hub().sinks if hasattr(s, "events")]
    kinds = {(e["kind"], e["name"]) for e in sink.events}
    assert ("span", "serve.prefill") in kinds
    assert ("span", "serve.queued") in kinds
    assert ("span", "serve.decode") in kinds
    assert ("counter", "serve.tokens") in kinds
    assert ("gauge", "serve.queue_depth") in kinds
    decode_spans = [
        e for e in sink.events
        if e["kind"] == "span" and e["name"] == "serve.decode"
    ]
    assert len(decode_spans) == 3
    assert all(e["dur"] >= 0 for e in decode_spans)
