"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dependency (pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import FedConfig, fedlrt_round, init_factor, materialize
from repro.core.dlrt import augment_basis, pick_rank, truncate
from repro.core.factorization import augmented_mask, check_invariants, rank_mask
from repro.fed.wire import (
    DowncastCodec,
    IdentityCodec,
    Int8AffineCodec,
    Payload,
    payload_nbytes,
)

SETTINGS = dict(max_examples=12, deadline=None)


def _quad_loss(key, n_in, n_out):
    """Random least-squares loss over a factorized layer."""
    k1, k2 = jax.random.split(key)
    X = jax.random.normal(k1, (3, 32, n_in)) / np.sqrt(n_in)
    Y = jax.random.normal(k2, (3, 32, n_out))

    def loss(f, batch):
        pred = ((batch["x"] @ f.U) @ f.S) @ f.V.T
        return jnp.mean((pred - batch["y"]) ** 2)

    return loss, {"x": X, "y": Y}


@settings(**SETTINGS)
@given(
    n_in=st.integers(12, 48),
    n_out=st.integers(12, 48),
    r_max=st.integers(2, 12),
    init_rank=st.integers(1, 12),
    seed=st.integers(0, 10_000),
)
def test_invariants_preserved_by_round(n_in, n_out, r_max, init_rank, seed):
    key = jax.random.PRNGKey(seed)
    f = init_factor(key, n_in, n_out, r_max=r_max, init_rank=init_rank)
    loss, batch = _quad_loss(jax.random.PRNGKey(seed + 1), n_in, n_out)
    cfg = FedConfig(num_clients=3, s_star=3, lr=1e-2, correction="simplified",
                    tau=0.1, eval_after=False)
    new_f, m = fedlrt_round(loss, f, batch, cfg)
    inv = check_invariants(new_f)
    assert float(inv["u_ortho_defect"]) < 1e-3
    assert float(inv["v_ortho_defect"]) < 1e-3
    assert float(inv["s_mask_violation"]) < 1e-6
    assert 1 <= float(new_f.rank) <= new_f.r_max
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(new_f))


@settings(**SETTINGS)
@given(
    rank=st.integers(1, 8),
    r_max=st.integers(8, 12),
    seed=st.integers(0, 10_000),
)
def test_augmentation_masks_and_exactness(rank, r_max, seed):
    key = jax.random.PRNGKey(seed)
    f = init_factor(key, 40, 40, r_max=r_max, init_rank=rank)
    GU = jax.random.normal(jax.random.PRNGKey(seed + 1), f.U.shape)
    GV = jax.random.normal(jax.random.PRNGKey(seed + 2), f.V.shape)
    aug = augment_basis(f, GU, GV)
    # same represented matrix
    np.testing.assert_allclose(materialize(aug), materialize(f), atol=1e-4)
    # active set has 2·rank directions
    am = augmented_mask(f.rank, r_max)
    assert int(am.sum()) == 2 * min(rank, r_max)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 10_000),
    tau=st.floats(1e-4, 0.9),
)
def test_truncation_error_never_exceeds_theta(seed, tau):
    key = jax.random.PRNGKey(seed)
    f = init_factor(key, 32, 32, r_max=8, init_rank=8)
    GU = jax.random.normal(jax.random.PRNGKey(seed + 1), f.U.shape)
    GV = jax.random.normal(jax.random.PRNGKey(seed + 2), f.V.shape)
    aug = augment_basis(f, GU, GV)
    import dataclasses

    S_star = jax.random.normal(jax.random.PRNGKey(seed + 3), aug.S.shape)
    from repro.core.factorization import mask_coeff
    from repro.core.dlrt import coeff_grad_mask

    S_star = mask_coeff(S_star, coeff_grad_mask(aug))
    aug = dataclasses.replace(aug, S=S_star)
    new_f, info = truncate(aug, tau=tau)
    err = float(jnp.linalg.norm(materialize(new_f) - materialize(aug)))
    theta = float(info["theta"])
    # error ≤ θ unless the r_max cap binds (then it equals the tail)
    if float(info["rank"]) < new_f.r_max:
        assert err <= theta * 1.01 + 1e-5


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000), width=st.integers(2, 16))
def test_pick_rank_monotone_in_theta(seed, width):
    sigma = jnp.sort(
        jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (width,)))
    )[::-1]
    thetas = jnp.linspace(0.0, float(jnp.linalg.norm(sigma)) * 1.5, 8)
    ranks = [float(pick_rank(sigma, t, r_max=width)) for t in thetas]
    assert all(a >= b for a, b in zip(ranks, ranks[1:]))


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), c=st.integers(2, 5))
def test_identical_clients_match_single_client(seed, c):
    """With identical client data the correction vanishes and any client
    count gives the same update as C=1 (linearity of aggregation)."""
    key = jax.random.PRNGKey(seed)
    f = init_factor(key, 24, 24, r_max=6, init_rank=6)
    loss, batch1 = _quad_loss(jax.random.PRNGKey(seed + 1), 24, 24)
    one = {k: v[:1] for k, v in batch1.items()}
    rep = {k: jnp.repeat(v[:1], c, axis=0) for k, v in batch1.items()}
    cfg1 = FedConfig(num_clients=1, s_star=3, lr=1e-2, correction="full",
                     tau=0.1, eval_after=False)
    cfgC = FedConfig(num_clients=c, s_star=3, lr=1e-2, correction="full",
                     tau=0.1, eval_after=False)
    f1, _ = fedlrt_round(loss, f, one, cfg1)
    fC, _ = fedlrt_round(loss, f, rep, cfgC)
    np.testing.assert_allclose(
        materialize(f1), materialize(fC), rtol=1e-3, atol=1e-4
    )


# ---------------------------------------------------------------------------
# wire codecs (repro.fed.wire): round-trip / error-bound invariants
# ---------------------------------------------------------------------------


def _wire_tree(n_in, n_out, r_max, init_rank, seed):
    """A payload like the rounds ship: a factor leaf + a dense leaf."""
    f = init_factor(
        jax.random.PRNGKey(seed), n_in, n_out, r_max=r_max, init_rank=init_rank
    )
    dense = 2.0 * jax.random.normal(jax.random.PRNGKey(seed + 1), (n_out, 3))
    return {"w": f, "dense": dense}


@settings(**SETTINGS)
@given(
    n_in=st.integers(8, 64),
    n_out=st.integers(8, 64),
    r_max=st.integers(2, 12),
    init_rank=st.integers(1, 12),
    seed=st.integers(0, 10_000),
)
def test_wire_identity_roundtrip_exact(n_in, n_out, r_max, init_rank, seed):
    """identity: bit-exact round trip and verbatim byte accounting on
    arbitrary factor shapes."""
    tree = _wire_tree(n_in, n_out, r_max, init_rank, seed)
    codec = IdentityCodec()
    msg = codec.encode(Payload(tensors=tree))
    dec = codec.decode(msg).tensors
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert codec.nbytes(msg) == payload_nbytes(tree)


@settings(**SETTINGS)
@given(
    n_in=st.integers(8, 64),
    n_out=st.integers(8, 64),
    r_max=st.integers(2, 12),
    init_rank=st.integers(1, 12),
    seed=st.integers(0, 10_000),
)
def test_wire_downcast_roundtrip_within_dtype_eps(
    n_in, n_out, r_max, init_rank, seed
):
    """downcast: every leaf returns at its rest dtype, within the wire
    dtype's relative eps (small leaves travel verbatim — error 0)."""
    tree = _wire_tree(n_in, n_out, r_max, init_rank, seed)
    codec = DowncastCodec()  # bf16: 8 mantissa bits → rel err ≤ 2^-8
    dec = codec.decode(codec.encode(Payload(tensors=tree))).tensors
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype
        np.testing.assert_allclose(b, a, rtol=2.0 ** -8, atol=1e-6)


@settings(**SETTINGS)
@given(
    n_in=st.integers(8, 64),
    n_out=st.integers(8, 64),
    r_max=st.integers(2, 12),
    init_rank=st.integers(1, 12),
    seed=st.integers(0, 10_000),
)
def test_wire_int8_error_bounded_by_scale(n_in, n_out, r_max, init_rank, seed):
    """int8_affine: per-leaf absolute error ≤ scale/2 with
    scale = (max − min)/255 (the affine quantization step)."""
    tree = _wire_tree(n_in, n_out, r_max, init_rank, seed)
    codec = Int8AffineCodec()
    dec = codec.decode(codec.encode(Payload(tensors=tree))).tensors
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
        a, b = np.asarray(a), np.asarray(b)
        scale = (a.max() - a.min()) / 255.0 if a.size else 0.0
        assert np.abs(b - a).max() <= scale / 2 + 1e-6


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_rank_mask_shapes(seed):
    r = jax.random.randint(jax.random.PRNGKey(seed), (5,), 0, 9).astype(jnp.float32)
    m = rank_mask(r, 8)
    assert m.shape == (5, 8)
    np.testing.assert_array_equal(m.sum(-1), np.minimum(np.asarray(r), 8))
