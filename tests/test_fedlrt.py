"""Integration tests of the FeDLRT round against the paper's claims.

C1 (Fig. 4): homogeneous lsq — rank identification + convergence.
C2 (Fig. 1): heterogeneous lsq — variance correction beats no correction.
C3 (Thm. 2): per-round global loss descent at the prescribed learning rate.
C4 (Thm. 1): client coefficient drift bound.
Eq. (10): aggregation with shared bases == averaging the full matrices.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedConfig, fedlrt_round, init_factor, materialize
from repro.core.factorization import LowRankFactor

from conftest import as_batches, lsq_loss, optimal_loss


def run_rounds(loss_fn, f, batches, cfg, rounds):
    step = jax.jit(lambda p, b: fedlrt_round(loss_fn, p, b, cfg))
    metrics = None
    for _ in range(rounds):
        f, metrics = step(f, batches)
    return f, metrics


# ---------------------------------------------------------------------- C1
def test_homogeneous_rank_identification_and_convergence(homo_prob, rng_key):
    batches = as_batches(homo_prob)
    f = init_factor(rng_key, 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0)
    cfg = FedConfig(num_clients=4, s_star=20, lr=0.1, correction="full", tau=0.1)
    f, m = run_rounds(lsq_loss, f, batches, cfg, 120)
    # identifies the target rank 4 and never underestimates it
    assert float(f.rank) == homo_prob.rank_star
    # converges to the minimizer (paper: up to ~1e-5 error regime)
    dist = float(jnp.linalg.norm(materialize(f) - homo_prob.W_star))
    assert float(m["loss_before"]) < 1e-5
    assert dist < 5e-2


def test_homogeneous_rank_never_underestimated(homo_prob, rng_key):
    batches = as_batches(homo_prob)
    f = init_factor(rng_key, 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0)
    cfg = FedConfig(num_clients=4, s_star=20, lr=0.1, correction="full", tau=0.1)
    step = jax.jit(lambda p, b: fedlrt_round(lsq_loss, p, b, cfg))
    for _ in range(60):
        f, _ = step(f, batches)
        assert float(f.rank) >= homo_prob.rank_star


# ---------------------------------------------------------------------- C2
@pytest.mark.parametrize("corr", ["simplified", "full"])
def test_heterogeneous_variance_correction_beats_none(hetero_prob, rng_key, corr):
    batches = as_batches(hetero_prob)
    opt = optimal_loss(hetero_prob)

    def run(correction):
        f = init_factor(rng_key, 10, 10, r_max=5, init_rank=5, spectrum_scale=1.0)
        cfg = FedConfig(
            num_clients=4, s_star=100, lr=0.02, correction=correction, tau=0.01,
            eval_after=False,
        )
        f, m = run_rounds(lsq_loss, f, batches, cfg, 200)
        return float(m["loss_before"]) - opt

    excess_corr = run(corr)
    excess_none = run("none")
    assert excess_corr < excess_none * 0.7  # correction clearly helps
    assert excess_corr < 1e-2


# ---------------------------------------------------------------------- C3
def test_global_loss_descent(homo_prob, rng_key):
    """Thm. 2: with λ ≤ 1/(12·L·s*), the global loss descends every round
    up to the L·ϑ truncation slack."""
    batches = as_batches(homo_prob)
    f = init_factor(rng_key, 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0)
    # features are orthonormalized Legendre → Hessian eigenvalues O(1);
    # λ·s* = 0.02·20 = 0.4 ≲ 1/(12·L) need not hold exactly — use a safe lr.
    cfg = FedConfig(
        num_clients=4, s_star=10, lr=5e-3, correction="full", tau=1e-3,
        eval_after=True,
    )
    step = jax.jit(lambda p, b: fedlrt_round(lsq_loss, p, b, cfg))
    prev = None
    for _ in range(30):
        f, m = step(f, batches)
        before, after = float(m["loss_before"]), float(m["loss_after"])
        assert after <= before + 1e-6  # descent within the round
        if prev is not None:
            assert before <= prev + 1e-6  # monotone across rounds
        prev = after


# ---------------------------------------------------------------------- C4
def test_coefficient_drift_bound(hetero_prob, rng_key):
    """Thm. 1: max_c,s ‖S̃_c^s − S̃‖ ≤ e·s*·λ·‖∇_S̃ L(W̃_r)‖."""
    batches = as_batches(hetero_prob)
    f = init_factor(rng_key, 10, 10, r_max=5, init_rank=5, spectrum_scale=1.0)
    s_star, lr = 50, 0.005
    cfg = FedConfig(
        num_clients=4, s_star=s_star, lr=lr, correction="full", tau=0.01,
        eval_after=False, track_drift=True,
    )
    step = jax.jit(lambda p, b: fedlrt_round(lsq_loss, p, b, cfg))
    for _ in range(10):
        f, m = step(f, batches)
        bound = np.e * s_star * lr * float(m["grad_norm_S"])
        # grad_norm_S is ‖∇_S L‖ at the pre-augmentation point, which equals
        # ‖∇_S̃ L(W̃_r)‖ up to the basis-augmentation block; allow slack 2x.
        assert float(m["max_coeff_drift"]) <= 2.0 * bound + 1e-8


# ------------------------------------------------------------------ Eq.(10)
def test_aggregation_equivalence(rng_key):
    """mean_c(Ũ S̃_c Ṽᵀ) == Ũ (mean_c S̃_c) Ṽᵀ — exact with shared bases."""
    from repro.core.dlrt import augment_basis

    f = init_factor(rng_key, 16, 16, r_max=4)
    GU = jax.random.normal(jax.random.PRNGKey(1), f.U.shape)
    GV = jax.random.normal(jax.random.PRNGKey(2), f.V.shape)
    aug = augment_basis(f, GU, GV)
    S_c = jax.random.normal(jax.random.PRNGKey(3), (5,) + aug.S.shape)
    lhs = jnp.mean(jnp.einsum("ik,ckl,jl->cij", aug.U, S_c, aug.V), axis=0)
    rhs = aug.U @ jnp.mean(S_c, axis=0) @ aug.V.T
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)


def test_single_client_equals_centralized(homo_prob, rng_key):
    """C=1 FeDLRT is the (rank-adaptive) centralized BUG scheme — no drift."""
    batches = jax.tree.map(
        lambda x: x.reshape((1, -1) + x.shape[2:]), as_batches(homo_prob)
    )
    f = init_factor(rng_key, 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0)
    cfg = FedConfig(num_clients=1, s_star=20, lr=0.1, correction="full", tau=0.1)
    f, m = run_rounds(lsq_loss, f, batches, cfg, 80)
    assert float(m["loss_before"]) < 1e-5


def test_variance_correction_is_zero_for_single_client(homo_prob, rng_key):
    """With C=1 the correction term vanishes: corrected == uncorrected."""
    batches = jax.tree.map(
        lambda x: x.reshape((1, -1) + x.shape[2:]), as_batches(homo_prob)
    )
    f0 = init_factor(rng_key, 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0)
    outs = {}
    for corr in ("none", "full"):
        cfg = FedConfig(num_clients=1, s_star=5, lr=0.05, correction=corr, tau=0.1)
        f, _ = fedlrt_round(lsq_loss, f0, batches, cfg)
        outs[corr] = materialize(f)
    np.testing.assert_allclose(outs["none"], outs["full"], atol=1e-5)


def test_round_works_with_mixed_dense_leaves(rng_key):
    """Params mixing LowRankFactor and dense arrays (bias) round-trip."""
    f = init_factor(rng_key, 8, 8, r_max=3)
    params = {"w": f, "b": jnp.zeros((8,))}
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 16, 8))
    y = jnp.ones((4, 16, 8))

    def loss_fn(p, batch):
        from repro.core import lr_matmul

        pred = lr_matmul(batch["x"], p["w"]) + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    cfg = FedConfig(num_clients=4, s_star=3, lr=0.1, correction="simplified", tau=0.05)
    new_params, m = fedlrt_round(loss_fn, params, {"x": x, "y": y}, cfg)
    assert isinstance(new_params["w"], LowRankFactor)
    assert new_params["b"].shape == (8,)
    assert float(m["loss_after"]) < float(m["loss_before"])


def test_weighted_aggregation(rng_key):
    """Paper §2 extension: non-uniform client weights ∝ |X_c|.

    Weighting one client ~1 and the others ~0 must reproduce (approximately)
    the single-client round on that client's data; uniform weights must
    equal the default mean path exactly."""
    from repro.core import lr_matmul

    f = init_factor(rng_key, 16, 16, r_max=4, init_rank=4)
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (3, 32, 16))
    y = jax.random.normal(ks[1], (3, 32, 16))

    def loss_fn(p, batch):
        return jnp.mean((lr_matmul(batch["x"], p) - batch["y"]) ** 2)

    batch = {"x": x, "y": y}
    cfg = FedConfig(num_clients=3, s_star=4, lr=0.05, correction="full",
                    tau=0.05, eval_after=False)
    # uniform weights == default mean
    f_mean, _ = fedlrt_round(loss_fn, f, batch, cfg)
    f_unif, _ = fedlrt_round(
        loss_fn, f, batch, cfg, client_weights=jnp.ones(3)
    )
    np.testing.assert_allclose(
        materialize(f_mean), materialize(f_unif), atol=1e-5
    )
    # concentrated weights ≈ single-client round on client 0
    f_conc, _ = fedlrt_round(
        loss_fn, f, batch, cfg, client_weights=jnp.array([1.0, 1e-6, 1e-6])
    )
    one = {k: v[:1] for k, v in batch.items()}
    cfg1 = FedConfig(num_clients=1, s_star=4, lr=0.05, correction="full",
                     tau=0.05, eval_after=False)
    f_one, _ = fedlrt_round(loss_fn, f, one, cfg1)
    np.testing.assert_allclose(
        materialize(f_conc), materialize(f_one), atol=1e-3
    )
