"""The wire layer: codecs, measured accounting, and the identity pin.

Covers the wire-layer contract from three sides:

- codec algebra: round-trip exactness (identity / topk_rank), bounded
  error (downcast / int8_affine), and byte accounting per codec;
- the round data plane: ``wire=identity`` must be bit-identical to the
  undecorated path for every method, and its measured bytes must equal the
  analytic :func:`repro.core.cost_model.wire_round_bytes` exactly;
- the engine: measured ``comm_total_bytes`` vs analytic
  ``comm_total_bytes_analytic``, and the int8 uplink-compression headline
  (≥ 3× measured uplink reduction on the fig5-style MLP head).

Plus the FedConfig validation error paths (they guard the same API).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedConfig,
    fedavg_round,
    fedlin_round,
    fedlrt_naive_round,
    fedlrt_round,
    init_factor,
    lr_matmul,
)
from repro.core import cost_model
from repro.data import FederatedBatcher, make_classification_data, partition_iid
from repro.fed import FederatedEngine
from repro.fed.wire import (
    DowncastCodec,
    IdentityCodec,
    Int8AffineCodec,
    Payload,
    TopKRankCodec,
    Wire,
    make_codec,
    payload_nbytes,
)

from conftest import as_batches, lsq_dense_loss, lsq_loss


# ---------------------------------------------------------------------------
# codec algebra
# ---------------------------------------------------------------------------


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert jnp.asarray(x).dtype == jnp.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _demo_tree(key, big=(96, 48), small=(7,)):
    k1, k2 = jax.random.split(key)
    return {
        "w": 3.0 * jax.random.normal(k1, big),
        "b": jax.random.normal(k2, small),
        "n": jnp.int32(4),
    }


def test_make_codec_specs():
    assert isinstance(make_codec("identity"), IdentityCodec)
    assert isinstance(make_codec("int8_affine"), Int8AffineCodec)
    assert isinstance(make_codec("topk_rank"), TopKRankCodec)
    dc = make_codec("downcast:float16")
    assert isinstance(dc, DowncastCodec) and dc.wire_dtype == jnp.float16
    assert make_codec("downcast").wire_dtype == jnp.bfloat16
    codec = IdentityCodec()
    assert make_codec(codec) is codec  # built codecs pass through
    with pytest.raises(ValueError, match="unknown wire codec"):
        make_codec("gzip")
    with pytest.raises(ValueError, match="takes no argument"):
        make_codec("int8_affine:7")


def test_identity_roundtrip_and_bytes():
    tree = _demo_tree(jax.random.PRNGKey(0))
    codec = IdentityCodec()
    msg = codec.encode(Payload(tensors=tree))
    _tree_equal(codec.decode(msg).tensors, tree)
    assert codec.nbytes(msg) == payload_nbytes(tree) == 96 * 48 * 4 + 7 * 4 + 4


def test_downcast_roundtrip_within_eps_and_halves_bytes():
    tree = _demo_tree(jax.random.PRNGKey(1))
    codec = DowncastCodec()
    msg = codec.encode(Payload(tensors=tree))
    dec = codec.decode(msg).tensors
    # large float tensor: bf16 on the wire (relative error ≤ 2^-8),
    # restored to f32 at rest
    assert dec["w"].dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(dec["w"]), np.asarray(tree["w"]), rtol=2.0 ** -8, atol=1e-6
    )
    # small / integer leaves travel verbatim
    np.testing.assert_array_equal(np.asarray(dec["b"]), np.asarray(tree["b"]))
    assert int(dec["n"]) == 4 and dec["n"].dtype == jnp.int32
    assert codec.nbytes(msg) == 96 * 48 * 2 + 7 * 4 + 4


def test_int8_affine_error_bounded_by_half_scale():
    tree = _demo_tree(jax.random.PRNGKey(2))
    codec = Int8AffineCodec()
    msg = codec.encode(Payload(tensors=tree))
    dec = codec.decode(msg).tensors
    w = np.asarray(tree["w"])
    scale = (w.max() - w.min()) / 255.0
    err = np.abs(np.asarray(dec["w"]) - w)
    assert err.max() <= scale / 2 + 1e-5
    np.testing.assert_array_equal(np.asarray(dec["b"]), np.asarray(tree["b"]))
    # int8 payload + 8B (lo, scale) for the one compressed tensor
    assert codec.nbytes(msg) == 96 * 48 + 8 + 7 * 4 + 4


def test_int8_affine_batched_keeps_per_client_scales():
    """A (C, …) payload quantizes per client slice: one client's outlier
    must not widen another client's quantization step."""
    x = jnp.concatenate(
        [jnp.ones((1, 16, 16)), 1e3 * jnp.ones((1, 16, 16))], axis=0
    ) + 0.01 * jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16))
    codec = Int8AffineCodec()
    dec = codec.decode(codec.encode(Payload(tensors=x, batched=True))).tensors
    err = np.abs(np.asarray(dec) - np.asarray(x))
    # per-slice scale: client 0's range is ~0.1, so its error stays tiny
    # even though client 1's values are 1000× larger
    assert err[0].max() < 1e-3
    assert err[1].max() <= (np.ptp(np.asarray(x[1])) / 255.0) / 2 + 1e-4


def test_topk_rank_exact_and_bytes_track_rank():
    full = init_factor(jax.random.PRNGKey(4), 40, 30, r_max=8, init_rank=8)
    codec = TopKRankCodec()
    msg_full = codec.encode(Payload(tensors={"w": full}))
    _tree_equal(codec.decode(msg_full).tensors, {"w": full})
    # at full rank the effective slice is the whole buffer: identity bytes
    assert float(codec.nbytes(msg_full)) == payload_nbytes({"w": full})
    # a truncated factor (invariant: inactive columns zero) costs less and
    # still round-trips exactly
    m = (jnp.arange(8) < 3).astype(jnp.float32)
    low = dataclasses.replace(
        full, U=full.U * m, V=full.V * m,
        S=full.S * m[:, None] * m[None, :], rank=jnp.float32(3.0),
    )
    msg_low = codec.encode(Payload(tensors={"w": low}))
    _tree_equal(codec.decode(msg_low).tensors, {"w": low})
    expect = ((40 + 30) * 3 + 3 * 3) * 4 + 4  # leading-σ slice + rank counter
    assert float(codec.nbytes(msg_low)) == expect
    assert float(codec.nbytes(msg_low)) < float(codec.nbytes(msg_full))


# ---------------------------------------------------------------------------
# the round data plane
# ---------------------------------------------------------------------------


@pytest.fixture()
def cfg():
    return FedConfig(
        num_clients=4, s_star=3, lr=0.05, correction="simplified", tau=0.05
    )


def _factor_params(key=0):
    f = init_factor(jax.random.PRNGKey(key), 12, 12, r_max=4, init_rank=4)
    return {"w1": f, "b": jnp.zeros((12,))}


def _factor_loss(p, batch):
    return jnp.mean((lr_matmul(batch["x"], p["w1"]) + p["b"] - batch["y"]) ** 2)


def _batch(C=4):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    return {
        "x": jax.random.normal(ks[0], (C, 16, 12)),
        "y": jax.random.normal(ks[1], (C, 16, 12)),
    }


@pytest.mark.parametrize("correction", ["none", "simplified", "full"])
def test_fedlrt_identity_wire_bit_identical(correction, cfg):
    """The satellite pin: an identity-codec wire must not change a single
    bit of a fedlrt round, for every correction mode."""
    cfg = dataclasses.replace(cfg, correction=correction)
    params, batch = _factor_params(), _batch()
    p_a, m_a = fedlrt_round(_factor_loss, params, batch, cfg)
    p_b, m_b = fedlrt_round(
        _factor_loss, params, batch, cfg, wire=Wire("identity")
    )
    _tree_equal(p_a, p_b)
    np.testing.assert_array_equal(
        np.asarray(m_a["loss_after"]), np.asarray(m_b["loss_after"])
    )


def test_topk_rank_wire_bit_identical(cfg):
    """topk_rank is lossless by the zero-inactive-columns invariant."""
    params, batch = _factor_params(), _batch()
    p_a, _ = fedlrt_round(_factor_loss, params, batch, cfg)
    p_b, m_b = fedlrt_round(
        _factor_loss, params, batch, cfg, wire=Wire("topk_rank")
    )
    _tree_equal(p_a, p_b)
    assert m_b["wire_bytes_down_per_client"] > 0


def test_dense_identity_wire_bit_identical(homo_prob, cfg):
    batches = as_batches(homo_prob)
    W0 = jnp.zeros((20, 20))
    for round_fn in (fedavg_round, fedlin_round):
        p_a, _ = round_fn(lsq_dense_loss, W0, batches, cfg)
        p_b, _ = round_fn(lsq_dense_loss, W0, batches, cfg, wire=Wire("identity"))
        _tree_equal(p_a, p_b)


def test_measured_identity_bytes_match_analytic_exactly(cfg):
    """Acceptance pin: measured per-round bytes == cost_model analytic
    bytes for the identity codec, per direction, per method."""
    params, batch = _factor_params(), _batch()
    for correction in ("none", "simplified", "full"):
        cfg_c = dataclasses.replace(cfg, correction=correction)
        _, m = fedlrt_round(
            _factor_loss, params, batch, cfg_c, wire=Wire("identity")
        )
        ana = cost_model.wire_round_bytes(params, "fedlrt", correction=correction)
        assert float(m["wire_bytes_down_per_client"]) == ana["down"]
        assert float(m["wire_bytes_up_per_client"]) == ana["up"]


def test_measured_identity_bytes_match_analytic_dense_and_naive(homo_prob, cfg):
    batches = as_batches(homo_prob)
    W0 = {"w": jnp.zeros((20, 20)), "b": jnp.zeros((20,))}

    def dense_loss(p, b):
        return lsq_dense_loss(p["w"] + p["b"][:, None] * 0.0, b)

    for name, fn in (("fedavg", fedavg_round), ("fedlin", fedlin_round)):
        _, m = fn(dense_loss, W0, batches, cfg, wire=Wire("identity"))
        ana = cost_model.wire_round_bytes(W0, name)
        assert float(m["wire_bytes_down_per_client"]) == ana["down"]
        assert float(m["wire_bytes_up_per_client"]) == ana["up"]

    f = init_factor(jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10)
    _, m = fedlrt_naive_round(lsq_loss, f, batches, cfg, wire=Wire("identity"))
    ana = cost_model.wire_round_bytes(f, "fedlrt_naive")
    assert float(m["wire_bytes_down_per_client"]) == ana["down"]
    assert float(m["wire_bytes_up_per_client"]) == ana["up"]


def test_lossy_wire_round_stays_finite(cfg):
    params, batch = _factor_params(), _batch()
    for codec in ("downcast", "int8_affine"):
        p, m = fedlrt_round(_factor_loss, params, batch, cfg, wire=Wire(codec))
        assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p))
        assert float(m["wire_bytes_down_per_client"]) < float(
            cost_model.wire_round_bytes(params, "fedlrt")["down"]
        )


# ---------------------------------------------------------------------------
# engine accounting + the compression headline
# ---------------------------------------------------------------------------

DIM, NCLS, HID = 32, 4, 128


def _mlp_params():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {
        "w1": init_factor(k1, DIM, HID, r_max=12, init_rank=12),
        "b1": jnp.zeros((HID,)),
        "w2": 0.06 * jax.random.normal(k2, (HID, NCLS)),
        "b2": jnp.zeros((NCLS,)),
    }


def _mlp_loss(p, batch):
    h = jax.nn.relu(lr_matmul(batch["x"], p["w1"]) + p["b1"])
    logp = jax.nn.log_softmax(h @ p["w2"] + p["b2"])
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))


def _mlp_engine(wire_codec, rounds=4, C=4):
    x, y = make_classification_data(
        dim=DIM, num_classes=NCLS, rank=4, num_points=1024, noise=0.2, seed=0
    )
    parts = partition_iid(len(x), C, seed=0)
    batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=32, seed=0)
    cfg = FedConfig(
        num_clients=C, s_star=4, lr=5e-2, tau=0.03, correction="simplified",
        eval_after=True,
    )
    eng = FederatedEngine(
        _mlp_loss, _mlp_params(), cfg, method="fedlrt",
        wire_codec=wire_codec, donate=False,
    )
    hist = eng.train(batcher, rounds, log_every=0)
    return eng, hist


def test_engine_measured_vs_analytic_accounting():
    eng, hist = _mlp_engine("identity", rounds=3)
    assert all(r.wire_codec == "identity" for r in hist)
    assert all(
        r.wire_bytes_down_per_client > 0 and r.wire_bytes_up_per_client > 0
        for r in hist
    )
    measured = sum(
        (r.wire_bytes_down_per_client + r.wire_bytes_up_per_client)
        * r.cohort_size
        for r in hist
    )
    assert eng.comm_total_bytes() == pytest.approx(measured)
    # the analytic (paper-protocol) figure is preserved, and differs: it
    # prices the multi-message protocol, not the phase-boundary payloads
    assert eng.comm_total_bytes_analytic() == pytest.approx(
        sum(r.comm_bytes_per_client * r.cohort_size for r in hist)
    )
    assert eng.comm_total_bytes() != eng.comm_total_bytes_analytic()


def test_engine_wire_none_falls_back_to_analytic():
    eng, hist = _mlp_engine(None, rounds=2)
    assert all(r.wire_codec == "" for r in hist)
    assert eng.comm_total_bytes() == pytest.approx(eng.comm_total_bytes_analytic())


def test_comm_total_bytes_mixed_history():
    """The documented best-effort contract for *mixed* histories: metered
    rounds contribute their measured bytes, while unmetered rounds —
    restored from a pre-wire checkpoint, or run with ``wire_codec=None``
    — contribute the analytic ``comm_bytes_per_client`` instead.  The
    analytic total stays uniform across all three."""
    from repro.fed import RoundResult

    eng, _ = _mlp_engine(None, rounds=0)
    pre_wire = RoundResult(  # restored from a pre-wire checkpoint: no
        round_idx=0,         # wire fields at all beyond their defaults
        loss_before=1.0, loss_after=None,
        comm_bytes_per_client=100.0, ranks={}, seconds=0.0, cohort_size=2,
    )
    metered = RoundResult(
        round_idx=1, loss_before=0.9, loss_after=None,
        comm_bytes_per_client=999.0,  # analytic — must NOT enter the total
        ranks={}, seconds=0.0, cohort_size=3,
        wire_bytes_down_per_client=30.0, wire_bytes_up_per_client=20.0,
        wire_codec="identity",
    )
    unmetered = RoundResult(  # wire_codec=None round: raw pytrees
        round_idx=2, loss_before=0.8, loss_after=None,
        comm_bytes_per_client=50.0, ranks={}, seconds=0.0, cohort_size=4,
    )
    eng.history = [pre_wire, metered, unmetered]
    assert eng.comm_total_bytes() == pytest.approx(
        100.0 * 2 + (30.0 + 20.0) * 3 + 50.0 * 4
    )
    assert eng.comm_total_bytes_analytic() == pytest.approx(
        100.0 * 2 + 999.0 * 3 + 50.0 * 4
    )


def test_int8_uplink_compression_headline():
    """≥ 3× measured uplink byte reduction vs identity, with the round
    still training (the full accuracy-delta sweep lives in bench_wire)."""
    eng_id, hist_id = _mlp_engine("identity", rounds=4)
    eng_q, hist_q = _mlp_engine("int8_affine", rounds=4)
    up_id = sum(r.wire_bytes_up_per_client for r in hist_id)
    up_q = sum(r.wire_bytes_up_per_client for r in hist_q)
    assert up_id / up_q >= 3.0
    # quantization noise must not derail training on this easy task
    assert hist_q[-1].loss_after < hist_q[0].loss_before
    assert hist_q[-1].loss_after == pytest.approx(
        hist_id[-1].loss_after, rel=0.25
    )


# ---------------------------------------------------------------------------
# FedConfig validation (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kwargs, match",
    [
        (dict(correction="fancy"), "correction"),
        (dict(num_clients=0), "num_clients"),
        (dict(num_clients=-3), "num_clients"),
        (dict(s_star=0), "s_star"),
        (dict(lr=0.0), "lr"),
        (dict(lr=-1e-3), "lr"),
        (dict(tau=1.0), "tau"),
        (dict(tau=-0.1), "tau"),
    ],
)
def test_fedconfig_rejects_bad_hyperparameters(kwargs, match):
    good = dict(num_clients=4, s_star=2)
    good.update(kwargs)
    with pytest.raises(ValueError, match=match):
        FedConfig(**good)


def test_fedconfig_accepts_boundary_values():
    FedConfig(num_clients=1, s_star=1, lr=1e-8, tau=0.0)
    FedConfig(num_clients=4, s_star=2, tau=0.999)
