"""Substrate tests: data pipeline, partitioners, checkpointing, engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import FedConfig, init_factor
from repro.data import (
    FederatedBatcher,
    make_classification_data,
    make_token_stream,
    partition_dirichlet,
    partition_iid,
)
from repro.fed import FederatedEngine

from conftest import as_batches, lsq_loss


def test_partition_iid_sizes():
    parts = partition_iid(1000, 7)
    assert len(parts) == 7
    assert all(len(p) == 142 for p in parts)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx)


def test_partition_dirichlet_skew_and_balance():
    x, y = make_classification_data(num_points=2000, num_classes=10, seed=0)
    parts = partition_dirichlet(y, 4, alpha=0.1, seed=0)
    sizes = [len(p) for p in parts]
    assert all(s == 500 for s in sizes)
    # skew: each client's label histogram should be far from uniform
    for p in parts:
        hist = np.bincount(y[p], minlength=10) / len(p)
        assert hist.max() > 0.2  # uniform would be 0.1


def test_batcher_shapes_and_epoch_cycling():
    x = np.arange(100, dtype=np.float32)[:, None]
    parts = partition_iid(100, 4, seed=0)
    b = FederatedBatcher({"x": x}, parts, batch_size=5, steps_per_round=3)
    r = b.next_round()
    assert r["x"].shape == (4, 3, 5, 1)
    # cycle through more than an epoch without error / duplication blowup
    seen = []
    for _ in range(5):
        seen.append(b.next_round()["x"])
    assert np.isfinite(np.stack(seen)).all()


def test_token_stream_is_learnable_markov():
    toks = make_token_stream(vocab_size=64, num_tokens=5000, rank=4, seed=0)
    assert toks.min() >= 0 and toks.max() < 64
    # bigram structure: conditional entropy < unigram entropy
    uni = np.bincount(toks, minlength=64) / len(toks)
    H_uni = -(uni[uni > 0] * np.log(uni[uni > 0])).sum()
    joint = np.zeros((64, 64))
    np.add.at(joint, (toks[:-1], toks[1:]), 1)
    joint /= joint.sum()
    cond = joint / (joint.sum(1, keepdims=True) + 1e-12)
    H_cond = -(joint[joint > 0] * np.log(cond[joint > 0])).sum()
    assert H_cond < H_uni - 0.1


def test_checkpoint_roundtrip(tmp_path, rng_key):
    params = {
        "layer": {
            "w": init_factor(rng_key, 32, 24, r_max=6, init_rank=4),
            "b": jnp.arange(24, dtype=jnp.float32),
        },
        "head": jnp.ones((8, 8)),
    }
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, params, meta={"round": 7})
    restored, meta = load_checkpoint(p)
    assert meta["round"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(a, b)
    assert float(restored["layer"]["w"].rank) == 4.0


def test_engine_runs_fedlrt_and_checkpoints(tmp_path, homo_prob, rng_key):
    f = init_factor(rng_key, 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0)
    cfg = FedConfig(num_clients=4, s_star=10, lr=0.1, correction="simplified", tau=0.1)
    eng = FederatedEngine(
        lsq_loss, f, cfg, method="fedlrt",
        checkpoint_dir=str(tmp_path), checkpoint_every=5,
    )
    batches = as_batches(homo_prob)

    class StaticBatcher:
        def next_round(self):
            return {k: np.asarray(v) for k, v in batches.items()}

    hist = eng.train(StaticBatcher(), 10, log_every=0)
    assert hist[-1].loss_before < hist[0].loss_before
    assert os.path.exists(tmp_path / "round_000010.npz")
    restored, meta = load_checkpoint(str(tmp_path / "round_000010.npz"))
    assert meta["round"] == 10


def test_engine_method_parity(homo_prob):
    import jax.numpy as jnp

    from conftest import lsq_dense_loss

    cfg = FedConfig(num_clients=4, s_star=10, lr=0.05, tau=0.1)
    batches = as_batches(homo_prob)

    class StaticBatcher:
        def next_round(self):
            return {k: np.asarray(v) for k, v in batches.items()}

    for method in ("fedavg", "fedlin"):
        eng = FederatedEngine(lsq_dense_loss, jnp.zeros((20, 20)), cfg, method=method)
        hist = eng.train(StaticBatcher(), 5, log_every=0)
        assert hist[-1].loss_before < hist[0].loss_before
