"""Input-spec construction + skip rules (no big mesh needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import SHAPES, sanitize_specs, shape_applies, train_specs


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524_288


def test_long_context_skip_rules():
    """DESIGN.md §4: long_500k runs only for sub-quadratic archs."""
    expected_runs = {
        "rwkv6_7b": True,  # linear RNN
        "jamba_15_large": True,  # hybrid (Mamba-dominant)
        "llava_next_mistral_7b": True,  # sliding window 4096
        "qwen2_7b": False,
        "codeqwen15_7b": False,
        "qwen3_32b": False,
        "qwen15_32b": False,
        "whisper_large_v3": False,
        "deepseek_moe_16b": False,
        "olmoe_1b_7b": False,
    }
    for arch, want in expected_runs.items():
        ok, reason = shape_applies(get_config(arch), SHAPES["long_500k"])
        assert ok == want, (arch, reason)


def test_all_other_shapes_apply_everywhere():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = shape_applies(cfg, SHAPES[s])
            assert ok, (arch, s)


def test_train_specs_batch_layout():
    cfg = get_config("qwen2_7b")
    structs, specs = train_specs(cfg, SHAPES["train_4k"], 16)
    assert structs["tokens"].shape == (16, 16, 4097)
    assert structs["tokens"].dtype == jnp.int32


def test_train_specs_vlm_accounts_for_vision_prefix():
    cfg = get_config("llava_next_mistral_7b")
    structs, _ = train_specs(cfg, SHAPES["train_4k"], 16)
    text = structs["tokens"].shape[-1] - 1
    assert text + cfg.vision_tokens == 4096
    assert structs["vision_embeds"].shape[-2:] == (2880, 4096)


class _FakeMesh:
    """sanitize_specs only consults .shape — avoids needing >1 device."""

    shape = {"data": 16, "model": 2}


def test_sanitize_specs_drops_nondivisible():
    mesh = _FakeMesh()
    shapes = {"a": jax.ShapeDtypeStruct((7, 4), jnp.float32)}
    specs = {"a": P("model", None)}
    fixed = sanitize_specs(mesh, shapes, specs)
    assert fixed["a"] == P(None, None)
    shapes2 = {"a": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    fixed2 = sanitize_specs(mesh, shapes2, specs)
    assert fixed2["a"] == P("model", None)
