"""The declarative ExperimentSpec API (repro.api).

Pins, in order:

- lossless round-trips: ``from_dict(to_dict(spec)) == spec`` (handwritten
  and hypothesis-randomized specs), TOML and JSON file round-trips;
- content-hash stability across field reordering and serialization, and
  sensitivity to any field change;
- every incoherent-combination validation rejects at *spec* time;
- dotted overrides (``--set engine.kind=async`` semantics);
- the legacy train-CLI flag path and the equivalent spec file produce the
  *same spec*, and a spec written to TOML, reloaded and run reproduces the
  flag invocation bit-for-bit (identical params and round histories);
- the spec hash stamped into checkpoints makes ``resume()`` refuse a
  mismatched spec.
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.api import (
    CheckpointSpec,
    DataSpec,
    EngineSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    ParticipationSpec,
    ServeSpec,
    SimSpec,
    WireSpec,
    build,
    load_spec,
)

# ---------------------------------------------------------------------------
# fixtures: a tiny, fast mlp scenario
# ---------------------------------------------------------------------------


def tiny_mlp_spec(**changes) -> ExperimentSpec:
    base = ExperimentSpec(
        name="tiny",
        rounds=2,
        log_every=0,
        model=ModelSpec(kind="mlp", dim=16, classes=4, hidden=32, r_max=8,
                        kernels="off"),
        data=DataSpec(kind="classification", batch=16, num_points=512,
                      holdout=128, partition="dirichlet:0.3"),
        fed=FedSpec(method="fedlrt", correction="simplified", clients=4,
                    local_steps=2, lr=5e-2, tau=0.03, eval_after=False),
    )
    return dataclasses.replace(base, **changes)


def params_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def histories_equal(ha, hb) -> bool:
    if len(ha) != len(hb):
        return False
    for ra, rb in zip(ha, hb):
        da, db = dataclasses.asdict(ra), dataclasses.asdict(rb)
        da.pop("seconds"), db.pop("seconds")  # host wall-clock, not pinned
        ra_ranks, rb_ranks = da.pop("ranks"), db.pop("ranks")
        if sorted(ra_ranks) != sorted(rb_ranks):
            return False
        if not all(np.array_equal(ra_ranks[k], rb_ranks[k]) for k in ra_ranks):
            return False
        ca, cb = da.pop("cohort"), db.pop("cohort")
        if not np.array_equal(ca, cb):
            return False
        if da != db:
            return False
    return True


# ---------------------------------------------------------------------------
# round-trips
# ---------------------------------------------------------------------------

EXAMPLE_SPECS = [
    ExperimentSpec(),
    tiny_mlp_spec(),
    tiny_mlp_spec(
        engine=EngineSpec(kind="async", buffer_size=2, staleness_power=0.25),
        sim=SimSpec(profile="straggler:0.25,10"),
        wire=WireSpec(codec="int8_affine"),
    ),
    tiny_mlp_spec(
        engine=EngineSpec(kind="hier", edges=2, edge_rounds=2),
        wire=WireSpec(codec="identity", edge_codec="int8_affine"),
    ),
    tiny_mlp_spec(
        participation=ParticipationSpec(mode="uniform", cohort_size=2),
        fed=FedSpec(method="fedavg", correction="none", clients=4,
                    weighted=True),
    ),
    ExperimentSpec(
        model=ModelSpec(kind="lm", preset=None, arch="qwen2-7b", smoke=True),
        checkpoint=CheckpointSpec(dir="/tmp/ckpt", every=5),
    ),
]


@pytest.mark.parametrize("spec", EXAMPLE_SPECS, ids=range(len(EXAMPLE_SPECS)))
def test_dict_roundtrip(spec):
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec


@pytest.mark.parametrize("spec", EXAMPLE_SPECS, ids=range(len(EXAMPLE_SPECS)))
def test_toml_json_roundtrip(spec):
    assert ExperimentSpec.from_toml(spec.to_toml()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec


def test_file_roundtrip(tmp_path):
    spec = EXAMPLE_SPECS[2]
    for name in ("spec.toml", "spec.json"):
        path = tmp_path / name
        spec.save(path)
        assert load_spec(path) == spec
    with pytest.raises(ValueError, match="toml or .json"):
        spec.save(tmp_path / "spec.yaml")


def test_from_dict_rejects_unknown_keys():
    d = ExperimentSpec().to_dict()
    d["engine"]["bufsize"] = 2  # typo must not be silently dropped
    with pytest.raises(ValueError, match="unknown key.*bufsize"):
        ExperimentSpec.from_dict(d)
    with pytest.raises(ValueError, match="unknown key"):
        ExperimentSpec.from_dict({"modle": {}})


def test_from_dict_missing_keys_take_defaults():
    spec = ExperimentSpec.from_dict({"fed": {"lr": 0.01}})
    assert spec.fed.lr == 0.01
    assert spec.fed.method == "fedlrt"
    assert spec.model.preset == "llm-tiny"


def test_toml_int_coerces_to_float_field():
    spec = ExperimentSpec.from_toml("[fed]\nlr = 1\n")
    assert spec.fed.lr == 1.0 and isinstance(spec.fed.lr, float)


def test_minimal_dense_method_spec_is_valid():
    """correction defaults to 'auto' (simplified for fedlrt, none for
    baselines), so a minimal hand-written dense-method spec stays valid."""
    spec = ExperimentSpec.from_toml('[fed]\nmethod = "fedavg"\n')
    assert spec.fed.correction == "auto"
    assert spec.fed.correction_effective == "none"
    assert spec.fed.to_fed_config().correction == "none"
    assert ExperimentSpec().fed.correction_effective == "simplified"
    assert FedSpec(method="fedlrt_naive").correction_effective == "none"


# ---------------------------------------------------------------------------
# content hash
# ---------------------------------------------------------------------------


def test_spec_hash_stable_across_field_reordering():
    spec = EXAMPLE_SPECS[2]
    d = spec.to_dict()
    reordered = {k: d[k] for k in reversed(list(d))}
    reordered = {
        k: ({kk: v[kk] for kk in reversed(list(v))} if isinstance(v, dict) else v)
        for k, v in reordered.items()
    }
    assert ExperimentSpec.from_dict(reordered).spec_hash() == spec.spec_hash()
    # and across serialization formats
    assert ExperimentSpec.from_toml(spec.to_toml()).spec_hash() == spec.spec_hash()
    assert ExperimentSpec.from_json(spec.to_json()).spec_hash() == spec.spec_hash()


def test_spec_hash_sensitive_to_every_field_change():
    spec = tiny_mlp_spec()
    h = spec.spec_hash()
    assert dataclasses.replace(spec, seed=1).spec_hash() != h
    assert dataclasses.replace(
        spec, fed=dataclasses.replace(spec.fed, lr=1e-3)
    ).spec_hash() != h
    assert dataclasses.replace(
        spec, wire=WireSpec(codec="downcast")
    ).spec_hash() != h


# ---------------------------------------------------------------------------
# spec-time validation of incoherent combinations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make,msg", [
    # model/task axes
    (lambda: ExperimentSpec(model=ModelSpec(kind="lm", preset="llm-tiny",
                                            arch="qwen2-7b")),
     "exactly one of model.preset / model.arch"),
    (lambda: ExperimentSpec(model=ModelSpec(kind="lm")),
     "exactly one of model.preset / model.arch"),
    (lambda: ExperimentSpec(model=ModelSpec(preset="nope")),
     "unknown model.preset"),
    (lambda: ExperimentSpec(model=ModelSpec(kind="cnn")),
     "unknown model.kind"),
    (lambda: tiny_mlp_spec(data=DataSpec(kind="token_stream")),
     "does not feed the 'mlp' task"),
    (lambda: ExperimentSpec(data=DataSpec(kind="token_stream",
                                          partition="dirichlet:0.3")),
     "token-stream pipeline partitions windows iid"),
    (lambda: DataSpec(partition="pareto:2"), "data.partition"),
    (lambda: DataSpec(partition="dirichlet:-1"), "ALPHA > 0"),
    (lambda: DataSpec(holdout=512, num_points=512), "leave training points"),
    (lambda: ModelSpec(kernels="fast"), "model.kernels"),
    # fed axes
    (lambda: FedSpec(method="fedavg", correction="simplified"),
     "must use correction='none'"),
    (lambda: FedSpec(correction="exact"), "fed.correction"),
    (lambda: ExperimentSpec(fed=FedSpec(method="fedsgd", correction="none")),
     "unknown fed.method"),
    (lambda: FedSpec(tau=1.5), "fed.tau"),
    (lambda: FedSpec(lr=0.0), "fed.lr"),
    # engine-axis coherence
    (lambda: EngineSpec(kind="warp"), "engine.kind"),
    (lambda: EngineSpec(kind="sync", buffer_size=2),
     "only applies to the async engine"),
    (lambda: EngineSpec(kind="hier", staleness_power=0.5),
     "only applies to the async engine"),
    (lambda: EngineSpec(kind="async", edges=2),
     "only applies to the hier engine"),
    (lambda: EngineSpec(kind="sync", edge_rounds=2),
     "only applies to the hier engine"),
    (lambda: tiny_mlp_spec(engine=EngineSpec(kind="async", buffer_size=8)),
     "could never fill"),
    (lambda: tiny_mlp_spec(engine=EngineSpec(kind="hier", edges=8)),
     "engine.edges"),
    # participation × engine
    (lambda: tiny_mlp_spec(
        engine=EngineSpec(kind="async"),
        participation=ParticipationSpec(mode="uniform", cohort_size=2)),
     "only composes with the sync engine"),
    (lambda: tiny_mlp_spec(
        engine=EngineSpec(kind="hier"),
        participation=ParticipationSpec(mode="dropout", dropout_prob=0.5)),
     "only composes with the sync engine"),
    (lambda: tiny_mlp_spec(
        participation=ParticipationSpec(mode="uniform", cohort_size=9)),
     "exceeds fed.clients"),
    # wire / sim / checkpoint
    (lambda: tiny_mlp_spec(wire=WireSpec(edge_codec="int8_affine")),
     "meaningless with engine.kind='sync'"),
    (lambda: WireSpec(codec="zip"), "unknown wire codec"),
    (lambda: SimSpec(profile="warp9"), "unknown fleet spec"),
    (lambda: tiny_mlp_spec(engine=EngineSpec(kind="hier"),
                           checkpoint=CheckpointSpec(dir="/tmp/x")),
     "hier engine does not support checkpointing"),
    (lambda: CheckpointSpec(every=-1), "checkpoint.every"),
    # serve axes
    (lambda: ServeSpec(quantize="int4"), "serve.quantize"),
    (lambda: ServeSpec(mode="dynamic"), "serve.mode"),
    (lambda: ServeSpec(max_batch=0), "serve.max_batch"),
    (lambda: ServeSpec(max_queue=0), "serve.max_queue"),
    (lambda: ServeSpec(max_prompt=0), "serve.max_prompt"),
    (lambda: ServeSpec(prompt_bucket=0), "serve.prompt_bucket"),
    (lambda: ServeSpec(max_new_tokens=0), "serve.max_new_tokens"),
    (lambda: ServeSpec(max_batch=8, max_queue=4), "full slot cohort"),
    (lambda: ServeSpec(max_prompt=20, prompt_bucket=16),
     "must divide serve.max_prompt"),
    (lambda: ServeSpec(temperature=-0.5), "serve.temperature"),
    (lambda: ServeSpec(eos_id=-1), "serve.eos_id"),
    (lambda: ServeSpec(materialize=True, quantize="int8"),
     "serve.materialize=True densifies"),
    (lambda: ServeSpec(materialize=True, rank_slice=True),
     "nothing to act on once serve.materialize"),
], ids=lambda p: p if isinstance(p, str) else "")
def test_incoherent_combinations_rejected(make, msg):
    with pytest.raises(ValueError, match=msg):
        make()


# ---------------------------------------------------------------------------
# dotted overrides
# ---------------------------------------------------------------------------


def test_with_overrides():
    spec = tiny_mlp_spec().with_overrides([
        "engine.kind=async", "engine.buffer_size=2",
        "sim.profile=straggler:0.25,10", "fed.lr=0.01", "rounds=7",
        "fed.weighted=true",
    ])
    assert spec.engine == EngineSpec(kind="async", buffer_size=2)
    assert spec.sim.profile == "straggler:0.25,10"
    assert spec.fed.lr == 0.01 and spec.fed.weighted and spec.rounds == 7


def test_with_overrides_none_clears_optional():
    spec = tiny_mlp_spec(sim=SimSpec(profile="uniform"))
    assert spec.with_overrides(["sim.profile=none"]).sim.profile is None


def test_with_overrides_rejects_unknown_and_badly_typed():
    with pytest.raises(ValueError, match="unknown spec field"):
        tiny_mlp_spec().with_overrides(["engine.bufsize=2"])
    with pytest.raises(ValueError, match="unknown spec section"):
        tiny_mlp_spec().with_overrides(["motor.kind=async"])
    with pytest.raises(ValueError, match="expected an integer"):
        tiny_mlp_spec().with_overrides(["fed.clients=many"])
    with pytest.raises(ValueError, match="section.key=value"):
        tiny_mlp_spec().with_overrides(["engine.kind"])


# ---------------------------------------------------------------------------
# build + run (mlp task: fast), resume hash guard
# ---------------------------------------------------------------------------


def test_build_and_run_sync():
    exp = build(tiny_mlp_spec())
    hist = exp.run()
    assert len(hist) == 2
    assert np.isfinite(hist[-1].loss_before)
    acc = exp.evaluate()
    assert 0.0 <= acc <= 1.0
    assert exp.comm_total_bytes() > 0
    assert "mlp" in exp.describe()


def test_build_is_deterministic():
    spec = tiny_mlp_spec()
    e1, e2 = build(spec), build(spec)
    h1, h2 = e1.run(), e2.run()
    assert params_equal(e1.params, e2.params)
    assert histories_equal(h1, h2)


def test_spec_equivalence_sync_vs_simulated_sync():
    """A sync run with a uniform fleet is numerically the plain sync run —
    the clock only adds timing fields."""
    plain = build(tiny_mlp_spec())
    timed = build(tiny_mlp_spec(sim=SimSpec(profile="uniform")))
    plain.run(), timed.run()
    assert params_equal(plain.params, timed.params)
    assert timed.history[-1].t_virtual > 0.0
    assert plain.history[-1].t_virtual == 0.0


def test_resume_refuses_mismatched_spec(tmp_path):
    spec = tiny_mlp_spec(
        checkpoint=CheckpointSpec(dir=str(tmp_path), every=2), rounds=2,
    )
    build(spec).run()
    # same spec: resume restores the checkpointed round
    meta = build(spec).resume()
    assert meta["spec_hash"] == spec.spec_hash()
    assert meta["round"] == 2
    # different hyperparameters: refuse loudly, BEFORE touching any state
    other = dataclasses.replace(
        spec, fed=dataclasses.replace(spec.fed, lr=1e-3)
    )
    exp = build(other)
    params0 = jax.tree.map(lambda x: np.asarray(x).copy(), exp.params)
    with pytest.raises(ValueError, match="refusing to resume"):
        exp.resume()
    assert exp.engine.round_idx == 0 and exp.history == []
    assert params_equal(exp.params, params0)  # refusal left nothing behind


def test_resume_replays_bit_identically(tmp_path):
    spec = tiny_mlp_spec(
        checkpoint=CheckpointSpec(dir=str(tmp_path), every=2), rounds=4,
    )
    straight = build(spec)
    straight.run()
    resumed = build(spec)
    resumed.resume(str(tmp_path / "round_000002.npz"))  # mid-run checkpoint
    resumed.run(rounds=2)
    assert params_equal(straight.params, resumed.params)


# ---------------------------------------------------------------------------
# the legacy flag path ≡ the spec file (acceptance pin)
# ---------------------------------------------------------------------------


def _mlp_config_file(tmp_path):
    path = tmp_path / "base.toml"
    tiny_mlp_spec().save(path)
    return str(path)


def test_legacy_flags_build_the_documented_spec():
    from repro.launch.train import spec_from_argv

    spec = spec_from_argv([
        "--method", "fedlrt", "--engine", "async",
        "--wire-codec", "int8_affine", "--sim-profile", "straggler:0.25,10",
        "--async-buffer", "2", "--clients", "4", "--rounds", "2",
    ])
    assert spec.fed.method == "fedlrt"
    assert spec.engine == EngineSpec(kind="async", buffer_size=2)
    assert spec.wire.codec == "int8_affine"
    assert spec.sim.profile == "straggler:0.25,10"
    # the flag path is nothing but a spec: a TOML round-trip is identity
    assert ExperimentSpec.from_toml(spec.to_toml()) == spec


def test_preset_arch_interplay():
    from repro.launch.train import spec_from_argv

    assert spec_from_argv([]).model.preset == "llm-tiny"
    s = spec_from_argv(["--arch", "qwen2-7b"])
    assert s.model.arch == "qwen2-7b" and s.model.preset is None
    s = spec_from_argv(["--preset", "none", "--set", "model.arch=qwen2-7b"])
    assert s.model.arch == "qwen2-7b" and s.model.preset is None
    with pytest.raises(SystemExit):  # mutually exclusive now, not clobbered
        spec_from_argv(["--preset", "llm-tiny", "--arch", "qwen2-7b"])
    with pytest.raises(ValueError, match="exactly one of"):
        spec_from_argv(["--preset", "none"])


def test_checkpoint_every_lives_in_the_spec(tmp_path):
    from repro.launch.train import spec_from_argv

    spec = spec_from_argv(["--config", _mlp_config_file(tmp_path)])
    assert spec.checkpoint.dir is None
    assert spec.checkpoint.effective_every == 0  # no dir → cadence 0
    spec = spec_from_argv([
        "--config", _mlp_config_file(tmp_path),
        "--checkpoint-dir", str(tmp_path), "--checkpoint-every", "7",
    ])
    assert spec.checkpoint.effective_every == 7


@pytest.mark.parametrize("legacy_flags", [
    # the headline axes riding together: compressed wire + partial
    # participation on the sync engine
    ["--method", "fedlrt", "--wire-codec", "int8_affine",
     "--participation", "uniform:2"],
    # async engine + straggler fleet + compressed wire (the async engine
    # derives participation from availability, so no cohort flag here —
    # the spec layer rejects that combination at validation time)
    ["--method", "fedlrt", "--engine", "async", "--async-buffer", "2",
     "--wire-codec", "int8_affine", "--sim-profile", "straggler:0.25,10"],
], ids=["sync-partial-int8", "async-straggler-int8"])
def test_legacy_flags_reproduce_spec_file_bit_for_bit(tmp_path, legacy_flags):
    """A spec written to TOML, reloaded, and run reproduces the legacy flag
    invocation bit-for-bit: same seed → identical params and histories."""
    from repro.launch.train import spec_from_argv

    base = _mlp_config_file(tmp_path)
    flag_spec = spec_from_argv(["--config", base, *legacy_flags,
                                "--rounds", "2", "--seed", "0"])
    path = tmp_path / "roundtrip.toml"
    flag_spec.save(path)
    file_spec = load_spec(path)
    assert file_spec == flag_spec
    assert file_spec.spec_hash() == flag_spec.spec_hash()

    via_flags = build(flag_spec)
    h_flags = via_flags.run()
    via_file = build(file_spec)
    h_file = via_file.run()
    assert params_equal(via_flags.params, via_file.params)
    assert histories_equal(h_flags, h_file)


def test_example_configs_validate():
    import glob
    import os

    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = sorted(glob.glob(os.path.join(here, "examples", "configs", "*.toml")))
    assert len(paths) >= 3
    for path in paths:
        spec = load_spec(path)  # parse + validate
        assert spec.spec_hash()
