"""Quickstart: FeDLRT on the paper's homogeneous least-squares test (§4.1).

Shows the whole public API in ~40 lines: a factorized parameter, a loss,
a FedConfig, and the round function.  Reproduces the headline behavior of
Fig. 4 — FeDLRT identifies the planted rank (4) within a few aggregation
rounds, never underestimates it, and converges to the global minimizer.

Run:  PYTHONPATH=src python examples/quickstart.py

(For the engine-level drivers — with measured on-the-wire compression via
``--wire-codec identity|downcast|int8_affine|topk_rank`` — see
``repro.launch.train`` and ``examples/federated_vision.py``.)
"""
import jax
import jax.numpy as jnp

from repro.core import FedConfig, fedlrt_round, init_factor, materialize
from repro.data import make_homogeneous_lsq


def loss_fn(f, batch):
    pred = jnp.sum(((batch["px"] @ f.U) @ f.S) * (batch["py"] @ f.V), -1)
    return 0.5 * jnp.mean((pred - batch["t"]) ** 2)


def main():
    prob = make_homogeneous_lsq(n=20, rank=4, num_points=4000, num_clients=4)
    batches = {
        "px": jnp.asarray(prob.px),
        "py": jnp.asarray(prob.py),
        "t": jnp.asarray(prob.target),
    }

    params = init_factor(
        jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0
    )
    # repro-lint: disable=RPL002 -- this example deliberately demos the
    # core API one layer below the engine (FedConfig + fedlrt_round);
    # the spec-API quickstart is examples/vision_federated.py
    cfg = FedConfig(
        num_clients=4, s_star=20, lr=0.1, correction="full", tau=0.1
    )
    step = jax.jit(lambda p, b: fedlrt_round(loss_fn, p, b, cfg))

    print(f"target rank: {prob.rank_star}")
    for t in range(1, 101):
        params, metrics = step(params, batches)
        if t % 10 == 0 or t == 1:
            dist = float(jnp.linalg.norm(materialize(params) - prob.W_star))
            print(
                f"round {t:3d}  loss={float(metrics['loss_before']):.3e}  "
                f"rank={int(params.rank)}  ‖W−W*‖={dist:.3e}  "
                f"comm={float(metrics['comm_bytes_per_client'])/1e3:.1f} KB/client"
            )
    assert int(params.rank) == prob.rank_star


if __name__ == "__main__":
    main()
