"""End-to-end federated LM training driver (deliverable b).

Thin wrapper over ``repro.launch.train``.  The default preset is the
CPU-feasible ``llm-tiny``; pass ``--preset llm-100m --rounds 300`` for the
~100M-parameter configuration (sized for accelerators — the same driver,
just bigger dims), or ``--arch qwen2-7b --smoke`` to drive any registry
architecture end-to-end at reduced size.

Run:  PYTHONPATH=src python examples/train_llm.py --rounds 40
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    main(sys.argv[1:])
