"""CV proxy of the paper's Fig. 5: variance correction vs client count.

Trains a 2-layer MLP head (its hidden layer FeDLRT-factorized — the exact
setting of the paper's ResNet18/CIFAR10 experiment, which applies FeDLRT
to the fully connected head) on a synthetic classification task with a
planted low-rank decision map, split non-iid (Dirichlet α=0.3) across
clients.  Compares FeDLRT {none, simplified} against FedAvg/FedLin for
growing client counts with s* = 240/C local steps, like the paper.

All methods run through the :class:`FederatedEngine`, so per-round client
participation is a flag away: ``--participation uniform:2`` samples a
2-client cohort per round (comm totals then scale with the active cohort,
not the population).

Comm totals are *measured* through the engine's wire layer
(:mod:`repro.fed.wire`); ``--wire-codec int8_affine`` quantizes every
payload on the wire and the comm column shrinks accordingly.

Run:  PYTHONPATH=src python examples/federated_vision.py [--clients 2 4 8]
      PYTHONPATH=src python examples/federated_vision.py \
          --clients 8 --participation uniform:4
      PYTHONPATH=src python examples/federated_vision.py \
          --clients 4 --wire-codec int8_affine
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, init_factor
from repro.core.factorization import is_factor, lr_matmul
from repro.data import (
    FederatedBatcher,
    make_classification_data,
    partition_dirichlet,
    partition_sizes,
)
from repro.fed import FederatedEngine, Participation

DIM, CLASSES, HID = 64, 10, 256


def init_params(key, lowrank=True):
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = (
        init_factor(k1, DIM, HID, r_max=24, init_rank=24)
        if lowrank
        else 0.18 * jax.random.normal(k1, (DIM, HID))
    )
    return {
        "w1": w1,
        "b1": jnp.zeros((HID,)),
        "w2": 0.06 * jax.random.normal(k3, (HID, CLASSES)),
        "b2": jnp.zeros((CLASSES,)),
    }


def _hidden(p, x, kernels="off"):
    """First (possibly factorized) layer: x @ w1 through the rank
    bottleneck — lr_matmul dispatches to the fused Pallas chain under a
    kernel policy, for LowRankFactor and the client loop's
    AugmentedFactor alike."""
    if is_factor(p["w1"]):
        return lr_matmul(x, p["w1"], kernels=kernels)
    return x @ p["w1"]


def make_loss_fn(kernels="off"):
    def loss_fn(p, batch):
        h = jax.nn.relu(_hidden(p, batch["x"], kernels) + p["b1"])
        logits = h @ p["w2"] + p["b2"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))

    return loss_fn


def accuracy(p, x, y, kernels="off"):
    h = jax.nn.relu(_hidden(p, x, kernels) + p["b1"])
    pred = jnp.argmax(h @ p["w2"] + p["b2"], -1)
    return float(jnp.mean(pred == y))


def run(method, C, rounds, x, y, xt, yt, seed=0, participation=None,
        weighted=False, kernels="off", wire_codec="identity",
        engine="sync", sim_profile=None):
    parts = partition_dirichlet(y, C, alpha=0.3, seed=seed)
    s_star = max(240 // C, 1)
    batcher = FederatedBatcher(
        {"x": x, "y": y}, parts, batch_size=64, seed=seed
    )
    cfg = FedConfig(
        num_clients=C, s_star=s_star, lr=5e-2, tau=0.03, eval_after=False,
        correction=method.split(":")[1] if ":" in method else "none",
    )
    lowrank = method.startswith("fedlrt")
    params = init_params(jax.random.PRNGKey(seed), lowrank=lowrank)
    client_weights = partition_sizes(parts) if weighted else None
    if engine != "sync" or sim_profile is not None:
        from repro.fed.sim import make_sim_engine

        kw = dict(
            sim_profile=sim_profile, seed=seed, wire_codec=wire_codec,
            method="fedlrt" if lowrank else method,
            client_weights=client_weights,
            # engines that can't honor the participation policy refuse
            # loudly rather than silently training full-participation
            participation=participation,
        )
        eng = make_sim_engine(engine, make_loss_fn(kernels), params, cfg, **kw)
    else:
        eng = FederatedEngine(
            make_loss_fn(kernels), params, cfg,
            method="fedlrt" if lowrank else method,
            participation=participation,
            client_weights=client_weights,
            wire_codec=wire_codec,
        )
    hist = eng.train(batcher, rounds, log_every=0)
    acc = accuracy(eng.params, xt, yt, kernels)
    rank = int(eng.params["w1"].rank) if lowrank else "-"
    mean_cohort = float(np.mean([r.cohort_size for r in hist]))
    return acc, eng.comm_total_bytes(), rank, mean_cohort, hist[-1].t_virtual


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument(
        "--participation", type=str, default="full",
        help="full | uniform:K | round_robin:K | dropout:P",
    )
    ap.add_argument("--weighted", action="store_true",
                    help="client weights ∝ |X_c| in every aggregation")
    ap.add_argument("--kernels", default="off",
                    choices=["auto", "interpret", "off"],
                    help="Pallas low-rank kernel dispatch for the factorized "
                    "layer (auto = TPU only; interpret = CPU validation)")
    ap.add_argument("--wire-codec", default="identity",
                    help="on-the-wire payload codec: identity | "
                    "downcast[:dtype] | int8_affine | topk_rank; the comm "
                    "column reports bytes *measured* through it")
    ap.add_argument("--engine", default="sync",
                    choices=["sync", "async", "hier"],
                    help="aggregation engine (repro.fed.sim): async = "
                    "FedBuff-style buffered, hier = two-tier edge→cloud")
    ap.add_argument("--sim-profile", type=str, default=None,
                    help="fleet spec for virtual-clock pricing: uniform | "
                    "straggler[:FRAC[,SLOWDOWN]] | lognormal[:SIGMA]")
    args = ap.parse_args()

    x, y = make_classification_data(
        dim=DIM, num_classes=CLASSES, rank=6, num_points=12_288, noise=0.3
    )
    xt, yt = jnp.asarray(x[-2048:]), jnp.asarray(y[-2048:])
    x, y = x[:-2048], y[:-2048]

    participation = Participation.from_spec(args.participation)
    print(
        f"participation={args.participation} wire_codec={args.wire_codec} "
        f"engine={args.engine}"
        + (f" sim_profile={args.sim_profile}" if args.sim_profile else "")
    )
    print(f"{'method':>18} | " + " | ".join(f"C={c}" for c in args.clients))
    for method in ("fedavg", "fedlin", "fedlrt:none", "fedlrt:simplified"):
        cells = []
        for C in args.clients:
            acc, comm, rank, mean_cohort, t_virtual = run(
                method, C, args.rounds, x, y, xt, yt,
                participation=participation, weighted=args.weighted,
                kernels=args.kernels, wire_codec=args.wire_codec,
                engine=args.engine, sim_profile=args.sim_profile,
            )
            cells.append(
                f"acc={acc:.3f} comm={comm/1e6:5.1f}MB "
                f"rank={rank} cohort={mean_cohort:.1f}"
                + (f" t={t_virtual:.1f}s" if t_virtual else "")
            )
        print(f"{method:>18} | " + " | ".join(cells))


if __name__ == "__main__":
    main()
