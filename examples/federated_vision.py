"""CV proxy of the paper's Fig. 5: variance correction vs client count.

Trains a 2-layer MLP head (its hidden layer FeDLRT-factorized — the exact
setting of the paper's ResNet18/CIFAR10 experiment, which applies FeDLRT
to the fully connected head) on a synthetic classification task with a
planted low-rank decision map, split non-iid (Dirichlet α=0.3) across
clients.  Compares FeDLRT {none, simplified} against FedAvg/FedLin for
growing client counts with s* = 240/C local steps, like the paper.

The whole scenario is one declarative :class:`repro.api.ExperimentSpec`;
the method × client-count sweep is ``dataclasses.replace`` on a base
spec, and every engine is constructed through :func:`repro.api.build` —
so per-round participation, wire compression and the simulation engines
are each one spec field away:

Run:  PYTHONPATH=src python examples/federated_vision.py [--clients 2 4 8]
      PYTHONPATH=src python examples/federated_vision.py \
          --clients 8 --participation uniform:4
      PYTHONPATH=src python examples/federated_vision.py \
          --clients 4 --wire-codec int8_affine
      PYTHONPATH=src python examples/federated_vision.py \
          --clients 8 --engine async --sim-profile straggler:0.25,10
"""
import argparse
import dataclasses

import numpy as np

from repro.api import (
    DataSpec,
    EngineSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    ParticipationSpec,
    SimSpec,
    WireSpec,
    build,
)

#: the four method columns of the fig-5 table → (round method, correction)
METHODS = {
    "fedavg": ("fedavg", "none"),
    "fedlin": ("fedlin", "none"),
    "fedlrt:none": ("fedlrt", "none"),
    "fedlrt:simplified": ("fedlrt", "simplified"),
}


def base_spec(args) -> ExperimentSpec:
    # the base spec carries the *largest* population of the sweep; run()
    # re-caps per C, so e.g. `--clients 2 4 8 --participation uniform:6`
    # validates here and caps to min(6, C) for the smaller columns
    C_max = max(args.clients)
    participation = ParticipationSpec.from_string(args.participation)
    if participation.cohort_size is not None:
        participation = dataclasses.replace(
            participation,
            cohort_size=min(participation.cohort_size, C_max),
        )
    return ExperimentSpec(
        name="federated-vision",
        rounds=args.rounds,
        log_every=0,
        model=ModelSpec(
            kind="mlp", dim=64, classes=10, hidden=256, r_max=24,
            kernels=args.kernels,
        ),
        data=DataSpec(
            kind="classification", batch=64, num_points=12_288, noise=0.3,
            planted_rank=6, partition="dirichlet:0.3", holdout=2048,
        ),
        fed=FedSpec(
            method="fedlrt", correction="simplified", clients=C_max,
            local_steps=0,  # 0 → the paper's s* = 240/C scaling
            lr=5e-2, tau=0.03, eval_after=False, weighted=args.weighted,
        ),
        participation=participation,
        engine=EngineSpec(kind=args.engine),
        wire=WireSpec(codec=args.wire_codec),
        sim=SimSpec(profile=args.sim_profile),
    )


def run(spec: ExperimentSpec, method: str, C: int):
    kind, correction = METHODS[method]
    part = spec.participation
    if part.cohort_size is not None and part.cohort_size > C:
        # sweeping C below the requested cohort: cap at the population (the
        # legacy min(k, C) behaviour; the spec itself rejects k > C)
        part = dataclasses.replace(part, cohort_size=C)
    spec = spec.replace(
        fed=dataclasses.replace(
            spec.fed, method=kind, correction=correction, clients=C
        ),
        participation=part,
    )
    exp = build(spec)
    hist = exp.run()
    acc = exp.evaluate()
    lowrank = kind.startswith("fedlrt")
    rank = int(exp.params["w1"].rank) if lowrank else "-"
    mean_cohort = float(np.mean([r.cohort_size for r in hist]))
    return acc, exp.comm_total_bytes(), rank, mean_cohort, hist[-1].t_virtual


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument(
        "--participation", type=str, default="full",
        help="full | uniform:K | round_robin:K | dropout:P",
    )
    ap.add_argument("--weighted", action="store_true",
                    help="client weights ∝ |X_c| in every aggregation")
    ap.add_argument("--kernels", default="off",
                    choices=["auto", "interpret", "off"],
                    help="Pallas low-rank kernel dispatch for the factorized "
                    "layer (auto = TPU only; interpret = CPU validation)")
    ap.add_argument("--wire-codec", default="identity",
                    help="on-the-wire payload codec: identity | "
                    "downcast[:dtype] | int8_affine | topk_rank; the comm "
                    "column reports bytes *measured* through it")
    ap.add_argument("--engine", default="sync",
                    choices=["sync", "async", "hier"],
                    help="aggregation engine (repro.fed.sim): async = "
                    "FedBuff-style buffered, hier = two-tier edge→cloud")
    ap.add_argument("--sim-profile", type=str, default=None,
                    help="fleet spec for virtual-clock pricing: uniform | "
                    "straggler[:FRAC[,SLOWDOWN]] | lognormal[:SIGMA]")
    args = ap.parse_args()

    base = base_spec(args)
    print(
        f"participation={args.participation} wire_codec={args.wire_codec} "
        f"engine={args.engine}"
        + (f" sim_profile={args.sim_profile}" if args.sim_profile else "")
    )
    print(f"{'method':>18} | " + " | ".join(f"C={c}" for c in args.clients))
    for method in METHODS:
        cells = []
        for C in args.clients:
            acc, comm, rank, mean_cohort, t_virtual = run(base, method, C)
            cells.append(
                f"acc={acc:.3f} comm={comm/1e6:5.1f}MB "
                f"rank={rank} cohort={mean_cohort:.1f}"
                + (f" t={t_virtual:.1f}s" if t_virtual else "")
            )
        print(f"{method:>18} | " + " | ".join(cells))


if __name__ == "__main__":
    main()
