"""Batched-serving example (4th example app).

Spins up the BatchedServer on a reduced registry architecture and decodes
a batch of random prompts — prefill + KV-cached greedy decode, the same
`serve_step` the decode dry-run shapes lower on the production mesh.

Run:  PYTHONPATH=src python examples/serve_llm.py --arch rwkv6-7b --smoke
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    args = sys.argv[1:] or ["--preset", "llm-tiny", "--new-tokens", "16"]
    main(args)
