"""Serving example: train two FeDLRT rounds, then serve the result.

The whole train→checkpoint→serve loop is one declarative
:class:`repro.api.ExperimentSpec`: ``build(spec).run()`` trains and
checkpoints, ``serve(spec)`` stands the same spec up as a continuous-
batching, factor-resident decode stack (``U S Vᵀ`` is never
materialized; quantization / rank slicing are spec knobs).  Prefer a
config file for real use:

Run:  PYTHONPATH=src python examples/serve_llm.py
      PYTHONPATH=src python examples/serve_llm.py --quantize int8 --skip-train
      PYTHONPATH=src python -m repro.api serve examples/configs/serve_lowrank.toml
"""
import argparse
import dataclasses
import tempfile

from repro.api import (
    CheckpointSpec,
    DataSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    ServeSpec,
    build,
    serve,
)
from repro.launch.serve import summarize, synthetic_requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quantize", choices=("none", "int8", "bf16"),
                    default="none")
    ap.add_argument("--mode", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--skip-train", action="store_true",
                    help="serve fresh seed-initialized params")
    args = ap.parse_args(argv)

    spec = ExperimentSpec(
        name="serve-llm-example",
        rounds=2,
        model=ModelSpec(kind="lm", preset="llm-tiny"),
        data=DataSpec(kind="token_stream", tokens_per_client=2048, batch=8),
        fed=FedSpec(method="fedlrt", clients=2, local_steps=2),
        serve=ServeSpec(
            quantize=args.quantize,
            rank_slice=args.quantize != "none",
            mode=args.mode,
            max_batch=3,
            max_prompt=32,
            prompt_bucket=8,
            max_new_tokens=16,
        ),
    )

    if args.skip_train:
        session = serve(spec)
    else:
        with tempfile.TemporaryDirectory() as ckpt_dir:
            spec = dataclasses.replace(
                spec,
                checkpoint=CheckpointSpec(dir=ckpt_dir, every=1),
                serve=dataclasses.replace(spec.serve, checkpoint=ckpt_dir),
            )
            exp = build(spec)
            hist = exp.run()
            print(f"trained {len(hist)} rounds: "
                  f"loss {hist[0].loss_before:.4f} → {hist[-1].loss_before:.4f}")
            session = serve(spec)  # reloads the round_2 checkpoint

    print(session.describe())
    comps = session.run(synthetic_requests(
        spec, args.requests, spread=args.mode == "continuous",
    ))
    print(summarize(comps))
    print("first sequence:", comps[0].tokens[:16].tolist())
    return 0


if __name__ == "__main__":
    main()
