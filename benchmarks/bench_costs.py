"""Benchmarks for the paper's Table 1 / Fig. 3 cost claims.

- :func:`fig3_scaling`: communication / client-compute / client-memory
  scaling vs rank for an n×n layer (n=512 like the paper's Fig. 3), with
  the amortization point.
- :func:`table1_measured`: cross-checks the analytic per-round comm bytes
  against the exact counters used by the runtime metrics, and against a
  measured FeDLRT round on a real factor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import FedConfig, fedlrt_round, init_factor
from repro.core import cost_model as cm


def fig3_scaling(n: int = 512, emit=print):
    am = cm.amortization_rank(n)
    emit(f"fig3_amortization_rank_n{n},0.0,r_star={am:.1f};frac={am/n:.3f}")
    rows = {}
    for r in (8, 32, 64, 128, 200, 256, 384):
        lrt = cm.table1("fedlrt_simplified", n=n, r=r, s_star=1, b=1)
        lin = cm.table1("fedlin", n=n, r=0, s_star=1, b=1)
        rows[r] = {
            "comm_ratio": lrt["comm"] / lin["comm"],
            "compute_ratio": lrt["client_compute"] / lin["client_compute"],
            "memory_ratio": lrt["client_memory"] / lin["client_memory"],
        }
        emit(
            f"fig3_scaling_r{r},0.0,"
            + ";".join(f"{k}={v:.4f}" for k, v in rows[r].items())
        )
    return rows


def table1_measured(emit=print):
    """Measured round comm vs Table-1 closed form for a 512×512 layer."""
    n, r = 512, 32
    f = init_factor(jax.random.PRNGKey(0), n, n, r_max=r)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, n))
    y = jax.random.normal(jax.random.PRNGKey(2), (4, 64, n))

    def loss(p, b):
        h = ((b["x"] @ p.U) @ p.S) @ p.V.T
        return jnp.mean((h - b["y"]) ** 2)

    out = {}
    for corr, method in (
        ("none", "fedlrt"),
        ("simplified", "fedlrt_simplified"),
        ("full", "fedlrt_full"),
    ):
        # repro-lint: disable=RPL002 -- microbench of the raw round
        # function: no engine in the loop, nothing for a spec to build
        cfg = FedConfig(num_clients=4, s_star=4, lr=1e-3, correction=corr,
                        tau=0.05, eval_after=False)
        step = jax.jit(lambda p, b, cfg=cfg: fedlrt_round(loss, p, b, cfg))
        p, m = step(f, {"x": x, "y": y})
        t0 = time.perf_counter()
        for _ in range(5):
            p, m = step(p, {"x": x, "y": y})
        us = (time.perf_counter() - t0) / 5 * 1e6
        measured = float(m["comm_bytes_per_client"])
        analytic = cm.table1(method, n=n, r=r)["comm"] * cm.BYTES
        out[corr] = (measured, analytic)
        emit(
            f"table1_comm_{corr},{us:.1f},"
            f"measured_B={measured:.0f};analytic_B={analytic:.0f};"
            f"ratio={measured/analytic:.3f}"
        )
    return out
