"""Wire-codec benchmark: bytes saved vs accuracy delta vs round wall-clock.

Runs the fig5-style CV proxy (FeDLRT simplified on the factorized MLP
head, non-iid Dirichlet split) once per wire codec and reports, relative
to the ``identity`` baseline:

- measured uplink / downlink MB (per client, summed over rounds),
- the uplink compression ratio (identity ÷ codec — the paper-facing
  number: ``int8_affine`` should clear 3×),
- final-accuracy delta, and
- mean per-round wall-clock (codec encode/decode rides inside the jitted
  round, so this shows the compression compute cost, not just bytes).

Emitted as ``wire_<codec>,us_per_round,derived`` CSV rows like every other
benchmark in this harness.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, init_factor
from repro.data import FederatedBatcher, make_classification_data, partition_dirichlet
from repro.fed import FederatedEngine

DIM, CLASSES, HID = 64, 10, 256

CODECS = ("identity", "downcast", "downcast:float16", "int8_affine", "topk_rank")


def _init(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": init_factor(k1, DIM, HID, r_max=24, init_rank=24),
        "b1": jnp.zeros((HID,)),
        "w2": 0.06 * jax.random.normal(k2, (HID, CLASSES)),
        "b2": jnp.zeros((CLASSES,)),
    }


def _fwd(p, x):
    h = ((x @ p["w1"].U) @ p["w1"].S) @ p["w1"].V.T
    h = jax.nn.relu(h + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, batch):
    logp = jax.nn.log_softmax(_fwd(p, batch["x"]))
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))


def _run_one(codec: str, rounds: int, C: int, x, y, xt, yt):
    parts = partition_dirichlet(y, C, alpha=0.3, seed=0)
    batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=64, seed=0)
    cfg = FedConfig(
        num_clients=C, s_star=max(240 // C, 1), lr=5e-2, tau=0.03,
        correction="simplified", eval_after=False,
    )
    eng = FederatedEngine(
        _loss, _init(jax.random.PRNGKey(0)), cfg,
        method="fedlrt", wire_codec=codec,
    )
    t0 = time.perf_counter()
    hist = eng.train(batcher, rounds, log_every=0)
    us = (time.perf_counter() - t0) / rounds * 1e6
    acc = float(jnp.mean(jnp.argmax(_fwd(eng.params, xt), -1) == yt))
    up = sum(r.wire_bytes_up_per_client * r.cohort_size for r in hist)
    down = sum(r.wire_bytes_down_per_client * r.cohort_size for r in hist)
    return acc, up, down, us


def wire_codecs(rounds: int = 25, C: int = 4, emit=print):
    x, y = make_classification_data(
        dim=DIM, num_classes=CLASSES, rank=6, num_points=10_240, noise=0.3, seed=0
    )
    xt, yt = jnp.asarray(x[-2048:]), jnp.asarray(y[-2048:])
    x, y = x[:-2048], y[:-2048]

    results = {}
    base_acc = base_up = None
    for codec in CODECS:
        acc, up, down, us = _run_one(codec, rounds, C, x, y, xt, yt)
        if base_acc is None:
            base_acc, base_up = acc, up
        results[codec] = (acc, up, down, us)
        emit(
            f"wire_{codec.replace(':', '_')},{us:.1f},"
            f"acc={acc:.4f};d_acc={acc - base_acc:+.4f};"
            f"up_MB={up/1e6:.3f};down_MB={down/1e6:.3f};"
            f"up_save={np.divide(base_up, up):.2f}x"
        )
    return results
