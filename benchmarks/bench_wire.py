"""Wire-codec benchmark: bytes saved vs accuracy delta vs round wall-clock.

Runs the fig5-style CV proxy (FeDLRT simplified on the factorized MLP
head, non-iid Dirichlet split) once per wire codec and reports, relative
to the ``identity`` baseline:

- measured uplink / downlink MB (per client, summed over rounds),
- the uplink compression ratio (identity ÷ codec — the paper-facing
  number: ``int8_affine`` should clear 3×),
- final-accuracy delta, and
- mean per-round wall-clock (codec encode/decode rides inside the jitted
  round, so this shows the compression compute cost, not just bytes).

The sweep is one :func:`dataclasses.replace` of ``wire.codec`` on the
shared CV base spec (:data:`benchmarks.bench_cv.BASE`); engines come
exclusively from :func:`repro.api.build`.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.bench_cv import BASE
from repro.api import WireSpec, build

CODECS = ("identity", "downcast", "downcast:float16", "int8_affine", "topk_rank")


def _run_one(codec: str, rounds: int):
    spec = BASE.replace(rounds=rounds, wire=WireSpec(codec=codec))
    exp = build(spec)
    t0 = time.perf_counter()
    hist = exp.run()
    us = (time.perf_counter() - t0) / rounds * 1e6
    acc = exp.evaluate()
    up = sum(r.wire_bytes_up_per_client * r.cohort_size for r in hist)
    down = sum(r.wire_bytes_down_per_client * r.cohort_size for r in hist)
    return acc, up, down, us


def wire_codecs(rounds: int = 25, emit=print):
    results = {}
    base_acc = base_up = None
    for codec in CODECS:
        acc, up, down, us = _run_one(codec, rounds)
        if base_acc is None:
            base_acc, base_up = acc, up
        results[codec] = (acc, up, down, us)
        emit(
            f"wire_{codec.replace(':', '_')},{us:.1f},"
            f"acc={acc:.4f};d_acc={acc - base_acc:+.4f};"
            f"up_MB={up/1e6:.3f};down_MB={down/1e6:.3f};"
            f"up_save={np.divide(base_up, up):.2f}x"
        )
    return results
