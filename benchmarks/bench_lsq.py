"""Benchmarks for the paper's §4.1 least-squares figures.

- :func:`fig4_homogeneous`: rank evolution, distance to minimizer, loss —
  FeDLRT (full v/c) vs FedLin, C ∈ {1,2,4,8} clients (paper Fig. 4).
- :func:`fig1_heterogeneous`: corrected vs uncorrected vs FedLin/FedAvg on
  per-client targets (paper Fig. 1: uncorrected plateaus, corrected
  converges).
Emits CSV rows and returns dicts for the claim-validation summary.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, fedlrt_round, init_factor, materialize
from repro.core.baselines import fedavg_round, fedlin_round
from repro.data import make_heterogeneous_lsq, make_homogeneous_lsq


def _loss(f, batch):
    pred = jnp.sum(((batch["px"] @ f.U) @ f.S) * (batch["py"] @ f.V), -1)
    return 0.5 * jnp.mean((pred - batch["t"]) ** 2)


def _dense_loss(W, batch):
    pred = jnp.einsum("ni,ij,nj->n", batch["px"], W, batch["py"])
    return 0.5 * jnp.mean((pred - batch["t"]) ** 2)


def _opt_loss(prob):
    return float(
        np.mean(
            [
                0.5
                * np.mean(
                    (
                        np.einsum(
                            "ni,ij,nj->n", prob.px[c], prob.W_star, prob.py[c]
                        )
                        - prob.target[c]
                    )
                    ** 2
                )
                for c in range(prob.px.shape[0])
            ]
        )
    )


def fig4_homogeneous(rounds: int = 150, emit=print):
    out = {}
    for C in (1, 2, 4, 8):
        prob = make_homogeneous_lsq(
            n=20, rank=4, num_points=4000, num_clients=C, seed=0
        )
        batches = {
            "px": jnp.asarray(prob.px),
            "py": jnp.asarray(prob.py),
            "t": jnp.asarray(prob.target),
        }
        # FeDLRT
        f = init_factor(
            jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10, spectrum_scale=1.0
        )
        # repro-lint: disable=RPL002 -- figure-4 microbench of the raw
        # round function (no engine in the loop); the engine-path lsq
        # scenarios live in bench_ablation via the spec API
        cfg = FedConfig(num_clients=C, s_star=20, lr=0.1, correction="full",
                        tau=0.1, eval_after=False)
        step = jax.jit(lambda p, b, cfg=cfg: fedlrt_round(_loss, p, b, cfg))
        t0 = time.perf_counter()
        rank_found_at = None
        for t in range(rounds):
            f, m = step(f, batches)
            if rank_found_at is None and float(f.rank) == prob.rank_star:
                rank_found_at = t + 1
        dt = (time.perf_counter() - t0) / rounds * 1e6
        dist = float(jnp.linalg.norm(materialize(f) - prob.W_star))
        # FedLin reference
        W = jnp.zeros((20, 20))
        lstep = jax.jit(lambda p, b, cfg=cfg: fedlin_round(_dense_loss, p, b, cfg))
        for _ in range(rounds):
            W, ml = lstep(W, batches)
        dist_lin = float(jnp.linalg.norm(W - prob.W_star))
        emit(
            f"fig4_homogeneous_C{C},{dt:.1f},"
            f"loss={float(m['loss_before']):.3e};rank={int(f.rank)};"
            f"rank_found_round={rank_found_at};dist={dist:.3e};"
            f"fedlin_dist={dist_lin:.3e};"
            f"comm_ratio={float(m['comm_bytes_per_client'])/float(ml['comm_bytes_per_client']):.3f}"
        )
        out[C] = dict(
            loss=float(m["loss_before"]), rank=int(f.rank),
            rank_found_at=rank_found_at, dist=dist, dist_fedlin=dist_lin,
        )
    return out


def fig1_heterogeneous(rounds: int = 200, emit=print):
    prob = make_heterogeneous_lsq(n=10, rank=1, num_points=1000, num_clients=4, seed=0)
    batches = {
        "px": jnp.asarray(prob.px),
        "py": jnp.asarray(prob.py),
        "t": jnp.asarray(prob.target),
    }
    opt = _opt_loss(prob)
    out = {}
    for name, corr in (("none", "none"), ("simplified", "simplified"), ("full", "full")):
        f = init_factor(jax.random.PRNGKey(0), 10, 10, r_max=5, init_rank=5,
                        spectrum_scale=1.0)
        # repro-lint: disable=RPL002 -- figure-1 microbench of the raw
        # round function, sweeping the core correction knob directly
        cfg = FedConfig(num_clients=4, s_star=100, lr=0.02, correction=corr,
                        tau=0.01, eval_after=False)
        step = jax.jit(lambda p, b, cfg=cfg: fedlrt_round(_loss, p, b, cfg))
        t0 = time.perf_counter()
        for _ in range(rounds):
            f, m = step(f, batches)
        dt = (time.perf_counter() - t0) / rounds * 1e6
        excess = float(m["loss_before"]) - opt
        emit(f"fig1_fedlrt_{name},{dt:.1f},excess_loss={excess:.3e}")
        out[name] = excess
    for name, rf in (("fedavg", fedavg_round), ("fedlin", fedlin_round)):
        W = jnp.zeros((10, 10))
        # repro-lint: disable=RPL002 -- dense-baseline microbench of the
        # raw round functions (same figure-1 loop as above)
        cfg = FedConfig(num_clients=4, s_star=100, lr=0.02, tau=0.01, eval_after=False)
        step = jax.jit(lambda p, b, rf=rf, cfg=cfg: rf(_dense_loss, p, b, cfg))
        t0 = time.perf_counter()
        for _ in range(rounds):
            W, m = step(W, batches)
        dt = (time.perf_counter() - t0) / rounds * 1e6
        excess = float(m["loss_before"]) - opt
        emit(f"fig1_{name},{dt:.1f},excess_loss={excess:.3e}")
        out[name] = excess
    return out
