"""Aggregate the dry-run JSONs into the §Roofline table.

Reads results/dryrun/*.json (produced by ``python -m repro.launch.dryrun``)
and emits one CSV row per (mesh × arch × shape) with the three roofline
terms, the dominant bottleneck, and the useful-FLOPs ratio.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "../results/dryrun")


def roofline_table(emit=print, results_dir: str = RESULTS):
    files = sorted(glob.glob(os.path.join(results_dir, "*.json")))
    if not files:
        emit("roofline_table,0.0,no_dryrun_results_found")
        return {}
    rows = {}
    for path in files:
        with open(path) as f:
            res = json.load(f)
        tag = f"{res.get('mesh','skip')}_{res['arch']}_{res['shape']}"
        if "skipped" in res:
            emit(f"roofline_{tag},0.0,skipped={res['skipped'].replace(',',';')}")
            continue
        r = res["roofline"]
        ufr = res.get("useful_flops_ratio")
        emit(
            f"roofline_{tag},{res['compile_s']*1e6:.0f},"
            f"compute_ms={r['compute_s']*1e3:.3f};"
            f"memory_ms={r['memory_s']*1e3:.3f};"
            f"collective_ms={r['collective_s']*1e3:.3f};"
            f"dominant={r['dominant']};"
            f"useful_flops_ratio={(f'{ufr:.3f}' if ufr else 'n/a')};"
            f"temp_GiB={res['memory']['temp_bytes']/2**30:.2f}"
        )
        rows[tag] = r
    return rows
