"""Fig.-5 proxy: accuracy vs client count on the CV-style task.

FeDLRT applied to an MLP head's hidden layer (the paper factorizes the
fully connected head of ResNet18), non-iid Dirichlet split; FeDLRT with
simplified correction should track FedLin and beat uncorrected FeDLRT /
FedAvg at larger client counts, while communicating a fraction of the
bytes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, init_factor
from repro.core.baselines import fedavg_round, fedlin_round
from repro.core.fedlrt import fedlrt_round
from repro.data import FederatedBatcher, make_classification_data, partition_dirichlet

DIM, CLASSES, HID = 64, 10, 256


def _init(key, lowrank):
    k1, k2 = jax.random.split(key)
    w1 = (
        init_factor(k1, DIM, HID, r_max=24, init_rank=24)
        if lowrank
        else 0.18 * jax.random.normal(k1, (DIM, HID))
    )
    return {
        "w1": w1,
        "b1": jnp.zeros((HID,)),
        "w2": 0.06 * jax.random.normal(k2, (HID, CLASSES)),
        "b2": jnp.zeros((CLASSES,)),
    }


def _fwd(p, x):
    if hasattr(p["w1"], "U"):
        h = ((x @ p["w1"].U) @ p["w1"].S) @ p["w1"].V.T
    else:
        h = x @ p["w1"]
    h = jax.nn.relu(h + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, batch):
    logp = jax.nn.log_softmax(_fwd(p, batch["x"]))
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))


def fig5_proxy(rounds: int = 25, clients=(2, 4, 8), emit=print):
    x, y = make_classification_data(
        dim=DIM, num_classes=CLASSES, rank=6, num_points=10_240, noise=0.3, seed=0
    )
    xt, yt = jnp.asarray(x[-2048:]), jnp.asarray(y[-2048:])
    x, y = x[:-2048], y[:-2048]
    results = {}
    for method in ("fedavg", "fedlin", "fedlrt:none", "fedlrt:simplified"):
        for C in clients:
            parts = partition_dirichlet(y, C, alpha=0.3, seed=0)
            batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=64, seed=0)
            corr = method.split(":")[1] if ":" in method else "none"
            cfg = FedConfig(
                num_clients=C, s_star=max(240 // C, 1), lr=5e-2, tau=0.03,
                correction=corr, eval_after=False,
            )
            lowrank = method.startswith("fedlrt")
            params = _init(jax.random.PRNGKey(0), lowrank)
            if lowrank:
                rf = lambda p, b: fedlrt_round(_loss, p, b, cfg)
            elif method == "fedavg":
                rf = lambda p, b: fedavg_round(_loss, p, b, cfg)
            else:
                rf = lambda p, b: fedlin_round(_loss, p, b, cfg)
            step = jax.jit(rf)
            comm = 0.0
            t0 = time.perf_counter()
            for _ in range(rounds):
                batch = {k: jnp.asarray(v) for k, v in batcher.next_round().items()}
                params, m = step(params, batch)
                comm += float(m["comm_bytes_per_client"])
            us = (time.perf_counter() - t0) / rounds * 1e6
            acc = float(jnp.mean(jnp.argmax(_fwd(params, xt), -1) == yt))
            results[(method, C)] = (acc, comm)
            emit(f"fig5_{method.replace(':','_')}_C{C},{us:.1f},acc={acc:.4f};comm_MB={comm/1e6:.2f}")
    return results
