"""Fig.-5 proxy: accuracy vs client count on the CV-style task.

FeDLRT applied to an MLP head's hidden layer (the paper factorizes the
fully connected head of ResNet18), non-iid Dirichlet split; FeDLRT with
simplified correction should track FedLin and beat uncorrected FeDLRT /
FedAvg at larger client counts, while communicating a fraction of the
bytes.

:func:`fig5_proxy` optionally takes a ``participation`` policy; with
uniform-k sampling the emitted ``comm_MB`` (server-side total) drops by
k/C while accuracy degrades gracefully — :func:`fig5_partial` emits that
comparison directly.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, init_factor
from repro.data import FederatedBatcher, make_classification_data, partition_dirichlet
from repro.fed import FederatedEngine, Participation

DIM, CLASSES, HID = 64, 10, 256


def _init(key, lowrank):
    k1, k2 = jax.random.split(key)
    w1 = (
        init_factor(k1, DIM, HID, r_max=24, init_rank=24)
        if lowrank
        else 0.18 * jax.random.normal(k1, (DIM, HID))
    )
    return {
        "w1": w1,
        "b1": jnp.zeros((HID,)),
        "w2": 0.06 * jax.random.normal(k2, (HID, CLASSES)),
        "b2": jnp.zeros((CLASSES,)),
    }


def _fwd(p, x):
    if hasattr(p["w1"], "U"):
        h = ((x @ p["w1"].U) @ p["w1"].S) @ p["w1"].V.T
    else:
        h = x @ p["w1"]
    h = jax.nn.relu(h + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, batch):
    logp = jax.nn.log_softmax(_fwd(p, batch["x"]))
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))


def _data():
    x, y = make_classification_data(
        dim=DIM, num_classes=CLASSES, rank=6, num_points=10_240, noise=0.3, seed=0
    )
    xt, yt = jnp.asarray(x[-2048:]), jnp.asarray(y[-2048:])
    return x[:-2048], y[:-2048], xt, yt


def _run_one(method, C, rounds, x, y, xt, yt, participation=None):
    parts = partition_dirichlet(y, C, alpha=0.3, seed=0)
    batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=64, seed=0)
    corr = method.split(":")[1] if ":" in method else "none"
    cfg = FedConfig(
        num_clients=C, s_star=max(240 // C, 1), lr=5e-2, tau=0.03,
        correction=corr, eval_after=False,
    )
    lowrank = method.startswith("fedlrt")
    params = _init(jax.random.PRNGKey(0), lowrank)
    eng = FederatedEngine(
        _loss, params, cfg,
        method="fedlrt" if lowrank else method,
        participation=participation,
    )
    t0 = time.perf_counter()
    eng.train(batcher, rounds, log_every=0)
    us = (time.perf_counter() - t0) / rounds * 1e6
    acc = float(jnp.mean(jnp.argmax(_fwd(eng.params, xt), -1) == yt))
    return acc, eng.comm_total_bytes(), us


def fig5_proxy(rounds: int = 25, clients=(2, 4, 8), emit=print, participation=None):
    x, y, xt, yt = _data()
    results = {}
    for method in ("fedavg", "fedlin", "fedlrt:none", "fedlrt:simplified"):
        for C in clients:
            acc, comm, us = _run_one(
                method, C, rounds, x, y, xt, yt, participation=participation
            )
            results[(method, C)] = (acc, comm)
            emit(
                f"fig5_{method.replace(':','_')}_C{C},{us:.1f},"
                f"acc={acc:.4f};comm_MB={comm/1e6:.2f}"
            )
    return results


def fig5_partial(rounds: int = 25, C: int = 8, cohorts=(8, 4, 2), emit=print):
    """Partial-participation sweep: uniform-k cohorts at fixed population.

    Server comm scales with k; FeDLRT's variance correction keeps accuracy
    close to the full-participation run down to small cohorts.
    """
    x, y, xt, yt = _data()
    results = {}
    for method in ("fedavg", "fedlrt:simplified"):
        for k in cohorts:
            part = (
                None if k >= C
                else Participation(mode="uniform", cohort_size=k, seed=0)
            )
            acc, comm, us = _run_one(
                method, C, rounds, x, y, xt, yt, participation=part
            )
            results[(method, k)] = (acc, comm)
            emit(
                f"fig5partial_{method.replace(':','_')}_k{k}of{C},{us:.1f},"
                f"acc={acc:.4f};comm_MB={comm/1e6:.2f}"
            )
    return results
