"""Fig.-5 proxy: accuracy vs client count on the CV-style task.

FeDLRT applied to an MLP head's hidden layer (the paper factorizes the
fully connected head of ResNet18), non-iid Dirichlet split; FeDLRT with
simplified correction should track FedLin and beat uncorrected FeDLRT /
FedAvg at larger client counts, while communicating a fraction of the
bytes.

Every cell of the sweep is ``dataclasses.replace`` on one base
:class:`repro.api.ExperimentSpec`, built and run through
:func:`repro.api.build` — no per-driver engine plumbing.
"""
from __future__ import annotations

import dataclasses
import time

from repro.api import (
    DataSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    ParticipationSpec,
    build,
)

#: the CV-proxy base scenario shared by the fig-5 sweeps (bench_wire and
#: bench_sim derive theirs from this too)
BASE = ExperimentSpec(
    name="fig5-cv-proxy",
    log_every=0,
    model=ModelSpec(kind="mlp", dim=64, classes=10, hidden=256, r_max=24,
                    kernels="off"),
    data=DataSpec(kind="classification", batch=64, num_points=10_240,
                  noise=0.3, planted_rank=6, partition="dirichlet:0.3",
                  holdout=2048),
    fed=FedSpec(method="fedlrt", correction="simplified", clients=4,
                local_steps=0, lr=5e-2, tau=0.03, eval_after=False),
)


def spec_for(method: str, C: int, rounds: int, participation=None) -> ExperimentSpec:
    corr = method.split(":")[1] if ":" in method else "none"
    kind = method.split(":")[0]
    if participation is not None and participation.cohort_size is not None:
        # sweeping C below the requested cohort: cap at the population (the
        # legacy min(k, C) behaviour; the spec itself rejects k > C)
        participation = dataclasses.replace(
            participation, cohort_size=min(participation.cohort_size, C)
        )
    return BASE.replace(
        rounds=rounds,
        fed=dataclasses.replace(BASE.fed, method=kind, correction=corr, clients=C),
        participation=participation or ParticipationSpec(),
    )


def _run_one(spec: ExperimentSpec):
    exp = build(spec)
    t0 = time.perf_counter()
    exp.run()
    us = (time.perf_counter() - t0) / spec.rounds * 1e6
    return exp.evaluate(), exp.comm_total_bytes(), us


def fig5_proxy(rounds: int = 25, clients=(2, 4, 8), emit=print, participation=None):
    results = {}
    for method in ("fedavg", "fedlin", "fedlrt:none", "fedlrt:simplified"):
        for C in clients:
            acc, comm, us = _run_one(
                spec_for(method, C, rounds, participation=participation)
            )
            results[(method, C)] = (acc, comm)
            emit(
                f"fig5_{method.replace(':','_')}_C{C},{us:.1f},"
                f"acc={acc:.4f};comm_MB={comm/1e6:.2f}"
            )
    return results


def fig5_partial(rounds: int = 25, C: int = 8, cohorts=(8, 4, 2), emit=print):
    """Partial-participation sweep: uniform-k cohorts at fixed population.

    Server comm scales with k; FeDLRT's variance correction keeps accuracy
    close to the full-participation run down to small cohorts.
    """
    results = {}
    for method in ("fedavg", "fedlrt:simplified"):
        for k in cohorts:
            part = (
                None if k >= C
                else ParticipationSpec(mode="uniform", cohort_size=k)
            )
            acc, comm, us = _run_one(spec_for(method, C, rounds, participation=part))
            results[(method, k)] = (acc, comm)
            emit(
                f"fig5partial_{method.replace(':','_')}_k{k}of{C},{us:.1f},"
                f"acc={acc:.4f};comm_MB={comm/1e6:.2f}"
            )
    return results
