"""System-simulator benchmark: time-to-target-loss under stragglers.

Sweeps aggregation engine (sync / async / hier) × wire codec × straggler
severity on the fig5-style CV proxy, pricing every round on the virtual
clock (:mod:`repro.fed.sim`), and reports for each cell:

- ``t_target`` — virtual seconds until the model first reaches the *sync
  engine's* final loss for that (codec, severity) (inf if never),
- the final loss and total virtual time, and
- measured total MB on the wire.

The paper-facing headline: under a 10×-slow straggler profile the async
(buffered) engine reaches the sync engine's target loss in strictly less
virtual wall-clock — the sync barrier waits for the straggler every
round, the buffer doesn't (pinned in miniature by
``tests/test_sim.py::test_async_beats_sync_under_straggler``).

Every cell is ``dataclasses.replace`` of the engine/wire/sim sections on
the shared CV base spec (:data:`benchmarks.bench_cv.BASE`); engines come
exclusively from :func:`repro.api.build`.

Emitted as ``sim_<engine>_<codec>_<severity>,us_per_round,derived`` CSV
rows like every other benchmark in this harness.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.bench_cv import BASE
from repro.api import EngineSpec, SimSpec, WireSpec, build

ENGINES = ("sync", "async", "hier")
CODECS = ("identity", "int8_affine")
SEVERITIES = (("flat", "uniform"), ("strag10", "straggler:0.25,10"))


def _spec(engine: str, codec: str, profile: str, rounds: int, C: int):
    if engine == "async":
        # half-cohort buffer: aggregates keep flowing while stragglers lag;
        # 2× the aggregations keeps the *client-round* budget equal to sync
        eng = EngineSpec(kind="async", buffer_size=max(C // 2, 1))
        n_aggregates = rounds * (C // eng.buffer_size)
    elif engine == "hier":
        eng = EngineSpec(kind="hier", edges=2, edge_rounds=1)
        n_aggregates = rounds
    else:
        eng = EngineSpec(kind="sync")
        n_aggregates = rounds
    return BASE.replace(
        name="sim-pareto",
        rounds=n_aggregates,
        fed=dataclasses.replace(BASE.fed, clients=C),
        engine=eng,
        wire=WireSpec(codec=codec),
        sim=SimSpec(profile=profile),
    )


def _run_one(engine: str, codec: str, profile: str, rounds: int, C: int):
    exp = build(_spec(engine, codec, profile, rounds, C))
    t0 = time.perf_counter()
    hist = exp.run()
    us = (time.perf_counter() - t0) / max(len(hist), 1) * 1e6
    return exp, hist, us


def _loss_timeline(hist):
    """(t, loss) pairs: ``loss_before`` of round *i* is observed on the
    params that existed since round *i−1* finished."""
    out = []
    t_prev = 0.0
    for r in hist:
        out.append((t_prev, r.loss_before))
        t_prev = r.t_virtual
    return out


def _time_to(hist, target: float) -> float:
    for t, loss in _loss_timeline(hist):
        if loss <= target:
            return t
    return float("inf")


def sim_pareto(rounds: int = 25, C: int = 8, smoke: bool = False, emit=print):
    if smoke:
        rounds, C = 3, 4
        codecs, severities, engines = ("identity",), (SEVERITIES[1],), ENGINES
    else:
        codecs, severities, engines = CODECS, SEVERITIES, ENGINES

    results = {}
    for codec in codecs:
        for sev_name, profile in severities:
            # the sync engine's final loss is the cell's target
            sync_exp, sync_hist, sync_us = _run_one(
                "sync", codec, profile, rounds, C
            )
            target = sync_hist[-1].loss_before
            for engine in engines:
                if engine == "sync":
                    exp, hist, us = sync_exp, sync_hist, sync_us
                else:
                    exp, hist, us = _run_one(engine, codec, profile, rounds, C)
                t_target = _time_to(hist, target)
                mb = exp.comm_total_bytes() / 1e6
                results[(engine, codec, sev_name)] = (t_target, hist)
                emit(
                    f"sim_{engine}_{codec}_{sev_name},{us:.1f},"
                    f"target={target:.4f};t_target={t_target:.1f}s;"
                    f"final={hist[-1].loss_before:.4f};"
                    f"t_end={hist[-1].t_virtual:.1f}s;MB={mb:.2f}"
                )
    return results
