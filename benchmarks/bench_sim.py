"""System-simulator benchmark: time-to-target-loss under stragglers.

Sweeps aggregation engine (sync / async / hier) × wire codec × straggler
severity on the fig5-style CV proxy, pricing every round on the virtual
clock (:mod:`repro.fed.sim`), and reports for each cell:

- ``t_target`` — virtual seconds until the model first reaches the *sync
  engine's* final loss for that (codec, severity) (inf if never),
- the final loss and total virtual time, and
- measured total MB on the wire.

The paper-facing headline: under a 10×-slow straggler profile the async
(buffered) engine reaches the sync engine's target loss in strictly less
virtual wall-clock — the sync barrier waits for the straggler every
round, the buffer doesn't (pinned in miniature by
``tests/test_sim.py::test_async_beats_sync_under_straggler``).

Emitted as ``sim_<engine>_<codec>_<severity>,us_per_round,derived`` CSV
rows like every other benchmark in this harness.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import FedConfig, init_factor
from repro.data import FederatedBatcher, make_classification_data, partition_dirichlet
from repro.fed.sim import make_sim_engine

DIM, CLASSES, HID = 64, 10, 256

ENGINES = ("sync", "async", "hier")
CODECS = ("identity", "int8_affine")
SEVERITIES = (("flat", "uniform"), ("strag10", "straggler:0.25,10"))


def _init(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": init_factor(k1, DIM, HID, r_max=24, init_rank=24),
        "b1": jnp.zeros((HID,)),
        "w2": 0.06 * jax.random.normal(k2, (HID, CLASSES)),
        "b2": jnp.zeros((CLASSES,)),
    }


def _loss(p, batch):
    h = ((batch["x"] @ p["w1"].U) @ p["w1"].S) @ p["w1"].V.T
    h = jax.nn.relu(h + p["b1"])
    logits = h @ p["w2"] + p["b2"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], -1))


def _run_one(engine: str, codec: str, profile: str, rounds: int, C: int, x, y):
    parts = partition_dirichlet(y, C, alpha=0.3, seed=0)
    batcher = FederatedBatcher({"x": x, "y": y}, parts, batch_size=64, seed=0)
    cfg = FedConfig(
        num_clients=C, s_star=max(240 // C, 1), lr=5e-2, tau=0.03,
        correction="simplified", eval_after=False,
    )
    kw = {}
    n_aggregates = rounds
    if engine == "async":
        # half-cohort buffer: aggregates keep flowing while stragglers lag;
        # 2× the aggregations keeps the *client-round* budget equal to sync
        kw = dict(buffer_size=max(C // 2, 1))
        n_aggregates = rounds * (C // kw["buffer_size"])
    elif engine == "hier":
        kw = dict(num_edges=2, edge_rounds=1)
    eng = make_sim_engine(
        engine, _loss, _init(jax.random.PRNGKey(0)), cfg,
        sim_profile=profile, method="fedlrt", wire_codec=codec, **kw,
    )
    t0 = time.perf_counter()
    hist = eng.train(batcher, n_aggregates, log_every=0)
    us = (time.perf_counter() - t0) / max(len(hist), 1) * 1e6
    return eng, hist, us


def _loss_timeline(hist):
    """(t, loss) pairs: ``loss_before`` of round *i* is observed on the
    params that existed since round *i−1* finished."""
    out = []
    t_prev = 0.0
    for r in hist:
        out.append((t_prev, r.loss_before))
        t_prev = r.t_virtual
    return out


def _time_to(hist, target: float) -> float:
    for t, loss in _loss_timeline(hist):
        if loss <= target:
            return t
    return float("inf")


def sim_pareto(rounds: int = 25, C: int = 8, smoke: bool = False, emit=print):
    if smoke:
        rounds, C = 3, 4
        codecs, severities, engines = ("identity",), (SEVERITIES[1],), ENGINES
    else:
        codecs, severities, engines = CODECS, SEVERITIES, ENGINES
    x, y = make_classification_data(
        dim=DIM, num_classes=CLASSES, rank=6, num_points=10_240, noise=0.3, seed=0
    )
    x, y = x[:-2048], y[:-2048]

    results = {}
    for codec in codecs:
        for sev_name, profile in severities:
            # the sync engine's final loss is the cell's target
            sync_eng, sync_hist, sync_us = _run_one(
                "sync", codec, profile, rounds, C, x, y
            )
            target = sync_hist[-1].loss_before
            for engine in engines:
                if engine == "sync":
                    eng, hist, us = sync_eng, sync_hist, sync_us
                else:
                    eng, hist, us = _run_one(
                        engine, codec, profile, rounds, C, x, y
                    )
                t_target = _time_to(hist, target)
                mb = eng.comm_total_bytes() / 1e6
                results[(engine, codec, sev_name)] = (t_target, hist)
                emit(
                    f"sim_{engine}_{codec}_{sev_name},{us:.1f},"
                    f"target={target:.4f};t_target={t_target:.1f}s;"
                    f"final={hist[-1].loss_before:.4f};"
                    f"t_end={hist[-1].t_virtual:.1f}s;MB={mb:.2f}"
                )
    return results
