"""Kernel-level benchmark: the low-rank bottleneck chain vs dense matmul.

On this CPU container the Pallas path runs in interpret mode (not timed —
Python emulation), so we time the XLA-compiled reference chain and report
*derived* quantities: FLOPs, HBM bytes, and arithmetic intensity for both
the dense layer and the factorized chain — the compute-side Table-1 claim.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import lowrank_apply
from repro.kernels import ref


def chain_vs_dense(emit=print):
    M, n, r = 4096, 2048, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (M, n), jnp.float32)
    U = jax.random.normal(ks[1], (n, r)) / np.sqrt(n)
    S = jax.random.normal(ks[2], (r, r))
    V = jax.random.normal(ks[3], (n, r)) / np.sqrt(n)
    W = jax.random.normal(ks[4], (n, n)) / np.sqrt(n)

    lr = jax.jit(lambda *a: ref.lowrank_matmul_ref(*a))
    dn = jax.jit(lambda x, W: x @ W)
    lr(x, U, S, V).block_until_ready()
    dn(x, W).block_until_ready()

    def timeit(fn, *a, iters=20):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*a)
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e6

    us_lr = timeit(lr, x, U, S, V)
    us_dn = timeit(dn, x, W)
    flops_lr = 2 * M * (n * r + r * r + r * n)
    flops_dn = 2 * M * n * n
    bytes_lr = 4 * (M * n * 2 + 2 * n * r + r * r)
    bytes_dn = 4 * (M * n * 2 + n * n)
    emit(
        f"kernel_lowrank_chain,{us_lr:.1f},"
        f"flops={flops_lr:.3e};bytes={bytes_lr:.3e};ai={flops_lr/bytes_lr:.1f}"
    )
    emit(
        f"kernel_dense_matmul,{us_dn:.1f},"
        f"flops={flops_dn:.3e};bytes={bytes_dn:.3e};ai={flops_dn/bytes_dn:.1f}"
    )
    emit(
        f"kernel_chain_speedup,{0.0:.1f},"
        f"time_ratio={us_dn/us_lr:.2f};flop_ratio={flops_dn/flops_lr:.2f}"
    )
    # correctness spot check of the pallas interpret path on a small shape
    xs, Us, Ss, Vs = x[:64, :256], U[:256], S, V[:256]
    y_k = lowrank_apply(xs, Us, Ss, Vs, True)
    y_r = ref.lowrank_matmul_ref(xs, Us, Ss, Vs)
    err = float(jnp.abs(y_k - y_r).max())
    emit(f"kernel_pallas_interpret_check,0.0,max_err={err:.2e}")
    return {"us_lowrank": us_lr, "us_dense": us_dn, "err": err}
