"""Kernel-level benchmark: the low-rank bottleneck chain vs dense matmul.

On this CPU container the Pallas path runs in interpret mode (not timed —
Python emulation), so we time the XLA-compiled reference chain and report
*derived* quantities: FLOPs, HBM bytes, and arithmetic intensity for both
the dense layer and the factorized chain — the compute-side Table-1 claim.

``fused_chain_rows`` exercises the *real* model dispatch path —
``lowrank_apply`` / ``lowrank_apply_nd`` with their custom VJP, (B, T, d)
activations and bf16 sublane padding — timing the compiled custom-VJP
reference against XLA's own autodiff of the chain, and checking interpret-
mode parity of forward and backward on every shape.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import lowrank_apply, lowrank_apply_nd
from repro.kernels import ref


def _timeit(fn, *a, iters=20):
    jax.block_until_ready(fn(*a))  # warm up / compile, fully drained
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*a)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def chain_vs_dense(emit=print):
    M, n, r = 4096, 2048, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (M, n), jnp.float32)
    U = jax.random.normal(ks[1], (n, r)) / np.sqrt(n)
    S = jax.random.normal(ks[2], (r, r))
    V = jax.random.normal(ks[3], (n, r)) / np.sqrt(n)
    W = jax.random.normal(ks[4], (n, n)) / np.sqrt(n)

    lr = jax.jit(lambda *a: ref.lowrank_matmul_ref(*a))
    dn = jax.jit(lambda x, W: x @ W)
    lr(x, U, S, V).block_until_ready()
    dn(x, W).block_until_ready()

    def timeit(fn, *a, iters=20):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*a)
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e6

    us_lr = timeit(lr, x, U, S, V)
    us_dn = timeit(dn, x, W)
    flops_lr = 2 * M * (n * r + r * r + r * n)
    flops_dn = 2 * M * n * n
    bytes_lr = 4 * (M * n * 2 + 2 * n * r + r * r)
    bytes_dn = 4 * (M * n * 2 + n * n)
    emit(
        f"kernel_lowrank_chain,{us_lr:.1f},"
        f"flops={flops_lr:.3e};bytes={bytes_lr:.3e};ai={flops_lr/bytes_lr:.1f}"
    )
    emit(
        f"kernel_dense_matmul,{us_dn:.1f},"
        f"flops={flops_dn:.3e};bytes={bytes_dn:.3e};ai={flops_dn/bytes_dn:.1f}"
    )
    emit(
        f"kernel_chain_speedup,{0.0:.1f},"
        f"time_ratio={us_dn/us_lr:.2f};flop_ratio={flops_dn/flops_lr:.2f}"
    )
    # correctness spot check of the pallas interpret path on a small shape
    xs, Us, Ss, Vs = x[:64, :256], U[:256], S, V[:256]
    y_k = lowrank_apply(xs, Us, Ss, Vs, True)
    y_r = ref.lowrank_matmul_ref(xs, Us, Ss, Vs)
    err = float(jnp.abs(y_k - y_r).max())
    emit(f"kernel_pallas_interpret_check,0.0,max_err={err:.2e}")
    out = {"us_lowrank": us_lr, "us_dense": us_dn, "err": err}
    out.update(fused_chain_rows(emit))
    return out


def fused_chain_rows(emit=print):
    """The model's actual dispatch path: custom-VJP fwd+bwd, batched
    activations, bf16 sublane padding — timed on the compiled reference
    branch, parity-checked against the interpret-mode kernel branch."""
    cases = [
        # (label, B, T, K, N, R, dtype) — T chosen so bf16 hits M%16==8
        ("f32_2d", 1, 2048, 1024, 1024, 64, jnp.float32),
        ("f32_btd", 4, 512, 1024, 1024, 64, jnp.float32),
        ("bf16_m8", 1, 1032, 1024, 1024, 64, jnp.bfloat16),
    ]
    results = {}
    for label, B, T, K, N, R, dtype in cases:
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        x = jax.random.normal(ks[0], (B, T, K) if B > 1 else (T, K), dtype)
        U = (jax.random.normal(ks[1], (K, R)) / np.sqrt(K)).astype(dtype)
        S = jax.random.normal(ks[2], (R, R), dtype)
        V = (jax.random.normal(ks[3], (N, R)) / np.sqrt(N)).astype(dtype)

        def fwd_bwd(x, U, S, V, use_kernels):
            def f(*a):
                return jnp.sum(lowrank_apply_nd(*a, use_kernels) ** 2)

            return jax.grad(f, argnums=(0, 1, 2, 3))(x, U, S, V)

        def xla_fwd_bwd(x, U, S, V):
            def f(x, U, S, V):
                h = x.reshape(-1, x.shape[-1])
                return jnp.sum((((h @ U) @ S) @ V.T) ** 2)

            return jax.grad(f, argnums=(0, 1, 2, 3))(x, U, S, V)

        us_vjp = _timeit(jax.jit(lambda *a: fwd_bwd(*a, False)), x, U, S, V)
        us_xla = _timeit(jax.jit(xla_fwd_bwd), x, U, S, V)

        # interpret-mode parity of the fused kernel branch (not timed)
        g_k = fwd_bwd(x, U, S, V, True)
        g_r = fwd_bwd(x, U, S, V, False)
        err = max(
            float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
            for a, b in zip(g_k, g_r)
        )
        emit(
            f"kernel_fused_chain_{label},{us_vjp:.1f},"
            f"xla_autodiff_us={us_xla:.1f};interpret_parity_err={err:.2e}"
        )
        results[label] = {"us_vjp": us_vjp, "us_xla": us_xla, "err": err}
    return results
