"""Telemetry-hub overhead benchmark: the cost of observing a run.

The hub's contract is "a disabled hub is a near-zero no-op, an enabled
hub costs microseconds per event" — this group pins that, per hot-path
operation and end-to-end through a real engine:

  telemetry_span_disabled   `with hub.span(...)` on a disabled hub
  telemetry_span_memory     same span on an enabled hub → MemorySink
  telemetry_counter_*       counter emission, disabled vs memory vs jsonl
  telemetry_gauge_sampled   off-cadence gauge (sample_every drops it)
  telemetry_run_off         3 sim rounds, telemetry disabled (baseline)
  telemetry_run_memory      same spec, memory sink
  telemetry_run_jsonl       same spec, jsonl sink (adds serialization+IO)

Rows follow the harness CSV: ``name,us_per_call,derived`` where derived
is events emitted (micro rows) or history length (run rows).  Engines
are built only through ``build(spec)``.
"""
from __future__ import annotations

import os
import tempfile
import time


def _time_op(fn, n: int):
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _micro_rows() -> None:
    from repro.telemetry import JsonlSink, MemorySink, TelemetryHub

    N = 20_000
    off = TelemetryHub(enabled=False)

    def span_off():
        with off.span("s", round=0):
            pass

    print(f"telemetry_span_disabled,{_time_op(span_off, N):.3f},0")
    print(f"telemetry_counter_disabled,{_time_op(lambda: off.counter('c'), N):.3f},0")

    mem = TelemetryHub([MemorySink()])

    def span_mem():
        with mem.span("s", round=0):
            pass

    print(f"telemetry_span_memory,{_time_op(span_mem, N):.3f},{N}")
    print(f"telemetry_counter_memory,{_time_op(lambda: mem.counter('c'), N):.3f},{N}")

    sampled = TelemetryHub([MemorySink()], sample_every=1_000_000)
    print(
        "telemetry_gauge_sampled,"
        f"{_time_op(lambda: sampled.gauge('g', 1.0, round=1), N):.3f},0"
    )

    with tempfile.TemporaryDirectory() as d:
        js = TelemetryHub([JsonlSink(os.path.join(d, "events.jsonl"))])
        us = _time_op(lambda: js.counter("c", 1.0, round=0), N)
        js.close()
        print(f"telemetry_counter_jsonl,{us:.3f},{N}")


def _run_spec(rounds: int, telemetry_kw, out_dir=None):
    from repro.api import ExperimentSpec, build

    spec = ExperimentSpec.from_dict({
        "name": "bench-telemetry", "rounds": rounds, "log_every": 0,
        "model": {"kind": "mlp", "preset": None, "dim": 16, "classes": 4,
                  "hidden": 32, "r_max": 8, "kernels": "off"},
        "data": {"kind": "classification", "num_points": 512,
                 "holdout": 128, "batch": 16},
        "fed": {"clients": 4, "local_steps": 2, "eval_after": False},
        "engine": {"kind": "async", "buffer_size": 2},
        "sim": {"profile": "straggler:0.25,10"},
        "telemetry": telemetry_kw,
    })
    exp = build(spec)
    t0 = time.perf_counter()
    hist = exp.run()
    us = (time.perf_counter() - t0) * 1e6
    exp.hub.close()
    return us, len(hist)


def telemetry_overhead(rounds: int = 6) -> None:
    _micro_rows()
    # end-to-end: same spec and seed, three observation levels.  One
    # untimed warm-up run absorbs the first-build jit/tracing cost so the
    # three timed rows differ only in what they observe.
    _run_spec(rounds, {"enabled": False})
    us, n = _run_spec(rounds, {"enabled": False})
    print(f"telemetry_run_off,{us:.0f},{n}")
    us, n = _run_spec(rounds, {"enabled": True, "sinks": "memory"})
    print(f"telemetry_run_memory,{us:.0f},{n}")
    with tempfile.TemporaryDirectory() as d:
        us, n = _run_spec(
            rounds, {"enabled": True, "sinks": "jsonl", "dir": d}
        )
        print(f"telemetry_run_jsonl,{us:.0f},{n}")
