"""Render the §Dry-run and §Roofline markdown tables from results/dryrun.

Usage: PYTHONPATH=src python -m benchmarks.render_tables
Rewrites the <!-- DRYRUN_TABLE --> and <!-- ROOFLINE_TABLE --> blocks of
EXPERIMENTS.md in place (idempotent).
"""
from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "results/dryrun")
EXPERIMENTS = os.path.join(ROOT, "EXPERIMENTS.md")

ARCH_ORDER = [
    "qwen2-7b", "codeqwen1.5-7b", "qwen3-32b", "qwen1.5-32b",
    "deepseek-moe-16b", "olmoe-1b-7b", "jamba-1.5-large-398b",
    "rwkv6-7b", "whisper-large-v3", "llava-next-mistral-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir=RESULTS):
    rows = {}
    for path in glob.glob(os.path.join(results_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("method", "fedlrt") != "fedlrt":
            continue
        key = (r.get("mesh", "skip"), r["arch"], r["shape"])
        rows[key] = r
    return rows


def dryrun_table(rows) -> str:
    out = [
        "| arch | shape | 16×16: compile / temp GiB/dev | 2×16×16: compile / temp GiB/dev |",
        "|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            single = rows.get(("16x16", arch, shape))
            multi = rows.get(("2x16x16", arch, shape))
            skip = rows.get(("skip", arch, shape))
            if single is None and multi is None:
                reason = (skip or {}).get("skipped", "?")
                out.append(f"| {arch} | {shape} | SKIP — {reason} | SKIP |")
                continue

            def cell(r):
                if r is None:
                    return "—"
                return (
                    f"{r['compile_s']:.0f}s / "
                    f"{r['memory']['temp_bytes']/2**30:.2f}"
                )

            out.append(f"| {arch} | {shape} | {cell(single)} | {cell(multi)} |")
    return "\n".join(out)


def roofline_table(rows, opt_rows=None) -> str:
    opt_rows = opt_rows or {}
    out = [
        "| arch | shape | compute ms (HLO / analytic) | memory ms | collective ms (baseline → optimized) | dominant | MODEL_FLOPS/HLO_FLOPs | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    advice = {
        ("memory", "train"): "larger per-chip batch / fewer remat recomputes (raise arithmetic intensity)",
        ("memory", "decode"): "KV-cache quantization (int8) or wider model-axis cache sharding",
        ("memory", "prefill"): "flash-style fused attention (skip score materialization)",
        ("collective", "train"): "overlap basis-gradient all-reduce with coefficient compute; reduce resharding between seq- and head-sharded layouts",
        ("collective", "prefill"): "keep activations seq-sharded through attention (ring attention) to avoid k/v gathers",
        ("collective", "decode"): "replicate small caches instead of gathering per step",
        ("compute", "train"): "pallas-fused low-rank chain (fewer HBM round-trips)",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = rows.get(("16x16", arch, shape))
            if r is None:
                continue
            rf = r["roofline"]
            kind = "train" if shape.startswith("train") else (
                "prefill" if "prefill" in shape else "decode"
            )
            ufr = r.get("useful_flops_ratio")
            analytic_ms = r["model_flops_per_device"] / 197e12 * 1e3
            o = opt_rows.get(("16x16", arch, shape))
            coll = f"{rf['collective_s']*1e3:.2f}"
            if o is not None:
                coll += f" → {o['roofline']['collective_s']*1e3:.2f}"
            out.append(
                f"| {arch} | {shape} | {rf['compute_s']*1e3:.2f} / "
                f"{analytic_ms:.2f} | "
                f"{rf['memory_s']*1e3:.2f} | {coll} | "
                f"**{rf['dominant']}** | "
                f"{(f'{ufr:.2f}' if ufr else 'n/a')} | "
                f"{advice.get((rf['dominant'], kind), '-')} |"
            )
    return "\n".join(out)


def replace_block(text: str, marker: str, content: str) -> str:
    pattern = re.compile(
        rf"<!-- {marker} -->.*?(?=\n## |\Z)", re.S
    )
    return pattern.sub(f"<!-- {marker} -->\n\n{content}\n\n", text)


def main():
    rows = load()
    opt = load(os.path.join(ROOT, "results/dryrun_opt"))
    with open(EXPERIMENTS) as f:
        text = f.read()
    text = replace_block(text, "DRYRUN_TABLE", dryrun_table(rows))
    text = replace_block(text, "ROOFLINE_TABLE", roofline_table(rows, opt))
    with open(EXPERIMENTS, "w") as f:
        f.write(text)
    print(f"rendered {len(rows)} baseline + {len(opt)} optimized rows")


if __name__ == "__main__":
    main()
