"""Serving benchmark: factor-resident decode vs dense, batching modes.

What this group pins (the PR-10 acceptance criteria):

  serve_decode_factor       f32 factor-resident decode — us/token, tok/s
  serve_decode_dense        materialized U S Vᵀ baseline at equal output
  serve_decode_int8         int8-factor decode — us/token, tok/s
  serve_match_*             1 iff greedy tokens equal the factor path
  serve_flops_*             cost-model decode FLOPs/token (factor < dense)
  serve_bytes_*             resident parameter bytes (int8 < f32 < dense)
  serve_latency_p50/p99     per-token decode latency percentiles (us)
  serve_mode_continuous     seeded Poisson arrivals, continuous batching
  serve_mode_static         same trace, static waves — more decode steps

Rows follow the harness CSV ``name,us_per_call,derived``.  Everything is
constructed through ``serve(spec)`` (RPL001/RPL002) and every arrival
trace is seeded — reruns are bit-deterministic in tokens and step counts.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _spec(quantize="none", materialize=False, mode="continuous", *,
          smoke: bool):
    from repro.api import ExperimentSpec, ModelSpec, ServeSpec

    return ExperimentSpec(
        name=f"bench-serve-{quantize}{'-dense' if materialize else ''}",
        model=ModelSpec(kind="lm", preset="llm-tiny", smoke=smoke),
        serve=ServeSpec(
            quantize=quantize,
            materialize=materialize,
            mode=mode,
            max_batch=2 if smoke else 4,
            max_prompt=16 if smoke else 32,
            prompt_bucket=8,
            max_new_tokens=8 if smoke else 24,
        ),
    )


def _prompts(spec, n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(1, 256, size=int(rng.integers(4, spec.serve.max_prompt)))
        .astype(np.int32)
        for _ in range(n)
    ]


def _poisson_trace(spec, n, mean_gap_steps, seed=0):
    """Seeded Poisson arrival trace in decode-step units (deterministic,
    unlike wall-clock arrival): exponential inter-arrival gaps, varied
    per-request decode budgets so static waves wait for their slowest."""
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    steps = np.floor(
        rng.exponential(scale=mean_gap_steps, size=n).cumsum()
    ).astype(int)
    budgets = rng.integers(2, spec.serve.max_new_tokens + 1, size=n)
    prompts = _prompts(spec, n, seed=seed + 1)
    return [
        Request(rid=i, tokens=prompts[i], max_new_tokens=int(budgets[i]),
                arrival_step=int(steps[i]))
        for i in range(n)
    ]


def _decode_row(name, comps):
    toks = sum(len(c.tokens) for c in comps)
    span = sum(c.prefill_s + c.decode_s for c in comps)
    us_per_tok = span / max(toks, 1) * 1e6
    print(f"{name},{us_per_tok:.1f},{toks / max(span, 1e-9):.1f}")
    return toks


def serve_paths(*, smoke: bool = False) -> None:
    """Factorized vs dense vs quantized decode at equal greedy output."""
    from repro.api import serve
    from repro.serve import decode_matmul_flops, resident_bytes

    n_req = 3 if smoke else 8
    base = _spec(smoke=smoke)
    factor_sess = serve(base)
    prompts = _prompts(base, n_req)

    factor_sess.generate(prompts)  # warm the executables before timing
    f_outs, f_comps = factor_sess.generate(prompts)
    _decode_row("serve_decode_factor", f_comps)

    per_tok = np.concatenate([
        np.full(max(len(c.tokens), 1), c.decode_s / max(len(c.tokens), 1))
        for c in f_comps
    ]) * 1e6
    p50, p99 = np.percentile(per_tok, [50, 99])
    print(f"serve_latency_p50,{p50:.1f},0")
    print(f"serve_latency_p99,{p99:.1f},0")

    dense_sess = serve(_spec(materialize=True, smoke=smoke))
    dense_sess.generate(prompts)
    d_outs, d_comps = dense_sess.generate(prompts)
    _decode_row("serve_decode_dense", d_comps)
    match = all(
        np.array_equal(a, b) for a, b in zip(f_outs, d_outs)
    )
    print(f"serve_match_factor_vs_dense,0.0,{int(match)}")

    int8_sess = serve(_spec(quantize="int8", smoke=smoke))
    int8_sess.generate(prompts)
    _, q_comps = int8_sess.generate(prompts)
    _decode_row("serve_decode_int8", q_comps)

    fp = factor_sess.engine.params
    flops_factor = decode_matmul_flops(fp, factor_resident=True)
    flops_dense = decode_matmul_flops(fp, factor_resident=False)
    print(f"serve_flops_factor,0.0,{flops_factor:.0f}")
    print(f"serve_flops_dense,0.0,{flops_dense:.0f}")
    assert flops_factor < flops_dense, "factor decode must cost fewer FLOPs"

    b_f32 = resident_bytes(fp)
    b_int8 = resident_bytes(int8_sess.engine.params)
    b_dense = resident_bytes(dense_sess.engine.params)
    print(f"serve_bytes_f32,0.0,{b_f32}")
    print(f"serve_bytes_int8,0.0,{b_int8}")
    print(f"serve_bytes_dense,0.0,{b_dense}")
    assert b_int8 < b_f32, "int8 factors must shrink resident bytes"


def serve_batching(*, smoke: bool = False) -> None:
    """Continuous vs static batching under one seeded Poisson trace."""
    from repro.api import serve
    from repro.telemetry.clock import perf_seconds

    n_req = 4 if smoke else 12
    gap = 2 if smoke else 3
    for mode in ("continuous", "static"):
        spec = _spec(mode=mode, smoke=smoke)
        sess = serve(spec)
        sess.generate(_prompts(spec, 2))  # warm executables off the clock
        trace = _poisson_trace(spec, n_req, gap)
        t0 = perf_seconds()
        comps = sess.scheduler.run(trace)
        wall = perf_seconds() - t0
        toks = sum(len(c.tokens) for c in comps)
        print(
            f"serve_mode_{mode},{wall / max(toks, 1) * 1e6:.1f},"
            f"{sess.scheduler.decode_steps}"
        )


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    serve_paths(smoke=smoke)
    serve_batching(smoke=smoke)
