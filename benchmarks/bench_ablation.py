"""Ablations beyond the paper's headline figures.

- :func:`tau_ablation` — truncation threshold τ vs identified rank and
  final loss on the homogeneous lsq problem (the O(ϑ) term of Thm. 3 made
  visible: larger τ ⇒ smaller rank ⇒ higher loss floor).
- :func:`s_star_ablation` — local steps s* vs rounds-to-converge and drift
  (the λ ≤ 1/(12·L·s*) trade-off of Thm. 2).
- :func:`participation_ablation` — active-cohort size k vs final loss and
  server comm under uniform-k sampling (the standard partial-participation
  FL regime the paper's full-participation algorithms are extended to).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.api import (
    DataSpec,
    ExperimentSpec,
    FedSpec,
    ModelSpec,
    ParticipationSpec,
    build,
)
from repro.core import FedConfig, fedlrt_round, init_factor, materialize
from repro.data import make_homogeneous_lsq


def _loss(f, batch):
    pred = jnp.sum(((batch["px"] @ f.U) @ f.S) * (batch["py"] @ f.V), -1)
    return 0.5 * jnp.mean((pred - batch["t"]) ** 2)


def tau_ablation(rounds: int = 120, emit=print):
    prob = make_homogeneous_lsq(n=20, rank=4, num_points=4000, num_clients=4)
    batches = {
        "px": jnp.asarray(prob.px),
        "py": jnp.asarray(prob.py),
        "t": jnp.asarray(prob.target),
    }
    out = {}
    for tau in (0.5, 0.2, 0.1, 0.01):
        f = init_factor(
            jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10,
            spectrum_scale=1.0,
        )
        # repro-lint: disable=RPL002 -- microbench of the raw round
        # function: times fedlrt_round itself with no engine in the loop,
        # so there is no ExperimentSpec scenario to route through
        cfg = FedConfig(num_clients=4, s_star=20, lr=0.1, correction="full",
                        tau=tau, eval_after=False)
        step = jax.jit(lambda p, b, cfg=cfg: fedlrt_round(_loss, p, b, cfg))
        t0 = time.perf_counter()
        for _ in range(rounds):
            f, m = step(f, batches)
        us = (time.perf_counter() - t0) / rounds * 1e6
        dist = float(jnp.linalg.norm(materialize(f) - prob.W_star))
        out[tau] = (int(f.rank), float(m["loss_before"]), dist)
        emit(
            f"ablation_tau{tau},{us:.1f},"
            f"rank={int(f.rank)};loss={float(m['loss_before']):.3e};dist={dist:.3e}"
        )
    return out


def s_star_ablation(emit=print):
    prob = make_homogeneous_lsq(n=20, rank=4, num_points=4000, num_clients=4)
    batches = {
        "px": jnp.asarray(prob.px),
        "py": jnp.asarray(prob.py),
        "t": jnp.asarray(prob.target),
    }
    out = {}
    for s_star in (1, 5, 20, 50):
        # Thm. 2 scaling: keep λ·s* fixed so each round does equal "work"
        lr = 2.0 / s_star * 0.05
        f = init_factor(
            jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10,
            spectrum_scale=1.0,
        )
        # repro-lint: disable=RPL002 -- microbench of the raw round
        # function (track_drift is a core-layer knob the spec surface
        # deliberately does not expose)
        cfg = FedConfig(num_clients=4, s_star=s_star, lr=lr, correction="full",
                        tau=0.1, eval_after=False, track_drift=True)
        step = jax.jit(lambda p, b, cfg=cfg: fedlrt_round(_loss, p, b, cfg))
        t0 = time.perf_counter()
        drift = 0.0
        for _ in range(60):
            f, m = step(f, batches)
            drift = max(drift, float(m["max_coeff_drift"]))
        us = (time.perf_counter() - t0) / 60 * 1e6
        out[s_star] = (float(m["loss_before"]), drift)
        emit(
            f"ablation_sstar{s_star},{us:.1f},"
            f"loss={float(m['loss_before']):.3e};max_drift={drift:.3e}"
        )
    return out


def participation_ablation(rounds: int = 60, C: int = 8, emit=print):
    """Uniform-k cohort sweep on the homogeneous lsq problem.

    Emits final loss and cohort-aware server comm per k — halving the
    cohort halves per-round comm while (on the homogeneous problem)
    convergence degrades only mildly.  Scenarios go through the spec API
    (the lsq task registered in ``repro.api.tasks``), so cohort policy,
    weighting and comm accounting are exactly what a user run would get.
    """
    num_points = 4000
    base = ExperimentSpec(
        name="ablation-participation",
        seed=0,
        rounds=rounds,
        log_every=0,
        model=ModelSpec(kind="lsq", dim=20, r_max=10),
        data=DataSpec(
            kind="lsq", num_points=num_points, planted_rank=4,
            batch=num_points // C,  # full client shard per round
            holdout=0,  # the lsq task defines no holdout eval
        ),
        fed=FedSpec(
            method="fedlrt", correction="full", clients=C, local_steps=20,
            lr=0.1, tau=0.1, eval_after=False,
        ),
    )
    out = {}
    for k in (C, C // 2, max(C // 4, 1)):
        spec = (
            base
            if k >= C
            else base.replace(
                participation=ParticipationSpec(mode="uniform", cohort_size=k)
            )
        )
        exp = build(spec)
        t0 = time.perf_counter()
        hist = exp.run()
        us = (time.perf_counter() - t0) / rounds * 1e6
        loss = hist[-1].loss_before
        comm = exp.comm_total_bytes()
        out[k] = (loss, comm)
        emit(
            f"ablation_cohort{k}of{C},{us:.1f},"
            f"loss={loss:.3e};comm_MB={comm/1e6:.2f}"
        )
    return out
