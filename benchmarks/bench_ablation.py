"""Ablations beyond the paper's headline figures.

- :func:`tau_ablation` — truncation threshold τ vs identified rank and
  final loss on the homogeneous lsq problem (the O(ϑ) term of Thm. 3 made
  visible: larger τ ⇒ smaller rank ⇒ higher loss floor).
- :func:`s_star_ablation` — local steps s* vs rounds-to-converge and drift
  (the λ ≤ 1/(12·L·s*) trade-off of Thm. 2).
- :func:`participation_ablation` — active-cohort size k vs final loss and
  server comm under uniform-k sampling (the standard partial-participation
  FL regime the paper's full-participation algorithms are extended to).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import FedConfig, fedlrt_round, init_factor, materialize
from repro.data import FederatedBatcher, make_homogeneous_lsq
from repro.fed import FederatedEngine, Participation


def _loss(f, batch):
    pred = jnp.sum(((batch["px"] @ f.U) @ f.S) * (batch["py"] @ f.V), -1)
    return 0.5 * jnp.mean((pred - batch["t"]) ** 2)


def tau_ablation(rounds: int = 120, emit=print):
    prob = make_homogeneous_lsq(n=20, rank=4, num_points=4000, num_clients=4)
    batches = {
        "px": jnp.asarray(prob.px),
        "py": jnp.asarray(prob.py),
        "t": jnp.asarray(prob.target),
    }
    out = {}
    for tau in (0.5, 0.2, 0.1, 0.01):
        f = init_factor(
            jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10,
            spectrum_scale=1.0,
        )
        cfg = FedConfig(num_clients=4, s_star=20, lr=0.1, correction="full",
                        tau=tau, eval_after=False)
        step = jax.jit(lambda p, b: fedlrt_round(_loss, p, b, cfg))
        t0 = time.perf_counter()
        for _ in range(rounds):
            f, m = step(f, batches)
        us = (time.perf_counter() - t0) / rounds * 1e6
        dist = float(jnp.linalg.norm(materialize(f) - prob.W_star))
        out[tau] = (int(f.rank), float(m["loss_before"]), dist)
        emit(
            f"ablation_tau{tau},{us:.1f},"
            f"rank={int(f.rank)};loss={float(m['loss_before']):.3e};dist={dist:.3e}"
        )
    return out


def s_star_ablation(emit=print):
    prob = make_homogeneous_lsq(n=20, rank=4, num_points=4000, num_clients=4)
    batches = {
        "px": jnp.asarray(prob.px),
        "py": jnp.asarray(prob.py),
        "t": jnp.asarray(prob.target),
    }
    out = {}
    for s_star in (1, 5, 20, 50):
        # Thm. 2 scaling: keep λ·s* fixed so each round does equal "work"
        lr = 2.0 / s_star * 0.05
        f = init_factor(
            jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10,
            spectrum_scale=1.0,
        )
        cfg = FedConfig(num_clients=4, s_star=s_star, lr=lr, correction="full",
                        tau=0.1, eval_after=False, track_drift=True)
        step = jax.jit(lambda p, b: fedlrt_round(_loss, p, b, cfg))
        t0 = time.perf_counter()
        drift = 0.0
        for _ in range(60):
            f, m = step(f, batches)
            drift = max(drift, float(m["max_coeff_drift"]))
        us = (time.perf_counter() - t0) / 60 * 1e6
        out[s_star] = (float(m["loss_before"]), drift)
        emit(
            f"ablation_sstar{s_star},{us:.1f},"
            f"loss={float(m['loss_before']):.3e};max_drift={drift:.3e}"
        )
    return out


def participation_ablation(rounds: int = 60, C: int = 8, emit=print):
    """Uniform-k cohort sweep on the homogeneous lsq problem.

    Emits final loss and cohort-aware server comm per k — halving the
    cohort halves per-round comm while (on the homogeneous problem)
    convergence degrades only mildly.
    """
    prob = make_homogeneous_lsq(n=20, rank=4, num_points=4000, num_clients=C)
    N = prob.px.shape[1]
    arrays = {
        "px": prob.px.reshape(-1, prob.px.shape[-1]),
        "py": prob.py.reshape(-1, prob.py.shape[-1]),
        "t": prob.target.reshape(-1),
    }
    parts = [list(range(c * N, (c + 1) * N)) for c in range(C)]
    out = {}
    for k in (C, C // 2, max(C // 4, 1)):
        f = init_factor(
            jax.random.PRNGKey(0), 20, 20, r_max=10, init_rank=10,
            spectrum_scale=1.0,
        )
        cfg = FedConfig(num_clients=C, s_star=20, lr=0.1, correction="full",
                        tau=0.1, eval_after=False)
        part = (
            None if k >= C else Participation(mode="uniform", cohort_size=k, seed=0)
        )
        eng = FederatedEngine(
            lambda p, b: _loss(p, b), f, cfg, method="fedlrt", participation=part
        )
        batcher = FederatedBatcher(arrays, parts, batch_size=N, seed=0)
        t0 = time.perf_counter()
        hist = eng.train(batcher, rounds, log_every=0)
        us = (time.perf_counter() - t0) / rounds * 1e6
        loss = hist[-1].loss_before
        comm = eng.comm_total_bytes()
        out[k] = (loss, comm)
        emit(
            f"ablation_cohort{k}of{C},{us:.1f},"
            f"loss={loss:.3e};comm_MB={comm/1e6:.2f}"
        )
    return out
