"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1_*       §4.1 heterogeneous least squares (variance correction)
  fig4_*       §4.1 homogeneous least squares (rank identification)
  fig3_*       communication/compute scaling + amortization point
  table1_*     measured vs analytic per-round communication
  fig5_*       CV proxy: accuracy vs client count, non-iid
  wire_*       wire codecs: measured bytes saved vs accuracy vs wall-clock
  kernel_*     low-rank chain vs dense matmul + Pallas interpret check
  sim_*        system simulator: time-to-target-loss, engines × stragglers
  roofline_*   dry-run roofline terms (requires results/dryrun/*.json)
  lint_*       repro-lint analyzer cost (dataflow tier runs on every PR)
  telemetry_*  telemetry hub overhead: disabled vs enabled vs jsonl sink
  serve_*      serving: factor-resident vs dense decode, continuous batching

Besides printing, every group persists its rows as a per-PR artifact
``<out-dir>/BENCH_<group>.json`` (schema: ``bench``, ``rows``,
``git_sha``, ``timestamp``) so perf claims stay comparable across PRs.

Run:  PYTHONPATH=src python -m benchmarks.run [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import contextlib
import datetime
import io
import json
import os
import subprocess
import sys


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


class _Tee(io.TextIOBase):
    """Pass writes through to the real stdout while keeping a copy."""

    def __init__(self, real):
        self.real = real
        self.copy = io.StringIO()

    def write(self, s: str) -> int:
        self.real.write(s)
        self.copy.write(s)
        return len(s)

    def flush(self) -> None:
        self.real.flush()


@contextlib.contextmanager
def _record(group: str, out_dir: str, git_sha: str):
    """Capture the group's CSV rows and persist BENCH_<group>.json."""
    tee = _Tee(sys.stdout)
    with contextlib.redirect_stdout(tee):
        yield
    rows = [ln for ln in tee.copy.getvalue().splitlines() if ln.strip()]
    artifact = {
        "bench": group,
        "rows": rows,
        "git_sha": git_sha,
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{group}.json")
    with open(path, "w") as fh:
        json.dump(artifact, fh, indent=2)
        fh.write("\n")
    print(f"# wrote {path} ({len(rows)} rows)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer rounds")
    ap.add_argument(
        "--smoke", action="store_true",
        help="minimal rounds — CI exercise of the benchmark drivers "
        "(implies --quick)",
    )
    ap.add_argument(
        "--only", type=str, default=None,
        help="comma-separated subset: lsq,costs,cv,wire,kernels,sim,"
        "ablation,roofline,lint,telemetry,serve",
    )
    ap.add_argument(
        "--out-dir", type=str, default="results",
        help="directory for the BENCH_<group>.json artifacts "
        "(default: results)",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    q = args.quick or args.smoke
    git_sha = _git_sha()

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    if want("lsq"):
        from benchmarks.bench_lsq import fig1_heterogeneous, fig4_homogeneous

        with _record("lsq", args.out_dir, git_sha):
            fig4_homogeneous(rounds=60 if q else 150)
            fig1_heterogeneous(rounds=80 if q else 200)
    if want("costs"):
        from benchmarks.bench_costs import fig3_scaling, table1_measured

        with _record("costs", args.out_dir, git_sha):
            fig3_scaling()
            table1_measured()
    if want("cv"):
        from benchmarks.bench_cv import fig5_partial, fig5_proxy

        with _record("cv", args.out_dir, git_sha):
            fig5_proxy(
                rounds=10 if q else 25, clients=(2, 4) if q else (2, 4, 8)
            )
            fig5_partial(
                rounds=10 if q else 25, C=8,
                cohorts=(8, 4) if q else (8, 4, 2),
            )
    if want("wire"):
        from benchmarks.bench_wire import wire_codecs

        with _record("wire", args.out_dir, git_sha):
            wire_codecs(rounds=3 if args.smoke else (10 if q else 25))
    if want("sim"):
        from benchmarks.bench_sim import sim_pareto

        with _record("sim", args.out_dir, git_sha):
            sim_pareto(rounds=10 if q else 25, smoke=args.smoke)
    if want("kernels"):
        from benchmarks.bench_kernels import chain_vs_dense

        with _record("kernels", args.out_dir, git_sha):
            chain_vs_dense()
    if want("ablation"):
        from benchmarks.bench_ablation import (
            participation_ablation,
            s_star_ablation,
            tau_ablation,
        )

        with _record("ablation", args.out_dir, git_sha):
            tau_ablation(rounds=50 if q else 120)
            s_star_ablation()
            participation_ablation(rounds=30 if q else 60)
    if want("roofline"):
        from benchmarks.bench_roofline import roofline_table

        with _record("roofline", args.out_dir, git_sha):
            roofline_table()
    if want("lint"):
        from benchmarks.bench_lint import lint_overhead

        with _record("lint", args.out_dir, git_sha):
            lint_overhead(repeats=1 if args.smoke else 3)
    if want("telemetry"):
        from benchmarks.bench_telemetry import telemetry_overhead

        with _record("telemetry", args.out_dir, git_sha):
            telemetry_overhead(rounds=3 if args.smoke else 6)
    if want("serve"):
        from benchmarks.bench_serve import serve_batching, serve_paths

        with _record("serve", args.out_dir, git_sha):
            serve_paths(smoke=args.smoke)
            serve_batching(smoke=args.smoke)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
