"""Analyzer-cost benchmark: repro-lint wall-clock over the shipped tree.

The dataflow tier (CFG construction + taint/shape fixpoints) made the
analyzer meaningfully more expensive than the old single-pass lexical
walk, and it now runs on every commit (pre-commit) and every PR (CI
``invariants`` job).  This group keeps that cost measurable across PRs:

  lint_full_tree       one full run over src/benchmarks/examples
  lint_kernels_rpl009  the shape interpreter alone on kernels/ops.py
  lint_taint_rpl005    the taint fixpoints alone over src
  lint_sarif_roundtrip SARIF emit + fingerprint + baseline diff overhead

Rows follow the harness CSV: ``name,us_per_call,derived`` where derived
is files-scanned (full tree) or findings (rule groups — 0 on a clean
tree, by design).
"""
from __future__ import annotations

import time


def _time(fn, repeats: int):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def lint_overhead(repeats: int = 3) -> None:
    from repro.analysis.core import lint_paths
    from repro.analysis.sarif import diff_baseline, dump_sarif, load_baseline

    paths = ["src", "benchmarks", "examples"]

    us, findings = _time(lambda: lint_paths(paths), repeats)
    import glob
    import json

    n_files = sum(
        len(glob.glob(f"{p}/**/*.py", recursive=True)) for p in paths
    )
    print(f"lint_full_tree,{us:.0f},{n_files}")

    us, f9 = _time(lambda: lint_paths(paths, select=["RPL009"]), repeats)
    print(f"lint_kernels_rpl009,{us:.0f},{len(f9)}")

    us, f5 = _time(lambda: lint_paths(paths, select=["RPL005"]), repeats)
    print(f"lint_taint_rpl005,{us:.0f},{len(f5)}")

    def roundtrip():
        log = dump_sarif(findings, ".")
        baseline = {
            res.get("fingerprints", {}).get("reproLint/v1")
            for run in json.loads(log).get("runs", [])
            for res in run.get("results", [])
        } - {None}
        return diff_baseline(findings, baseline, ".")

    us, (new, old) = _time(roundtrip, repeats)
    print(f"lint_sarif_roundtrip,{us:.0f},{len(new)}")

    # keep the committed baseline honest: loading it must subtract
    # everything the shipped tree produces
    try:
        known = load_baseline("analysis-baseline.sarif")
    except OSError:
        return
    gating, _ = diff_baseline(findings, known, ".")
    print(f"lint_baseline_gating,0,{len(gating)}")
